"""Shared harness for the paper-claim reproduction experiments.

All experiments run the single-host faithful simulator (repro.core.simulator)
on the synthetic mixture classification task (data/pipeline.py documents why
MNIST/CIFAR are substituted). Experiments mirror the paper's figures; each
module exposes run(quick: bool) -> dict and a textual summary.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum, l2_diameter)
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

DEFAULT_MIX = MixtureSpec(n_classes=10, dim=32, sep=1.0, noise=1.2)


def run_byzsgd(cfg: ByzSGDConfig, *, steps: int, batch: int, seed: int = 0,
               lr0: float = 0.05, decay: float = 0.005,
               mix: MixtureSpec = DEFAULT_MIX, metrics_every: int = 10,
               track_delta: bool = False, hidden: int = 64):
    """Train with ByzSGD; returns (logs, final accuracy, wall seconds)."""
    init, loss, acc = make_mlp_problem(dim=mix.dim, hidden=hidden,
                                       n_classes=mix.n_classes)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(lr0, decay))
    state = sim.init_state(jax.random.PRNGKey(seed))
    stream, eval_set = classification_stream(seed, mix, cfg.n_workers, batch,
                                             steps)
    ex, ey = eval_set(2048)

    def metrics(s):
        p0 = jax.tree.map(lambda l: l[0], s.params)
        m = {"acc": float(acc(p0, ex, ey))}
        if track_delta:
            m["delta"] = float(coordinatewise_diameter_sum(s.params,
                                                           cfg.h_servers))
            m["l2_diam"] = float(l2_diameter(s.params, cfg.h_servers))
        return m

    t0 = time.time()
    state, logs = sim.run(state, stream, metrics_fn=metrics,
                          metrics_every=metrics_every)
    wall = time.time() - t0
    final = metrics(state)
    return logs, final, wall


def run_vanilla_sgd(*, steps: int, batch: int, n_workers: int = 9,
                    seed: int = 0, lr0: float = 0.05, decay: float = 0.005,
                    mix: MixtureSpec = DEFAULT_MIX, hidden: int = 64):
    """Paper baseline: single trusted server, plain averaging."""
    init, loss, acc = make_mlp_problem(dim=mix.dim, hidden=hidden,
                                       n_classes=mix.n_classes)
    lr = inverse_linear(lr0, decay)
    params = init(jax.random.PRNGKey(seed))
    grad = jax.jit(jax.grad(loss))
    stream, eval_set = classification_stream(seed, mix, n_workers, batch, steps)
    ex, ey = eval_set(2048)
    logs = []
    t0 = time.time()
    for t, (x, y) in enumerate(stream):
        g = jax.tree.map(
            lambda *gs: jnp.mean(jnp.stack(gs), 0),
            *[grad(params, (x[i], y[i])) for i in range(n_workers)])
        params = jax.tree.map(lambda p, gg: p - lr(t) * gg, params, g)
        if t % 10 == 0:
            logs.append({"step": t, "acc": float(acc(params, ex, ey))})
    return logs, {"acc": float(acc(params, ex, ey))}, time.time() - t0


def fmt_curve(logs, key="acc", stride=1):
    return " ".join(f"{m['step']}:{m[key]:.3f}" for m in logs[::stride])
