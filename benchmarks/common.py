"""Shared harness for the paper-claim reproduction experiments.

All experiments are :class:`repro.exp.Experiment` specs run through
``repro.exp.run`` (the synthetic mixture classification task substitutes
MNIST/CIFAR — data/pipeline.py documents why). Each ``exp_*`` module mirrors
one paper figure/claim: it exposes ``run(quick: bool) -> dict`` plus a
textual ``summarize``, and its ``main`` goes through :func:`claim_main` —
one shared CLI instead of eleven hand-rolled argparse blocks. The
``--exp``/``--override`` spec-level CLI lives in ``benchmarks/run.py``
(:func:`parse_overrides` does the value parsing).
"""
from __future__ import annotations

import argparse
import ast
import time

import jax
import jax.numpy as jnp

import repro.agg as agg
import repro.exp as exp
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

#: the benchmark default data spec (kept as a name in the exp DATA registry)
DEFAULT_MIX: MixtureSpec = exp.DATA["mixture10"]


def run_exp(e: exp.Experiment):
    """Run a spec; return the legacy (logs, final, wall_s) triple the claim
    experiments consume."""
    res = exp.run(e)
    return res.logs, res.final, res.wall_s


# ---------------------------------------------------------------------------
# shared CLI
# ---------------------------------------------------------------------------


def parse_overrides(pairs: list[str]) -> dict:
    """``key=val`` pairs -> Experiment field overrides. Values parse as
    Python literals when possible (``steps=50``, ``track_delta=True``,
    ``scenario='crash_storm'``), else stay strings (``gar=krum``)."""
    out = {}
    for pair in pairs or ():
        key, sep, val = pair.partition("=")
        if not sep:
            raise SystemExit(f"--override needs key=val, got {pair!r}")
        try:
            out[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            out[key] = val
    return out


def claim_main(run_fn, summarize_fn, description: str | None = None,
               gar_flag: bool = False, argv=None) -> None:
    """The shared ``python -m benchmarks.exp_*`` entry point: ``--full``
    everywhere, plus a registry-generated ``--gar`` for the experiments that
    sweep the worker-gradient rule."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale step counts (slow)")
    if gar_flag:
        ap.add_argument("--gar", default="mda",
                        choices=[n for n in agg.names()
                                 if agg.get(n).tree_mode is not None])
    args = ap.parse_args(argv)
    kw = {"gar": args.gar} if gar_flag else {}
    print(summarize_fn(run_fn(quick=not args.full, **kw)))


# ---------------------------------------------------------------------------
# the non-ByzSGD baseline (single trusted server — not an Experiment)
# ---------------------------------------------------------------------------


def run_vanilla_sgd(*, steps: int, batch: int, n_workers: int = 9,
                    seed: int = 0, lr0: float = 0.05, decay: float = 0.005,
                    mix: MixtureSpec = DEFAULT_MIX, hidden: int = 64):
    """Paper baseline: single trusted server, plain averaging."""
    from repro.configs.paper_models import make_mlp_problem
    init, loss, acc = make_mlp_problem(dim=mix.dim, hidden=hidden,
                                       n_classes=mix.n_classes)
    lr = inverse_linear(lr0, decay)
    params = init(jax.random.PRNGKey(seed))
    grad = jax.jit(jax.grad(loss))
    stream, eval_set = classification_stream(seed, mix, n_workers, batch, steps)
    ex, ey = eval_set(2048)
    logs = []
    t0 = time.time()
    for t, (x, y) in enumerate(stream):
        g = jax.tree.map(
            lambda *gs: jnp.mean(jnp.stack(gs), 0),
            *[grad(params, (x[i], y[i])) for i in range(n_workers)])
        params = jax.tree.map(lambda p, gg: p - lr(t) * gg, params, g)
        if t % 10 == 0:
            logs.append({"step": t, "acc": float(acc(params, ex, ey))})
    return logs, {"acc": float(acc(params, ex, ey))}, time.time() - t0


def fmt_curve(logs, key="acc", stride=1):
    return " ".join(f"{m['step']}:{m[key]:.3f}" for m in logs[::stride])
