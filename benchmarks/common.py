"""Shared harness for the paper-claim reproduction experiments.

All experiments run the single-host faithful simulator (repro.core.simulator)
on the synthetic mixture classification task (data/pipeline.py documents why
MNIST/CIFAR are substituted). Experiments mirror the paper's figures; each
module exposes run(quick: bool) -> dict and a textual summary.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.engine import EpochEngine
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum, l2_diameter)
from repro.data.pipeline import (DeviceBatchStream, MixtureSpec,
                                 classification_stream)
from repro.optim.schedules import inverse_linear

DEFAULT_MIX = MixtureSpec(n_classes=10, dim=32, sep=1.0, noise=1.2)


def run_byzsgd(cfg: ByzSGDConfig, *, steps: int, batch: int, seed: int = 0,
               lr0: float = 0.05, decay: float = 0.005,
               mix: MixtureSpec = DEFAULT_MIX, metrics_every: int = 10,
               track_delta: bool = False, hidden: int = 64,
               stepwise: bool = False):
    """Train with ByzSGD; returns (logs, final accuracy, wall seconds).

    Runs on the fused epoch engine (repro.core.engine): batches come from the
    device-side PRNG stream, metrics are accumulated on device, and the host
    conversion happens ONCE after training (no per-sample float() syncs).
    ``stepwise=True`` falls back to the per-step reference loop (debugging;
    equivalence of the two paths is tested in tests/test_engine.py).
    """
    init, loss, acc = make_mlp_problem(dim=mix.dim, hidden=hidden,
                                       n_classes=mix.n_classes)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(lr0, decay))
    state = sim.init_state(jax.random.PRNGKey(seed))

    if stepwise:
        stream, eval_set = classification_stream(seed, mix, cfg.n_workers,
                                                 batch, steps)
        ex, ey = eval_set(2048)

        def metrics(s):
            p0 = jax.tree.map(lambda l: l[0], s.params)
            m = {"acc": float(acc(p0, ex, ey))}
            if track_delta:
                m["delta"] = float(coordinatewise_diameter_sum(s.params,
                                                               cfg.h_servers))
                m["l2_diam"] = float(l2_diameter(s.params, cfg.h_servers))
            return m

        t0 = time.time()
        state, logs = sim.run(state, stream, metrics_fn=metrics,
                              metrics_every=metrics_every)
        wall = time.time() - t0
        return logs, metrics(state), wall

    stream = DeviceBatchStream(seed, mix, cfg.n_workers, batch)
    ex, ey = stream.eval_set(2048)
    eng = EpochEngine(sim, acc_fn=acc, eval_set=(ex, ey),
                      track_delta=track_delta, metrics_every=metrics_every)
    t0 = time.time()
    state, mbuf = eng.run(state, stream=stream, steps=steps)
    wall = time.time() - t0

    logs = []
    for i in range(0, steps, metrics_every):
        m = {"step": i, "acc": float(mbuf["acc"][i])}
        if track_delta:
            m["delta"] = float(mbuf["delta"][i])
            m["l2_diam"] = float(mbuf["l2_diam"][i])
        if "rejects" in mbuf:
            m["rejects"] = int(mbuf["rejects"][i].sum())
        stal = sim.delivery.staleness(i)
        if stal:
            m.update(stal)
        logs.append(m)

    # final metrics on the final state (the last step is off-stride in general)
    p0 = jax.tree.map(lambda l: l[0], state.params)
    final = {"acc": float(acc(p0, ex, ey))}
    if track_delta:
        final["delta"] = float(mbuf["delta"][-1])
        final["l2_diam"] = float(mbuf["l2_diam"][-1])
    if "rejects" in mbuf:
        final["rejects"] = int(mbuf["rejects"][-1].sum())
    return logs, final, wall


def run_vanilla_sgd(*, steps: int, batch: int, n_workers: int = 9,
                    seed: int = 0, lr0: float = 0.05, decay: float = 0.005,
                    mix: MixtureSpec = DEFAULT_MIX, hidden: int = 64):
    """Paper baseline: single trusted server, plain averaging."""
    init, loss, acc = make_mlp_problem(dim=mix.dim, hidden=hidden,
                                       n_classes=mix.n_classes)
    lr = inverse_linear(lr0, decay)
    params = init(jax.random.PRNGKey(seed))
    grad = jax.jit(jax.grad(loss))
    stream, eval_set = classification_stream(seed, mix, n_workers, batch, steps)
    ex, ey = eval_set(2048)
    logs = []
    t0 = time.time()
    for t, (x, y) in enumerate(stream):
        g = jax.tree.map(
            lambda *gs: jnp.mean(jnp.stack(gs), 0),
            *[grad(params, (x[i], y[i])) for i in range(n_workers)])
        params = jax.tree.map(lambda p, gg: p - lr(t) * gg, params, g)
        if t % 10 == 0:
            logs.append({"step": t, "acc": float(acc(params, ex, ey))})
    return logs, {"acc": float(acc(params, ex, ey))}, time.time() - t0


def fmt_curve(logs, key="acc", stride=1):
    return " ".join(f"{m['step']}:{m[key]:.3f}" for m in logs[::stride])
