"""Benchmark entry point.

    python -m benchmarks.run [--full] [--only name,...]      # figure lanes
    python -m benchmarks.run --list                          # what exists
    python -m benchmarks.run --exp smoke --override steps=30 # any spec
    python -m benchmarks.run --exp smoke \
        --runners stepwise,fused,netsim,protocol
    python -m benchmarks.run --exp smoke --store             # sweep cache

Figure lanes run one experiment per paper figure/claim (reduced sizes by
default; --full runs paper-scale step counts) plus the roofline table from
the dry-run artifacts when present. ``--exp`` runs a ``repro.exp`` preset
(with ``--override key=val`` field overrides) through one or more runners and
writes each RunResult verbatim. Every result JSON carries a ``provenance``
block (spec hash, git sha, jax version, device). ``--store`` additionally
appends the results to the spec-hash-keyed store (``benchmarks/store.py``):
identical (spec_hash, runner, git_sha) entries dedupe, metric drift vs the
stored run is diffed and printed.
"""
from __future__ import annotations

import argparse
import json
import os
import time

EXPERIMENTS = [
    ("convergence", "exp_convergence"),
    ("byz_workers", "exp_byz_workers"),
    ("byz_servers", "exp_byz_servers"),
    ("variance_bound", "exp_variance_bound"),
    ("contraction", "exp_contraction"),
    ("t_sensitivity", "exp_t_sensitivity"),
    ("filters", "exp_filters"),
    ("messages", "exp_messages"),
    ("netsim", "exp_netsim"),
    ("agg", "exp_agg_backends"),
    ("throughput", "exp_throughput"),
    ("serve", "exp_serve"),
    ("elastic", "exp_elastic"),
    ("analyze", "exp_analyze"),
]


def _lane_provenance(name: str, full: bool) -> dict:
    """Provenance for a figure lane: the 'spec' is the lane's (name, scale)
    pair — hashed the same way Experiment hashes its dict."""
    import hashlib

    import repro.exp as exp
    blob = json.dumps({"lane": name, "full": full}, sort_keys=True)
    return exp.provenance(hashlib.sha256(blob.encode()).hexdigest()[:16])


def list_everything() -> str:
    import repro.exp as exp
    lines = ["figure lanes (--only name,...):"]
    for name, mod in EXPERIMENTS:
        lines.append(f"  {name:15s} -> benchmarks/{mod}.py")
    lines.append("\nexperiment presets (--exp NAME, override with "
                 "--override key=val):\n")
    lines.append(exp.markdown_table())
    return "\n".join(lines)


def run_preset(args) -> None:
    import repro.exp as exp
    from benchmarks.common import parse_overrides
    overrides = parse_overrides(args.override)
    runners = (args.runners.split(",") if args.runners
               else [exp.get(args.exp, **overrides).runner])
    for runner in runners:
        res = exp.run(args.exp, **{**overrides, "runner": runner})
        print(res.summary())
        path = exp.write_result(res, out_dir=args.out)
        print(f"  -> {path}")
        if args.store:
            from benchmarks import store
            status, drift = store.store(res.to_dict())
            print(f"  store[{res.experiment.spec_hash}/{runner}]: {status}")
            for line in drift:
                print(f"    drift vs stored: {line}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale step counts (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/benchmarks")
    ap.add_argument("--list", action="store_true",
                    help="print figure lanes + registered experiment presets")
    ap.add_argument("--exp", default=None, metavar="PRESET",
                    help="run one repro.exp preset instead of figure lanes")
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VAL",
                    help="Experiment field override (repeatable)")
    ap.add_argument("--runners", default=None,
                    help="comma list for --exp (e.g. stepwise,fused,netsim,"
                    "protocol); default: the preset's declared runner")
    ap.add_argument("--store", action="store_true",
                    help="with --exp: append each RunResult to "
                    "results/store.jsonl keyed on provenance.spec_hash, "
                    "deduping identical (spec_hash, runner, git_sha) entries "
                    "and printing a diff when metrics drift")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="after the throughput experiment, fail (exit 1) on "
                    "a fused steps/sec regression beyond --compare-tol vs "
                    "this baseline file")
    ap.add_argument("--compare-tol", type=float, default=0.25,
                    help="relative regression tolerance for --compare "
                    "(default 0.25)")
    args = ap.parse_args()

    if args.list:
        print(list_everything())
        return
    if args.exp:
        os.makedirs(args.out, exist_ok=True)
        run_preset(args)
        return

    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    baseline = None
    if args.compare:
        # load before running: the run overwrites results/benchmarks/*.json
        with open(args.compare) as f:
            baseline = json.load(f)

    import importlib
    t00 = time.time()
    results = {}
    for name, mod_name in EXPERIMENTS:
        if only and name not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        res = mod.run(quick=not args.full)
        results[name] = res
        print(mod.summarize(res))
        print(f"  ({time.time()-t0:.1f}s)\n")
        res["provenance"] = _lane_provenance(name, args.full)
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(res, f, indent=1, default=float)

    # roofline table (if the dry-run has been run)
    try:
        from repro.launch import roofline
        rows = roofline.full_table()
        ok_rows = [r for r in rows if "skipped" not in r]
        if ok_rows:
            print("[roofline] single-pod baseline (naive engine):")
            print(roofline.format_table(rows))
    except Exception as e:  # noqa: BLE001
        print(f"[roofline] unavailable: {e}")
    print(f"\ntotal {time.time()-t00:.1f}s")

    if baseline is not None:
        if "throughput" not in results:
            print("[compare] --compare given but the throughput experiment "
                  "did not run (add --only throughput or drop --only)")
            raise SystemExit(2)
        from benchmarks.exp_throughput import compare
        problems = compare(results["throughput"], baseline,
                           tol=args.compare_tol)
        if problems:
            print("[compare] throughput REGRESSION vs "
                  f"{args.compare}:")
            for p in problems:
                print(f"  {p}")
            raise SystemExit(1)
        print(f"[compare] fused throughput within {100*args.compare_tol:.0f}%"
              f" of {args.compare} — OK")


if __name__ == "__main__":
    main()
