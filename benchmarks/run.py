"""Benchmark entry point: python -m benchmarks.run [--full] [--only name,...]

One experiment per paper figure/claim (reduced sizes by default; --full runs
paper-scale step counts), plus the roofline table from the dry-run artifacts
when present.
"""
from __future__ import annotations

import argparse
import json
import os
import time

EXPERIMENTS = [
    ("convergence", "exp_convergence"),
    ("byz_workers", "exp_byz_workers"),
    ("byz_servers", "exp_byz_servers"),
    ("variance_bound", "exp_variance_bound"),
    ("contraction", "exp_contraction"),
    ("t_sensitivity", "exp_t_sensitivity"),
    ("filters", "exp_filters"),
    ("messages", "exp_messages"),
    ("netsim", "exp_netsim"),
    ("agg", "exp_agg_backends"),
    ("throughput", "exp_throughput"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale step counts (slow)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/benchmarks")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="after the throughput experiment, fail (exit 1) on "
                    "a fused steps/sec regression beyond --compare-tol vs "
                    "this baseline file")
    ap.add_argument("--compare-tol", type=float, default=0.25,
                    help="relative regression tolerance for --compare "
                    "(default 0.25)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(args.out, exist_ok=True)

    baseline = None
    if args.compare:
        # load before running: the run overwrites results/benchmarks/*.json
        with open(args.compare) as f:
            baseline = json.load(f)

    import importlib
    t00 = time.time()
    results = {}
    for name, mod_name in EXPERIMENTS:
        if only and name not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        res = mod.run(quick=not args.full)
        results[name] = res
        print(mod.summarize(res))
        print(f"  ({time.time()-t0:.1f}s)\n")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(res, f, indent=1, default=float)

    # roofline table (if the dry-run has been run)
    try:
        from repro.launch import roofline
        rows = roofline.full_table()
        ok_rows = [r for r in rows if "skipped" not in r]
        if ok_rows:
            print("[roofline] single-pod baseline (naive engine):")
            print(roofline.format_table(rows))
    except Exception as e:  # noqa: BLE001
        print(f"[roofline] unavailable: {e}")
    print(f"\ntotal {time.time()-t00:.1f}s")

    if baseline is not None:
        if "throughput" not in results:
            print("[compare] --compare given but the throughput experiment "
                  "did not run (add --only throughput or drop --only)")
            raise SystemExit(2)
        from benchmarks.exp_throughput import compare
        problems = compare(results["throughput"], baseline,
                           tol=args.compare_tol)
        if problems:
            print("[compare] throughput REGRESSION vs "
                  f"{args.compare}:")
            for p in problems:
                print(f"  {p}")
            raise SystemExit(1)
        print(f"[compare] fused throughput within {100*args.compare_tol:.0f}%"
              f" of {args.compare} — OK")


if __name__ == "__main__":
    main()
