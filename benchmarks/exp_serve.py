"""Quorum-read serving overhead + Byzantine-correctness lane.

Three measurements, one JSON (``results/benchmarks/serve.json``):

  1. **overhead** — tok/s of a single honest replica vs a 4-replica quorum
     service (same model, same prompts): the price of Byzantine-tolerant
     reads (one extra vmap axis + a median/vote per token);
  2. **correctness** — with 1 of 4 replicas Byzantine under EVERY model
     attack in ``repro.core.attacks.MODEL_ATTACKS``, both read rules must
     produce continuations token-identical to the honest single-replica
     run (asserted, not just recorded), and the divergence detector must
     eject the attacker;
  3. **flood** — a ``repro.netsim`` request flood (1000+ clients) against
     the replicated service shape, with per-replica latency/byte accounting.

Run via ``python -m benchmarks.run --only serve`` or ``make serve-bench``.
"""
from __future__ import annotations

import time

import jax

from repro.core.attacks import MODEL_ATTACKS, ByzantineSpec
from repro.models.registry import get_bundle
from repro.netsim import flood as nsflood
from repro.netsim import scenarios
from repro.serve import READ_RULES, QuorumService, ReplicaPool


def _continuations(pool, bundle, prompts, max_new, rule="median"):
    svc = QuorumService(pool, bundle, n_slots=len(prompts),
                        max_len=len(prompts[0]) + max_new + 1, rule=rule)
    t0 = time.time()
    outs = svc.generate(prompts, max_new=max_new)
    wall = time.time() - t0
    return outs, wall, svc.report()


def run(quick: bool = True):
    R, f = 4, 1
    n_prompts, plen, max_new = (2, 8, 6) if quick else (4, 16, 16)
    bundle = get_bundle("phi4-mini-3.8b", reduced=True)
    params = bundle.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = [[int(t) for t in row] for row in jax.random.randint(
        key, (n_prompts, plen), 0, bundle.cfg.vocab)]

    # 1. honest baseline: one replica, no quorum machinery beyond R=1
    base_pool = ReplicaPool.from_params(params, 1, f=0)
    base_out, base_wall, base_rep = _continuations(base_pool, bundle,
                                                   prompts, max_new)
    results = {
        "quick": quick, "arch": "phi4-mini-3.8b (reduced)",
        "R": R, "f": f, "prompts": n_prompts, "max_new": max_new,
        "baseline": {"tok_s": base_rep["tok_s"], "wall_s": base_wall},
        "attacks": {},
    }

    # 2. honest quorum (overhead) + every attack x every read rule
    honest_pool = ReplicaPool.from_params(params, R, f=f)
    h_out, h_wall, h_rep = _continuations(honest_pool, bundle, prompts,
                                          max_new)
    assert h_out == base_out, "honest quorum continuation diverged"
    assert not h_rep["ejections"], "detector ejected an honest replica"
    results["quorum_honest"] = {
        "tok_s": h_rep["tok_s"], "wall_s": h_wall,
        "overhead_x": base_rep["tok_s"] / max(h_rep["tok_s"], 1e-9),
    }

    for attack in sorted(MODEL_ATTACKS):
        spec = ByzantineSpec(server_attack=attack, n_byz_servers=1)
        entry = {}
        for rule in READ_RULES:
            pool = ReplicaPool.from_params(params, R, f=f).corrupt(
                spec, jax.random.PRNGKey(7))
            outs, wall, rep = _continuations(pool, bundle, prompts,
                                             max_new, rule=rule)
            identical = outs == base_out
            assert identical, (f"{attack}/{rule}: quorum continuation NOT "
                               f"token-identical to honest baseline")
            entry[rule] = {
                "token_identical": identical,
                "tok_s": rep["tok_s"],
                "disagreement_rate": rep["disagreement_rate"],
                "ejections": rep["ejections"],
                "retries": rep["retries"],
                "n_active": rep["n_active"],
            }
        results["attacks"][attack] = entry

    # 3. request flood with per-replica accounting
    n_clients = 1000 if quick else 5000
    sc = scenarios.request_flood(
        n_clients=n_clients, rate=2.0, duration_ms=1000.0, n_replicas=R, f=f,
        slow_replicas=(R - 1,), slow_factor=6.0, deadline_ms=25.0, seed=0)
    trace = nsflood.run_flood(sc)
    results["flood"] = {
        "n_clients": n_clients, "n_requests": trace.n_requests,
        "percentiles_ms": trace.percentiles(),
        "deadline_missed": trace.deadline_missed,
        "per_replica": [
            {"id": r, "served": int(trace.replica_served[r]),
             "busy_ms": float(trace.replica_busy_ms[r]),
             "late_replies": int(trace.replica_late[r]),
             "max_queue_ms": float(trace.max_queue_ms[r])}
            for r in range(R)],
        "ledger": trace.ledger.totals(),
        "summary": trace.summary(),
    }
    from repro.exp import provenance
    results["provenance"] = provenance()
    return results


def summarize(res: dict) -> str:
    q = res["quorum_honest"]
    lines = [
        f"[serve] {res['arch']}: R={res['R']} f={res['f']}, "
        f"{res['prompts']} prompts x {res['max_new']} new tokens",
        f"  single replica {res['baseline']['tok_s']:8.1f} tok/s | "
        f"quorum {q['tok_s']:8.1f} tok/s "
        f"(overhead {q['overhead_x']:.2f}x)",
    ]
    for attack, entry in sorted(res["attacks"].items()):
        bits = []
        for rule, r in entry.items():
            tick = "identical" if r["token_identical"] else "DIVERGED"
            bits.append(f"{rule}: {tick}, ejected {len(r['ejections'])}")
        lines.append(f"  1-of-4 Byzantine [{attack:12s}]  " + " | ".join(bits))
    fl = res["flood"]
    pc = fl["percentiles_ms"]
    lines.append(
        f"  flood: {fl['n_clients']} clients -> {fl['n_requests']} requests, "
        f"p50 {pc['p50']:.2f}ms p99 {pc['p99']:.2f}ms, "
        f"missed>{25}ms: {fl['deadline_missed']}")
    return "\n".join(lines)


if __name__ == "__main__":
    import json
    r = run(quick=True)
    print(summarize(r))
    print(json.dumps({k: v for k, v in r.items() if k != "flood"},
                     indent=1, default=float)[:2000])
