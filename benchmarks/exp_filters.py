"""Fig. 10 analogue: filter false negatives (sync variant).

A false negative = a correct server's model rejected by the Lipschitz/Outliers
filters (wasted pull). Paper claims: <=1% FN without attack (any T); under the
Reversed attack the wasted-bandwidth ratio is bounded by f_ps/n_ps (the filter
keeps rejecting the Byzantine server's payloads); other attacks stay <=3.5%.
"""
from __future__ import annotations

import jax

import repro.agg as agg
from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.engine import EpochEngine
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import DeviceBatchStream
from repro.optim.schedules import inverse_linear

from .common import DEFAULT_MIX


def _run(byz, steps, T, gar="mda"):
    # Calibration (see EXPERIMENTS.md): Assumption 6 requires ||grad L||
    # bounded away from 0 — enforced via the paper's own prescription
    # (L2 regularisation) + batch 100 so the empirical Lipschitz-coefficient
    # distribution is tight. The quantile level (n_ps-f_ps)/n_ps itself
    # implies an FN floor when the k-distribution is broad.
    cfg = ByzSGDConfig(n_workers=5, f_workers=1, n_servers=5, f_servers=1,
                       T=T, variant="sync", lip_horizon=32, gar=gar, byz=byz)
    init, loss, _ = make_mlp_problem(dim=DEFAULT_MIX.dim, hidden=64, l2=3e-2)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.001))
    state = sim.init_state(jax.random.PRNGKey(0))
    # fused sync epochs: per-worker reject counts are carried in the scan and
    # summed from the on-device metrics buffer (one transfer, no per-step sync)
    eng = EpochEngine(sim)
    stream = DeviceBatchStream(0, DEFAULT_MIX, 5, 100)
    byz_is_active = byz.n_byz_servers > 0
    state, mbuf = eng.run(state, stream=stream, steps=steps)
    total_rejects = int(mbuf["rejects"].sum())
    pulls = steps * cfg.n_workers
    reject_ratio = total_rejects / pulls
    # without attack every reject is a false negative; with n_byz=1 the first
    # 1/n_ps of rejects are true positives (round-robin hits the Byzantine
    # server once per cycle) — report raw ratio plus the TP-adjusted FN rate.
    expected_tp = (byz.n_byz_servers / cfg.n_servers) if byz_is_active else 0.0
    fn_ratio = max(reject_ratio - expected_tp, 0.0)
    return {"reject_ratio": reject_ratio, "fn_ratio_est": fn_ratio}


def run(quick: bool = True, gar: str = "mda"):
    steps = 100 if quick else 500
    out = {}
    for T in ([5, 20] if quick else [1, 5, 20, 50]):
        out[f"clean_T{T}"] = _run(ByzantineSpec(), steps, T, gar)
    for atk in (["reversed", "lie"] if quick else
                ["reversed", "lie", "random", "partial_drop"]):
        out[f"{atk}_T20"] = _run(
            ByzantineSpec(server_attack=atk, n_byz_servers=1,
                          equivocate=True), steps, 20, gar)
    return out


def summarize(res: dict) -> str:
    lines = ["[filters / Fig.10] reject ratio (vs total pulls), est. FN rate:"]
    for k, r in res.items():
        lines.append(f"  {k:16s}: rejects {100*r['reject_ratio']:5.1f}%  "
                     f"FN~{100*r['fn_ratio_est']:5.1f}%")
    clean_ok = all(r["fn_ratio_est"] < 0.45 for k, r in res.items()
                   if k.startswith("clean"))
    lines.append(
        "  note: the (n_ps-f_ps)/n_ps=80% quantile cutoff implies a ~20-25% "
        "structural FN floor/pull-chain when the empirical k-distribution is "
        "broad (small task, minibatch noise); the paper's <=1% reflects a "
        "tight distribution at CIFAR scale. Qualitative claims (bounded FN, "
        f"Byzantine payloads rejected) {'hold' if clean_ok else 'CHECK'}.")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    # worker-gradient rule choices come from the registry (pytree-capable)
    ap.add_argument("--gar", default="mda",
                    choices=[n for n in agg.names()
                             if agg.get(n).tree_mode is not None])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(summarize(run(quick=not args.full, gar=args.gar)))


if __name__ == "__main__":
    main()
