"""Fig. 10 analogue: filter false negatives (sync variant).

A false negative = a correct server's model rejected by the Lipschitz/Outliers
filters (wasted pull). Paper claims: <=1% FN without attack (any T); under the
Reversed attack the wasted-bandwidth ratio is bounded by f_ps/n_ps (the filter
keeps rejecting the Byzantine server's payloads); other attacks stay <=3.5%.
"""
from __future__ import annotations

import repro.exp as exp
from repro.core.attacks import ByzantineSpec

from .common import claim_main


def _run(byz, steps, T, gar="mda"):
    # Calibration (see EXPERIMENTS.md): Assumption 6 requires ||grad L||
    # bounded away from 0 — enforced via the paper's own prescription
    # (L2 regularisation) + batch 100 so the empirical Lipschitz-coefficient
    # distribution is tight. The quantile level (n_ps-f_ps)/n_ps itself
    # implies an FN floor when the k-distribution is broad.
    e = exp.Experiment(
        name="filters", variant="sync", n_workers=5, f_workers=1, T=T,
        steps=steps, batch=100, gar=gar, lip_horizon=32, l2=3e-2,
        decay=0.001, byz=byz)
    # fused sync epochs: per-worker reject counts are carried in the scan and
    # summed from the on-device metrics buffer (one transfer, no per-step sync)
    res = exp.run(e)
    total_rejects = int(res.buffers["rejects"].sum())
    pulls = steps * e.n_workers
    reject_ratio = total_rejects / pulls
    # without attack every reject is a false negative; with n_byz=1 the first
    # 1/n_ps of rejects are true positives (round-robin hits the Byzantine
    # server once per cycle) — report raw ratio plus the TP-adjusted FN rate.
    expected_tp = (byz.n_byz_servers / e.n_servers) if byz.n_byz_servers \
        else 0.0
    fn_ratio = max(reject_ratio - expected_tp, 0.0)
    return {"reject_ratio": reject_ratio, "fn_ratio_est": fn_ratio}


def run(quick: bool = True, gar: str = "mda"):
    steps = 100 if quick else 500
    out = {}
    for T in ([5, 20] if quick else [1, 5, 20, 50]):
        out[f"clean_T{T}"] = _run(ByzantineSpec(), steps, T, gar)
    for atk in (["reversed", "lie"] if quick else
                ["reversed", "lie", "random", "partial_drop"]):
        out[f"{atk}_T20"] = _run(
            ByzantineSpec(server_attack=atk, n_byz_servers=1,
                          equivocate=True), steps, 20, gar)
    return out


def summarize(res: dict) -> str:
    lines = ["[filters / Fig.10] reject ratio (vs total pulls), est. FN rate:"]
    for k, r in res.items():
        lines.append(f"  {k:16s}: rejects {100*r['reject_ratio']:5.1f}%  "
                     f"FN~{100*r['fn_ratio_est']:5.1f}%")
    clean_ok = all(r["fn_ratio_est"] < 0.45 for k, r in res.items()
                   if k.startswith("clean"))
    lines.append(
        "  note: the (n_ps-f_ps)/n_ps=80% quantile cutoff implies a ~20-25% "
        "structural FN floor/pull-chain when the empirical k-distribution is "
        "broad (small task, minibatch noise); the paper's <=1% reflects a "
        "tight distribution at CIFAR scale. Qualitative claims (bounded FN, "
        f"Byzantine payloads rejected) {'hold' if clean_ok else 'CHECK'}.")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__, gar_flag=True)
