"""Fig. 3 analogue: convergence in a non-Byzantine environment.

Vanilla SGD (single trusted server, plain averaging) vs ByzSGD async and sync,
at two batch sizes. Paper claim: near-identical accuracy-per-step with a small
final-accuracy gap (~5%), and a wall-clock overhead (~32% on their testbed; we
report simulator step time + modelled communication bytes — see exp_messages).
"""
from __future__ import annotations

from repro.exp import Experiment

from .common import claim_main, run_exp, run_vanilla_sgd


def run(quick: bool = True, gar: str = "mda"):
    steps = 120 if quick else 600
    batches = [25] if quick else [25, 100]
    out = {}
    for b in batches:
        v_logs, v_final, v_wall = run_vanilla_sgd(steps=steps, batch=b)
        a_exp = Experiment(name="convergence_async", variant="async", gar=gar,
                           steps=steps, batch=b)
        a_logs, a_final, a_wall = run_exp(a_exp)
        s_logs, s_final, s_wall = run_exp(
            a_exp.replace(name="convergence_sync", variant="sync"))
        out[f"b{b}"] = {
            "vanilla": {"final_acc": v_final["acc"], "wall_s": v_wall},
            "byzsgd_async": {"final_acc": a_final["acc"], "wall_s": a_wall},
            "byzsgd_sync": {"final_acc": s_final["acc"], "wall_s": s_wall},
            "acc_gap_async": v_final["acc"] - a_final["acc"],
            "acc_gap_sync": v_final["acc"] - s_final["acc"],
        }
    return out


def summarize(res: dict) -> str:
    lines = ["[convergence / Fig.3] final accuracy (gap vs vanilla):"]
    for b, r in res.items():
        lines.append(
            f"  batch {b[1:]:>4s}: vanilla {r['vanilla']['final_acc']:.3f} | "
            f"async {r['byzsgd_async']['final_acc']:.3f} "
            f"(gap {r['acc_gap_async']:+.3f}) | "
            f"sync {r['byzsgd_sync']['final_acc']:.3f} "
            f"(gap {r['acc_gap_sync']:+.3f})")
    lines.append("  paper: convergence parity, <=5% final-accuracy loss — "
                 "PASS" if all(abs(r["acc_gap_async"]) < 0.08
                               for r in res.values()) else "  CHECK gaps")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__, gar_flag=True)
