"""Fig. 3 analogue: convergence in a non-Byzantine environment.

Vanilla SGD (single trusted server, plain averaging) vs ByzSGD async and sync,
at two batch sizes. Paper claim: near-identical accuracy-per-step with a small
final-accuracy gap (~5%), and a wall-clock overhead (~32% on their testbed; we
report simulator step time + modelled communication bytes — see exp_messages).
"""
from __future__ import annotations

import repro.agg as agg
from repro.core.simulator import ByzSGDConfig

from .common import run_byzsgd, run_vanilla_sgd


def run(quick: bool = True, gar: str = "mda"):
    steps = 120 if quick else 600
    batches = [25] if quick else [25, 100]
    out = {}
    for b in batches:
        v_logs, v_final, v_wall = run_vanilla_sgd(steps=steps, batch=b)
        a_cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5,
                             f_servers=1, T=10, variant="async", gar=gar)
        a_logs, a_final, a_wall = run_byzsgd(a_cfg, steps=steps, batch=b)
        s_cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5,
                             f_servers=1, T=10, variant="sync", gar=gar)
        s_logs, s_final, s_wall = run_byzsgd(s_cfg, steps=steps, batch=b)
        out[f"b{b}"] = {
            "vanilla": {"final_acc": v_final["acc"], "wall_s": v_wall},
            "byzsgd_async": {"final_acc": a_final["acc"], "wall_s": a_wall},
            "byzsgd_sync": {"final_acc": s_final["acc"], "wall_s": s_wall},
            "acc_gap_async": v_final["acc"] - a_final["acc"],
            "acc_gap_sync": v_final["acc"] - s_final["acc"],
        }
    return out


def summarize(res: dict) -> str:
    lines = ["[convergence / Fig.3] final accuracy (gap vs vanilla):"]
    for b, r in res.items():
        lines.append(
            f"  batch {b[1:]:>4s}: vanilla {r['vanilla']['final_acc']:.3f} | "
            f"async {r['byzsgd_async']['final_acc']:.3f} "
            f"(gap {r['acc_gap_async']:+.3f}) | "
            f"sync {r['byzsgd_sync']['final_acc']:.3f} "
            f"(gap {r['acc_gap_sync']:+.3f})")
    lines.append("  paper: convergence parity, <=5% final-accuracy loss — "
                 "PASS" if all(abs(r["acc_gap_async"]) < 0.08
                               for r in res.values()) else "  CHECK gaps")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    # worker-gradient rule choices come from the registry (pytree-capable)
    ap.add_argument("--gar", default="mda",
                    choices=[n for n in agg.names()
                             if agg.get(n).tree_mode is not None])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print(summarize(run(quick=not args.full, gar=args.gar)))


if __name__ == "__main__":
    main()
