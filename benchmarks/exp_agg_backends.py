"""Aggregator backend timing: jnp reference vs Pallas kernels, per rule.

Times every registered rule on each backend it declares and writes
``results/benchmarks/agg_backends.json``. Off-TPU the Pallas kernels run in
interpret mode — correct but slow, so those timings measure the *fallback*,
not the kernel (flagged ``interpret: true`` in the output). Run via
``python -m benchmarks.run --only agg`` or ``make agg-bench``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

import repro.agg as agg

OUT_PATH = os.path.join("results", "benchmarks", "agg_backends.json")


def _pick_f(name: str, n: int) -> int:
    """Largest declared f the rule's breakdown admits at this n (>= 1)."""
    k, c = agg.get(name).requires
    f = (n - c) // k if k else n - 1
    return max(min(f, n - 1, 2), 1)


def _time_call(fn, x, iters: int) -> float:
    fn(x).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e3


def run(quick: bool = True):
    n, d = (13, 1024) if quick else (15, 16384)
    iters = 3 if quick else 10
    interpreted = jax.default_backend() != "tpu"
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    results = {"n": n, "d": d, "platform": jax.default_backend(), "rules": {}}
    for name in agg.names():
        spec = agg.get(name)
        f = _pick_f(name, n)
        entry = {"f": f, "breakdown": spec.breakdown, "backends": {}}
        for backend in spec.backends:
            def call(x, _b=backend):
                return spec(x, f, backend=_b)
            try:
                ms = _time_call(jax.jit(call), x, iters)
            except Exception as e:  # noqa: BLE001 - record, don't die
                entry["backends"][backend] = {"error": str(e)[:200]}
                continue
            entry["backends"][backend] = {
                "ms": ms, "interpret": backend == "pallas" and interpreted}
        results["rules"][name] = entry
    from repro.exp import provenance
    results["provenance"] = provenance()
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump(results, fh, indent=1, default=float)
    results["out"] = OUT_PATH
    return results


def summarize(res: dict) -> str:
    lines = [f"[agg backends] per-rule timings, [n={res['n']}, d={res['d']}] "
             f"on {res['platform']} -> {res.get('out', OUT_PATH)}:"]
    for name, entry in res["rules"].items():
        cells = []
        for backend, r in entry["backends"].items():
            if "error" in r:
                cells.append(f"{backend}: ERR")
            else:
                tag = " (interpret)" if r.get("interpret") else ""
                cells.append(f"{backend}: {r['ms']:8.2f} ms{tag}")
        lines.append(f"  {name:14s} f={entry['f']}  " + "  ".join(cells))
    if res["platform"] != "tpu":
        lines.append("  note: off-TPU the pallas column is interpret-mode "
                     "(fallback correctness path, not kernel speed)")
    return "\n".join(lines)


if __name__ == "__main__":
    from .common import claim_main
    claim_main(run, summarize, description=__doc__)
