"""Fig. 4 analogue + §5 claim: message/byte cost, synchronous vs asynchronous.

We cannot measure 10 Gbps-cluster wall-clock throughput on this host, so we
report the *communication model* the paper argues from, instantiated with the
actual tensor sizes (documented deviation):

per normal (scatter) step, per worker/server, d = model size in floats:
  async:  worker rx = q_ps * d (pull all, Median)   worker tx = n_ps * d
          server rx = q_w * d                       server tx = n_w * d
  sync:   worker rx = 1 * d (round-robin + filters) worker tx = 1 * d
          server rx = n_w/n_ps * d                  server tx = n_w/n_ps * d
plus the amortised DMC gather every T steps (n_ps^2 * d server exchange).

The sync schedule is a round-robin request/reply *pair*: worker w sends its
gradient to server (w + k) % n_ps only, which replies with its model —
neither direction is a broadcast (the worker_tx n_ps·d -> 1·d correction
flagged in ROADMAP; repro.netsim counts the same schedule, and exp_netsim's
wallclock section logs the deviation vs the old broadcast accounting).

Also cross-checked against the *measured* per-device collective bytes of the
compiled distributed protocol (results/dryrun), which uses all-gathers instead
of point-to-point sends.
"""
from __future__ import annotations


def model_bytes(d: int, n_w: int, n_ps: int, f_w: int, f_ps: int, T: int,
                dtype_bytes: int = 4):
    q_ps = n_ps - f_ps
    q_w = n_w - f_w
    D = d * dtype_bytes
    async_step = {
        "worker_rx": q_ps * D, "worker_tx": n_ps * D,
        "server_rx": q_w * D, "server_tx": n_w * D,
    }
    sync_step = {
        "worker_rx": 1 * D, "worker_tx": 1 * D,       # round-robin reply pair
        "server_rx": n_w * D / n_ps, "server_tx": n_w * D / n_ps,
    }
    dmc = {"server_exchange": (n_ps - 1) * D + q_ps * D}
    tot_async = sum(async_step.values()) + dmc["server_exchange"] / T
    tot_sync = sum(sync_step.values()) + dmc["server_exchange"] / T
    return {"async": async_step, "sync": sync_step, "dmc": dmc,
            "total_async": tot_async, "total_sync": tot_sync,
            "sync_gain": tot_async / tot_sync}


def run(quick: bool = True):
    del quick
    out = {}
    # paper-scale models (Table 2)
    for name, d in [("MNIST_CNN", 79_510), ("CifarNet", 1_756_426),
                    ("ResNet-50", 23_539_850), ("ResNet-200", 62_697_610)]:
        out[name] = model_bytes(d, n_w=20, n_ps=6, f_w=5, f_ps=1, T=333)
    # our assigned archs (per server-group replica, fp32)
    for name, d in [("phi4-mini-3.8b", 3_800_000_000),
                    ("internlm2-20b", 20_000_000_000)]:
        out[name] = model_bytes(d, n_w=16, n_ps=16, f_w=5, f_ps=4, T=50)
    return out


def summarize(res: dict) -> str:
    lines = ["[messages / Fig.4] modelled bytes per step (per node) and "
             "sync-vs-async gain:"]
    for name, r in res.items():
        lines.append(
            f"  {name:16s}: async {r['total_async']/1e6:10.1f} MB  "
            f"sync {r['total_sync']/1e6:10.1f} MB  gain x{r['sync_gain']:.2f}")
    lines.append("  paper: synchrony cuts messages (up to ~70% throughput "
                 "boost, growing with model size)")
    return "\n".join(lines)


if __name__ == "__main__":
    from .common import claim_main
    claim_main(run, summarize, description=__doc__)
