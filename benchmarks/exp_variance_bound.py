"""Appendix D / Fig. 7 analogue: the variance-to-norm assumption.

Measures sqrt(E||g - Eg||^2) / ||grad|| over the first 100 steps at several
batch sizes and compares against the MDA bound (n-f)/(2f) and the Krum /
Multi-Krum bound (1/eta(n,f)).

Paper claims: MDA's requirement is satisfied at practical batch sizes (e.g.
b=128 with f=1) while Multi-Krum's is not; with f=5, even b=256 violates MDA's
bound on their workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.agg as agg
from repro.configs.paper_models import make_mlp_problem
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

from .common import DEFAULT_MIX


def measure_ratio(batch: int, steps: int = 60, n_est: int = 8, seed: int = 0,
                  mix: MixtureSpec = DEFAULT_MIX):
    """Train a model; at each step estimate std/norm across n_est gradient
    replicas at the same parameters (i.i.d. minibatches)."""
    init, loss, _ = make_mlp_problem(dim=mix.dim, hidden=64,
                                     n_classes=mix.n_classes)
    lr = inverse_linear(0.05, 0.005)
    params = init(jax.random.PRNGKey(seed))
    gradf = jax.jit(jax.grad(loss))
    stream, _ = classification_stream(seed, mix, n_est, batch, steps)
    ratios = []
    for t, (x, y) in enumerate(stream):
        gs = [gradf(params, (x[i], y[i])) for i in range(n_est)]
        flat = jnp.stack([jnp.concatenate([l.ravel() for l in jax.tree.leaves(g)])
                          for g in gs])
        mean_g = jnp.mean(flat, axis=0)
        std = jnp.sqrt(jnp.mean(jnp.sum((flat - mean_g) ** 2, axis=1)))
        ratios.append(float(std / jnp.maximum(jnp.linalg.norm(mean_g), 1e-12)))
        params = jax.tree.map(lambda p, g: p - lr(t) * g, params,
                              jax.tree.map(lambda *ls: jnp.mean(jnp.stack(ls), 0),
                                           *gs))
    r = jnp.asarray(ratios)
    return float(jnp.mean(r)), float(jnp.std(r))


def run(quick: bool = True):
    n_w = 18
    batches = [16, 128] if quick else [16, 32, 64, 128, 256]
    out = {"ratios": {}, "bounds": {}}
    for b in batches:
        out["ratios"][b] = measure_ratio(b, steps=30 if quick else 100)
    for f in (1, 5):
        out["bounds"][f] = {
            "mda": agg.get("mda").variance_threshold(n_w, f),
            "krum": agg.get("krum").variance_threshold(n_w, f),
        }
    return out


def summarize(res: dict) -> str:
    lines = ["[variance bound / Fig.7] std/norm ratio vs GAR requirements "
             "(n=18):"]
    for b, (m, s) in res["ratios"].items():
        checks = []
        for f, bd in res["bounds"].items():
            checks.append(f"MDA(f={f}):{'ok' if m < bd['mda'] else 'VIOLATED'}")
            checks.append(f"Krum(f={f}):{'ok' if m < bd['krum'] else 'VIOLATED'}")
        lines.append(f"  b={b:<4d} ratio={m:.3f}±{s:.3f}  " + " ".join(checks))
    bd = res["bounds"]
    lines.append(f"  thresholds: MDA f=1 {bd[1]['mda']:.2f}, f=5 "
                 f"{bd[5]['mda']:.2f}; Krum f=1 {bd[1]['krum']:.3f}, f=5 "
                 f"{bd[5]['krum']:.3f}")
    lines.append("  paper: MDA's bound is looser than Krum's by orders of "
                 "magnitude — visible above")
    return "\n".join(lines)


if __name__ == "__main__":
    from .common import claim_main
    claim_main(run, summarize, description=__doc__)
