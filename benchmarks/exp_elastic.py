"""Elastic-membership lane: churn overhead + recovery-time-to-parity.

Three measurements, one JSON (``results/benchmarks/elastic.json``):

  1. **equivalence** — the same spec through ``runner="protocol"`` and an
     empty-plan ``runner="elastic"`` must land bit-identical final params
     (asserted, not just recorded); steps/s of both quantifies the price
     of the membership machinery (epoch chunking + boundary checks) when
     nothing churns;
  2. **planned churn** — ``elastic/planned_churn`` (G 5 -> 4 -> 8 steps
     -> 5, the rejoiner re-seeded from the DMC median of the survivors)
     vs the static oracle: per-step accuracy curves plus
     *recovery-time-to-parity* — how many post-rejoin steps until the
     churned run is back within tolerance of the static run at the same
     step;
  3. **netsim churn** — the same measurement with the plan lowered from
     the realized ``membership_churn`` crash trace instead of authored.

Each RunResult also lands in the spec-hash-keyed store
(``benchmarks/store.py``), so churn-run metric drift across revisions is
diffed like any other sweep point. Run via ``python -m benchmarks.run
--only elastic`` or ``make elastic-bench``.
"""
from __future__ import annotations

import jax
import numpy as np

import repro.exp as exp
from benchmarks import store

#: parity = within this absolute accuracy of the static oracle's same step
PARITY_TOL = 0.02


def _steps_per_s(res) -> float:
    return res.experiment.steps / max(res.wall_s, 1e-9)


def _recovery_to_parity(churned, static, rejoin_step: int) -> int | None:
    """Steps after ``rejoin_step`` until the churned run's accuracy is
    within ``PARITY_TOL`` of the static run's at the same step (None =
    never inside the run)."""
    ca = np.asarray(churned.buffers["acc"], np.float64)
    sa = np.asarray(static.buffers["acc"], np.float64)
    for j in range(rejoin_step, min(len(ca), len(sa))):
        if ca[j] >= sa[j] - PARITY_TOL:
            return j - rejoin_step
    return None


def _churn_entry(res, static) -> dict:
    mem = res.provenance["membership"]
    joins = [e["step"] for e in mem["events"] if e["kind"] == "join"]
    rejoin = max(joins) if joins else res.experiment.steps
    return {
        "plan_source": mem["plan_source"],
        "events": mem["events"],
        "epochs": mem["epochs"],
        "steps_per_s": _steps_per_s(res),
        "final_acc": res.final["acc"],
        "acc_at_rejoin": float(np.asarray(res.buffers["acc"])[rejoin - 1]),
        "recovery_steps_to_parity": _recovery_to_parity(res, static, rejoin),
    }


def run(quick: bool = True):
    overrides = {} if quick else {"steps": 48, "metrics_every": 8}
    results = {"quick": quick, "parity_tol": PARITY_TOL}

    # 1. equivalence: protocol vs empty-plan elastic, bit for bit
    static_proto = exp.run("elastic/static", runner="protocol", **overrides)
    static = exp.run("elastic/static", **overrides)
    pp = jax.tree.leaves(static_proto.state.params)
    pe = jax.tree.leaves(static.state.params)
    identical = all(np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(pp, pe))
    assert identical, "empty-plan elastic diverged from runner=protocol"
    results["equivalence"] = {
        "bit_identical": identical,
        "protocol_steps_per_s": _steps_per_s(static_proto),
        "elastic_steps_per_s": _steps_per_s(static),
        "overhead_x": (_steps_per_s(static_proto)
                       / max(_steps_per_s(static), 1e-9)),
        "final_acc": static.final["acc"],
    }

    # 2. authored churn vs the static oracle
    churned = exp.run("elastic/planned_churn", **overrides)
    results["planned_churn"] = _churn_entry(churned, static)

    # 3. the same, with the plan lowered from the realized netsim trace
    netsim = exp.run("elastic/netsim_churn", **overrides)
    results["netsim_churn"] = _churn_entry(netsim, static)

    for res in (static_proto, static, churned, netsim):
        store.store(res.to_dict())
    results["provenance"] = exp.provenance()
    return results


def summarize(res: dict) -> str:
    eq = res["equivalence"]
    lines = [
        f"[elastic] empty plan vs protocol: bit-identical={eq['bit_identical']}"
        f", {eq['protocol_steps_per_s']:.1f} vs {eq['elastic_steps_per_s']:.1f}"
        f" steps/s (overhead {eq['overhead_x']:.2f}x)",
    ]
    for lane in ("planned_churn", "netsim_churn"):
        e = res[lane]
        rec = e["recovery_steps_to_parity"]
        rec = "never" if rec is None else f"{rec} steps"
        lines.append(
            f"  {lane:13s} [{e['plan_source']}]: G trajectory "
            f"{'->'.join(str(len(ep['active'])) for ep in e['epochs'])}, "
            f"final acc {e['final_acc']:.3f} "
            f"(static {res['equivalence']['final_acc']:.3f}), "
            f"parity {rec} after rejoin, {e['steps_per_s']:.1f} steps/s")
    return "\n".join(lines)


if __name__ == "__main__":
    import json
    r = run(quick=True)
    print(summarize(r))
    print(json.dumps(r, indent=1, default=float)[:2000])
