"""Throughput lane: the fused epoch engine vs the per-step ``run()`` loop.

Three lanes per (variant, model size), all training the same default MLP
problem end-to-end (data pipeline included) for the same number of steps:

  * ``seed_loop`` — the per-step ``run()`` loop driving the *seed* hot path:
    host batch iterator, one jitted dispatch per step, and the order-statistic
    rules routed through XLA's generic sort (``use_sort_network(False)``).
    This is the training loop this PR replaces.
  * ``stepwise`` — the same per-step ``run()`` loop on today's optimized
    rules (sorting-network medians, per-instance jit cache). Isolates how
    much of the win is loop fusion vs step-math optimization.
  * ``fused`` — :class:`repro.core.engine.EpochEngine` with the device-side
    batch stream: whole epochs as one donated-buffer ``lax.scan`` dispatch.

The acceptance config additionally runs the distributed protocol through
``ProtocolEngine`` fused epochs on a mesh over the available devices —
``protocol_naive`` vs ``protocol_sharded`` (the two collective engines, with
their modeled per-step cross-'rep' collective volume attached) — so the
multi-device path's steps/sec rides the same perf-trajectory file as the
single-host engine.

The ``model/lm/*`` lanes time one zoo model family each (dense transformer /
MoE / RWKV6) through the protocol runner's engine construction — token
stream, activation-sharding rules, fsdp-aware modeled collective volume —
so every trainable family has a committed steps/sec number the 25%
regression gate watches.

Wall-clock is measured with ``block_until_ready`` around interleaved
best-of-``repeats`` trials (this container's CPU throttles erratically;
interleaving + best-of keeps the *ratios* meaningful), and compile time is
reported separately from steady-state steps/sec.

``python -m benchmarks.run --only throughput`` writes
``results/benchmarks/throughput.json``; ``--compare <baseline.json>`` gates
on >25% fused steps/sec regression. ``python -m benchmarks.exp_throughput
--seed-baseline`` refreshes ``BENCH_throughput.json``, the committed perf
trajectory baseline.
"""
from __future__ import annotations

import time
from contextlib import nullcontext

import jax

from repro.agg.rules import use_sort_network
from repro.core.engine import EpochEngine
from repro.data.pipeline import DeviceBatchStream, classification_stream
from repro.exp import Experiment

from .common import DEFAULT_MIX

BATCH = 25
T = 10
ACCEPTANCE_KEY = "async/mlp_h64"   # default MLP problem, async, T=10
ACCEPTANCE_TARGET = 5.0
#: one protocol-runner lane per trainable model family (dense transformer /
#: MoE / RWKV6 SSM), riding the registered lm/* presets; transformer steps
#: are ~100x an MLP step on this backend, so they time far fewer of them
LM_PRESETS = ("lm/tfm_tiny", "lm/moe_tiny", "lm/rwkv_tiny")
LM_STEPS = 12
LM_EPOCH_STEPS = 6


def _build(variant: str, hidden: int):
    """Lanes are specs too: the same `Experiment` lowers to the config and
    simulator each lane drives (the timing loops below stay hand-rolled —
    they intentionally compare run paths the uniform runner hides)."""
    e = Experiment(
        name=f"throughput_{variant}_h{hidden}", variant=variant,
        n_workers=5 if variant == "sync" else 9,
        f_workers=1 if variant == "sync" else 2,
        T=T, batch=BATCH, model=f"mlp_h{hidden}")
    return e.to_config(), e.build_sim()


def _stepwise_lane(variant: str, hidden: int, steps: int, seed_path: bool):
    """Returns (compile_s, trial_fn) for the per-step run() loop."""
    ctx = use_sort_network(False) if seed_path else nullcontext()
    with ctx:
        cfg, sim = _build(variant, hidden)  # fresh sim => fresh traces

        def one_run():
            state = sim.init_state(jax.random.PRNGKey(0))
            stream, _ = classification_stream(0, DEFAULT_MIX, cfg.n_workers,
                                              BATCH, steps)
            t0 = time.time()
            state, _ = sim.run(state, stream)
            jax.block_until_ready(state.params)
            return steps / (time.time() - t0)

        # first short run compiles all step executables
        state = sim.init_state(jax.random.PRNGKey(0))
        stream, _ = classification_stream(0, DEFAULT_MIX, cfg.n_workers,
                                          BATCH, T + 1)
        t0 = time.time()
        state, _ = sim.run(state, stream)
        jax.block_until_ready(state.params)
        compile_s = time.time() - t0

    def trial():
        with (use_sort_network(False) if seed_path else nullcontext()):
            return one_run()

    return compile_s, trial


def _protocol_lane(hidden: int, steps: int, epoch_steps: int, engine: str):
    """(compile_s, trial_fn, volume_bytes) for the distributed protocol's
    fused epochs (G = 5 groups on a mesh over the available devices)."""
    from repro.core import protocol as proto
    from repro.launch.mesh import make_protocol_mesh

    e = Experiment(name=f"throughput_protocol_{engine}_h{hidden}",
                   n_workers=5, f_workers=1, n_servers=5, f_servers=1,
                   T=T, batch=BATCH, model=f"mlp_h{hidden}",
                   runner="protocol", protocol_engine=engine)
    pcfg = e.to_protocol_config()
    init, loss, _ = e.build_problem()
    bundle = proto.ProblemBundle(init=init, loss=loss)
    mesh = make_protocol_mesh(pcfg.n_groups)
    eng = proto.ProtocolEngine(bundle, pcfg, e.build_schedule(), mesh=mesh)
    n_params = sum(l.size for l in jax.tree.leaves(
        jax.eval_shape(init, jax.random.PRNGKey(0))))

    def one_run():
        state = eng.init_state(jax.random.PRNGKey(0))
        stream = DeviceBatchStream(0, DEFAULT_MIX, pcfg.n_groups, BATCH)
        t0 = time.time()
        state, _ = eng.run(state, stream=stream, steps=steps,
                           epoch_steps=epoch_steps)
        jax.block_until_ready(state.params)
        return steps / (time.time() - t0)

    state = eng.init_state(jax.random.PRNGKey(0))
    stream = DeviceBatchStream(0, DEFAULT_MIX, pcfg.n_groups, BATCH)
    t0 = time.time()
    state, _ = eng.run(state, stream=stream, steps=epoch_steps,
                       epoch_steps=epoch_steps)
    jax.block_until_ready(state.params)
    compile_s = time.time() - t0
    return compile_s, one_run, proto.collective_volume_bytes(pcfg, n_params)


def _model_lane(preset: str, steps: int, epoch_steps: int):
    """(compile_s, trial_fn, volume_bytes, family) for one zoo model family
    through ``ProtocolEngine`` fused epochs — the same engine construction
    as ``repro.exp.runners._run_protocol`` (token stream, activation-
    sharding rules from the launch layer), minus the metrics plumbing."""
    from repro.core import protocol as proto
    from repro.data.pipeline import DeviceTokenStream
    from repro.exp import presets, runners
    from repro.exp.spec import DATA
    from repro.launch.mesh import use_mesh
    from repro.launch.steps import train_rules

    e = presets.get(preset)
    pcfg = e.to_protocol_config()
    G = pcfg.n_groups
    bundle = e.build_bundle()
    mesh = runners._protocol_mesh(G)
    K = dict(zip(mesh.axis_names, mesh.devices.shape))["fsdp"]
    rules = train_rules(mesh, bundle.cfg)
    n_params = sum(l.size for l in jax.tree.leaves(
        jax.eval_shape(bundle.init, jax.random.PRNGKey(0))))

    with use_mesh(mesh):
        eng = proto.ProtocolEngine(bundle, pcfg, e.build_schedule(),
                                   mesh=mesh, rules=rules)

    def one_run():
        with use_mesh(mesh):
            state = eng.init_state(jax.random.PRNGKey(0))
            stream = DeviceTokenStream(e.seed, DATA[e.data], G, e.batch)
            t0 = time.time()
            state, _ = eng.run(state, stream=stream, steps=steps,
                               epoch_steps=epoch_steps)
            jax.block_until_ready(state.params)
            return steps / (time.time() - t0)

    with use_mesh(mesh):
        state = eng.init_state(jax.random.PRNGKey(0))
        stream = DeviceTokenStream(e.seed, DATA[e.data], G, e.batch)
        t0 = time.time()
        state, _ = eng.run(state, stream=stream, steps=epoch_steps,
                           epoch_steps=epoch_steps)
        jax.block_until_ready(state.params)
        compile_s = time.time() - t0
    vol = proto.collective_volume_bytes(pcfg, n_params, fsdp=K)
    return compile_s, one_run, vol, bundle.cfg.family


def _fused_lane(variant: str, hidden: int, steps: int, epoch_steps: int):
    cfg, sim = _build(variant, hidden)
    eng = EpochEngine(sim)

    def one_run():
        state = sim.init_state(jax.random.PRNGKey(0))
        stream = DeviceBatchStream(0, DEFAULT_MIX, cfg.n_workers, BATCH)
        t0 = time.time()
        state, _ = eng.run(state, stream=stream, steps=steps,
                           epoch_steps=epoch_steps)
        jax.block_until_ready(state.params)
        return steps / (time.time() - t0)

    state = sim.init_state(jax.random.PRNGKey(0))
    stream = DeviceBatchStream(0, DEFAULT_MIX, cfg.n_workers, BATCH)
    t0 = time.time()
    state, _ = eng.run(state, stream=stream, steps=epoch_steps,
                       epoch_steps=epoch_steps)
    jax.block_until_ready(state.params)
    compile_s = time.time() - t0
    return compile_s, one_run


def run(quick: bool = True):
    steps = 150 if quick else 500
    repeats = 3 if quick else 5
    epoch_steps = 50  # scan chunk; gather boundary is t-driven, chunk is free
    configs = [("async", "mlp_h64", 64), ("async", "mlp_h256", 256),
               ("sync", "mlp_h64", 64)]
    if not quick:
        configs += [("async", "mlp_h1024", 1024), ("sync", "mlp_h256", 256)]

    out = {"device": jax.devices()[0].platform, "steps": steps,
           "batch": BATCH, "T": T, "repeats": repeats,
           "epoch_steps": epoch_steps, "lanes": {}}
    for variant, mname, hidden in configs:
        key = f"{variant}/{mname}"
        lane_fns, compile_s, volumes = {}, {}, {}
        compile_s["seed_loop"], lane_fns["seed_loop"] = _stepwise_lane(
            variant, hidden, steps, seed_path=True)
        compile_s["stepwise"], lane_fns["stepwise"] = _stepwise_lane(
            variant, hidden, steps, seed_path=False)
        compile_s["fused"], lane_fns["fused"] = _fused_lane(
            variant, hidden, steps, epoch_steps)
        if key == ACCEPTANCE_KEY:
            # the distributed protocol rides the acceptance config: both
            # collective engines, interleaved with the single-host lanes
            for engine in ("naive", "sharded"):
                name = f"protocol_{engine}"
                compile_s[name], lane_fns[name], volumes[name] = \
                    _protocol_lane(hidden, steps, epoch_steps, engine)
        trials = {name: [] for name in lane_fns}
        for _ in range(repeats):          # interleaved: same machine state
            for name, fn in lane_fns.items():
                trials[name].append(fn())
        # the protocol rows are an order of magnitude faster per trial than
        # the stepwise loops, so their best-of is noisier: give them extra
        # interleaved rounds (on a 1-device mesh the two collective engines
        # compile to near-identical programs — no wire to differ on)
        for _ in range(2 * repeats):
            for name, fn in lane_fns.items():
                if name.startswith("protocol_"):
                    trials[name].append(fn())
        entry = {name: {"steps_per_s": max(v), "trials": v,
                        "compile_s": compile_s[name]}
                 for name, v in trials.items()}
        for name, vol in volumes.items():
            entry[name]["collective_bytes_per_step"] = vol
        entry["speedup_vs_stepwise"] = (entry["fused"]["steps_per_s"] /
                                        entry["stepwise"]["steps_per_s"])
        entry["speedup_vs_seed_loop"] = (entry["fused"]["steps_per_s"] /
                                         entry["seed_loop"]["steps_per_s"])
        out["lanes"][key] = entry

    # model-family lanes: the zoo through the protocol runner, one lane per
    # family, interleaved best-of like the MLP lanes (fewer, pricier steps)
    lm_fns, lm_meta = {}, {}
    for preset in LM_PRESETS:
        key = f"model/{preset}"
        compile_s, fn, vol, family = _model_lane(preset, LM_STEPS,
                                                 LM_EPOCH_STEPS)
        lm_fns[key] = fn
        lm_meta[key] = {"compile_s": compile_s,
                        "collective_bytes_per_step": vol, "family": family}
    lm_trials = {key: [] for key in lm_fns}
    for _ in range(repeats):
        for key, fn in lm_fns.items():
            lm_trials[key].append(fn())
    for key, v in lm_trials.items():
        meta = lm_meta[key]
        out["lanes"][key] = {
            "family": meta["family"], "steps": LM_STEPS,
            "protocol": {"steps_per_s": max(v), "trials": v,
                         "compile_s": meta["compile_s"],
                         "collective_bytes_per_step":
                             meta["collective_bytes_per_step"]}}

    pl = out["lanes"][ACCEPTANCE_KEY]
    out["protocol"] = {
        "config": ACCEPTANCE_KEY, "n_groups": 5,
        "naive_sps": pl["protocol_naive"]["steps_per_s"],
        "sharded_sps": pl["protocol_sharded"]["steps_per_s"],
        "sharded_over_naive": (pl["protocol_sharded"]["steps_per_s"] /
                               pl["protocol_naive"]["steps_per_s"]),
        "sharded_ge_naive": bool(pl["protocol_sharded"]["steps_per_s"] >=
                                 pl["protocol_naive"]["steps_per_s"]),
        "naive_collective_bytes_per_step":
            pl["protocol_naive"]["collective_bytes_per_step"],
        "sharded_collective_bytes_per_step":
            pl["protocol_sharded"]["collective_bytes_per_step"],
    }

    acc = out["lanes"][ACCEPTANCE_KEY]
    out["acceptance"] = {
        "config": ACCEPTANCE_KEY,
        "fused_sps": acc["fused"]["steps_per_s"],
        "stepwise_sps": acc["stepwise"]["steps_per_s"],
        "seed_loop_sps": acc["seed_loop"]["steps_per_s"],
        "speedup_vs_seed_loop": acc["speedup_vs_seed_loop"],
        "speedup_vs_stepwise": acc["speedup_vs_stepwise"],
        "target": ACCEPTANCE_TARGET,
        "pass": acc["speedup_vs_seed_loop"] >= ACCEPTANCE_TARGET,
    }
    return out


def summarize(res: dict) -> str:
    lines = [f"[throughput] fused epoch engine vs per-step run() "
             f"({res['device']}, {res['steps']} steps, batch {res['batch']}, "
             f"T={res['T']}, best of {res['repeats']}):"]
    for key, e in res["lanes"].items():
        if "fused" not in e:  # model-family lane: protocol runner only
            p = e["protocol"]
            lines.append(
                f"  {key:15s}: protocol {p['steps_per_s']:7.2f} steps/s  "
                f"({e['family']}; compile {p['compile_s']:.1f}s; modeled "
                f"{p['collective_bytes_per_step']/1e6:.2f} MB/step)")
            continue
        lines.append(
            f"  {key:15s}: seed_loop {e['seed_loop']['steps_per_s']:7.1f}  "
            f"stepwise {e['stepwise']['steps_per_s']:7.1f}  "
            f"fused {e['fused']['steps_per_s']:7.1f} steps/s  "
            f"({e['speedup_vs_seed_loop']:.1f}x vs seed, "
            f"{e['speedup_vs_stepwise']:.1f}x vs stepwise; "
            f"compile {e['fused']['compile_s']:.1f}s)")
    p = res.get("protocol")
    if p:
        lines.append(
            f"  protocol [{p['config']}, G={p['n_groups']}]: naive "
            f"{p['naive_sps']:.1f} vs sharded {p['sharded_sps']:.1f} steps/s "
            f"(x{p['sharded_over_naive']:.2f}); modeled collective volume "
            f"{p['naive_collective_bytes_per_step']/1e6:.2f} vs "
            f"{p['sharded_collective_bytes_per_step']/1e6:.2f} MB/step — "
            f"{'OK' if p['sharded_ge_naive'] else 'CHECK'} (sharded >= naive)")
    a = res["acceptance"]
    lines.append(f"  acceptance [{a['config']}]: fused {a['fused_sps']:.1f} "
                 f"steps/s = {a['speedup_vs_seed_loop']:.1f}x the seed loop "
                 f"(target >= {a['target']:.0f}x) — "
                 f"{'PASS' if a['pass'] else 'CHECK'}")
    return "\n".join(lines)


def compare(new: dict, baseline: dict, tol: float = 0.25) -> list[str]:
    """Regressions of steps/sec vs a baseline run. Each lane gates on its
    timed engine — ``fused`` for the MLP lanes, ``protocol`` for the
    model-family lanes — and regresses when more than ``tol`` slower than
    the committed number."""
    problems = []
    for key, old in baseline.get("lanes", {}).items():
        gate = "fused" if "fused" in old else "protocol"
        cur = new.get("lanes", {}).get(key)
        if cur is None or gate not in cur:
            problems.append(f"{key}: lane missing from this run")
            continue
        old_sps = old[gate]["steps_per_s"]
        new_sps = cur[gate]["steps_per_s"]
        if new_sps < (1.0 - tol) * old_sps:
            problems.append(f"{key}: {gate} {new_sps:.1f} steps/s vs baseline "
                            f"{old_sps:.1f} (-{100*(1-new_sps/old_sps):.0f}%, "
                            f"tolerance {100*tol:.0f}%)")
    return problems


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed-baseline", action="store_true",
                    help="write BENCH_throughput.json (perf trajectory "
                    "baseline at the repo root)")
    args = ap.parse_args()
    res = run(quick=not args.full)
    print(summarize(res))
    if args.seed_baseline:
        from repro.exp import provenance
        res["provenance"] = provenance()
        with open("BENCH_throughput.json", "w") as f:
            json.dump(res, f, indent=1, default=float)
        print("wrote BENCH_throughput.json")


if __name__ == "__main__":
    main()
