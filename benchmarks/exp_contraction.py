"""Lemmas 4.2/4.3 validation: DMC contraction of the coordinate-wise-diameter
sum Delta_t.

Claims verified empirically:
  * Safety (4.2): Delta never increases ACROSS a gather step, for any attack.
  * Contraction (4.3): E[Delta_after / Delta_before] < 1 at gather steps
    (strictly, approx <= 1 - rho/4 for some delivery distribution).
  * Drift (4.4): during scatter, Delta grows at most O(eta) per step.
"""
from __future__ import annotations

import jax.numpy as jnp

import repro.exp as exp
from repro.core.attacks import ByzantineSpec

from .common import claim_main


def run(quick: bool = True):
    steps = 60 if quick else 300
    T = 5
    out = {}
    for label, byz in [("clean", ByzantineSpec()),
                       ("lie_server", ByzantineSpec(server_attack="lie",
                                                    n_byz_servers=1,
                                                    equivocate=True))]:
        e = exp.Experiment(name=f"contraction_{label}", T=T, steps=steps,
                           batch=25, track_delta=True, byz=byz)
        # fused engine: delta_pre (post-scatter, pre-gather) and delta
        # (post-gather) come back as on-device per-step buffers — the gather
        # contraction ratio is computed from ONE host transfer.
        mbuf = exp.run(e).buffers
        ratios, grew = [], 0
        for i in range(T - 1, steps, T):  # gather fires when (i+1) % T == 0
            d_pre, d_post = float(mbuf["delta_pre"][i]), float(mbuf["delta"][i])
            if d_pre > 1e-9:
                ratios.append(d_post / d_pre)
                if d_post > d_pre + 1e-6:
                    grew += 1
        deltas = [float(v) for v in mbuf["delta_pre"]]
        out[label] = {
            "mean_contraction": float(jnp.mean(jnp.asarray(ratios))),
            "max_contraction": float(jnp.max(jnp.asarray(ratios))),
            "gather_increases": grew,
            "n_gathers": len(ratios),
            "delta_first": deltas[0], "delta_last": deltas[-1],
        }
    return out


def summarize(res: dict) -> str:
    lines = ["[DMC contraction / Lemmas 4.2-4.3] Delta ratio across gather:"]
    for label, r in res.items():
        ok = r["gather_increases"] == 0 and r["mean_contraction"] < 1.0
        lines.append(
            f"  {label:10s}: mean {r['mean_contraction']:.3f}, max "
            f"{r['max_contraction']:.3f}, increases {r['gather_increases']}/"
            f"{r['n_gathers']} — {'PASS' if ok else 'CHECK'}")
    lines.append("  paper: Median never dilates Delta (4.2) and contracts in "
                 "expectation (4.3)")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__)
