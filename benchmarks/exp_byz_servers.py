"""Fig. 5 analogue: convergence with 1 Byzantine server under 4 attacks:
Reversed, Partial Drop (10% zeroed), Random, LIE (z = 1.035).

Paper claim: ByzSGD tolerates all four and converges to high accuracy.
Run with the asynchronous variant (Median pull) and the synchronous variant
(Lipschitz + Outliers filters).
"""
from __future__ import annotations

from repro.core.attacks import ByzantineSpec
from repro.exp import Experiment

from .common import claim_main, run_exp

ATTACKS = ["reversed", "partial_drop", "random", "lie"]


def run(quick: bool = True):
    steps = 120 if quick else 500
    out = {}
    for variant in ("async", "sync"):
        out[variant] = {}
        base = Experiment(
            name=f"byz_servers_{variant}", variant=variant,
            n_workers=5 if variant == "sync" else 9,
            f_workers=1 if variant == "sync" else 2,
            steps=steps, batch=25)
        _, clean, _ = run_exp(base)
        out[variant]["no_attack"] = clean["acc"]
        for atk in (ATTACKS if not quick else ATTACKS[:4]):
            byz = ByzantineSpec(server_attack=atk, n_byz_servers=1,
                                equivocate=True)
            _, final, _ = run_exp(base.replace(byz=byz))
            out[variant][atk] = final["acc"]
    return out


def summarize(res: dict) -> str:
    lines = ["[Byzantine server / Fig.5] final accuracy under 4 attacks:"]
    for variant, r in res.items():
        lines.append(f"  {variant:5s}: " + "  ".join(
            f"{k}={v:.3f}" for k, v in r.items()))
        worst = min(v for k, v in r.items() if k != "no_attack")
        ok = worst > r["no_attack"] - 0.10
        lines.append(f"         paper: tolerates all four — "
                     f"{'PASS' if ok else 'CHECK'} (worst {worst:.3f} vs "
                     f"clean {r['no_attack']:.3f})")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__)
