"""Fig. 5 analogue: convergence with 1 Byzantine server under 4 attacks:
Reversed, Partial Drop (10% zeroed), Random, LIE (z = 1.035).

Paper claim: ByzSGD tolerates all four and converges to high accuracy.
Run with the asynchronous variant (Median pull) and the synchronous variant
(Lipschitz + Outliers filters).
"""
from __future__ import annotations

from repro.core.attacks import ByzantineSpec
from repro.core.simulator import ByzSGDConfig

from .common import run_byzsgd

ATTACKS = ["reversed", "partial_drop", "random", "lie"]


def run(quick: bool = True):
    steps = 120 if quick else 500
    out = {}
    for variant in ("async", "sync"):
        out[variant] = {}
        base = dict(n_workers=5 if variant == "sync" else 9,
                    f_workers=1 if variant == "sync" else 2,
                    n_servers=5, f_servers=1, T=10, variant=variant)
        _, clean, _ = run_byzsgd(ByzSGDConfig(**base), steps=steps, batch=25)
        out[variant]["no_attack"] = clean["acc"]
        for atk in (ATTACKS if not quick else ATTACKS[:4]):
            cfg = ByzSGDConfig(**base, byz=ByzantineSpec(
                server_attack=atk, n_byz_servers=1, equivocate=True))
            _, final, _ = run_byzsgd(cfg, steps=steps, batch=25)
            out[variant][atk] = final["acc"]
    return out


def summarize(res: dict) -> str:
    lines = ["[Byzantine server / Fig.5] final accuracy under 4 attacks:"]
    for variant, r in res.items():
        lines.append(f"  {variant:5s}: " + "  ".join(
            f"{k}={v:.3f}" for k, v in r.items()))
        worst = min(v for k, v in r.items() if k != "no_attack")
        ok = worst > r["no_attack"] - 0.10
        lines.append(f"         paper: tolerates all four — "
                     f"{'PASS' if ok else 'CHECK'} (worst {worst:.3f} vs "
                     f"clean {r['no_attack']:.3f})")
    return "\n".join(lines)
