"""Benchmark lane: the full static-analysis audit as a CI artifact.

Runs ``python -m repro.analyze --hlo`` in a subprocess (the forced
8-device CPU topology must be set before jax initialises, so the audit
cannot share this process) and republishes its report —
``results/analyze/report.json``, provenance included — as the lane
result. A non-empty violation list fails the lane the same way a perf
regression fails the throughput lane.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

REPORT = os.path.join("results", "analyze", "report.json")


def run(quick: bool = True) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)   # let the CLI force its 8-device topology
    # both scales run the full two-layer audit; "quick" has nothing to cut
    cmd = [sys.executable, "-m", "repro.analyze", "--hlo", "--json", REPORT]
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    wall = time.time() - t0
    if not os.path.exists(REPORT):
        return {"clean": False, "wall_s": wall, "exit": proc.returncode,
                "error": (proc.stderr or proc.stdout)[-2000:]}
    with open(REPORT) as f:
        doc = json.load(f)
    return {"clean": doc["clean"], "wall_s": wall, "exit": proc.returncode,
            "violations": doc["violations"], "baselined": doc["baselined"],
            "rules_run": doc["stats"].get("rules_run", []),
            "files_linted": doc["stats"].get("files_linted"),
            "report": REPORT}


def summarize(res: dict) -> str:
    if "error" in res:
        return f"[analyze] FAILED to produce a report: {res['error'][:200]}"
    state = "clean" if res["clean"] else \
        f"{len(res['violations'])} violation(s)"
    return (f"[analyze] {state}  rules={len(res['rules_run'])} "
            f"files={res['files_linted']}  ({res['wall_s']:.0f}s)"
            f"  -> {res['report']}")


if __name__ == "__main__":
    r = run()
    print(summarize(r))
    raise SystemExit(0 if r.get("clean") else 1)
