"""Fig. 9 / Appendix E.2 analogue: effect of the scatter length T.

Paper claims: T barely affects accuracy-per-update in clean runs; larger T
converges faster in wall-clock (less communication); under attack, T=1 is the
most stable and large T increases end-of-training noise (drift between
gathers grows, easier for Byzantine servers to hide).
"""
from __future__ import annotations

from repro.core.attacks import ByzantineSpec
from repro.exp import Experiment

from .common import claim_main, run_exp


def run(quick: bool = True):
    steps = 120 if quick else 400
    ts = [1, 10, 40] if quick else [1, 5, 10, 40, 100]
    reversed_server = ByzantineSpec(server_attack="reversed",
                                    n_byz_servers=1, equivocate=True)
    out = {"clean": {}, "reversed_server": {}}
    for T in ts:
        base = Experiment(name=f"t_sensitivity_T{T}", T=T, steps=steps,
                          batch=25)
        _, final, wall = run_exp(base)
        out["clean"][T] = {"acc": final["acc"], "wall_s": wall}
        _, final, wall = run_exp(base.replace(byz=reversed_server))
        out["reversed_server"][T] = {"acc": final["acc"], "wall_s": wall}
    return out


def summarize(res: dict) -> str:
    lines = ["[T sensitivity / Fig.9] final accuracy by scatter length:"]
    for mode, r in res.items():
        lines.append(f"  {mode:15s}: " + "  ".join(
            f"T={t}->{v['acc']:.3f}" for t, v in r.items()))
    clean = [v["acc"] for v in res["clean"].values()]
    flat = max(clean) - min(clean) < 0.08
    lines.append(f"  paper: T has little effect on per-update convergence in "
                 f"clean runs — {'PASS' if flat else 'CHECK'}")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__)
