"""netsim scenario lab: simulated communication vs the analytic model, plus
virtual wall-clock and staleness under faults.

Cross-validates exp_messages' per-step byte model against *counted* messages
on the uniform scenario (the §5/"no extra rounds" bookkeeping), then reports
what the analytic model cannot express: realized step latency, per-phase
staleness, late/dropped traffic and quorum shortfalls under heavy-tail
stragglers, crash storms, and partitions.

The ``wallclock`` section closes the ROADMAP loop on the §5 claim: the
cluster's compute-time model is calibrated from the *measured* fused-engine
steps/sec (``scenarios.measured_compute``, reading the committed
``BENCH_throughput.json``), and the sync message schedule (one round-robin
model pull per worker per step vs the async q-of-n quorums) runs head-to-head
against async on end-to-end virtual wall-clock and bytes on the wire.
"""
from __future__ import annotations

import numpy as np

import repro.exp as exp
from repro.netsim import ClusterSim, scenarios
from repro.netsim.accounting import compare_with_model

SCENARIO_NAMES = ("baseline_uniform", "heavy_tail_stragglers", "crash_storm",
                  "partitioned_dmc", "byzantine_plus_slow")

# the paper's 10 Gbps testbed, MNIST_CNN payload (Table 2)
WALLCLOCK_MODEL_D = 79_510
WALLCLOCK_GBPS = 10.0


def _wallclock(steps: int) -> dict:
    """Sync vs async end-to-end virtual wall-clock off measured compute."""
    out = {}
    for variant in ("async", "sync"):
        n_w = 9 if variant == "async" else 5
        f_w = 2 if variant == "async" else 1
        try:
            compute = scenarios.measured_compute("mlp_h64", variant)
        except (FileNotFoundError, KeyError) as err:
            return {"skipped": str(err)}
        sc = scenarios.build(
            "baseline_uniform", variant=variant, n_workers=n_w,
            f_workers=f_w, steps=steps, compute=compute, update_ms=0.05,
            model_d=WALLCLOCK_MODEL_D, bandwidth_gbps=WALLCLOCK_GBPS)
        trace = ClusterSim(sc).run()
        tot = trace.ledger.totals()
        out[variant] = {
            "measured_compute_ms": compute.mean_ms,
            "virtual_ms": float(trace.step_done_ms[-1]),
            "ms_per_step": float(trace.step_done_ms[-1]) / sc.steps,
            # per-worker-step bytes, comparable to exp_messages' model (the
            # cluster sizes differ between variants, so totals are normalized)
            "tx_bytes_per_worker_step": sum(
                d["tx_bytes"] for d in tot.values()) / (n_w * sc.steps),
            "totals": tot,
        }
        if variant == "sync":
            # the §5 byte-model correction: sync pushes are round-robin
            # request/reply pairs (worker_tx = 1·d), not broadcasts
            # (worker_tx = n_ps·d). Log counted-vs-model so the deviation the
            # old accounting carried stays visible in the wallclock totals.
            D = WALLCLOCK_MODEL_D * 4
            counted = tot["push"]["tx_bytes"] / (n_w * sc.steps)
            out[variant]["push_byte_model"] = {
                "counted_worker_tx_per_step": counted,
                "roundrobin_model": D,
                "broadcast_model": sc.n_servers * D,
                "deviation_vs_roundrobin": abs(counted - D) / D,
                "deviation_vs_broadcast":
                    abs(counted - sc.n_servers * D) / (sc.n_servers * D),
            }
    a, s = out["async"], out["sync"]
    out["sync_speedup_wallclock"] = a["virtual_ms"] / s["virtual_ms"]
    out["sync_byte_saving"] = 1.0 - (s["tx_bytes_per_worker_step"]
                                     / a["tx_bytes_per_worker_step"])
    return out


def run(quick: bool = True):
    steps = 30 if quick else 200
    out = {}
    for name in SCENARIO_NAMES:
        # the exp presets subsume the scenario registry: lower through the
        # Experiment layer so the spec-level round-trip is exercised here too
        sc = exp.get(f"netsim/{name}").to_scenario(steps=steps,
                                                   model_d=79_510)
        trace = ClusterSim(sc).run()
        tot = trace.ledger.totals()
        # step_done_ms is not monotone under crashes (a straggler can finish
        # step k after survivors finish k+1); step durations come from the
        # running envelope.
        step_ms = np.diff(np.maximum.accumulate(trace.step_done_ms),
                          prepend=0.0)
        entry = {
            "steps": sc.steps,
            "events": trace.events,
            "virtual_ms": float(trace.step_done_ms[-1]),
            "mean_step_ms": float(step_ms.mean()),
            "p95_step_ms": float(np.percentile(step_ms, 95)),
            "mean_pull_staleness_ms": float(trace.pull_stale.mean()),
            "p95_pull_staleness_ms": float(np.percentile(trace.pull_stale, 95)),
            "late_msgs": sum(d["late_msgs"] for d in tot.values()),
            "dropped_msgs": sum(d["dropped_msgs"] for d in tot.values()),
            "dup_msgs": sum(d["dup_msgs"] for d in tot.values()),
            "shortfalls": trace.shortfalls,
        }
        if name == "baseline_uniform":
            cmp = compare_with_model(trace.ledger, sc, sc.steps,
                                     trace.n_gathers)
            entry["vs_analytic"] = {k: {"sim": s, "model": a, "rel_err": e}
                                    for k, (s, a, e) in cmp.items()}
            entry["max_rel_err"] = max(e for _, _, e in cmp.values())
        out[name] = entry
    out["wallclock"] = _wallclock(steps)
    return out


def summarize(res: dict) -> str:
    lines = ["[netsim] event-driven cluster simulation "
             "(virtual ms, per-scenario):"]
    for name, r in res.items():
        if name == "wallclock":
            continue
        lines.append(
            f"  {name:22s}: step {r['mean_step_ms']:7.2f}ms "
            f"(p95 {r['p95_step_ms']:7.2f})  "
            f"staleness {r['mean_pull_staleness_ms']:6.2f}ms  "
            f"late {r['late_msgs']:5d}  dropped {r['dropped_msgs']:4d}  "
            f"shortfall {r['shortfalls']:4d}")
    if "baseline_uniform" in res and "max_rel_err" in res["baseline_uniform"]:
        e = res["baseline_uniform"]["max_rel_err"]
        lines.append(f"  uniform scenario vs exp_messages analytic model: "
                     f"max rel err {e:.2%} (claim: < 1%)")
    wc = res.get("wallclock", {})
    if "skipped" in wc:
        lines.append(f"  wallclock (§5): skipped — {wc['skipped']}")
    elif wc:
        a, s = wc["async"], wc["sync"]
        lines.append(
            f"  wallclock (§5, measured compute {a['measured_compute_ms']:.1f}"
            f"/{s['measured_compute_ms']:.1f}ms, {WALLCLOCK_GBPS:.0f} Gbps): "
            f"async {a['ms_per_step']:.2f} ms/step vs sync "
            f"{s['ms_per_step']:.2f} ms/step "
            f"(sync x{wc['sync_speedup_wallclock']:.2f} wall-clock, "
            f"{100*wc['sync_byte_saving']:.0f}% fewer bytes/worker-step)")
        pm = s.get("push_byte_model")
        if pm:
            over = pm["broadcast_model"] / max(
                pm["counted_worker_tx_per_step"], 1e-12)
            lines.append(
                f"  sync push byte model: counted "
                f"{pm['counted_worker_tx_per_step']/1e3:.1f} kB/worker-step "
                f"vs round-robin model {pm['roundrobin_model']/1e3:.1f} "
                f"(dev {pm['deviation_vs_roundrobin']:.2%}); the old "
                f"broadcast model {pm['broadcast_model']/1e3:.1f} "
                f"overcounted x{over:.1f}")
    return "\n".join(lines)


if __name__ == "__main__":
    from .common import claim_main
    claim_main(run, summarize, description=__doc__)
