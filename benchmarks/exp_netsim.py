"""netsim scenario lab: simulated communication vs the analytic model, plus
virtual wall-clock and staleness under faults.

Cross-validates exp_messages' per-step byte model against *counted* messages
on the uniform scenario (the §5/"no extra rounds" bookkeeping), then reports
what the analytic model cannot express: realized step latency, per-phase
staleness, late/dropped traffic and quorum shortfalls under heavy-tail
stragglers, crash storms, and partitions.
"""
from __future__ import annotations

import numpy as np

from repro.netsim import ClusterSim, scenarios
from repro.netsim.accounting import compare_with_model

SCENARIO_NAMES = ("baseline_uniform", "heavy_tail_stragglers", "crash_storm",
                  "partitioned_dmc", "byzantine_plus_slow")


def run(quick: bool = True):
    steps = 30 if quick else 200
    out = {}
    for name in SCENARIO_NAMES:
        sc = scenarios.get(name, steps=steps, model_d=79_510)
        trace = ClusterSim(sc).run()
        tot = trace.ledger.totals()
        # step_done_ms is not monotone under crashes (a straggler can finish
        # step k after survivors finish k+1); step durations come from the
        # running envelope.
        step_ms = np.diff(np.maximum.accumulate(trace.step_done_ms),
                          prepend=0.0)
        entry = {
            "steps": sc.steps,
            "events": trace.events,
            "virtual_ms": float(trace.step_done_ms[-1]),
            "mean_step_ms": float(step_ms.mean()),
            "p95_step_ms": float(np.percentile(step_ms, 95)),
            "mean_pull_staleness_ms": float(trace.pull_stale.mean()),
            "p95_pull_staleness_ms": float(np.percentile(trace.pull_stale, 95)),
            "late_msgs": sum(d["late_msgs"] for d in tot.values()),
            "dropped_msgs": sum(d["dropped_msgs"] for d in tot.values()),
            "dup_msgs": sum(d["dup_msgs"] for d in tot.values()),
            "shortfalls": trace.shortfalls,
        }
        if name == "baseline_uniform":
            cmp = compare_with_model(trace.ledger, sc, sc.steps,
                                     trace.n_gathers)
            entry["vs_analytic"] = {k: {"sim": s, "model": a, "rel_err": e}
                                    for k, (s, a, e) in cmp.items()}
            entry["max_rel_err"] = max(e for _, _, e in cmp.values())
        out[name] = entry
    return out


def summarize(res: dict) -> str:
    lines = ["[netsim] event-driven cluster simulation "
             "(virtual ms, per-scenario):"]
    for name, r in res.items():
        lines.append(
            f"  {name:22s}: step {r['mean_step_ms']:7.2f}ms "
            f"(p95 {r['p95_step_ms']:7.2f})  "
            f"staleness {r['mean_pull_staleness_ms']:6.2f}ms  "
            f"late {r['late_msgs']:5d}  dropped {r['dropped_msgs']:4d}  "
            f"shortfall {r['shortfalls']:4d}")
    if "baseline_uniform" in res and "max_rel_err" in res["baseline_uniform"]:
        e = res["baseline_uniform"]["max_rel_err"]
        lines.append(f"  uniform scenario vs exp_messages analytic model: "
                     f"max rel err {e:.2%} (claim: < 1%)")
    return "\n".join(lines)
