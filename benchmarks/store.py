"""Spec-hash-keyed result store — a sweep cache / regression tracker.

``python -m benchmarks.run --exp NAME --store`` appends each ``RunResult``
(as its ``to_dict()`` JSON) to ``results/store.jsonl``, one entry per line,
keyed on ``(provenance.spec_hash, experiment.runner, provenance.git_sha)``:

* an entry whose key already exists with the **same final metrics** is a
  duplicate and is skipped (re-running a sweep point costs no store growth);
* same key but **drifting metrics** (same spec, same code revision, different
  numbers — nondeterminism or an environment change) replaces the stored
  entry and the diff is printed so the drift is never silent;
* a new ``git_sha`` is a new key, so the store accumulates the metric
  trajectory of every spec across revisions — ``diff vs stored`` is exactly
  what a regression gate reads.

``wall_s`` and the netsim accounting are stored but excluded from the drift
comparison (timing wobbles are not metric drift).
"""
from __future__ import annotations

import json
import os

STORE_PATH = os.path.join("results", "store.jsonl")

#: relative tolerance for "same metrics" (floats travel through JSON)
DRIFT_RTOL = 1e-6


def entry_key(entry: dict) -> tuple:
    """(spec_hash, runner, git_sha) — the dedupe/diff identity."""
    prov = entry.get("provenance", {})
    return (prov.get("spec_hash"), entry.get("experiment", {}).get("runner"),
            prov.get("git_sha"))


def load(path: str = STORE_PATH) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def _close(a, b) -> bool:
    if isinstance(a, float) or isinstance(b, float):
        try:
            a, b = float(a), float(b)
        except (TypeError, ValueError):
            return a == b
        return abs(a - b) <= DRIFT_RTOL * max(abs(a), abs(b), 1e-12)
    return a == b


def metric_diff(stored: dict, new: dict) -> list[str]:
    """Human-readable drift lines between two entries' final metrics (and
    logged curves); empty = identical within tolerance."""
    out = []
    sf, nf = stored.get("final", {}), new.get("final", {})
    for k in sorted(set(sf) | set(nf)):
        if k not in sf or k not in nf:
            out.append(f"final.{k}: {sf.get(k)!r} -> {nf.get(k)!r}")
        elif not _close(sf[k], nf[k]):
            out.append(f"final.{k}: {sf[k]} -> {nf[k]}")
    slog, nlog = stored.get("logs", []), new.get("logs", [])
    if len(slog) != len(nlog):
        out.append(f"logs: {len(slog)} -> {len(nlog)} entries")
    else:
        for i, (a, b) in enumerate(zip(slog, nlog)):
            bad = [k for k in sorted(set(a) | set(b))
                   if not _close(a.get(k), b.get(k))]
            if bad:
                out.append(f"logs[{i}] (step {a.get('step', i)}): "
                           + ", ".join(f"{k} {a.get(k)} -> {b.get(k)}"
                                       for k in bad))
    return out


def store(entry: dict, path: str = STORE_PATH) -> tuple[str, list[str]]:
    """Insert ``entry`` (a ``RunResult.to_dict()``); returns
    ``(status, drift_lines)`` with status one of ``"appended"`` (new key),
    ``"duplicate"`` (identical entry already stored — store untouched) or
    ``"updated"`` (same key, metrics drifted — entry replaced)."""
    key = entry_key(entry)
    entries = load(path)
    for i, old in enumerate(entries):
        if entry_key(old) == key:
            drift = metric_diff(old, entry)
            if not drift:
                return "duplicate", []
            entries[i] = entry
            _write(entries, path)
            return "updated", drift
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "a") as fh:
        fh.write(json.dumps(entry, default=float) + "\n")
    return "appended", []


def _write(entries: list[dict], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        for e in entries:
            fh.write(json.dumps(e, default=float) + "\n")
