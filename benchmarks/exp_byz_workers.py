"""Fig. 6 analogue: convergence under the ALIE ("a little is enough") worker
attack, vs the Byzantine-worker ratio (6a) and vs batch size (6b).

Paper claims: effect appears once Byzantine workers exceed ~20% of the total;
max allowed f_w degrades accuracy substantially (67% -> 40% on CIFAR);
larger batches improve robustness (variance bound easier to satisfy).
"""
from __future__ import annotations

from repro.core.attacks import ByzantineSpec
from repro.exp import Experiment

from .common import claim_main, run_exp


def _alie(nb: int) -> ByzantineSpec:
    return ByzantineSpec(worker_attack="alie", n_byz_workers=nb,
                         equivocate=True)


def run(quick: bool = True):
    steps = 120 if quick else 500
    base = Experiment(name="byz_workers", n_workers=13, f_workers=4,
                      steps=steps, batch=25)
    out = {"by_fw": {}, "by_batch": {}}
    # 6a: sweep actual Byzantine workers at fixed declared f_w = 4 (max for 13)
    byz_counts = [0, 2, 4] if quick else [0, 1, 2, 3, 4]
    for nb in byz_counts:
        _, final, _ = run_exp(base.replace(byz=_alie(nb)))
        out["by_fw"][nb] = final["acc"]
    # 6b: max ratio, sweep batch size
    for b in ([16, 64] if quick else [16, 32, 64, 128, 256]):
        _, final, _ = run_exp(base.replace(byz=_alie(4), batch=b))
        out["by_batch"][b] = final["acc"]
    return out


def summarize(res: dict) -> str:
    lines = ["[ALIE workers / Fig.6] final accuracy:"]
    lines.append("  vs n_byz (f_w=4/13): " + "  ".join(
        f"{k}->{v:.3f}" for k, v in res["by_fw"].items()))
    lines.append("  vs batch (n_byz=4):  " + "  ".join(
        f"b{k}->{v:.3f}" for k, v in res["by_batch"].items()))
    accs = list(res["by_batch"].values())
    trend = "PASS (larger batch helps)" if accs[-1] >= accs[0] - 0.02 else "CHECK"
    lines.append(f"  paper: bigger batch => more robust — {trend}")
    return "\n".join(lines)


if __name__ == "__main__":
    claim_main(run, summarize, description=__doc__)
