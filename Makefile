# Convenience lanes around the tier-1 verify command (see ROADMAP.md).
PY      := python
ENV     := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: all tier1 test fast lint lint-fast netsim agg-bench bench examples perf exp serve serve-bench elastic-bench

# default: static analysis first (seconds to fail on a repo-invariant
# violation), then the full tier-1 gate
all: lint tier1

# alias so `make test` means the tier-1 gate
test: tier1

# static analysis, both layers: AST repo-invariant lint + compiled-artifact
# audit on a forced 8-device CPU topology. Exits 1 on any violation that is
# neither inline-suppressed nor in results/analyze/baseline.json (committed
# empty — the repo lints clean).
lint:
	$(ENV) $(PY) -m repro.analyze --hlo --json results/analyze/report.json

# layer 1 only, taint scoped to changed-file SCC (jax-free) — pre-commit speed
lint-fast:
	$(ENV) $(PY) -m repro.analyze --fast

# full tier-1 gate: everything, stop at first failure
tier1:
	$(ENV) $(PY) -m pytest -x -q

# fast lane: skip the slow subprocess end-to-end drivers
fast:
	$(ENV) $(PY) -m pytest -q -m "not slow"

# netsim subsystem only (tests + benchmark)
netsim:
	$(ENV) $(PY) -m pytest -q tests/test_netsim.py
	$(ENV) $(PY) -m benchmarks.run --only netsim

# aggregator backend timings (jnp vs Pallas per registry rule)
agg-bench:
	$(ENV) $(PY) -m benchmarks.run --only agg

# perf lane: fused-engine throughput benchmark (incl. the protocol_naive /
# protocol_sharded rows on the acceptance config), gated (>25% fused
# steps/sec regression fails) against the committed perf-trajectory baseline
# (which a run never overwrites; refresh it deliberately with
# `python -m benchmarks.exp_throughput --seed-baseline`)
perf:
	$(ENV) $(PY) -m benchmarks.run --only throughput --compare BENCH_throughput.json

# serve subsystem: unit/property tests (incl. the forced-8-device subprocess
# lane) + the quorum-read overhead / Byzantine-correctness benchmark
serve:
	$(ENV) $(PY) -m pytest -q tests/test_serve.py tests/test_serve_distributed.py

serve-bench:
	$(ENV) $(PY) -m benchmarks.run --only serve

# elastic membership: protocol-vs-elastic equivalence (bit-identity asserted),
# churn overhead, and recovery-time-to-parity after a G 5->4->5 cycle
elastic-bench:
	$(ENV) $(PY) -m benchmarks.run --only elastic

# experiment-API smoke lane: one spec through all four runners (stepwise
# oracle, fused engine, netsim trace, distributed protocol on a 1-device
# mesh), results + provenance under results/benchmarks/exp_smoke_*.json
exp:
	$(ENV) $(PY) -m benchmarks.run --exp smoke --runners stepwise,fused,netsim,protocol

bench:
	$(ENV) $(PY) -m benchmarks.run

examples:
	$(ENV) $(PY) examples/netsim_scenarios.py --steps 20
