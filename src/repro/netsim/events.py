"""Seeded discrete-event core: virtual clock + event heap.

Determinism contract: given the same seed and the same schedule of calls, a
simulation is bit-identical. Two ingredients enforce this:

  * ties in the event heap break on a monotonically increasing sequence
    number (scheduling order), never on callback identity;
  * all randomness flows from :class:`EventLoop` streams created by
    :meth:`EventLoop.stream`, which derive child PRNGs from (seed, label) —
    independent of scheduling interleavings.
"""
from __future__ import annotations

import heapq
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


@dataclass(order=True)
class Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    args: tuple = field(compare=False, default=())


class EventLoop:
    """Minimal event engine with a float virtual clock (milliseconds)."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.now = 0.0
        self._heap: list[Event] = []
        self._seq = 0
        self.processed = 0

    # -- scheduling --------------------------------------------------------
    def at(self, time: float, fn: Callable, *args: Any) -> None:
        """Schedule ``fn(*args)`` at absolute virtual ``time`` (clamped so the
        clock never moves backwards)."""
        heapq.heappush(self._heap,
                       Event(max(float(time), self.now), self._seq, fn, args))
        self._seq += 1

    def after(self, delay: float, fn: Callable, *args: Any) -> None:
        self.at(self.now + max(float(delay), 0.0), fn, *args)

    # -- execution ---------------------------------------------------------
    def run(self, until: float | None = None, max_events: int = 5_000_000) -> int:
        """Drain the heap (or run up to virtual time ``until``). Returns the
        number of events processed in this call."""
        n0 = self.processed
        while self._heap:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            ev.fn(*ev.args)
            self.processed += 1
            if self.processed - n0 > max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")
        return self.processed - n0

    @property
    def pending(self) -> int:
        return len(self._heap)

    # -- deterministic child PRNG streams ----------------------------------
    def stream(self, label: str) -> np.random.Generator:
        """Independent generator derived from (loop seed, label)."""
        return np.random.default_rng(
            [self.seed & 0xFFFFFFFF, zlib.crc32(label.encode())])
