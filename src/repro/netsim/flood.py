"""Request floods against a replicated quorum-read service.

The training-side netsim replays the scatter/gather schedule; this module
models the *serving* side (``repro.serve``): ``n_clients`` independent
clients fire Poisson request streams at R replicas, every request fans out
to all replicas (a quorum read), each replica serves its own FIFO queue,
and the client's read completes at the (R-f)-th reply — replies landing
after the quorum closed are *late* (counted, not consumed), exactly the
ledger convention of the training simulator.

The hot path is vectorized end-to-end: one Poisson draw for all arrival
counts, one latency draw per (request, replica) matrix, and a per-replica
Lindley recursion computed with ``np.maximum.accumulate`` (no Python loop
over requests) — floods of 10^5+ requests take well under a second.

Accounting lands in the standard :class:`~repro.netsim.accounting
.MessageLedger` with nodes ``0..R-1`` the replicas ("servers") and
``R..R+n_clients-1`` the clients: ``push`` = requests up, ``pull`` =
replies down.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .accounting import MessageLedger
from .events import EventLoop
from .latency import (ComputeTime, FixedLatency, LatencyModel,
                      LognormalLatency, ParetoLatency)


@dataclass(frozen=True)
class RequestFloodScenario:
    """Shape + load + timing of one flood (deliberately *not* a training
    :class:`~repro.netsim.scenarios.Scenario` — serving has no Table-1
    worker/server preconditions, only the read quorum n >= 2f+1)."""
    name: str = "request_flood"
    n_clients: int = 1000
    rate: float = 2.0                 # requests/sec per client
    duration_ms: float = 1000.0
    n_replicas: int = 4
    f: int = 1
    req_bytes: int = 256              # prompt ids
    reply_bytes: int = 2048           # logits / tokens back
    latency: LatencyModel = field(default_factory=LognormalLatency)
    # default keeps the fleet stable (~70% utilization at 1000 x 2/s: every
    # request hits every replica, so per-replica load = total rate x service)
    service: ComputeTime = field(default_factory=lambda: ComputeTime(0.35, 0.2))
    slow_replicas: tuple[int, ...] = ()   # degraded replicas...
    slow_factor: float = 1.0              # ...serve this much slower
    deadline_ms: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.n_replicas < 2 * self.f + 1:
            raise ValueError(f"quorum reads need n >= 2f+1 replicas "
                             f"(got n={self.n_replicas}, f={self.f})")
        if any(not 0 <= i < self.n_replicas for i in self.slow_replicas):
            raise ValueError(f"slow_replicas out of range: "
                             f"{self.slow_replicas}")


def _sample_many(model: LatencyModel, rng: np.random.Generator,
                 n: int) -> np.ndarray:
    """Vectorized n-sample for the link-independent latency models; generic
    models fall back to a per-message loop (same distributions either way)."""
    if isinstance(model, FixedLatency):
        return np.full(n, model.ms)
    if isinstance(model, LognormalLatency):
        return model.median_ms * np.exp(model.sigma * rng.standard_normal(n))
    if isinstance(model, ParetoLatency):
        return model.floor_ms * (1.0 + rng.pareto(model.alpha, n))
    return np.array([model.sample(rng, 0, 1) for _ in range(n)])


def _service_many(model: ComputeTime, rng: np.random.Generator,
                  n: int) -> np.ndarray:
    return model.mean_ms * np.exp(model.sigma * rng.standard_normal(n)
                                  - 0.5 * model.sigma ** 2)


def _lindley(arrive: np.ndarray, svc: np.ndarray
             ) -> tuple[np.ndarray, np.ndarray]:
    """FIFO single-server queue: finish[i] = max(arrive[i], finish[i-1]) +
    svc[i], vectorized as C[i] + max_{j<=i}(arrive[j] - C[j-1]) with C the
    service-time cumsum. Returns (start, finish) in arrival order."""
    C = np.cumsum(svc)
    start = np.maximum.accumulate(arrive - (C - svc))
    return start + (C - svc), start + C


@dataclass
class FloodTrace:
    """Result of one flood: per-request quorum latencies + the ledger."""
    scenario: RequestFloodScenario
    n_requests: int
    quorum_ms: np.ndarray             # [n_req] client-side read latency
    replica_busy_ms: np.ndarray       # [R] total service time per replica
    replica_served: np.ndarray        # [R] requests served per replica
    replica_late: np.ndarray          # [R] replies past the quorum close
    max_queue_ms: np.ndarray          # [R] worst queueing delay per replica
    deadline_missed: int
    ledger: MessageLedger
    wall_ms: float

    def percentiles(self, qs=(50, 95, 99)) -> dict:
        if self.n_requests == 0:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(self.quorum_ms, q)) for q in qs}

    def summary(self) -> str:
        sc = self.scenario
        pc = self.percentiles()
        util = self.replica_busy_ms / max(self.wall_ms, 1e-9)
        lines = [
            f"[flood] {sc.name}: {sc.n_clients} clients x {sc.rate}/s over "
            f"{sc.duration_ms:.0f}ms -> {self.n_requests} requests, "
            f"R={sc.n_replicas} f={sc.f}",
            f"  quorum latency ms: p50 {pc['p50']:.2f}  p95 {pc['p95']:.2f}  "
            f"p99 {pc['p99']:.2f}"
            + (f"  deadline>{sc.deadline_ms:.0f}ms missed: "
               f"{self.deadline_missed}" if sc.deadline_ms else ""),
        ]
        for r in range(sc.n_replicas):
            tag = " (slow)" if r in sc.slow_replicas else ""
            lines.append(
                f"  replica {r}{tag}: served {int(self.replica_served[r]):6d}"
                f"  busy {self.replica_busy_ms[r]:9.1f}ms"
                f" (util {util[r]:5.1%})"
                f"  late {int(self.replica_late[r]):6d}"
                f"  max queue {self.max_queue_ms[r]:8.2f}ms")
        lines.append("  " + self.ledger.summary().replace("\n", "\n  "))
        return "\n".join(lines)


def run_flood(sc: RequestFloodScenario) -> FloodTrace:
    """Simulate one flood (see module docstring for the model)."""
    loop = EventLoop(sc.seed)     # deterministic (seed, label) streams
    R, nC = sc.n_replicas, sc.n_clients
    ledger = MessageLedger(R + nC, n_servers=R)

    # -- arrivals: one Poisson draw across all clients ---------------------
    rng_arr = loop.stream("flood/arrivals")
    lam = sc.rate * sc.duration_ms / 1e3
    counts = rng_arr.poisson(lam, nC)                      # [nC]
    n_req = int(counts.sum())
    client = np.repeat(np.arange(nC), counts)              # [n_req]
    t_arr = rng_arr.uniform(0.0, sc.duration_ms, n_req)
    order = np.argsort(t_arr, kind="stable")
    client, t_arr = client[order], t_arr[order]

    if n_req == 0:
        return FloodTrace(sc, 0, np.zeros(0), np.zeros(R), np.zeros(R),
                          np.zeros(R), np.zeros(R), 0, ledger, 0.0)

    # -- fan-out: every request hits every replica -------------------------
    rng_net = loop.stream("flood/links")
    up = _sample_many(sc.latency, rng_net, n_req * R).reshape(n_req, R)
    t_at_replica = t_arr[:, None] + up                     # [n_req, R]
    np.add.at(ledger.c["push"]["tx_msgs"], R + client, R)
    np.add.at(ledger.c["push"]["tx_bytes"], R + client, R * sc.req_bytes)
    ledger.c["push"]["rx_msgs"][:R] += n_req
    ledger.c["push"]["rx_bytes"][:R] += n_req * sc.req_bytes

    # -- per-replica FIFO queues (Lindley, vectorized) ---------------------
    rng_svc = loop.stream("flood/service")
    t_reply = np.empty((n_req, R))
    busy = np.zeros(R)
    served = np.zeros(R, np.int64)
    max_q = np.zeros(R)
    for r in range(R):
        svc = _service_many(sc.service, rng_svc, n_req)
        if r in sc.slow_replicas:
            svc = svc * sc.slow_factor
        idx = np.argsort(t_at_replica[:, r], kind="stable")
        start, finish = _lindley(t_at_replica[idx, r], svc[idx])
        max_q[r] = float(np.max(start - t_at_replica[idx, r]))
        down = _sample_many(sc.latency, rng_net, n_req)
        t_reply[idx, r] = finish + down
        busy[r] = float(svc.sum())
        served[r] = n_req

    # -- quorum close: the (R-f)-th reply completes the read ---------------
    need = R - sc.f
    t_quorum = np.partition(t_reply, need - 1, axis=1)[:, need - 1]
    quorum_ms = t_quorum - t_arr
    late = t_reply > t_quorum[:, None]                     # [n_req, R]

    ledger.c["pull"]["tx_msgs"][:R] += n_req
    ledger.c["pull"]["tx_bytes"][:R] += n_req * sc.reply_bytes
    on_time = ~late
    np.add.at(ledger.c["pull"]["rx_msgs"], R + client, on_time.sum(1))
    np.add.at(ledger.c["pull"]["rx_bytes"], R + client,
              on_time.sum(1) * sc.reply_bytes)
    np.add.at(ledger.c["pull"]["late_msgs"], R + client, late.sum(1))
    np.add.at(ledger.c["pull"]["late_bytes"], R + client,
              late.sum(1) * sc.reply_bytes)

    missed = int((quorum_ms > sc.deadline_ms).sum()) if sc.deadline_ms else 0
    wall = float(t_reply.max())
    return FloodTrace(sc, n_req, quorum_ms, busy, served,
                      late.sum(0).astype(np.int64), max_q, missed, ledger,
                      wall)
