"""repro.netsim — event-driven cluster/network simulation for ByzSGD.

Replaces the uniform q-of-n abstraction of Assumption 7 with a discrete-event
simulation of the actual scatter/gather message schedule: per-link latency
models, fault injectors (crash/recovery, partitions, drops/duplication, slow
churn), and per-node message/byte accounting. A run produces a
:class:`~repro.netsim.cluster.NetsimTrace` whose *realized* per-step quorums
and staleness tensors plug into the protocol simulator through
``repro.core.quorum.TraceDelivery``.

Quick start::

    from repro.netsim import scenarios, cluster
    sc = scenarios.build("heavy_tail_stragglers", steps=20)
    trace = cluster.ClusterSim(sc).run()
    print(trace.ledger.summary(sc))
    delivery = trace.to_delivery()      # feed to ByzSGDSimulator(delivery=...)
"""
from . import accounting, cluster, events, faults, flood, latency, scenarios  # noqa: F401
from .cluster import ClusterSim, NetsimTrace  # noqa: F401
from .flood import FloodTrace, RequestFloodScenario, run_flood  # noqa: F401
from .scenarios import SCENARIOS, Scenario  # noqa: F401
