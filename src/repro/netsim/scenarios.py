"""Declarative scenario library for the netsim engine.

A :class:`Scenario` bundles cluster shape (same resilience preconditions as
``ByzSGDConfig``), the latency/compute models, a fault plan, and payload
sizes. The registry maps names to factories; every factory accepts keyword
overrides (``steps=…``, ``seed=…``, ``model_d=…``) forwarded to the dataclass
so tests and benchmarks can shrink or scale runs::

    sc = scenarios.build("crash_storm", steps=20, seed=3)
    trace = ClusterSim(sc).run()

The *experiment-level* entry point is ``repro.exp``: its ``netsim/<name>``
presets name these scenarios and train over the realized trace
(``exp.run("netsim/crash_storm")``); ``Experiment.to_scenario()`` lowers to
this registry. The old module-level ``get()`` survives as a deprecation shim
over :func:`build`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from dataclasses import dataclass, field

import repro.agg as agg
from repro.core.quorum import validate_counts

from .faults import (CrashPlan, CrashWindow, FaultPlan, LossyLink,
                     PartitionPlan, PartitionWindow, SlowChurn)
from .latency import (ComputeTime, LatencyModel, LognormalLatency,
                      ParetoLatency, TopologyLatency)


@dataclass(frozen=True)
class Scenario:
    name: str = "baseline_uniform"
    # cluster shape (paper Table 1 preconditions enforced in __post_init__)
    n_workers: int = 9
    f_workers: int = 2
    n_servers: int = 5
    f_servers: int = 1
    q_workers: int | None = None
    q_servers: int | None = None
    T: int = 5
    steps: int = 30
    # message schedule: "async" waits on q-of-n quorums; "sync" (§5) pairs
    # each worker with ONE round-robin server per step — one gradient up, one
    # model reply down (server-side round-robin replies; neither direction is
    # a broadcast) — fewer bytes on the wire, the paper's throughput argument
    variant: str = "async"
    # payload: model dimension in scalars (d) and bytes per scalar
    model_d: int = 79_510          # paper's MNIST CNN
    dtype_bytes: int = 4
    # timing
    latency: LatencyModel = field(default_factory=LognormalLatency)
    compute: ComputeTime = field(default_factory=ComputeTime)
    update_ms: float = 0.5
    bandwidth_gbps: float | None = None
    # faults + reproducibility
    faults: FaultPlan = field(default_factory=FaultPlan)
    seed: int = 0
    max_events: int = 5_000_000
    # aggregation rule the servers apply to worker gradients when the trace
    # drives the protocol simulator (any registry name with pytree support;
    # per-role rules — e.g. MDA-at-servers, arXiv:1911.07537 — ride on the
    # simulator's pull_gar/gather_gar knobs)
    gar: str = "mda"
    # Byzantine roles (consumed by the protocol simulator, not the network:
    # netsim only makes these nodes slow/faulty; attacks are injected by
    # repro.core.attacks when the trace drives ByzSGDSimulator)
    worker_attack: str | None = None
    server_attack: str | None = None
    n_byz_workers: int = 0
    n_byz_servers: int = 0

    def __post_init__(self):
        if self.variant not in ("async", "sync"):
            raise ValueError(f"unknown variant {self.variant!r}")
        qw = self.q_workers or (self.n_workers - self.f_workers)
        qs = self.q_servers or max(self.n_servers - self.f_servers,
                                   2 * self.f_servers + 2)
        object.__setattr__(self, "q_workers", qw)
        object.__setattr__(self, "q_servers", qs)
        validate_counts(self.n_workers, self.f_workers, self.n_servers,
                        self.f_servers, qw, qs,
                        synchronous=(self.variant == "sync"))
        agg.get(self.gar).validate(qw, self.f_workers)

    # effective per-step quorum sizes the cluster waits on (the DMC gather
    # keeps q_servers in both variants)
    @property
    def pull_need(self) -> int:
        return 1 if self.variant == "sync" else self.q_servers

    @property
    def push_need(self) -> int:
        """Push-trace row width: in the sync schedule a server receives only
        the gradients of the workers whose round-robin exchange lands on it
        this step (<= ceil(n_w / n_ps)), not all n_w."""
        if self.variant == "sync":
            return -(-self.n_workers // self.n_servers)  # ceil
        return self.q_workers

    def push_scheduled(self, s: int, k: int) -> int:
        """How many gradients server ``s`` waits for at step ``k``: the sync
        schedule assigns worker w to server (w + k) % n_ps, so s's senders are
        the workers w ≡ (s - k) (mod n_ps); async waits on the q_w quorum."""
        if self.variant != "sync":
            return self.q_workers
        r = (s - k) % self.n_servers
        if r >= self.n_workers:
            return 0
        return (self.n_workers - 1 - r) // self.n_servers + 1

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# factories — each returns a Scenario; kwargs override any dataclass field.

def baseline_uniform(**kw) -> Scenario:
    """Well-behaved cluster: tight lognormal links, no faults. The analytic
    communication model of exp_messages should hold exactly."""
    kw.setdefault("latency", LognormalLatency(1.0, 0.05))
    return Scenario(name="baseline_uniform", **kw)


def heavy_tail_stragglers(**kw) -> Scenario:
    """Pareto link tail + a rotating set of persistently slow workers: the
    regime where realized quorums are *biased* toward fast nodes, unlike
    Assumption 7's uniform sampling."""
    n_w = kw.pop("n_workers", 9)
    kw.setdefault("latency", ParetoLatency(0.5, alpha=1.6))
    kw.setdefault("faults", FaultPlan(
        churn=SlowChurn(n_nodes=5 + n_w, n_slow=2, factor=12.0,
                        period_ms=40.0)))
    return Scenario(name="heavy_tail_stragglers", n_workers=n_w, **kw)


def partitioned_dmc(**kw) -> Scenario:
    """Two-zone topology; mid-run a partition isolates a minority of servers,
    starving their DMC gather quorums (visible as shortfalls + diameter
    blow-up on the isolated side)."""
    n_ps = kw.pop("n_servers", 5)
    n_w = kw.pop("n_workers", 9)
    zone_of = tuple(i % 2 for i in range(n_ps + n_w))
    kw.setdefault("latency", TopologyLatency(
        zone_of=zone_of, zone_ms=((0.5, 2.5), (2.5, 0.5)),
        jitter=LognormalLatency(1.0, 0.1)))
    minority = tuple(s for s in range(n_ps) if s % 2 == 1)
    majority = tuple(i for i in range(n_ps + n_w) if i not in minority)
    kw.setdefault("faults", FaultPlan(partitions=PartitionPlan((
        PartitionWindow(t0=80.0, t1=220.0, groups=(majority, minority)),))))
    return Scenario(name="partitioned_dmc", n_servers=n_ps, n_workers=n_w,
                    **kw)


def crash_storm(**kw) -> Scenario:
    """Staggered fail-stop crashes with recovery, never exceeding the declared
    f bounds simultaneously: liveness holds but quorums shift and late/dropped
    traffic spikes."""
    n_ps = kw.pop("n_servers", 5)
    n_w = kw.pop("n_workers", 9)
    windows = [CrashWindow(node=0, t_down=40.0, t_up=120.0),          # server
               CrashWindow(node=n_ps + 1, t_down=60.0, t_up=160.0),   # worker
               CrashWindow(node=n_ps + 4, t_down=150.0, t_up=260.0),
               CrashWindow(node=2, t_down=200.0, t_up=280.0)]         # server
    kw.setdefault("faults", FaultPlan(
        crashes=CrashPlan(tuple(windows)),
        lossy=LossyLink(p_drop=0.01, p_dup=0.005)))
    kw.setdefault("latency", LognormalLatency(1.0, 0.3))
    return Scenario(name="crash_storm", n_servers=n_ps, n_workers=n_w, **kw)


def membership_churn(**kw) -> Scenario:
    """One co-located group (server g + worker n_ps+g) fail-stops mid-run and
    recovers — the elastic-training scenario. The elastic runner lowers the
    *realized* crash windows into a MembershipPlan
    (``repro.core.membership.plan_from_trace``): the group leaves before the
    first step finishing after ``t_down`` and stays out for the outage
    duration converted at the honest step rate, so G shrinks 5 -> 4 -> 5.
    Defaults are calibrated to the healthy cadence (~8.5 virtual ms/step
    under the default latency): down around step 8, back around step 16 of a
    24-step run. Shape defaults keep the surviving quorums exactly
    satisfiable while the group is down (4-of-5 up, q = 4)."""
    n_ps = kw.pop("n_servers", 5)
    n_w = kw.pop("n_workers", 5)
    group = kw.pop("churn_group", n_ps - 1)
    t_down = kw.pop("t_down", 66.0)
    t_up = kw.pop("t_up", 134.0)
    kw.setdefault("f_workers", 1)
    kw.setdefault("T", 5)
    windows = (CrashWindow(node=group, t_down=t_down, t_up=t_up),
               CrashWindow(node=n_ps + group, t_down=t_down, t_up=t_up))
    kw.setdefault("faults", FaultPlan(crashes=CrashPlan(windows)))
    kw.setdefault("latency", LognormalLatency(1.0, 0.1))
    return Scenario(name="membership_churn", n_servers=n_ps, n_workers=n_w,
                    **kw)


def byzantine_plus_slow(**kw) -> Scenario:
    """The compound adversary: f_w Byzantine workers that are ALSO slow (their
    messages arrive last, maximizing their staleness leverage) — netsim makes
    them slow, the simulator's attack injection makes them malicious."""
    n_ps = kw.pop("n_servers", 5)
    n_w = kw.pop("n_workers", 9)
    f_w = kw.pop("f_workers", 2)
    byz_nodes = tuple(n_ps + n_w - 1 - i for i in range(f_w))  # last workers
    kw.setdefault("faults", FaultPlan(
        churn=SlowChurn(n_nodes=n_ps + n_w, n_slow=f_w, factor=8.0,
                        only=byz_nodes)))
    kw.setdefault("latency", LognormalLatency(1.0, 0.25))
    kw.setdefault("worker_attack", "alie")
    kw.setdefault("n_byz_workers", f_w)
    return Scenario(name="byzantine_plus_slow", n_servers=n_ps, n_workers=n_w,
                    f_workers=f_w, **kw)


def request_flood(n_clients: int = 1000, rate: float = 2.0, **kw):
    """Serving-side flood against a replicated quorum-read service (see
    :mod:`repro.netsim.flood`). Returns a :class:`~repro.netsim.flood
    .RequestFloodScenario`, NOT a training :class:`Scenario` — serving has no
    Table-1 worker/server preconditions, so it lives outside ``SCENARIOS``
    (run with ``flood.run_flood``, not ``ClusterSim``)."""
    from .flood import RequestFloodScenario
    return RequestFloodScenario(n_clients=n_clients, rate=rate, **kw)


SCENARIOS = {
    "baseline_uniform": baseline_uniform,
    "heavy_tail_stragglers": heavy_tail_stragglers,
    "partitioned_dmc": partitioned_dmc,
    "crash_storm": crash_storm,
    "byzantine_plus_slow": byzantine_plus_slow,
    "membership_churn": membership_churn,
}


def build(name: str, **kw) -> Scenario:
    """Canonical scenario constructor: factory by name, kwargs override any
    dataclass field."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}") from None
    return factory(**kw)


def get(name: str, **kw) -> Scenario:
    """Deprecated alias of :func:`build`.

    Scenario presets are subsumed by the experiment registry: prefer
    ``repro.exp.get("netsim/<name>")`` (a full trainable spec) or
    :func:`build` for the bare Scenario.
    """
    warnings.warn(
        "repro.netsim.scenarios.get() is deprecated; use "
        "scenarios.build(name, ...) or the repro.exp presets "
        "(exp.get('netsim/<name>'))", DeprecationWarning, stacklevel=2)
    return build(name, **kw)


# --------------------------------------------------------------------------
# measured compute times (ROADMAP: feed the engine's honest steps/sec into
# the wall-clock model instead of the guessed ComputeTime default)


def measured_compute(model: str = "mlp_h64", variant: str = "async",
                     path: str | None = None, sigma: float = 0.1
                     ) -> ComputeTime:
    """A :class:`ComputeTime` calibrated from the committed throughput
    baseline (``BENCH_throughput.json``, the fused-engine lane).

    ``1000 / steps_per_s`` of the ``{variant}/{model}`` lane becomes the mean
    per-step compute cost, so netsim's sync-vs-async end-to-end wall-clock
    (§5) runs off *measured* numbers rather than the default guess. The
    measured time includes the server update, so scenarios using it should
    keep ``update_ms`` small to avoid double counting.
    """
    if path is None:
        here = os.path.dirname(os.path.abspath(__file__))
        candidates = ["BENCH_throughput.json",
                      os.path.join(here, *[os.pardir] * 3,
                                   "BENCH_throughput.json")]
        path = next((p for p in candidates if os.path.exists(p)), None)
        if path is None:
            raise FileNotFoundError(
                "BENCH_throughput.json not found (run `python -m "
                "benchmarks.exp_throughput --seed-baseline` or pass path=)")
    with open(path) as fh:
        bench = json.load(fh)
    lane = f"{variant}/{model}"
    try:
        sps = float(bench["lanes"][lane]["fused"]["steps_per_s"])
    except KeyError:
        raise KeyError(f"lane {lane!r} not in {path}; have "
                       f"{sorted(bench.get('lanes', {}))}") from None
    return ComputeTime(mean_ms=1000.0 / sps, sigma=sigma)
