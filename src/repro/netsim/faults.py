"""Fault injectors: crash/recovery, partitions, loss/duplication, slow churn.

All injectors are declarative (frozen dataclasses of time windows and rates)
and are consulted by the cluster engine at send/delivery time. They compose
through :class:`FaultPlan`. Times are virtual milliseconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

INF = float("inf")


@dataclass(frozen=True)
class CrashWindow:
    node: int
    t_down: float
    t_up: float = INF  # INF = crash without recovery


@dataclass(frozen=True)
class CrashPlan:
    """Fail-stop crash/recovery schedule. A down node neither sends, computes,
    nor delivers; in-flight messages to it are dropped at arrival."""
    windows: tuple[CrashWindow, ...] = ()

    def is_up(self, node: int, t: float) -> bool:
        return all(not (w.node == node and w.t_down <= t < w.t_up)
                   for w in self.windows)

    def next_up(self, node: int, t: float) -> float:
        """Earliest time >= t at which ``node`` is up (may be inf)."""
        while True:
            for w in self.windows:
                if w.node == node and w.t_down <= t < w.t_up:
                    t = w.t_up
                    break
            else:
                return t


@dataclass(frozen=True)
class PartitionWindow:
    t0: float
    t1: float
    groups: tuple[tuple[int, ...], ...]  # disjoint node groups; cross-group cut

    def blocks(self, src: int, dst: int, t: float) -> bool:
        if not (self.t0 <= t < self.t1):
            return False
        gs = gd = -1
        for gi, g in enumerate(self.groups):
            if src in g:
                gs = gi
            if dst in g:
                gd = gi
        # nodes not named in any group communicate freely
        return gs >= 0 and gd >= 0 and gs != gd


@dataclass(frozen=True)
class PartitionPlan:
    windows: tuple[PartitionWindow, ...] = ()

    def blocks(self, src: int, dst: int, t: float) -> bool:
        return any(w.blocks(src, dst, t) for w in self.windows)


@dataclass(frozen=True)
class LossyLink:
    """IID message drop and duplication. A duplicated message is re-delivered
    once more after ``dup_extra_ms`` additional delay."""
    p_drop: float = 0.0
    p_dup: float = 0.0
    dup_extra_ms: float = 1.0

    def drops(self, rng: np.random.Generator) -> bool:
        return self.p_drop > 0 and rng.random() < self.p_drop

    def duplicates(self, rng: np.random.Generator) -> bool:
        return self.p_dup > 0 and rng.random() < self.p_dup


@dataclass(frozen=True)
class SlowChurn:
    """Rotating set of slow nodes: every ``period_ms`` the window of
    ``n_slow`` consecutive node ids (mod ``n_nodes``) advances by ``n_slow``.
    A slow *sender or receiver* multiplies message latency by ``factor`` —
    persistent per-node slowness, unlike BimodalStraggler's per-message tail."""
    n_nodes: int = 0
    n_slow: int = 0
    factor: float = 10.0
    period_ms: float = 50.0
    only: tuple[int, ...] = ()  # restrict churn to these node ids (e.g. Byz)

    def is_slow(self, node: int, t: float) -> bool:
        if self.n_slow <= 0 or self.n_nodes <= 0:
            return False
        if self.only:
            return node in self.only
        r = int(t // self.period_ms)
        lo = (r * self.n_slow) % self.n_nodes
        off = (node - lo) % self.n_nodes
        return off < self.n_slow

    def scale(self, src: int, dst: int, t: float) -> float:
        return self.factor if (self.is_slow(src, t) or self.is_slow(dst, t)) \
            else 1.0


@dataclass(frozen=True)
class FaultPlan:
    crashes: CrashPlan = field(default_factory=CrashPlan)
    partitions: PartitionPlan = field(default_factory=PartitionPlan)
    lossy: LossyLink = field(default_factory=LossyLink)
    churn: SlowChurn = field(default_factory=SlowChurn)

    def is_up(self, node: int, t: float) -> bool:
        return self.crashes.is_up(node, t)

    def next_up(self, node: int, t: float) -> float:
        return self.crashes.next_up(node, t)

    def blocked(self, src: int, dst: int, t: float) -> bool:
        return self.partitions.blocks(src, dst, t)

    def latency_scale(self, src: int, dst: int, t: float) -> float:
        return self.churn.scale(src, dst, t)
