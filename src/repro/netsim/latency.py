"""Per-link latency models (milliseconds) and compute-time models.

A :class:`LatencyModel` maps (rng, src, dst) -> one-way network delay for a
single message. Models are frozen dataclasses so scenarios stay hashable and
printable; all randomness comes from the generator passed in (owned by the
event loop), keeping runs bit-deterministic per seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class LatencyModel(Protocol):
    def sample(self, rng: np.random.Generator, src: int, dst: int) -> float:
        ...


@dataclass(frozen=True)
class FixedLatency:
    """Constant one-way delay — the degenerate 'uniform cluster' link."""
    ms: float = 1.0

    def sample(self, rng, src, dst) -> float:
        del rng, src, dst
        return self.ms


@dataclass(frozen=True)
class LognormalLatency:
    """Median ``median_ms`` with multiplicative jitter exp(N(0, sigma)) —
    the standard well-behaved datacenter link."""
    median_ms: float = 1.0
    sigma: float = 0.25

    def sample(self, rng, src, dst) -> float:
        del src, dst
        return float(self.median_ms * np.exp(self.sigma * rng.standard_normal()))


@dataclass(frozen=True)
class ParetoLatency:
    """Heavy-tailed delay floor_ms * (1 + Pareto(alpha)): most messages are
    fast, a power-law tail models stragglers/retransmits. alpha <= 2 gives
    infinite variance — the adversarial regime for quorum systems."""
    floor_ms: float = 0.5
    alpha: float = 1.8

    def sample(self, rng, src, dst) -> float:
        del src, dst
        return float(self.floor_ms * (1.0 + rng.pareto(self.alpha)))


@dataclass(frozen=True)
class BimodalStraggler:
    """With probability ``p_slow`` a message takes ``slow_factor`` times the
    base delay (GC pause / queueing spike), else the base delay alone."""
    base: LatencyModel = LognormalLatency()
    slow_factor: float = 20.0
    p_slow: float = 0.05

    def sample(self, rng, src, dst) -> float:
        d = self.base.sample(rng, src, dst)
        if rng.random() < self.p_slow:
            d *= self.slow_factor
        return d


@dataclass(frozen=True)
class TopologyLatency:
    """Rack/datacenter topology: nodes live in zones; a zone-pair RTT matrix
    sets the base delay and ``jitter`` multiplies it. ``zone_of[i]`` is node
    i's zone; nodes beyond the tuple wrap around (i % len)."""
    zone_of: tuple[int, ...]
    zone_ms: tuple[tuple[float, ...], ...]  # [n_zones, n_zones] one-way base
    jitter: LatencyModel = LognormalLatency(1.0, 0.1)

    def sample(self, rng, src, dst) -> float:
        zs = self.zone_of[src % len(self.zone_of)]
        zd = self.zone_of[dst % len(self.zone_of)]
        return self.zone_ms[zs][zd] * self.jitter.sample(rng, src, dst)


@dataclass(frozen=True)
class ComputeTime:
    """Lognormal task duration (gradient computation, server update)."""
    mean_ms: float = 5.0
    sigma: float = 0.2

    def sample(self, rng: np.random.Generator) -> float:
        return float(self.mean_ms * np.exp(
            self.sigma * rng.standard_normal() - 0.5 * self.sigma ** 2))


def transfer_ms(nbytes: int, bandwidth_gbps: float | None) -> float:
    """Serialization delay of a payload on a link, 0 if bandwidth unmodelled."""
    if not bandwidth_gbps:
        return 0.0
    return nbytes * 8.0 / (bandwidth_gbps * 1e9) * 1e3
