"""Event-driven ByzSGD cluster: servers/workers exchanging the paper's
scatter/gather schedule over a simulated network.

Node processes (state machines driven by the event loop):

  * server s entering scatter step k broadcasts its model (tagged k) to every
    worker, then waits for q_w gradients tagged k, applies the GAR update
    (``update_ms``), and — every T steps — runs a DMC gather round with the
    other servers (q_ps models including its own) before entering k+1;
  * worker w at step k waits for q_ps models tagged k, aggregates, computes a
    gradient (``compute`` time model), pushes it (tagged k) to every server
    and enters k+1.

Messages carry their send time; realized per-step quorums are the first q
distinct senders in *arrival order* and per-message staleness is
arrival - send (virtual ms). There are no retransmits: losses, partitions and
crashes surface as late quorums or — when a quorum can never fill — as
*forced* closes (padded with already-delivered senders, counted in
``trace.shortfalls``) so the emitted trace is always complete and can drive
the jitted protocol simulator.

Node ids: servers are 0..n_ps-1, workers n_ps..n_ps+n_w-1 (the ledger's
convention).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .accounting import MessageLedger
from .events import EventLoop
from .latency import transfer_ms


@dataclass
class NetsimTrace:
    """Realized delivery schedule + staleness + accounting of one run."""
    scenario: "Scenario"  # noqa: F821 - repro.netsim.scenarios.Scenario
    pull_idx: np.ndarray     # [steps, n_w, q_ps] int32, server ids
    pull_stale: np.ndarray   # [steps, n_w, q_ps] float32 ms
    push_idx: np.ndarray     # [steps, n_ps, q_w] int32, worker ids (0-based)
    push_stale: np.ndarray   # [steps, n_ps, q_w] float32 ms
    gather_idx: np.ndarray   # [n_gathers, n_ps, q_ps] int32, server ids
    gather_stale: np.ndarray  # [n_gathers, n_ps, q_ps] float32 ms
    step_done_ms: np.ndarray  # [steps] last server update completion time
    ledger: MessageLedger
    shortfalls: int = 0      # quorum slots force-filled (faults starved them)
    events: int = 0

    @property
    def n_gathers(self) -> int:
        return self.gather_idx.shape[0]

    def to_delivery(self):
        """Package as a repro.core.quorum.TraceDelivery for the simulator."""
        from repro.core.quorum import TraceDelivery
        return TraceDelivery(self.pull_idx, self.push_idx, self.gather_idx,
                             T=self.scenario.T, pull_stale=self.pull_stale,
                             push_stale=self.push_stale,
                             gather_stale=self.gather_stale)

    # -- realized quorums as delivery masks --------------------------------
    # The masked-aggregation form of the trace: [steps, n_recv, n_send] bool,
    # consumable by any mask-capable rule in the repro.agg registry
    # (``agg.get(name)(x, f, mask=pull_masks()[k, w])``), not just the Median.
    @staticmethod
    def _to_masks(idx: np.ndarray, n_send: int) -> np.ndarray:
        steps, n_recv, q = idx.shape
        m = np.zeros((steps, n_recv, n_send), bool)
        s = np.repeat(np.arange(steps), n_recv * q)
        r = np.tile(np.repeat(np.arange(n_recv), q), steps)
        m[s, r, idx.ravel()] = True
        return m

    def pull_masks(self) -> np.ndarray:
        """[steps, n_w, n_ps] delivered-server masks per worker."""
        return self._to_masks(self.pull_idx, self.scenario.n_servers)

    def push_masks(self) -> np.ndarray:
        """[steps, n_ps, n_w] delivered-worker masks per server."""
        return self._to_masks(self.push_idx, self.scenario.n_workers)

    def gather_masks(self) -> np.ndarray:
        """[n_gathers, n_ps, n_ps] delivered-server masks per server."""
        return self._to_masks(self.gather_idx, self.scenario.n_servers)


class _Quorum:
    """Arrival buffer for one (receiver, tag): first q distinct senders."""
    __slots__ = ("senders", "stale", "closed")

    def __init__(self):
        self.senders: list[int] = []
        self.stale: list[float] = []
        self.closed = False

    def seen(self, src: int) -> bool:
        return src in self.senders

    def add(self, src: int, staleness: float) -> None:
        self.senders.append(src)
        self.stale.append(staleness)


class ClusterSim:
    def __init__(self, scenario):
        self.sc = scenario
        self.loop = EventLoop(scenario.seed)
        self.lat_rng = self.loop.stream("latency")
        self.fault_rng = self.loop.stream("faults")
        self.comp_rng = self.loop.stream("compute")
        sc = scenario
        self.n_ps, self.n_w = sc.n_servers, sc.n_workers
        self.nbytes = sc.model_d * sc.dtype_bytes
        self.n_gathers = sc.steps // sc.T
        self.ledger = MessageLedger(self.n_ps + self.n_w, self.n_ps)
        # node progress
        self.s_step = [0] * self.n_ps      # server's current scatter step
        self.w_step = [0] * self.n_w
        self.s_done = [False] * self.n_ps
        self.w_done = [False] * self.n_w
        # open quorums: bufs[receiver][(phase, tag)] -> _Quorum
        self.s_push: list[dict[int, _Quorum]] = [dict() for _ in range(self.n_ps)]
        self.s_gather: list[dict[int, _Quorum]] = [dict() for _ in range(self.n_ps)]
        self.w_pull: list[dict[int, _Quorum]] = [dict() for _ in range(self.n_w)]
        self.shortfalls = 0
        self._gather_next_k: dict[tuple[int, int], int] = {}
        # trace arrays
        S, G = sc.steps, self.n_gathers
        self.pull_idx = np.zeros((S, self.n_w, sc.pull_need), np.int32)
        self.pull_stale = np.zeros((S, self.n_w, sc.pull_need), np.float32)
        self.push_idx = np.zeros((S, self.n_ps, sc.push_need), np.int32)
        self.push_stale = np.zeros((S, self.n_ps, sc.push_need), np.float32)
        self.gather_idx = np.zeros((G, self.n_ps, sc.q_servers), np.int32)
        self.gather_stale = np.zeros((G, self.n_ps, sc.q_servers), np.float32)
        self.step_done_ms = np.zeros(S, np.float64)
        # closed-row flags: a legitimately closed quorum can record the
        # all-zeros row (e.g. sync pull_need=1 delivering server 0), so the
        # dead-row fill must not infer "never closed" from the values
        self.pull_closed = np.zeros((S, self.n_w), bool)
        self.push_closed = np.zeros((S, self.n_ps), bool)

    def _pull_fallback(self, w: int, k: int):
        """Pad pattern for a starved pull quorum: in the sync schedule the
        only scheduled sender is the round-robin server (w + k) % n_ps."""
        if self.sc.variant == "sync":
            return lambda i: (w + k + i) % self.n_ps
        return lambda i: (w + i) % self.n_ps

    def _push_fallback(self, s: int, k: int):
        """Pad pattern for a starved push quorum: in the sync schedule the
        scheduled senders are the workers w ≡ (s - k) (mod n_ps) — the
        round-robin exchange partners of server s at step k. Pads cycle
        WITHIN that class so a forced close never attributes a gradient to a
        worker the schedule would not route here."""
        if self.sc.variant == "sync":
            r = (s - k) % self.n_ps
            cnt = self.sc.push_scheduled(s, k)
            if cnt == 0:  # degenerate (n_ps > n_w residue): nothing scheduled
                return lambda i: (r + i) % self.n_w
            return lambda i: r + (i % cnt) * self.n_ps
        return lambda i: (s + i) % self.n_w

    # -- wire --------------------------------------------------------------
    def _send(self, src: int, dst: int, phase: str, tag: int) -> None:
        t = self.loop.now
        self.ledger.send(src, phase, self.nbytes)
        f = self.sc.faults
        if f.blocked(src, dst, t) or f.lossy.drops(self.fault_rng):
            self.ledger.drop(dst, phase)
            return
        delay = (self.sc.latency.sample(self.lat_rng, src, dst)
                 * f.latency_scale(src, dst, t)
                 + transfer_ms(self.nbytes, self.sc.bandwidth_gbps))
        self.loop.after(delay, self._deliver, src, dst, phase, tag, t, False)
        if f.lossy.duplicates(self.fault_rng):
            self.loop.after(delay + f.lossy.dup_extra_ms, self._deliver,
                            src, dst, phase, tag, t, True)

    def _deliver(self, src, dst, phase, tag, send_t, is_dup) -> None:
        t = self.loop.now
        if not self.sc.faults.is_up(dst, t):
            self.ledger.drop(dst, phase)
            return
        if is_dup:
            self.ledger.dup(dst, phase)
        stale = t - send_t
        if phase == "pull":
            self._worker_on_model(dst - self.n_ps, tag, src, stale)
        elif phase == "push":
            self._server_on_grad(dst, tag, src - self.n_ps, stale)
        else:
            self._server_on_gather(dst, tag, src, stale)

    # -- worker process ----------------------------------------------------
    def _worker_enter_step(self, w: int, k: int) -> None:
        if k >= self.sc.steps:
            self.w_done[w] = True
            return
        self.w_step[w] = k
        self._worker_try_close(w)

    def _worker_on_model(self, w: int, tag: int, server: int,
                         stale: float) -> None:
        if self.w_done[w] or tag < self.w_step[w]:
            self.ledger.late(self.n_ps + w, "pull", self.nbytes)
            return
        q = self.w_pull[w].setdefault(tag, _Quorum())
        if q.closed or q.seen(server):
            self.ledger.late(self.n_ps + w, "pull", self.nbytes)
            return
        q.add(server, stale)
        if tag == self.w_step[w]:
            self._worker_try_close(w)

    def _worker_try_close(self, w: int, force: bool = False) -> None:
        k = self.w_step[w]
        q = self.w_pull[w].setdefault(k, _Quorum())
        need = self.sc.pull_need
        if q.closed or (len(q.senders) < need and not force):
            return
        q.closed = True
        # sync pads must name the round-robin server that was actually
        # scheduled to send at step k, or the trace/ledger would attribute
        # the pull to a server that never sent it
        fb = self._pull_fallback(w, k)
        idx, stale = _pad(q.senders, q.stale, need, fallback=fb)
        self.shortfalls += max(need - len(q.senders), 0)
        self.pull_idx[k, w] = idx
        self.pull_stale[k, w] = stale
        self.pull_closed[k, w] = True
        for _ in range(min(len(q.senders), need)):
            self.ledger.deliver(self.n_ps + w, "pull", self.nbytes)
        for _ in range(max(len(q.senders) - need, 0)):
            self.ledger.late(self.n_ps + w, "pull", self.nbytes)
        dt = self.sc.compute.sample(self.comp_rng)
        self.loop.after(dt, self._worker_compute_done, w, k)

    def _worker_compute_done(self, w: int, k: int) -> None:
        t = self.loop.now
        if not self.sc.faults.is_up(self.n_ps + w, t):
            up = self.sc.faults.next_up(self.n_ps + w, t)
            if up != float("inf"):
                self.loop.at(up, self._worker_compute_done, w, k)
            return
        for s in range(self.n_ps):
            # sync (§5): the gradient goes ONLY to the round-robin server the
            # worker exchanges with this step — the request half of the
            # server-side round-robin reply pair, not a broadcast (the
            # worker_tx n_ps·d -> 1·d byte-model correction; see
            # exp_messages.model_bytes). Async broadcasts to every server.
            if self.sc.variant == "sync" and (w + k) % self.n_ps != s:
                continue
            self._send(self.n_ps + w, s, "push", k)
        self._worker_enter_step(w, k + 1)

    # -- server process ----------------------------------------------------
    def _server_enter_step(self, s: int, k: int) -> None:
        t = self.loop.now
        if not self.sc.faults.is_up(s, t):
            up = self.sc.faults.next_up(s, t)
            if up != float("inf"):
                self.loop.at(up, self._server_enter_step, s, k)
            return
        if k >= self.sc.steps:
            self.s_done[s] = True
            return
        self.s_step[s] = k
        for w in range(self.n_w):
            # sync variant (§5): worker w pulls ONE model per step, from the
            # round-robin server (w + k) % n_ps — the byte saving the paper's
            # throughput argument rests on. Async broadcasts to everyone.
            if self.sc.variant == "sync" and (w + k) % self.n_ps != s:
                continue
            self._send(s, self.n_ps + w, "pull", k)
        self._server_try_close(s)

    def _server_on_grad(self, s: int, tag: int, worker: int,
                        stale: float) -> None:
        if self.s_done[s] or tag < self.s_step[s]:
            self.ledger.late(s, "push", self.nbytes)
            return
        q = self.s_push[s].setdefault(tag, _Quorum())
        if q.closed or q.seen(worker):
            self.ledger.late(s, "push", self.nbytes)
            return
        q.add(worker, stale)
        if tag == self.s_step[s]:
            self._server_try_close(s)

    def _server_try_close(self, s: int, force: bool = False) -> None:
        k = self.s_step[s]
        q = self.s_push[s].setdefault(k, _Quorum())
        # the wait threshold is the SCHEDULED sender count (sync: only the
        # round-robin exchange partners; async: the q_w quorum); the trace row
        # width stays the rectangular push_need, padded by cycling — width
        # padding is schedule geometry, never counted as a shortfall
        need = self.sc.push_scheduled(s, k)
        width = self.sc.push_need
        if q.closed or (len(q.senders) < need and not force):
            return
        q.closed = True
        idx, stale = _pad(q.senders, q.stale, width,
                          fallback=self._push_fallback(s, k))
        self.shortfalls += max(need - len(q.senders), 0)
        self.push_idx[k, s] = idx
        self.push_stale[k, s] = stale
        self.push_closed[k, s] = True
        for _ in range(min(len(q.senders), width)):
            self.ledger.deliver(s, "push", self.nbytes)
        for _ in range(max(len(q.senders) - width, 0)):
            self.ledger.late(s, "push", self.nbytes)
        self.loop.after(self.sc.update_ms, self._server_update_done, s, k)

    def _server_update_done(self, s: int, k: int) -> None:
        t = self.loop.now
        if not self.sc.faults.is_up(s, t):
            up = self.sc.faults.next_up(s, t)
            if up != float("inf"):
                self.loop.at(up, self._server_update_done, s, k)
            return
        self.step_done_ms[k] = max(self.step_done_ms[k], t)
        if (k + 1) % self.sc.T == 0 and (k + 1) // self.sc.T <= self.n_gathers:
            self._server_enter_gather(s, (k + 1) // self.sc.T - 1, k + 1)
        else:
            self._server_enter_step(s, k + 1)

    # -- DMC gather round --------------------------------------------------
    def _server_enter_gather(self, s: int, r: int, next_k: int) -> None:
        q = self.s_gather[s].setdefault(r, _Quorum())
        # Own model goes FIRST regardless of remote models already buffered
        # for this round (they waited for the receiver to enter it): a server
        # always aggregates its own parameter vector (Algorithm 2).
        q.senders.insert(0, s)
        q.stale.insert(0, 0.0)
        self.ledger.deliver(s, "gather", self.nbytes)
        for o in range(self.n_ps):
            if o != s:
                self._send(s, o, "gather", r)
        self._gather_next_k[(s, r)] = next_k
        self._server_try_gather_close(s, r)

    def _server_on_gather(self, s: int, r: int, src: int,
                          stale: float) -> None:
        q = self.s_gather[s].setdefault(r, _Quorum())
        if q.closed or q.seen(src):
            self.ledger.late(s, "gather", self.nbytes)
            return
        q.add(src, stale)
        self._server_try_gather_close(s, r)

    def _server_try_gather_close(self, s: int, r: int,
                                 force: bool = False) -> None:
        q = self.s_gather[s].setdefault(r, _Quorum())
        need = self.sc.q_servers
        if q.closed or (s, r) not in self._gather_next_k \
                or (len(q.senders) < need and not force):
            return
        q.closed = True
        idx, stale = _pad(q.senders, q.stale, need,
                          fallback=lambda i: (s + i) % self.n_ps)
        self.shortfalls += max(need - len(q.senders), 0)
        self.gather_idx[r, s] = idx
        self.gather_stale[r, s] = stale
        for _ in range(min(len(q.senders), need) - 1):  # self counted at entry
            self.ledger.deliver(s, "gather", self.nbytes)
        for _ in range(max(len(q.senders) - need, 0)):
            self.ledger.late(s, "gather", self.nbytes)
        next_k = self._gather_next_k.pop((s, r))
        self.loop.after(self.sc.update_ms, self._server_enter_step, s, next_k)

    # -- run ---------------------------------------------------------------
    def _alive(self, node: int) -> bool:
        """Node can still make progress (not crashed forever)."""
        t = self.loop.now
        return self.sc.faults.is_up(node, t) or \
            self.sc.faults.next_up(node, t) != float("inf")

    def run(self) -> NetsimTrace:
        for s in range(self.n_ps):
            self.loop.at(0.0, self._server_enter_step, s, 0)
        for w in range(self.n_w):
            self.loop.at(0.0, self._worker_enter_step, w, 0)
        guard = 4 * (self.n_ps + self.n_w) * max(self.sc.steps, 1)
        for _ in range(guard):
            self.loop.run(max_events=self.sc.max_events)
            stuck_s = [s for s in range(self.n_ps)
                       if not self.s_done[s] and self._alive(s)]
            stuck_w = [w for w in range(self.n_w)
                       if not self.w_done[w] and self._alive(self.n_ps + w)]
            if not stuck_s and not stuck_w:
                break
            # heap drained with live nodes blocked: faults starved a quorum.
            # Force-close the open quorums so the schedule stays complete.
            for w in stuck_w:
                self._worker_try_close(w, force=True)
            for s in stuck_s:
                r = next((r for (s2, r) in self._gather_next_k
                          if s2 == s), None)
                if r is not None:
                    self._server_try_gather_close(s, r, force=True)
                else:
                    self._server_try_close(s, force=True)
        self._fill_dead_rows()
        return NetsimTrace(self.sc, self.pull_idx, self.pull_stale,
                           self.push_idx, self.push_stale, self.gather_idx,
                           self.gather_stale, self.step_done_ms, self.ledger,
                           self.shortfalls, self.loop.processed)

    def _fill_dead_rows(self) -> None:
        """Rows owned by permanently-dead nodes never closed; fill them with
        deterministic pads so the trace always drives the simulator."""
        for k in range(self.sc.steps):
            for w in range(self.n_w):
                if not self.pull_closed[k, w] and self.w_step[w] <= k \
                        and not self.w_done[w]:
                    fb = self._pull_fallback(w, k)
                    self.pull_idx[k, w] = [fb(i)
                                           for i in range(self.sc.pull_need)]
                    self.shortfalls += self.sc.pull_need
            for s in range(self.n_ps):
                if not self.push_closed[k, s] and self.s_step[s] <= k \
                        and not self.s_done[s]:
                    fb = self._push_fallback(s, k)
                    self.push_idx[k, s] = [fb(i)
                                           for i in range(self.sc.push_need)]
                    self.shortfalls += self.sc.push_scheduled(s, k)
        for r in range(self.n_gathers):
            for s in range(self.n_ps):
                if not self.gather_idx[r, s].any():
                    self.gather_idx[r, s] = [(s + i) % self.n_ps
                                             for i in range(self.sc.q_servers)]


def _pad(senders: list[int], stale: list[float], need: int, fallback):
    """First ``need`` senders in arrival order; cycle delivered senders (or a
    deterministic fallback pattern when nothing arrived) to fill shortfall."""
    idx = list(senders[:need])
    st = list(stale[:need])
    i = 0
    while len(idx) < need:
        if senders:
            idx.append(senders[i % len(senders)])
            st.append(stale[i % len(stale)])
        else:
            idx.append(fallback(i))
            st.append(0.0)
        i += 1
    return np.asarray(idx, np.int32), np.asarray(st, np.float32)
