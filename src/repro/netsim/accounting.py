"""Per-node message/byte/round accounting and analytic cross-validation.

Counting conventions (chosen to match ``benchmarks/exp_messages.model_bytes``):

  * ``tx`` is counted at send time, once per copy put on the wire;
  * ``rx`` counts only messages *consumed by a quorum* — arrivals after the
    receiver's quorum closed are ``late`` (the paper's model charges a
    receiver q-of-n deliveries, not n);
  * in the DMC gather a server's own model counts as one ``rx`` (the analytic
    model charges q_ps aggregated models including self);
  * ``dropped`` covers loss, partitions, and dead endpoints; ``dup`` counts
    extra copies delivered by duplication.
"""
from __future__ import annotations

import numpy as np

PHASES = ("pull", "push", "gather")
_COUNTERS = ("tx_msgs", "tx_bytes", "rx_msgs", "rx_bytes", "late_msgs",
             "late_bytes", "dropped_msgs", "dup_msgs")


class MessageLedger:
    """Counter matrix [phase][counter][node]; nodes 0..n_servers-1 are
    servers, the rest workers (the cluster engine's id convention)."""

    def __init__(self, n_nodes: int, n_servers: int):
        self.n_nodes = n_nodes
        self.n_servers = n_servers
        self.c = {p: {k: np.zeros(n_nodes, np.int64) for k in _COUNTERS}
                  for p in PHASES}

    # -- recording ---------------------------------------------------------
    def send(self, node, phase, nbytes, copies=1):
        self.c[phase]["tx_msgs"][node] += copies
        self.c[phase]["tx_bytes"][node] += nbytes * copies

    def deliver(self, node, phase, nbytes):
        self.c[phase]["rx_msgs"][node] += 1
        self.c[phase]["rx_bytes"][node] += nbytes

    def late(self, node, phase, nbytes):
        self.c[phase]["late_msgs"][node] += 1
        self.c[phase]["late_bytes"][node] += nbytes

    def drop(self, node, phase):
        self.c[phase]["dropped_msgs"][node] += 1

    def dup(self, node, phase):
        self.c[phase]["dup_msgs"][node] += 1

    # -- views -------------------------------------------------------------
    def _srv(self, phase, key):
        return int(self.c[phase][key][:self.n_servers].sum())

    def _wrk(self, phase, key):
        return int(self.c[phase][key][self.n_servers:].sum())

    def totals(self) -> dict:
        return {p: {k: int(v.sum()) for k, v in d.items()}
                for p, d in self.c.items()}

    def per_step_bytes(self, n_steps: int, n_gathers: int) -> dict:
        """Average per-node per-step byte rates in the analytic model's five
        categories. ``dmc_server_exchange`` is per server per *gather*."""
        n_w = self.n_nodes - self.n_servers
        n_ps = self.n_servers
        out = {
            "worker_rx": self._wrk("pull", "rx_bytes") / (n_w * n_steps),
            "worker_tx": self._wrk("push", "tx_bytes") / (n_w * n_steps),
            "server_rx": self._srv("push", "rx_bytes") / (n_ps * n_steps),
            "server_tx": self._srv("pull", "tx_bytes") / (n_ps * n_steps),
        }
        if n_gathers:
            out["dmc_server_exchange"] = (
                self._srv("gather", "tx_bytes")
                + self._srv("gather", "rx_bytes")) / (n_ps * n_gathers)
        return out

    def summary(self, scenario=None) -> str:
        head = f"[netsim ledger] {scenario.name}" if scenario is not None \
            else "[netsim ledger]"
        lines = [head]
        for p in PHASES:
            d = self.c[p]
            lines.append(
                f"  {p:6s}: tx {int(d['tx_msgs'].sum()):7d} msgs "
                f"({d['tx_bytes'].sum()/1e6:9.2f} MB)  "
                f"rx {int(d['rx_msgs'].sum()):7d}  "
                f"late {int(d['late_msgs'].sum()):6d}  "
                f"dropped {int(d['dropped_msgs'].sum()):5d}  "
                f"dup {int(d['dup_msgs'].sum()):4d}")
        return "\n".join(lines)

    def __eq__(self, other):
        return (isinstance(other, MessageLedger)
                and self.n_nodes == other.n_nodes
                and self.n_servers == other.n_servers
                and all(np.array_equal(self.c[p][k], other.c[p][k])
                        for p in PHASES for k in _COUNTERS))


def compare_with_model(ledger: MessageLedger, scenario, n_steps: int,
                       n_gathers: int) -> dict:
    """Simulated per-step byte rates vs the analytic communication model of
    exp_messages.model_bytes. Returns {category: (simulated, analytic,
    rel_err)}; on the uniform no-fault scenario every rel_err should be ~0."""
    from benchmarks.exp_messages import model_bytes  # late: keeps core dep-free
    m = model_bytes(scenario.model_d, scenario.n_workers, scenario.n_servers,
                    scenario.f_workers, scenario.f_servers, scenario.T,
                    dtype_bytes=scenario.dtype_bytes)
    D = scenario.model_d * scenario.dtype_bytes
    analytic = dict(m["async"],
                    dmc_server_exchange=m["dmc"]["server_exchange"])
    # model_bytes hardcodes q = n - f; when the scenario overrides a quorum
    # (e.g. q_servers = 2f+2 > n-f on small server counts), adjust the
    # q-dependent categories so the comparison stays apples-to-apples.
    if scenario.q_servers != scenario.n_servers - scenario.f_servers:
        analytic["worker_rx"] = scenario.q_servers * D
        analytic["dmc_server_exchange"] = \
            (scenario.n_servers - 1 + scenario.q_servers) * D
    if scenario.q_workers != scenario.n_workers - scenario.f_workers:
        analytic["server_rx"] = scenario.q_workers * D
    sim = ledger.per_step_bytes(n_steps, n_gathers)
    out = {}
    for k, s in sim.items():
        a = analytic[k]
        out[k] = (s, a, abs(s - a) / max(a, 1e-12))
    return out
