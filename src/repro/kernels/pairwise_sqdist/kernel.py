"""Pallas TPU kernel: Gram matrix / pairwise squared distances for MDA.

MDA's subset selection needs the [n, n] pairwise-distance matrix of n gradient
vectors of dimension d — an O(n^2 d) contraction that dominates the server-side
aggregation cost for large d (paper §4 complexity: O(n_w^2 d)). On TPU we
compute it as a d-tiled Gram accumulation X X^T feeding the MXU: each grid step
loads an [n, block_d] tile into VMEM and accumulates the f32 [n, n] Gram in the
output block, which stays resident in VMEM across the whole grid (revisiting
BlockSpec). d2 is then recovered exactly as diag+diag'-2G (ops.py).

TPU alignment: block_d is a multiple of 128 (lane width); n is padded to a
multiple of 8 (sublane width) by ops.py. Zero padding changes neither Gram nor
distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref):
    step = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # [n_pad, block_d] VMEM tile
    partial = jax.lax.dot_general(
        x, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # MXU: [n_pad, n_pad]

    @pl.when(step == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(step > 0)
    def _acc():
        o_ref[...] += partial


def gram_pallas_call(n_pad: int, d_pad: int, block_d: int, dtype,
                     interpret: bool = False):
    """Build the pallas_call for an [n_pad, d_pad] input (both pre-padded)."""
    grid = (d_pad // block_d,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n_pad, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )
