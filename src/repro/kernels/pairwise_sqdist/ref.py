"""Pure-jnp oracle for the pairwise_sqdist kernel."""
import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    return x @ x.T


def pairwise_sqdists_ref(x: jax.Array) -> jax.Array:
    g = gram_ref(x)
    sq = jnp.diagonal(g)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)
