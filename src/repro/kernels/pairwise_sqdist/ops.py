"""Jitted wrapper around the Gram/pairwise-distance Pallas kernel.

The Pallas backend for every distance-based aggregator (MDA, Krum family);
call sites reach it through ``repro.agg`` dispatch (``backend="pallas"`` or
auto on TPU) rather than importing this module directly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import gram_pallas_call

_LANE = 128   # TPU lane width
_SUBLANE = 8  # TPU sublane width


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram(x: jax.Array, *, block_d: int = 512, interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [n, n] f32 Gram matrix (zero-padded to TPU tile alignment)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    n_pad = -(-n // _SUBLANE) * _SUBLANE
    block_d = min(block_d, -(-d // _LANE) * _LANE)
    block_d = -(-block_d // _LANE) * _LANE
    d_pad = -(-d // block_d) * block_d
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    g = gram_pallas_call(n_pad, d_pad, block_d, x.dtype, interpret)(xp)
    return g[:n, :n]


def pairwise_sqdists(x: jax.Array, *, block_d: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [n, n] exact squared L2 distances via the Pallas Gram kernel."""
    g = gram(x, block_d=block_d, interpret=interpret)
    sq = jnp.diagonal(g)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)
