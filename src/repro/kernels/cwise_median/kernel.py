"""Pallas TPU kernels: coordinate-wise order statistics over a replica stack.

The DMC gather phase and every worker model-pull apply a coordinate-wise
order-statistic rule (Median / MeaMed / trimmed mean) over n <= 64
parameter/model vectors of dimension d (up to 1e11 here) — pure memory-bound
streaming ops (paper complexity O(n_ps * d)). All three kernels stream
[n, block_d] VMEM tiles and share ONE static bitonic sorting network built
from jnp.minimum/maximum (vector ops only; no data-dependent control flow,
so it maps to the VPU with full lanes); the rules differ only in how they
reduce the sorted rows.

n is padded to the next power of two with +inf rows; since pads sort last,
the statistics of the n real rows live in the first n sorted rows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bitonic_pairs(n_pow2: int):
    """Static compare-exchange schedule of the bitonic sorting network."""
    pairs = []
    k = 2
    while k <= n_pow2:
        j = k // 2
        while j >= 1:
            stage = []
            for i in range(n_pow2):
                l = i ^ j
                if l > i:
                    ascending = (i & k) == 0
                    stage.append((i, l) if ascending else (l, i))
            pairs.append(stage)
            j //= 2
        k *= 2
    return pairs


def _sorted_rows(x_ref, n_pow2: int):
    """Sort the tile's row axis through the shared bitonic network."""
    rows = [x_ref[i, :] for i in range(n_pow2)]  # each [block_d]
    for stage in bitonic_pairs(n_pow2):
        for (lo_i, hi_i) in stage:
            a, b = rows[lo_i], rows[hi_i]
            rows[lo_i] = jnp.minimum(a, b)
            rows[hi_i] = jnp.maximum(a, b)
    return rows


def _median_kernel(x_ref, o_ref, *, n: int, n_pow2: int):
    rows = _sorted_rows(x_ref, n_pow2)
    med = 0.5 * (rows[(n - 1) // 2] + rows[n // 2])
    o_ref[0, :] = med


def _trimmed_mean_kernel(x_ref, o_ref, *, n: int, n_pow2: int, f: int):
    """Mean of sorted rows f..n-f-1 (drop the f lowest and f highest)."""
    rows = _sorted_rows(x_ref, n_pow2)
    acc = rows[f]
    for i in range(f + 1, n - f):
        acc = acc + rows[i]
    o_ref[0, :] = acc / (n - 2 * f)


def _meamed_kernel(x_ref, o_ref, *, n: int, n_pow2: int, f: int):
    """Mean-around-Median: per coordinate, mean of the n-f values closest to
    the median. In sorted order those values form a contiguous window
    [i, i+n-f), i <= f, whose max distance to the median is attained at an
    endpoint — so the selection is a running elementwise argmin over f+1
    window candidates, all on sorted rows from the shared network.

    Windows can TIE on the max endpoint distance (duplicate values — e.g.
    colluding Byzantine payloads), and the max alone cannot discriminate
    them; ties break toward the smaller in-window distance *sum*, which is
    what "the n-f smallest distances" (the jnp reference's argsort) uniquely
    minimizes.

    Tie contract: the selected window always matches the reference's
    selection *quality* exactly — same max distance and same distance sum,
    the quantities the robustness analysis depends on (gated by
    tests/test_agg_backends.py on tie-heavy integer stacks). When two values
    sit at exactly the same distance on opposite sides of the median, the
    reference breaks the tie by input position, which sorted tiles cannot
    observe — the kernel then averages the equidistant value from the
    leftmost (smaller-valued) best window instead; on continuous data such
    ties have probability zero."""
    rows = _sorted_rows(x_ref, n_pow2)
    med = 0.5 * (rows[(n - 1) // 2] + rows[n // 2])
    m = n - f
    dist = [jnp.abs(rows[j] - med) for j in range(n)]
    win_sum = rows[0]
    win_dsum = dist[0]
    for j in range(1, m):
        win_sum = win_sum + rows[j]
        win_dsum = win_dsum + dist[j]
    best_sum, best_dsum = win_sum, win_dsum
    best_d = jnp.maximum(med - rows[0], rows[m - 1] - med)
    for i in range(1, f + 1):
        win_sum = win_sum - rows[i - 1] + rows[i + m - 1]
        win_dsum = win_dsum - dist[i - 1] + dist[i + m - 1]
        d = jnp.maximum(med - rows[i], rows[i + m - 1] - med)
        take = (d < best_d) | ((d == best_d) & (win_dsum < best_dsum))
        best_sum = jnp.where(take, win_sum, best_sum)
        best_dsum = jnp.where(take, win_dsum, best_dsum)
        best_d = jnp.minimum(best_d, d)
    o_ref[0, :] = best_sum / m


def _rule_pallas_call(kernel, n_pow2: int, d_pad: int, block_d: int,
                      interpret: bool, **kw):
    return pl.pallas_call(
        partial(kernel, n_pow2=n_pow2, **kw),
        grid=(d_pad // block_d,),
        in_specs=[pl.BlockSpec((n_pow2, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
        interpret=interpret,
    )


def median_pallas_call(n: int, n_pow2: int, d_pad: int, block_d: int,
                       interpret: bool = False):
    return _rule_pallas_call(_median_kernel, n_pow2, d_pad, block_d,
                             interpret, n=n)


def trimmed_mean_pallas_call(n: int, f: int, n_pow2: int, d_pad: int,
                             block_d: int, interpret: bool = False):
    return _rule_pallas_call(_trimmed_mean_kernel, n_pow2, d_pad, block_d,
                             interpret, n=n, f=f)


def meamed_pallas_call(n: int, f: int, n_pow2: int, d_pad: int,
                       block_d: int, interpret: bool = False):
    return _rule_pallas_call(_meamed_kernel, n_pow2, d_pad, block_d,
                             interpret, n=n, f=f)
