"""Pallas TPU kernel: coordinate-wise median over a small replica stack.

The DMC gather phase and every worker model-pull apply a coordinate-wise
median over n <= 64 parameter/model vectors of dimension d (up to 1e11 here) —
a pure memory-bound streaming op (paper complexity O(n_ps * d)). The kernel
streams [n, block_d] VMEM tiles and sorts the n-axis with a static bitonic
sorting network built from jnp.minimum/maximum (vector ops only; no
data-dependent control flow, so it maps to the VPU with full lanes).

n is padded to the next power of two with +inf rows; since pads sort last, the
median of the n real rows is row (n-1)//2 and n//2 of the sorted tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bitonic_pairs(n_pow2: int):
    """Static compare-exchange schedule of the bitonic sorting network."""
    pairs = []
    k = 2
    while k <= n_pow2:
        j = k // 2
        while j >= 1:
            stage = []
            for i in range(n_pow2):
                l = i ^ j
                if l > i:
                    ascending = (i & k) == 0
                    stage.append((i, l) if ascending else (l, i))
            pairs.append(stage)
            j //= 2
        k *= 2
    return pairs


def _median_kernel(x_ref, o_ref, *, n: int, n_pow2: int):
    rows = [x_ref[i, :] for i in range(n_pow2)]  # each [block_d]
    for stage in bitonic_pairs(n_pow2):
        for (lo_i, hi_i) in stage:
            a, b = rows[lo_i], rows[hi_i]
            rows[lo_i] = jnp.minimum(a, b)
            rows[hi_i] = jnp.maximum(a, b)
    med = 0.5 * (rows[(n - 1) // 2] + rows[n // 2])
    o_ref[0, :] = med


def median_pallas_call(n: int, n_pow2: int, d_pad: int, block_d: int,
                       interpret: bool = False):
    from functools import partial
    grid = (d_pad // block_d,)
    return pl.pallas_call(
        partial(_median_kernel, n=n, n_pow2=n_pow2),
        grid=grid,
        in_specs=[pl.BlockSpec((n_pow2, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
        interpret=interpret,
    )
