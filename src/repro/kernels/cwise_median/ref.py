"""Pure-jnp oracle for the coordinate-wise median kernel."""
import jax
import jax.numpy as jnp


def cwise_median_ref(x: jax.Array) -> jax.Array:
    return jnp.median(x.astype(jnp.float32), axis=0)
