"""Jitted wrappers around the coordinate-wise order-statistic Pallas kernels.

The Pallas backends of the ``median``, ``trimmed_mean`` and ``meamed``
aggregators (one shared bitonic sorting network, three reductions); call
sites reach them through ``repro.agg`` dispatch (``backend="pallas"`` or
auto on TPU), which falls back to the jnp reference for stacks larger than
the kernels' n <= 64 limit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import (meamed_pallas_call, median_pallas_call,
                     trimmed_mean_pallas_call)

_LANE = 128
_BIG = 3.4e38  # finite sentinel (f32 max ~3.4e38): NaN/pad lanes sort last


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile(x: jax.Array, block_d: int):
    """Pad the stack to (next-pow2 rows of ``_BIG``, lane-aligned d) for
    the sorting-network kernels; pads sort last. NaN payloads are mapped
    to ``_BIG`` too — NaN poisons the jnp.minimum/maximum
    compare-exchanges (every comparison involving it is False, so it
    drifts arbitrarily instead of sorting last), and a Byzantine replica
    sending NaN would otherwise corrupt the whole coordinate. Mirrors
    ``agg.rules.sort_stack``."""
    n, d = x.shape
    if n > 64:
        raise ValueError("cwise order-statistic kernels are sized for "
                         "replica stacks n <= 64")
    n_pow2 = 1
    while n_pow2 < n:
        n_pow2 *= 2
    block_d = min(block_d, -(-d // _LANE) * _LANE)
    block_d = -(-block_d // _LANE) * _LANE
    d_pad = -(-d // block_d) * block_d
    xf = x.astype(jnp.float32)
    xf = jnp.where(jnp.isnan(xf), jnp.float32(_BIG), xf)
    xp = jnp.full((n_pow2, d_pad), jnp.float32(_BIG), jnp.float32)
    xp = xp.at[:n, :d].set(xf)
    return xp, n_pow2, d_pad, block_d


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def cwise_median(x: jax.Array, *, block_d: int = 1024,
                 interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [d] f32 coordinate-wise median (n <= 64)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    xp, n_pow2, d_pad, block_d = _tile(x, block_d)
    out = median_pallas_call(n, n_pow2, d_pad, block_d, interpret)(xp)
    return out[0, :d]


@partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def cwise_trimmed_mean(x: jax.Array, f: int, *, block_d: int = 1024,
                       interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [d] f32 trimmed mean (drop f lowest/highest; n <= 64)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    if n <= 2 * f:
        raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")
    xp, n_pow2, d_pad, block_d = _tile(x, block_d)
    out = trimmed_mean_pallas_call(n, f, n_pow2, d_pad, block_d,
                                   interpret)(xp)
    return out[0, :d]


@partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def cwise_meamed(x: jax.Array, f: int, *, block_d: int = 1024,
                 interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [d] f32 mean-around-median (n <= 64)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    if n <= f:
        raise ValueError(f"meamed needs n > f (n={n}, f={f})")
    xp, n_pow2, d_pad, block_d = _tile(x, block_d)
    out = meamed_pallas_call(n, f, n_pow2, d_pad, block_d, interpret)(xp)
    return out[0, :d]
