"""Jitted wrapper around the coordinate-wise median Pallas kernel.

The Pallas backend of the ``median`` aggregator; call sites reach it through
``repro.agg`` dispatch (``backend="pallas"`` or auto on TPU), which falls
back to the jnp reference for stacks larger than the kernel's n <= 64 limit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import median_pallas_call

_LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def cwise_median(x: jax.Array, *, block_d: int = 1024,
                 interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [d] f32 coordinate-wise median (n <= 64)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    if n > 64:
        raise ValueError("cwise_median kernel is sized for replica stacks n<=64")
    n_pow2 = 1
    while n_pow2 < n:
        n_pow2 *= 2
    block_d = min(block_d, -(-d // _LANE) * _LANE)
    block_d = -(-block_d // _LANE) * _LANE
    d_pad = -(-d // block_d) * block_d
    xp = jnp.full((n_pow2, d_pad), jnp.inf, jnp.float32)
    xp = xp.at[:n, :d].set(x.astype(jnp.float32))
    out = median_pallas_call(n, n_pow2, d_pad, block_d, interpret)(xp)
    return out[0, :d]
