"""Pallas TPU kernel: fused flash attention (online softmax).

§Perf motivation: the roofline memory term of every *_4k/32k cell is
dominated by HLO-visible [S, S] score traffic — the pure-JAX blocked
attention still materialises each [q_block, kv_block] score tile in HBM at
the HLO level. This kernel keeps the running (m, l, acc) statistics in VMEM
scratch across the kv-grid dimension, so scores never leave VMEM: HBM traffic
drops from O(S^2) to O(S * hd) per head — the single biggest lever on the
memory roofline term identified in EXPERIMENTS.md §Perf.

Layout: grid = (batch*heads, n_q_blocks, n_kv_blocks), kv innermost; the
output block index ignores the kv dim (revisited), and f32 scratch carries
the softmax state. MXU alignment: q_block/kv_block multiples of 128 on the
lane dim, hd padded to 128.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, sq: int, skv: int,
                  q_block: int, kv_block: int, n_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [qb, hd]
    k = k_ref[0].astype(jnp.float32)                  # [kb, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [qb, kb]

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = (kpos < skv) & (qpos < sq)
    if causal:
        off = skv - sq  # prefix length when kv longer than q
        mask &= kpos <= (qpos + off)
        if window > 0:
            mask &= kpos > (qpos + off - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                                # [qb]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_pallas_call(bh: int, sq_pad: int, skv_pad: int, hd_pad: int, *,
                      sq: int, skv: int, causal: bool, window: int,
                      q_block: int, kv_block: int, scale: float, dtype,
                      interpret: bool = False):
    n_q = sq_pad // q_block
    n_kv = skv_pad // kv_block
    kern = partial(_flash_kernel, causal=causal, window=window, sq=sq,
                   skv=skv, q_block=q_block, kv_block=kv_block, n_kv=n_kv,
                   scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, hd_pad), dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )
