"""Pallas TPU kernel: fused flash attention (online softmax).

§Perf motivation: the roofline memory term of every *_4k/32k cell is
dominated by HLO-visible [S, S] score traffic — the pure-JAX blocked
attention still materialises each [q_block, kv_block] score tile in HBM at
the HLO level. This kernel keeps the running (m, l, acc) statistics in VMEM
scratch across the kv-grid dimension, so scores never leave VMEM: HBM traffic
drops from O(S^2) to O(S * hd) per head — the single biggest lever on the
memory roofline term identified in EXPERIMENTS.md §Perf.

Layout: grid = (batch*heads, n_q_blocks, n_kv_blocks), kv innermost; the
output block index ignores the kv dim (revisited), and f32 scratch carries
the softmax state. MXU alignment: q_block/kv_block multiples of 128 on the
lane dim, hd padded to 128.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, causal: bool, window: int, sq: int, skv: int,
                  q_block: int, kv_block: int, n_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [qb, hd]
    k = k_ref[0].astype(jnp.float32)                  # [kb, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [qb, kb]

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = (kpos < skv) & (qpos < sq)
    if causal:
        off = skv - sq  # prefix length when kv longer than q
        mask &= kpos <= (qpos + off)
        if window > 0:
            mask &= kpos > (qpos + off - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                                # [qb]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)
        # log-sum-exp of the (scaled, masked) scores per q row — the backward
        # kernels re-derive p = exp(s - lse) from it without re-running the
        # online softmax. Fully-masked (padded) rows get lse ~ NEG; their
        # upstream do is zero-padded, so their garbage p never contributes.
        lse_scr = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        lse_ref[0] = lse_scr


def flash_pallas_call(bh: int, sq_pad: int, skv_pad: int, hd_pad: int, *,
                      sq: int, skv: int, causal: bool, window: int,
                      q_block: int, kv_block: int, scale: float, dtype,
                      interpret: bool = False):
    """Forward: (q, k, v) [bh, s_pad, hd_pad] -> (out, lse [bh, sq_pad])."""
    n_q = sq_pad // q_block
    n_kv = skv_pad // kv_block
    kern = partial(_flash_kernel, causal=causal, window=window, sq=sq,
                   skv=skv, q_block=q_block, kv_block=kv_block, n_kv=n_kv,
                   scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq_pad, hd_pad), dtype),
            jax.ShapeDtypeStruct((bh, sq_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )


def _bwd_mask_and_p(q, k, lse, qi, ki, *, causal, window, sq, skv,
                    q_block, kv_block, scale):
    """Recompute the [qb, kb] probability tile exactly as the forward masked
    it (padding + causal + window), from the saved per-row lse."""
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = (kpos < skv) & (qpos < sq)
    if causal:
        off = skv - sq
        mask &= kpos <= (qpos + off)
        if window > 0:
            mask &= kpos > (qpos + off - window)
    s = jnp.where(mask, s, NEG)
    return jnp.exp(s - lse[:, None])


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_scr, *, causal: bool, window: int,
                         sq: int, skv: int, q_block: int, kv_block: int,
                         n_kv: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    p = _bwd_mask_and_p(q, k, lse_ref[0], qi, ki, causal=causal,
                        window=window, sq=sq, skv=skv, q_block=q_block,
                        kv_block=kv_block, scale=scale)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [qb, kb]
    ds = p * (dp - delta_ref[0][:, None])
    dq_scr[...] += scale * jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *, causal: bool,
                          window: int, sq: int, skv: int, q_block: int,
                          kv_block: int, n_q: int, scale: float):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    p = _bwd_mask_and_p(q, k, lse_ref[0], qi, ki, causal=causal,
                        window=window, sq=sq, skv=skv, q_block=q_block,
                        kv_block=kv_block, scale=scale)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])
    dk_scr[...] += scale * jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_dq_call(bh: int, sq_pad: int, skv_pad: int, hd_pad: int, *,
                      sq: int, skv: int, causal: bool, window: int,
                      q_block: int, kv_block: int, scale: float, dtype,
                      interpret: bool = False):
    """dq: grid (bh, n_q, n_kv) — kv innermost, dq accumulated in VMEM."""
    n_q = sq_pad // q_block
    n_kv = skv_pad // kv_block
    kern = partial(_flash_bwd_dq_kernel, causal=causal, window=window, sq=sq,
                   skv=skv, q_block=q_block, kv_block=kv_block, n_kv=n_kv,
                   scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, hd_pad), dtype),
        scratch_shapes=[pltpu.VMEM((q_block, hd_pad), jnp.float32)],
        interpret=interpret,
    )


def flash_bwd_dkv_call(bh: int, sq_pad: int, skv_pad: int, hd_pad: int, *,
                       sq: int, skv: int, causal: bool, window: int,
                       q_block: int, kv_block: int, scale: float, dtype,
                       interpret: bool = False):
    """(dk, dv): grid (bh, n_kv, n_q) — q innermost, dk/dv accumulated in
    VMEM. Mask positions are derived from (program_id(2)=q block,
    program_id(1)=kv block), matching the forward's tile masks exactly."""
    n_q = sq_pad // q_block
    n_kv = skv_pad // kv_block
    kern = partial(_flash_bwd_dkv_kernel, causal=causal, window=window,
                   sq=sq, skv=skv, q_block=q_block, kv_block=kv_block,
                   n_q=n_q, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, q_block, hd_pad), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, q_block), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd_pad), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, skv_pad, hd_pad), dtype),
            jax.ShapeDtypeStruct((bh, skv_pad, hd_pad), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv_block, hd_pad), jnp.float32),
            pltpu.VMEM((kv_block, hd_pad), jnp.float32),
        ],
        interpret=interpret,
    )
