"""Jitted public wrapper around the Pallas flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import flash_pallas_call

_LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: bool | None = None):
    """Fused attention. q: [B, Sq, H, hd]; k, v: [B, Skv, kvH, hd] (GQA:
    kv heads repeated into H). Returns [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, H, hd = q.shape
    Skv, kvH = k.shape[1], k.shape[2]
    rep = H // kvH
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / np.sqrt(hd)

    q_block = min(q_block, max(Sq, 8))
    kv_block = min(kv_block, max(Skv, 8))
    sq_pad = -(-Sq // q_block) * q_block
    skv_pad = -(-Skv // kv_block) * kv_block
    hd_pad = -(-hd // _LANE) * _LANE

    def to_bh(x, s_pad):
        x = jnp.moveaxis(x, 2, 1).reshape(B * H, x.shape[1], hd)
        x = _pad_to(_pad_to(x, s_pad, 1), hd_pad, 2)
        return x

    qb = to_bh(q, sq_pad)
    kb = to_bh(kr, skv_pad)
    vb = to_bh(vr, skv_pad)
    out = flash_pallas_call(
        B * H, sq_pad, skv_pad, hd_pad, sq=Sq, skv=Skv, causal=causal,
        window=window, q_block=q_block, kv_block=kv_block, scale=scale,
        dtype=q.dtype, interpret=interpret)(qb, kb, vb)
    out = out[:, :Sq, :hd].reshape(B, H, Sq, hd)
    return jnp.moveaxis(out, 1, 2)
