"""Jitted public wrapper around the Pallas flash-attention kernels.

``flash_attention`` is differentiable end-to-end: the forward kernel saves
the per-row log-sum-exp alongside the output, and a ``jax.custom_vjp`` pairs
it with two Pallas backward kernels (dq over a kv-innermost grid, dk/dv over
a q-innermost grid) that recompute the probability tiles from the saved lse
with the forward's exact padding/causal/window masks — so the training hot
path never materialises an [S, S] score matrix in either direction. All
kernel arithmetic accumulates in f32 regardless of the bf16 input dtype;
``delta = rowsum(do * o)`` is precomputed in plain JAX.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import flash_bwd_dkv_call, flash_bwd_dq_call, flash_pallas_call

_LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _blocking(Sq, Skv, hd, q_block, kv_block):
    q_block = min(q_block, max(Sq, 8))
    kv_block = min(kv_block, max(Skv, 8))
    sq_pad = -(-Sq // q_block) * q_block
    skv_pad = -(-Skv // kv_block) * kv_block
    hd_pad = -(-hd // _LANE) * _LANE
    return q_block, kv_block, sq_pad, skv_pad, hd_pad


def _to_bh(x, s_pad, hd_pad):
    """[B, S, H, hd] -> padded [B*H, s_pad, hd_pad]."""
    B, S, H, hd = x.shape
    x = jnp.moveaxis(x, 2, 1).reshape(B * H, S, hd)
    return _pad_to(_pad_to(x, s_pad, 1), hd_pad, 2)


def _from_bh(x, B, H, S, hd):
    """Padded [B*H, s_pad, hd_pad] -> [B, S, H, hd]."""
    x = x[:, :S, :hd].reshape(B, H, S, hd)
    return jnp.moveaxis(x, 1, 2)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_block, kv_block, interpret):
    out, _ = _flash_fwd(q, k, v, causal, window, q_block, kv_block, interpret)
    return out


def _flash_fwd(q, k, v, causal, window, q_block, kv_block, interpret):
    B, Sq, H, hd = q.shape
    Skv, kvH = k.shape[1], k.shape[2]
    rep = H // kvH
    scale = 1.0 / np.sqrt(hd)
    q_block, kv_block, sq_pad, skv_pad, hd_pad = _blocking(
        Sq, Skv, hd, q_block, kv_block)
    qb = _to_bh(q, sq_pad, hd_pad)
    kb = _to_bh(jnp.repeat(k, rep, axis=2), skv_pad, hd_pad)
    vb = _to_bh(jnp.repeat(v, rep, axis=2), skv_pad, hd_pad)
    ob, lse = flash_pallas_call(
        B * H, sq_pad, skv_pad, hd_pad, sq=Sq, skv=Skv, causal=causal,
        window=window, q_block=q_block, kv_block=kv_block, scale=scale,
        dtype=q.dtype, interpret=interpret)(qb, kb, vb)
    out = _from_bh(ob, B, H, Sq, hd)
    return out, (q, k, v, ob, lse)


def _flash_bwd(causal, window, q_block, kv_block, interpret, res, do):
    q, k, v, ob, lse = res
    B, Sq, H, hd = q.shape
    Skv, kvH = k.shape[1], k.shape[2]
    rep = H // kvH
    scale = 1.0 / np.sqrt(hd)
    q_block, kv_block, sq_pad, skv_pad, hd_pad = _blocking(
        Sq, Skv, hd, q_block, kv_block)
    qb = _to_bh(q, sq_pad, hd_pad)
    kb = _to_bh(jnp.repeat(k, rep, axis=2), skv_pad, hd_pad)
    vb = _to_bh(jnp.repeat(v, rep, axis=2), skv_pad, hd_pad)
    dob = _to_bh(do, sq_pad, hd_pad)
    # delta_i = sum_d do_id * o_id (zero on padded rows since do is
    # zero-padded) — plain JAX, one [bh, sq_pad] vector
    delta = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)

    common = dict(sq=Sq, skv=Skv, causal=causal, window=window,
                  q_block=q_block, kv_block=kv_block, scale=scale,
                  dtype=q.dtype, interpret=interpret)
    dqb = flash_bwd_dq_call(B * H, sq_pad, skv_pad, hd_pad, **common)(
        qb, kb, vb, dob, lse, delta)
    dkb, dvb = flash_bwd_dkv_call(B * H, sq_pad, skv_pad, hd_pad, **common)(
        qb, dob, lse, delta, kb, vb)

    dq = _from_bh(dqb, B, H, Sq, hd)
    # un-repeat GQA heads: h = kvh * rep + r -> sum over r
    dk_full = _from_bh(dkb, B, H, Skv, hd)
    dv_full = _from_bh(dvb, B, H, Skv, hd)
    dk = dk_full.reshape(B, Skv, kvH, rep, hd).sum(axis=3).astype(k.dtype)
    dv = dv_full.reshape(B, Skv, kvH, rep, hd).sum(axis=3).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: bool | None = None):
    """Fused attention, forward AND backward. q: [B, Sq, H, hd]; k, v:
    [B, Skv, kvH, hd] (GQA: kv heads repeated into H, gradients summed back).
    Returns [B, Sq, H, hd]."""
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal, window, q_block, kv_block, interpret)
