"""Pure-jnp oracle for the flash-attention kernel."""
from repro.models.layers import _naive_attention


def attention_ref(q, k, v, *, causal=True, window=0):
    """q [B,S,H,hd]; k,v [B,S,kvH,hd] -> [B,S,H,hd]."""
    return _naive_attention(q, k, v, causal=causal, window=window,
                            cross=not causal)
