"""Jitted wrapper: full Pallas MDA = Gram kernel + diameter-scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...core import gars
from ..pairwise_sqdist.ops import pairwise_sqdists
from .kernel import diam_pallas_call

_LANE = 128
_SUBLANE = 8


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def subset_diameters(d2: jax.Array, masks: jax.Array, *, block_s: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """[n,n] dists + [S,n] bool masks -> [S] subset diameters."""
    if interpret is None:
        interpret = _default_interpret()
    s, n = masks.shape
    n_pad = -(-n // _LANE) * _LANE
    block_s = min(block_s, -(-s // _SUBLANE) * _SUBLANE)
    block_s = -(-block_s // _SUBLANE) * _SUBLANE
    s_pad = -(-s // block_s) * block_s
    d2p = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(d2)
    mp = jnp.zeros((s_pad, n_pad), jnp.float32).at[:s, :n].set(
        masks.astype(jnp.float32))
    out = diam_pallas_call(n_pad, s_pad, block_s, interpret)(d2p, mp)
    return out[0, :s]


def mda(x: jax.Array, f: int, *, interpret: bool | None = None) -> jax.Array:
    """Full MDA via the Pallas kernels: [n,d] -> [d]."""
    n = x.shape[0]
    if f == 0:
        return jnp.mean(x, axis=0)
    d2 = pairwise_sqdists(x, interpret=interpret)
    masks = jnp.asarray(gars.subset_masks(n, f))
    diam = subset_diameters(d2, masks, interpret=interpret)
    sel = masks[jnp.argmin(diam)]
    return (sel.astype(jnp.float32) @ x.astype(jnp.float32)) / (n - f)
