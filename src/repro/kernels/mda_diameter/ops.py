"""Jitted wrapper around the subset-diameter Pallas kernel.

This is the Pallas *backend* for exact MDA selection, reached through
``repro.agg`` dispatch (``backend="pallas"`` or auto on TPU); the full
MDA entry point lives in the registry (``repro.agg.get("mda")``), which
composes the Gram kernel, this diameter scan, and the selection logic of
``repro.agg.rules``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import diam_pallas_call

_LANE = 128
_SUBLANE = 8


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def subset_diameters(d2: jax.Array, masks: jax.Array, *, block_s: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """[n,n] dists + [S,n] bool masks -> [S] subset diameters."""
    if interpret is None:
        interpret = _default_interpret()
    s, n = masks.shape
    n_pad = -(-n // _LANE) * _LANE
    block_s = min(block_s, -(-s // _SUBLANE) * _SUBLANE)
    block_s = -(-block_s // _SUBLANE) * _SUBLANE
    s_pad = -(-s // block_s) * block_s
    d2p = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(d2)
    mp = jnp.zeros((s_pad, n_pad), jnp.float32).at[:s, :n].set(
        masks.astype(jnp.float32))
    out = diam_pallas_call(n_pad, s_pad, block_s, interpret)(d2p, mp)
    return out[0, :s]


def mda(x: jax.Array, f: int, *, interpret: bool | None = None) -> jax.Array:
    """Full MDA on the Pallas backend: [n,d] -> [d] (registry-routed)."""
    from ... import agg
    return agg.get("mda")(x, f, backend="pallas", interpret=interpret)
