"""Pallas TPU kernel: MDA subset-diameter scan.

Exact MDA evaluates, for every size-(n-f) subset of the n inputs, the max
pairwise distance inside the subset, then picks the argmin — C(n, f) masked
max-reductions over the [n, n] distance matrix (paper complexity O(C(n_w,f_w))).
The kernel tiles the static subset-mask table [S, n] over the grid and keeps
the distance matrix resident in VMEM; each grid step reduces a [block_s, n, n]
masked broadcast on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diam_kernel(d2_ref, masks_ref, o_ref):
    d2 = d2_ref[...]                      # [n, n] f32, VMEM-resident
    m = masks_ref[...]                    # [block_s, n] f32 (1.0 / 0.0)
    pair = m[:, :, None] * m[:, None, :]  # [block_s, n, n]
    neg = jnp.float32(-3.4e38)
    vals = jnp.where(pair > 0, d2[None], neg)
    o_ref[0, :] = jnp.max(vals, axis=(1, 2))


def diam_pallas_call(n_pad: int, s_pad: int, block_s: int, interpret: bool = False):
    grid = (s_pad // block_s,)
    return pl.pallas_call(
        _diam_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((block_s, n_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
        interpret=interpret,
    )
