"""Pure-jnp oracle for the MDA subset-diameter kernel."""
import jax
import jax.numpy as jnp


def subset_diameters_ref(d2: jax.Array, masks: jax.Array) -> jax.Array:
    pair = masks[:, :, None] & masks[:, None, :]
    return jnp.max(jnp.where(pair, d2[None], -jnp.inf), axis=(1, 2))
