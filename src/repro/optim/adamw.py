"""AdamW (optional, for the smaller architectures / examples).

Note: with ByzSGD each server replica would carry its own (m, v) — 3x replica
memory. The framework permits it for layout-A archs; the paper's analysis is
SGD-only, so examples default to sgd.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: any
    v: any
    count: jax.Array


def init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params),
                      jnp.zeros((), jnp.int32))


def update(grads, state: AdamWState, params, lr, *, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.0):
    c = state.count + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                     state.v, grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** c), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** c), v)
    new_params = jax.tree.map(
        lambda p, mh_, vh_: (p - lr * (mh_ / (jnp.sqrt(vh_) + eps)
                                       + weight_decay * p.astype(jnp.float32))).astype(p.dtype),
        params, mh, vh)
    return new_params, AdamWState(m, v, c)
