"""Learning-rate schedules satisfying the paper's conditions (B.1):
monotone decreasing, sum eta = inf, sum eta^2 < inf.

Every factory stamps a structural ``cache_key`` on the returned closure so
the epoch engine (repro.core.engine) can share compiled executables between
sweep points that rebuild the schedule with equal arguments.
"""
from __future__ import annotations

import jax.numpy as jnp


def inverse_sqrt(eta0: float = 0.1, warmup: int = 0, offset: float = 1.0):
    def lr(t):
        base = eta0 / jnp.sqrt(offset + t)
        if warmup > 0:
            base = base * jnp.minimum(1.0, (t + 1) / warmup)
        return base
    lr.cache_key = ("inverse_sqrt", eta0, warmup, offset)
    return lr


def inverse_linear(eta0: float = 0.1, decay: float = 0.01):
    # eta_t = eta0 / (1 + decay * t): sum = inf, sum^2 < inf for decay > 0... note
    # sum eta^2 ~ 1/t converges; sum eta ~ log t diverges. Satisfies B.1.
    def lr(t):
        return eta0 / (1.0 + decay * t)
    lr.cache_key = ("inverse_linear", eta0, decay)
    return lr


def constant(eta0: float = 0.01):
    """For throughput benchmarks only (violates sum eta_t^2 < inf)."""
    def lr(t):
        del t
        return jnp.asarray(eta0)
    lr.cache_key = ("constant", eta0)
    return lr
