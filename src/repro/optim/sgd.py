"""Plain SGD — the paper's optimizer (Eq. 2): theta <- theta - eta_t * G.

Stateless by design: ByzSGD's server replicas carry *no* moment state, which is
what makes per-replica memory tractable at 100B+ scale (DESIGN.md layouts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params):
    del params
    return ()


def update(grads, opt_state, params, lr):
    # f32 accumulation regardless of param dtype (paper Eq. 2 arithmetic —
    # matches the protocol scatter step exactly, oracle equivalence)
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_params, opt_state
