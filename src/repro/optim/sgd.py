"""Plain SGD — the paper's optimizer (Eq. 2): theta <- theta - eta_t * G.

Stateless by design: ByzSGD's server replicas carry *no* moment state, which is
what makes per-replica memory tractable at 100B+ scale (DESIGN.md layouts).
"""
from __future__ import annotations

import jax


def init(params):
    del params
    return ()


def update(grads, opt_state, params, lr):
    new_params = jax.tree.map(
        lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads)
    return new_params, opt_state
