"""repro.optim — optimizers + lr schedules for the protocol update step.

``OPTIMIZERS`` is the spec-level registry ``Experiment.optimizer`` (and
``ProtocolConfig.optimizer``) resolve: each entry is an ``(init, update)``
pair with the uniform signature

    opt_state = init(params)
    new_params, new_opt_state = update(grads, opt_state, params, lr)

applied to the replica-stacked ``[G, ...]`` param tree, so every server
replica carries its own moment state (stacked alongside its replica and
sharded with the same per-leaf-name layout — see
``repro.core.protocol.state_shardings``). ``sgd`` is stateless (the paper's
Eq. 2 update; its opt_state is ``()``) and is the default everywhere; the
single-host simulator implements Eq. 2 directly, so non-sgd optimizers are a
protocol-runner capability.
"""
from __future__ import annotations

from typing import NamedTuple

from . import adamw, schedules, sgd  # noqa: F401


class Optimizer(NamedTuple):
    name: str
    init: callable
    update: callable


OPTIMIZERS: dict[str, Optimizer] = {
    "sgd": Optimizer("sgd", sgd.init, sgd.update),
    "adamw": Optimizer("adamw", adamw.init, adamw.update),
}


def get(name: str) -> Optimizer:
    try:
        return OPTIMIZERS[name]
    except KeyError:
        raise KeyError(f"unknown optimizer {name!r}; "
                       f"have {sorted(OPTIMIZERS)}") from None


__all__ = ["OPTIMIZERS", "Optimizer", "adamw", "get", "schedules", "sgd"]
