"""Device-agnostic, elastic, Byzantine-aware checkpointing.

Design goals for 1000+-node runnability:
  * **Device-agnostic**: leaves are saved as logical (unsharded) arrays plus a
    JSON manifest (step, tree structure, dtypes). Loading re-shards onto
    whatever mesh the restarted job has — elastic scaling across restarts.
  * **Sharded writes**: each leaf is a separate .npy (a real multi-host
    deployment writes per-host shards; single-process here writes whole leaves
    — the format is identical either way, so restore logic is shared).
  * **Byzantine-safe restore**: ByzSGD state carries one replica per server
    group. ``restore_consolidated`` applies coordinate-wise median across the
    replica axis so a corrupted/stale replica in the checkpoint is outvoted —
    the checkpoint-level analogue of DMC.
  * **Atomicity**: writes go to ``<dir>.tmp`` then rename; interrupted saves
    never shadow the last good checkpoint (crash-restart safety).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree):
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in paths_leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def step_dir(ckpt_dir: str, step: int) -> str:
    """Canonical directory of one checkpoint step."""
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _parse_step(entry: str) -> int | None:
    """``step_NNNNNNNN`` -> N; anything else (stray files, ``.tmp`` leftovers,
    malformed names) -> None."""
    if not entry.startswith("step_") or entry.endswith(".tmp"):
        return None
    suffix = entry[len("step_"):]
    if not suffix.isdigit():
        return None
    return int(suffix)


def _gc_orphan_tmp(ckpt_dir: str) -> None:
    """Remove ``step_*.tmp`` leftovers from killed saves (they never shadow a
    good checkpoint, but they accumulate and confuse directory listings)."""
    for entry in os.listdir(ckpt_dir):
        if entry.startswith("step_") and entry.endswith(".tmp"):
            path = os.path.join(ckpt_dir, entry)
            if os.path.isdir(path):
                shutil.rmtree(path, ignore_errors=True)


def save(ckpt_dir: str, step: int, state_tree, *,
         meta: dict | None = None) -> str:
    """Atomically save a pytree checkpoint. Returns the final directory.

    ``meta`` is an optional JSON-compatible dict stored verbatim in the
    manifest (the elastic runner records the active-group set there so a
    resume re-forms the right fleet)."""
    final = step_dir(ckpt_dir, step)
    tmp = final + ".tmp"
    os.makedirs(ckpt_dir, exist_ok=True)
    _gc_orphan_tmp(ckpt_dir)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    if meta is not None:
        manifest["meta"] = meta
    for name, leaf in _leaf_paths(state_tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][name] = {"file": fname, "dtype": str(arr.dtype),
                                    "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for entry in os.listdir(ckpt_dir):
        step = _parse_step(entry)
        if step is None or not os.path.isdir(os.path.join(ckpt_dir, entry)):
            continue
        # a step dir without its manifest is an interrupted/corrupt write
        if not os.path.exists(os.path.join(ckpt_dir, entry, "manifest.json")):
            continue
        steps.append(step)
    return max(steps) if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The manifest dict of one checkpoint step (leaf shapes/dtypes + any
    ``meta`` the saver attached) — no array data is touched."""
    with open(os.path.join(step_dir(ckpt_dir, step), "manifest.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; re-shard with `shardings`
    (a matching pytree of NamedSharding or None -> default placement).
    Elastic: the stored logical shapes must match, the mesh need not."""
    d = step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _leaf_paths(like_tree)]
    leaves = []
    for n in names:
        info = manifest["leaves"][n]
        arr = np.load(os.path.join(d, info["file"]))
        leaves.append(arr)
    treedef = jax.tree.structure(like_tree)
    restored = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else jnp.asarray(a),
            restored, shardings)
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored, manifest["step"]


def restore_consolidated(ckpt_dir: str, step: int, like_tree, *,
                         replica_axis: int = 0):
    """Median-of-replicas restore: collapse the leading server-replica axis
    with a coordinate-wise median (Byzantine-corrupted replica is outvoted)."""
    stacked, s = restore(ckpt_dir, step, like_tree)
    collapsed = jax.tree.map(
        lambda l: (jnp.median(l.astype(jnp.float32),
                              axis=replica_axis).astype(l.dtype)
                   if l.ndim > replica_axis else l),
        stacked)
    return collapsed, s
