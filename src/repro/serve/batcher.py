"""Request queue + continuous batching over the prefill/decode loop.

The decode hot path runs a *fixed* number of slots (static shapes, one
compiled executable); requests flow through the slots continuously:

  * **admission** — ``submit`` appends to a bounded queue (beyond
    ``max_queue`` the request is rejected at the door, the standard
    overload response);
  * **refill** — whenever a slot frees up (request finished, deadline hit)
    the next queued request is prefilled into it while the other slots keep
    decoding — no barrier between requests (continuous batching);
  * **deadlines** — each request carries a wall-clock budget; a request that
    exceeds it is truncated and reported with ``status="deadline"``.

The batcher is pure bookkeeping (host-side); the service owns the device
loop and calls :meth:`fill` / :meth:`finish` around it.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class Request:
    """One generation request and its lifecycle record."""
    rid: int
    prompt: list[int]
    max_new: int = 32
    deadline_ms: float | None = None     # wall budget from admission
    # -- lifecycle (filled by the batcher/service) -------------------------
    slot: int | None = None
    out_tokens: list[int] = field(default_factory=list)
    status: str = "queued"               # queued|running|done|deadline|rejected
    t_submit: float = field(default_factory=time.perf_counter)
    t_start: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def deadline_met(self) -> bool:
        return self.status == "done"

    def past_deadline(self, now: float) -> bool:
        return (self.deadline_ms is not None
                and (now - self.t_submit) * 1e3 > self.deadline_ms)


class ContinuousBatcher:
    """Slot allocator + admission queue (see module docstring)."""

    def __init__(self, n_slots: int, max_queue: int | None = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * n_slots
        self._rid = 0
        self._slot_used = [False] * n_slots
        self.rejected = 0
        self.refills = 0

    # -- admission ---------------------------------------------------------
    def submit(self, prompt, max_new: int = 32,
               deadline_ms: float | None = None) -> Request:
        """Admit a request (or mark it rejected when the queue is full)."""
        req = Request(rid=self._rid, prompt=list(map(int, prompt)),
                      max_new=max_new, deadline_ms=deadline_ms)
        self._rid += 1
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.status = "rejected"
            self.rejected += 1
            return req
        self.queue.append(req)
        return req

    # -- slot management ---------------------------------------------------
    def fill(self) -> list[Request]:
        """Move queued requests into free slots; returns the newly placed
        requests (the service prefills exactly these)."""
        placed = []
        for s in range(self.n_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.slot, req.status = s, "running"
            req.t_start = time.perf_counter()
            self.slots[s] = req
            placed.append(req)
            if self._slot_used[s]:           # slot turned over mid-run
                self.refills += 1
            self._slot_used[s] = True
        return placed

    def finish(self, req: Request, status: str = "done") -> None:
        """Release a request's slot and stamp its completion."""
        req.status = status
        req.t_done = time.perf_counter()
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None

    def expire(self) -> list[Request]:
        """Truncate running requests past their deadline (freeing slots)."""
        now = time.perf_counter()
        hit = [r for r in self.slots if r is not None and r.past_deadline(now)]
        for r in hit:
            self.finish(r, status="deadline")
        return hit

    # -- views -------------------------------------------------------------
    @property
    def running(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
