"""repro.serve — Byzantine-tolerant replicated inference.

The training side of the paper keeps f+1-of-n redundancy across server
groups; this package carries that redundancy through to serving: a
:class:`ReplicaPool` of independently-sourced parameter replicas answers
every read, and quorum rules (registered in ``repro.agg``) consolidate the
answers so up to f Byzantine replicas cannot corrupt a response.

    ReplicaPool        — n replicas: fresh init / live state / checkpoint
    quorum_tokens      — median-of-logits or vote-of-tokens read rules
    DivergenceDetector — flags + ejects persistently-divergent replicas
    ContinuousBatcher  — admission queue + slot refill + deadlines
    QuorumService      — the replicated decode loop with metrics

``python -m repro.serve`` prints the README quorum-read table.
"""
from .batcher import ContinuousBatcher, Request
from .quorum import (READ_RULES, DetectorConfig, DivergenceDetector,
                     disagreement, quorum_logits, quorum_tokens)
from .replica import ReplicaPool, checkpoint_groups
from .service import QuorumService

__all__ = [
    "ContinuousBatcher", "Request",
    "READ_RULES", "DetectorConfig", "DivergenceDetector",
    "disagreement", "quorum_logits", "quorum_tokens",
    "ReplicaPool", "checkpoint_groups",
    "QuorumService",
]
