"""`ReplicaPool` — n independent parameter replicas behind one read surface.

The ByzSGD protocol maintains f+1-of-n redundancy across server groups
(`ByzState.params` leaves are `[G, ...]` replica stacks, and checkpoints save
that stack verbatim). Serving discards that redundancy today; the pool keeps
it: every replica answers each read independently and the quorum rules in
:mod:`repro.serve.quorum` consolidate the answers so up to f Byzantine
replicas cannot corrupt a response.

Replica sources:

  * :meth:`from_params` — broadcast one trusted model to n bit-identical
    replicas (fresh init, or a consolidated checkpoint);
  * :meth:`from_stacked` — adopt an existing `[R, ...]` stack (a live
    ``ProtocolEngine`` state's params);
  * :meth:`from_checkpoint` — restore a replica-stacked ByzSGD checkpoint
    (``checkpoint/checkpointer.py`` format) straight into a pool.

The pool is device-agnostic: callers may ``device_put`` ``params`` with any
sharding (e.g. the replica axis over the serve mesh's 'data' axis) before
building a service; every pool op is a pure `jax.vmap` over the leading axis.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import checkpointer as ck
from ..core.attacks import ByzantineSpec, inject_models


def checkpoint_groups(ckpt_dir: str, step: int | None = None
                      ) -> tuple[int, int]:
    """(step, n_replicas) of a replica-stacked checkpoint, read from the
    manifest (any ``params`` leaf's leading dim is the replica count)."""
    if step is None:
        step = ck.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    with open(os.path.join(ck.step_dir(ckpt_dir, step),
                           "manifest.json")) as fh:
        manifest = json.load(fh)
    for name, info in manifest["leaves"].items():
        if "params" in name.split("/")[0] and info["shape"]:
            return step, int(info["shape"][0])
    raise ValueError(f"checkpoint {ckpt_dir!r} step {step} has no "
                     "replica-stacked params leaves")


@dataclass
class ReplicaPool:
    """n parameter replicas (leaves ``[R, ...]``) + the declared Byzantine
    tolerance f and a host-side liveness mask (quorum ejections land here)."""
    params: Any
    f: int = 0
    active: np.ndarray = field(default=None)  # [R] bool

    def __post_init__(self):
        leaves = jax.tree.leaves(self.params)
        if not leaves:
            raise ValueError("ReplicaPool needs a non-empty params tree")
        R = leaves[0].shape[0]
        if any(l.shape[0] != R for l in leaves):
            raise ValueError("all param leaves must share the leading "
                             "replica axis")
        if self.active is None:
            self.active = np.ones(R, bool)
        self.active = np.asarray(self.active, bool)
        if self.active.shape != (R,):
            raise ValueError(f"active mask must be [R={R}], "
                             f"got {self.active.shape}")
        if self.f < 0 or R < 2 * self.f + 1:
            raise ValueError(f"quorum reads need n >= 2f+1 replicas "
                             f"(got n={R}, f={self.f})")

    # -- shape -------------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return jax.tree.leaves(self.params)[0].shape[0]

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def quorum_floor(self) -> int:
        """Graceful-degradation floor: ejections never go below 2f+1."""
        return 2 * self.f + 1

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_params(cls, params, n_replicas: int, f: int = 0) -> "ReplicaPool":
        """Broadcast one trusted model to n bit-identical replicas."""
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_replicas,) + l.shape), params)
        return cls(params=stacked, f=f)

    @classmethod
    def from_stacked(cls, stacked, f: int = 0,
                     active: np.ndarray | None = None) -> "ReplicaPool":
        """Adopt an existing ``[R, ...]`` stack (e.g. ``ByzState.params``)."""
        return cls(params=stacked, f=f, active=active)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, init_params, *,
                        step: int | None = None, f: int = 0) -> "ReplicaPool":
        """Restore a replica-stacked ByzSGD checkpoint into a pool.

        ``init_params(key) -> single-replica params`` names the param tree
        (``bundle.init`` or an `Experiment.build_problem` init); the replica
        count comes from the manifest, so one call serves any G. The restored
        state is the protocol's ``ByzState`` (params/t/key)."""
        from ..core.protocol import ByzState
        step, G = checkpoint_groups(ckpt_dir, step)

        def like(key):
            k_model, k_run = jax.random.split(key)
            p0 = init_params(k_model)
            params = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (G,) + l.shape), p0)
            return ByzState(params=params, t=jnp.zeros((), jnp.int32),
                            key=k_run)

        like_state = jax.eval_shape(like, jax.random.PRNGKey(0))
        state, _ = ck.restore(ckpt_dir, step, like_state)
        return cls(params=state.params, f=f)

    # -- reads -------------------------------------------------------------
    def replica_outputs(self, apply_fn, *args):
        """``[R, ...]`` stack of per-replica outputs: ``apply_fn(params_r,
        *args)`` vmapped over the replica axis (flagged replicas still
        compute — the read rules mask them out, keeping shapes static)."""
        return jax.vmap(lambda p: apply_fn(p, *args))(self.params)

    def single(self, i: int = 0):
        """One replica's params (the non-resilient baseline)."""
        return jax.tree.map(lambda l: l[i], self.params)

    def consolidated(self):
        """Median-of-active-replicas -> one serving model (the DMC rule
        applied at read time; checkpoint-level analogue:
        ``checkpointer.restore_consolidated``)."""
        mask = np.asarray(self.active)
        return jax.tree.map(
            lambda l: jnp.median(l[mask].astype(jnp.float32),
                                 axis=0).astype(l.dtype), self.params)

    # -- fault injection / membership --------------------------------------
    def corrupt(self, spec: ByzantineSpec, key) -> "ReplicaPool":
        """A new pool with the last ``spec.n_byz_servers`` replicas replaced
        by the named model attack (testing/benchmark hook — the serving
        analogue of the trainer's Byzantine server injection)."""
        if spec.n_byz_servers > self.f:
            raise ValueError(f"corrupting {spec.n_byz_servers} replicas "
                             f"exceeds the declared tolerance f={self.f}")
        return ReplicaPool(params=inject_models(self.params, spec, key),
                           f=self.f, active=self.active.copy())

    def deactivate(self, i: int) -> bool:
        """Eject replica i unless that would break the 2f+1 read quorum.
        Returns True when the ejection took effect."""
        if not self.active[i]:
            return False
        if self.n_active - 1 < self.quorum_floor:
            return False
        self.active[i] = False
        return True

    def reactivate(self, i: int, healed=None) -> bool:
        """Re-admit an ejected replica, healing its params first.

        The serving analogue of elastic re-admission in training
        (``repro.core.membership.reform_params``): the returning replica is
        overwritten with ``healed`` — by default :meth:`consolidated`, the
        DMC median of the currently active replicas — so a corrupted model
        never rejoins the read quorum carrying its corruption. Returns False
        when the replica is already active."""
        if self.active[i]:
            return False
        if healed is None:
            healed = self.consolidated()
        self.params = jax.tree.map(
            lambda l, h: l.at[i].set(h.astype(l.dtype)), self.params, healed)
        self.active[i] = True
        return True
