"""`QuorumService` — the replicated inference loop.

Every decode step runs on **all** replicas (one double-vmap: outer axis
replicas, inner axis batch slots, each slot a B=1 KV cache so per-slot
positions stay independent), then a single quorum read consolidates the
per-replica logits into the committed next token
(:func:`repro.serve.quorum.quorum_tokens`). Up to f Byzantine replicas
therefore cannot corrupt a continuation, and with bit-identical honest
replicas the output is token-identical to an honest single-replica run.

On top of the device loop:

  * continuous batching — :class:`~repro.serve.batcher.ContinuousBatcher`
    refills freed slots while the others keep decoding;
  * divergence detection — per-read replica distances feed the
    :class:`~repro.serve.quorum.DivergenceDetector`; an ejection triggers a
    same-read retry (the quorum is re-read without the flagged replica
    before the token commits) and flips the pool's active mask;
  * metrics — tok/s, quorum-disagreement rate, ejections/retries, and
    per-request latency + deadline outcomes.

Prompts are prefilled unpadded (one compile per distinct prompt length);
right-padding would put a pad token at the read position and left-padding
breaks positions, so exactness wins over compile reuse here. Token-in
families only (vlm/audio need embeds at decode time).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import sharding as shrules
from . import quorum
from .batcher import ContinuousBatcher, Request
from .replica import ReplicaPool


class QuorumService:
    """Byzantine-tolerant replicated decode over a :class:`ReplicaPool`."""

    def __init__(self, pool: ReplicaPool, bundle, *, n_slots: int = 4,
                 max_len: int = 128, n_chunks: int = 4, rule: str = "median",
                 detector: quorum.DetectorConfig | None = None,
                 max_queue: int | None = None, rules=()):
        if bundle.cfg.family in ("vlm", "audio"):
            raise ValueError(f"QuorumService serves token-in families only "
                             f"(got {bundle.cfg.family!r})")
        if rule not in quorum.READ_RULES:
            raise ValueError(f"unknown read rule {rule!r}; "
                             f"have {quorum.READ_RULES}")
        self.pool = pool
        self.bundle = bundle
        self.rule = rule
        self.max_len = max_len
        self.batcher = ContinuousBatcher(n_slots, max_queue=max_queue)
        self.detector = quorum.DivergenceDetector(pool.n_replicas, pool.f,
                                                  detector)
        self._rules = dict(rules)   # logical-name -> axes sharding rules

        # per-slot B=1 caches stacked [R, n_slots, ...] so every slot keeps
        # its own length counter (independent decode positions)
        c1 = bundle.init_caches(1, max_len=max_len, n_chunks=n_chunks)
        R = pool.n_replicas
        self.caches = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (R, n_slots) + l.shape) + 0,
            c1)

        def prefill_fn(params, slot_caches, tokens):
            with shrules.sharding_rules(self._rules):
                def one(p, c):
                    return bundle.prefill(p, {"tokens": tokens}, c)
                return jax.vmap(one)(params, slot_caches)

        def decode_fn(params, caches, toks):
            with shrules.sharding_rules(self._rules):
                def one_rep(p, c_r):
                    def one_slot(c_s, t):
                        return bundle.decode(p, c_s, {"token": t})
                    return jax.vmap(one_slot)(c_r, toks)
                logits, caches = jax.vmap(one_rep)(params, caches)
                return logits[..., 0, :], caches    # [R, n_slots, V]

        self._jprefill = jax.jit(prefill_fn)
        self._jdecode = jax.jit(decode_fn, donate_argnums=1)

        # metrics
        self.committed = 0
        self.decode_s = 0.0
        self.reads = 0
        self.disagreement_sum = 0.0
        self.ejections: list[tuple[int, int]] = []   # (read idx, replica)
        self.retries = 0
        self.requests: list[Request] = []

    # -- admission ---------------------------------------------------------
    def submit(self, prompt, max_new: int = 8,
               deadline_ms: float | None = None) -> Request:
        req = self.batcher.submit(prompt, max_new=max_new,
                                  deadline_ms=deadline_ms)
        self.requests.append(req)
        return req

    # -- membership --------------------------------------------------------
    def readmit(self, i: int) -> bool:
        """Re-admit an ejected replica: heal its params from the active
        quorum's DMC median (:meth:`ReplicaPool.reactivate`) and reset its
        detector record with a probation window (one outlier read re-ejects
        it). The serving half of elastic membership — see
        ``repro.core.membership`` for the training half. Returns False when
        the replica is already active."""
        if not self.pool.reactivate(i):
            return False
        self.detector.readmit(i)
        return True

    # -- quorum read (+ detector, + retry-on-ejection) ---------------------
    def _read(self, logits) -> np.ndarray:
        """One quorum read of per-replica logits ``[R, n_slots, V]`` ->
        committed token per slot ``[n_slots]``, applying the detector and
        retrying the read without any replica it ejects."""
        mask = self.pool.active.copy()
        answer = quorum.quorum_logits(logits, self.pool.f, mask=mask)
        dist = self.detector.distances(logits, answer)
        newly = [i for i in self.detector.observe(dist, mask)
                 if self.pool.deactivate(i)]
        if newly:
            self.ejections.extend((self.detector.reads, i) for i in newly)
            self.retries += 1
            mask = self.pool.active.copy()    # retry against the honest rest
        toks = quorum.quorum_tokens(logits, self.pool.f, self.rule, mask=mask)
        self.reads += 1
        self.disagreement_sum += quorum.disagreement(
            logits, toks, mask=mask)
        return np.asarray(toks)

    # -- device loop -------------------------------------------------------
    def _prefill_into(self, req: Request) -> int:
        """Prefill ``req`` into its slot on every replica; quorum-read and
        commit the first generated token."""
        if len(req.prompt) + req.max_new + 1 > self.max_len:
            raise ValueError(f"request {req.rid}: prompt+max_new exceeds "
                             f"max_len={self.max_len}")
        s = req.slot
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]      # [1, P]
        slot = jax.tree.map(lambda big: big[:, s], self.caches)
        logits, slot = self._jprefill(self.pool.params, slot, tokens)
        self.caches = jax.tree.map(
            lambda big, c: big.at[:, s].set(c), self.caches, slot)
        tok = int(self._read(logits)[0])    # prefill logits are [R, 1, V]
        req.out_tokens.append(tok)
        self.committed += 1
        return tok

    def step(self) -> bool:
        """One service tick: expire deadlines, refill slots (prefill), decode
        one token on every replica x slot, quorum-commit. Returns False when
        fully idle."""
        self.batcher.expire()
        for req in self.batcher.fill():
            t0 = time.perf_counter()
            self._prefill_into(req)
            self.decode_s += time.perf_counter() - t0
            if len(req.out_tokens) >= req.max_new:
                self.batcher.finish(req)
        running = self.batcher.running
        if not running:
            return not self.batcher.idle
        last = np.zeros((self.batcher.n_slots, 1, 1), np.int32)
        for r in running:
            last[r.slot, 0, 0] = r.out_tokens[-1]
        t0 = time.perf_counter()
        logits, self.caches = self._jdecode(
            self.pool.params, self.caches, jnp.asarray(last))
        toks = self._read(logits)
        self.decode_s += time.perf_counter() - t0
        for r in running:
            r.out_tokens.append(int(toks[r.slot]))
            self.committed += 1
            if len(r.out_tokens) >= r.max_new:
                self.batcher.finish(r)
        return not self.batcher.idle

    # -- compiled-artifact audit hook (repro.analyze layer 2) --------------
    def lowered_decode(self):
        """Lower one decode step over the pool's params/caches without
        running it — the ``REPRO-HLO-DONATION`` audit checks the compiled
        ``input_output_alias`` table covers the donated cache stack."""
        toks = jnp.zeros((self.batcher.n_slots, 1, 1), jnp.int32)
        return self._jdecode.lower(self.pool.params, self.caches, toks)

    def generate(self, prompts, max_new: int = 8,
                 deadline_ms: float | None = None) -> list[list[int]]:
        """Convenience driver: submit all prompts, run to idle, return each
        request's committed continuation (token ids)."""
        reqs = [self.submit(p, max_new=max_new, deadline_ms=deadline_ms)
                for p in prompts]
        while self.step():
            pass
        return [r.out_tokens for r in reqs]

    # -- metrics -----------------------------------------------------------
    def report(self) -> dict:
        done = [r for r in self.requests if r.t_done is not None]
        lat = [r.latency_s for r in done]
        return {
            "rule": self.rule,
            "n_replicas": self.pool.n_replicas,
            "n_active": self.pool.n_active,
            "f": self.pool.f,
            "committed_tokens": self.committed,
            "tok_s": self.committed / max(self.decode_s, 1e-9),
            "reads": self.reads,
            "disagreement_rate": self.disagreement_sum / max(self.reads, 1),
            "ejections": list(self.ejections),
            "retries": self.retries,
            "refills": self.batcher.refills,
            "rejected": self.batcher.rejected,
            "requests": {
                "total": len(self.requests),
                "done": sum(r.status == "done" for r in self.requests),
                "deadline": sum(r.status == "deadline" for r in self.requests),
                "latency_s_mean": float(np.mean(lat)) if lat else None,
            },
            "replicas": [
                {"id": i, "active": bool(self.pool.active[i]),
                 "flagged": bool(self.detector.flagged[i]),
                 "strikes": int(self.detector.strikes[i]),
                 "probation": int(self.detector.probation[i])}
                for i in range(self.pool.n_replicas)
            ],
        }
