"""``python -m repro.serve`` — print the README quorum-read table."""
from .quorum import markdown_table

if __name__ == "__main__":
    print(markdown_table())
