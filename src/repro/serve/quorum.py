"""Byzantine-tolerant read rules + the divergence detector.

A *quorum read* consolidates the per-replica answers of a
:class:`~repro.serve.replica.ReplicaPool` through a rule registered in
``repro.agg`` — the read-time extension of the paper's DMC/median machinery
(median-of-replicas answers survive up to f = ⌊(n−1)/2⌋ arbitrary replicas;
we declare the protocol-matched f and keep n ≥ 2f+1):

  * ``median`` — coordinate-wise median over the replica *logits*; the next
    token is the argmax of the consolidated distribution. With bit-identical
    honest replicas the median of [corrupt, h, h, h] is exactly h in every
    coordinate, so continuations are token-identical to the honest model.
  * ``vote``  — majority vote over the replicas' *argmax token ids* (the
    discrete plurality rule registered in ``repro.agg``); cheaper on the wire
    (one int per replica instead of a vocab-sized vector) and exact whenever
    ≥ f+1 honest replicas agree on the top token.

The :class:`DivergenceDetector` watches per-replica distance to the quorum
answer: a replica persistently outside the honest envelope is flagged and
ejected from the read mask — graceful degradation that never drops the pool
below its 2f+1 quorum floor.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

import repro.agg as agg

#: read-rule registry names (both live in ``repro.agg``)
READ_RULES = ("median", "vote")


def quorum_logits(logits, f: int, mask=None):
    """Consolidated logits: coordinate-wise median over the replica axis.
    ``logits`` is ``[R, ...]``; ``mask`` (host bool ``[R]``) drops ejected
    replicas with exact delivered-subset semantics."""
    return agg.get("median")(logits, f, mask=mask)


def quorum_tokens(logits, f: int, rule: str = "median", mask=None):
    """One quorum-read step: per-replica logits ``[R, B, V]`` -> next token
    ids ``[B]`` consolidated by ``rule`` (see module docstring)."""
    if rule not in READ_RULES:
        raise ValueError(f"unknown quorum read rule {rule!r}; "
                         f"have {READ_RULES}")
    if rule == "median":
        return jnp.argmax(quorum_logits(logits, f, mask=mask),
                          axis=-1).astype(jnp.int32)
    votes = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [R, B]
    return agg.get("vote")(votes, f, mask=mask)


def disagreement(logits, tokens, mask=None) -> float:
    """Fraction of (active replica, slot) argmax votes that differ from the
    committed quorum token — the service's per-read disagreement metric."""
    votes = np.asarray(jnp.argmax(logits, axis=-1))         # [R, B]
    toks = np.asarray(tokens)[None, :]
    m = np.ones(votes.shape[0], bool) if mask is None else np.asarray(mask)
    if not m.any():
        return 0.0
    return float((votes[m] != toks).mean())


@dataclass
class DetectorConfig:
    """Envelope test knobs: a replica strikes when its RMS logit distance to
    the quorum answer exceeds ``abs_tol`` AND ``rel`` times the active-set
    median distance; ``patience`` consecutive strikes flag it. A re-admitted
    replica serves ``probation`` reads under a zero-patience rule — one
    outlier read re-ejects it immediately."""
    patience: int = 3
    rel: float = 4.0
    abs_tol: float = 1e-4
    probation: int = 16


class DivergenceDetector:
    """Flags/ejects replicas whose outputs persistently sit outside the
    quorum envelope.

    Purely host-side: :meth:`observe` takes the per-replica distances of one
    read plus the pool's active mask and returns the indices it ejected this
    read (never taking the active count below ``2f+1`` — beyond that the
    detector keeps flagging but stops ejecting)."""

    def __init__(self, n_replicas: int, f: int,
                 cfg: DetectorConfig | None = None):
        self.n = int(n_replicas)
        self.f = int(f)
        self.cfg = cfg or DetectorConfig()
        self.strikes = np.zeros(self.n, np.int64)
        self.flagged = np.zeros(self.n, bool)
        self.probation = np.zeros(self.n, np.int64)   # reads left on watch
        self.reads = 0

    @staticmethod
    def distances(logits, answer) -> np.ndarray:
        """Per-replica RMS distance to the quorum answer: [R, ...] vs [...]
        -> [R] (device math, one scalar per replica on the host)."""
        diff = (logits.astype(jnp.float32)
                - jnp.asarray(answer, jnp.float32)[None])
        axes = tuple(range(1, diff.ndim))
        return np.asarray(jnp.sqrt(jnp.mean(diff * diff, axis=axes)))

    def observe(self, dist: np.ndarray, active: np.ndarray) -> list[int]:
        """Update strikes from one read's distances; flag on ``patience``
        consecutive strikes; return replicas ejected this read (callers apply
        them to the pool's mask). Honest replicas at distance ~0 never strike
        (the ``abs_tol`` floor), so clean pools never eject."""
        dist = np.asarray(dist, np.float64)
        active = np.asarray(active, bool)
        self.reads += 1
        envelope = np.median(dist[active]) if active.any() else 0.0
        thresh = max(self.cfg.abs_tol, self.cfg.rel * envelope)
        outlier = active & (dist > thresh)
        self.strikes = np.where(outlier, self.strikes + 1, 0)
        # probationers (recent re-admissions) flag on a single outlier read
        newly = (~self.flagged) & ((self.strikes >= self.cfg.patience)
                                   | (outlier & (self.probation > 0)))
        self.flagged |= newly
        self.probation = np.where(active, np.maximum(self.probation - 1, 0),
                                  self.probation)
        # eject worst-first while the read quorum survives (>= 2f+1 active)
        floor = 2 * self.f + 1
        ejected = []
        order = sorted(np.nonzero(newly)[0], key=lambda i: -dist[i])
        n_active = int(active.sum())
        for i in order:
            if n_active - 1 < floor:
                break
            ejected.append(int(i))
            n_active -= 1
        return ejected

    def readmit(self, i: int) -> None:
        """Reset replica i's record and start its probation window (callers
        re-admit the healed replica into the pool first — see
        ``QuorumService.readmit``)."""
        self.strikes[i] = 0
        self.flagged[i] = False
        self.probation[i] = self.cfg.probation


def markdown_table() -> str:
    """The README quorum-read table (``python -m repro.serve`` regenerates
    it), derived from the ``repro.agg`` registry specs."""
    rows = [
        ("median", "coordinate-wise median over replica logits, then argmax",
         "exact while <= f of n replicas are corrupt (n >= 2f+1)",
         "one [B, V] logit stack per replica"),
        ("vote", "plurality vote over per-replica argmax token ids",
         "exact while >= f+1 honest replicas agree on the top token",
         "one token id per replica"),
    ]
    out = ["| read rule | consolidation | guarantee | read payload |",
           "|---|---|---|---|"]
    for name, how, guarantee, payload in rows:
        spec = agg.get(name)
        out.append(f"| `{name}` (breakdown {spec.breakdown}) | {how} | "
                   f"{guarantee} | {payload} |")
    out.append("| divergence detector | RMS distance to the quorum answer vs "
               "the active-set envelope | ejects a persistent outlier after "
               "`patience` reads, never below 2f+1 active | — |")
    return "\n".join(out)
