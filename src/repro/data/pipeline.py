"""Deterministic synthetic data pipelines.

The paper trains MNIST/CIFAR-10 on a CPU cluster; neither dataset is vendored
offline here, so the reproduction experiments use a synthetic Gaussian-mixture
classification task with controllable difficulty (documented deviation —
EXPERIMENTS.md §Repro). Properties preserved:

* i.i.d. across workers (paper Assumption, §2.5) — every worker samples from the
  same distribution with decorrelated seeds.
* mini-batch SGD noise scales as 1/sqrt(b) — the variance-to-norm experiments
  (Appendix D) depend on this and reproduce cleanly.

For the LM architectures, ``token_stream`` yields deterministic pseudo-random
token batches (the dry-run itself only needs ShapeDtypeStructs; tokens are for
smoke tests and the end-to-end examples).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@dataclass(frozen=True)
class MixtureSpec:
    n_classes: int = 10
    dim: int = 64
    sep: float = 2.5      # class-centre separation (controls task difficulty)
    noise: float = 1.0


def make_mixture(spec: MixtureSpec, key: jax.Array):
    """Class centres for a Gaussian mixture classification task."""
    centres = spec.sep * jax.random.normal(key, (spec.n_classes, spec.dim))
    return centres


@partial(jax.jit, static_argnames=("spec", "n_workers", "batch_per_worker"))
def sample_classification_batch(key: jax.Array, centres: jax.Array,
                                spec: MixtureSpec, n_workers: int,
                                batch_per_worker: int):
    """Returns (x [n_w, b, dim], y [n_w, b]) — i.i.d. across workers."""
    ky, kx = jax.random.split(key)
    shape = (n_workers, batch_per_worker)
    y = jax.random.randint(ky, shape, 0, spec.n_classes)
    noise = spec.noise * jax.random.normal(kx, shape + (spec.dim,))
    x = centres[y] + noise
    return x, y


def classification_stream(seed: int, spec: MixtureSpec, n_workers: int,
                          batch_per_worker: int, steps: int):
    """Generator of per-worker-sharded batches + a held-out eval set maker."""
    key = jax.random.PRNGKey(seed)
    kc, key = jax.random.split(key)
    centres = make_mixture(spec, kc)
    def gen():
        k = key
        for _ in range(steps):
            k, kb = jax.random.split(k)
            yield sample_classification_batch(kb, centres, spec, n_workers,
                                              batch_per_worker)
    def eval_set(n: int = 2048, eval_seed: int = 10_007):
        x, y = sample_classification_batch(jax.random.PRNGKey(eval_seed),
                                           centres, spec, 1, n)
        return x[0], y[0]
    return gen(), eval_set


@partial(jax.jit, static_argnames=("spec", "n_workers", "batch_per_worker",
                                   "length"))
def sample_classification_epoch(key: jax.Array, centres: jax.Array,
                                spec: MixtureSpec, n_workers: int,
                                batch_per_worker: int, length: int):
    """``length`` stacked batches from one device-side PRNG call.

    Walks the same key chain as :func:`classification_stream` (one split per
    step), so the produced ``(x [L, n_w, b, dim], y [L, n_w, b])`` tensor is
    bit-identical to ``length`` host-iterator batches. Returns
    ``(next_key, (x, y))``.
    """
    def split_one(k, _):
        k, kb = jax.random.split(k)
        return k, kb

    key, kbs = lax.scan(split_one, key, None, length=length)
    x, y = jax.vmap(lambda kb: sample_classification_batch(
        kb, centres, spec, n_workers, batch_per_worker))(kbs)
    return key, (x, y)


@partial(jax.jit, static_argnames=("length",))
def _advance_key(key: jax.Array, length: int) -> jax.Array:
    """The carried key after ``length`` stream steps (one split per step —
    the same walk as :func:`sample_classification_epoch`, batches discarded)."""
    def split_one(k, _):
        return jax.random.split(k)[0], None

    key, _ = lax.scan(split_one, key, None, length=length)
    return key


class DeviceBatchStream:
    """Device-resident data stream for the fused epoch engine.

    Unlike :func:`classification_stream` (a host generator dispatching one
    sampling kernel per step), ``next(L)`` produces the whole epoch's batches
    as one ``[L, n_w, b, ...]`` device tensor from a single jitted call, so
    the training hot path stays trace-closed with no host iterator in the
    loop. Same seed => the concatenation of successive ``next`` calls equals
    the host stream's batch sequence exactly.
    """

    def __init__(self, seed: int, spec: MixtureSpec, n_workers: int,
                 batch_per_worker: int):
        key = jax.random.PRNGKey(seed)
        kc, key = jax.random.split(key)
        self.spec = spec
        self.n_workers = n_workers
        self.batch_per_worker = batch_per_worker
        self.centres = make_mixture(spec, kc)
        self._key = key

    def next(self, length: int, n_workers: int | None = None):
        """Next ``length`` batches: ``(x [L, n_w, b, dim], y [L, n_w, b])``.

        ``n_workers`` overrides the stream width for this call (the elastic
        runner draws narrower batches while the fleet is shrunk). The carried
        key chain advances one split per *step* regardless of width, so a
        width change never desynchronizes the stream from a full-width run —
        the basis of the elastic runner's resume/bit-identity guarantees."""
        nw = self.n_workers if n_workers is None else n_workers
        self._key, batches = sample_classification_epoch(
            self._key, self.centres, self.spec, nw,
            self.batch_per_worker, length)
        return batches

    def skip(self, length: int):
        """Advance the key chain ``length`` steps without sampling — exactly
        the splits ``next`` would have consumed (checkpointed-resume
        fast-forward)."""
        if length:
            self._key = _advance_key(self._key, length)

    def eval_set(self, n: int = 2048, eval_seed: int = 10_007):
        """Held-out eval set, identical to ``classification_stream``'s."""
        x, y = sample_classification_batch(jax.random.PRNGKey(eval_seed),
                                           self.centres, self.spec, 1, n)
        return x[0], y[0]


@dataclass(frozen=True)
class TokenSpec:
    """Synthetic LM data spec (the token analogue of :class:`MixtureSpec`).

    Zipf-distributed tokens (``zipf > 0``) keep the unigram statistics
    learnable — uniform tokens pin the cross-entropy at ``ln vocab`` and no
    training signal exists; ``zipf = 0`` gives uniform tokens."""
    vocab: int = 512
    seq: int = 64
    zipf: float = 1.2


def _token_logits(spec: TokenSpec):
    return -spec.zipf * jnp.log(jnp.arange(1, spec.vocab + 1,
                                           dtype=jnp.float32))


@partial(jax.jit, static_argnames=("spec", "n_workers", "batch_per_worker"))
def sample_token_batch(key: jax.Array, spec: TokenSpec, n_workers: int,
                       batch_per_worker: int):
    """One next-token batch: dict(tokens, labels), leaves [n_w, b, seq]."""
    shape = (n_workers, batch_per_worker, spec.seq + 1)
    if spec.zipf > 0:
        toks = jax.random.categorical(key, _token_logits(spec),
                                      shape=shape).astype(jnp.int32)
    else:
        toks = jax.random.randint(key, shape, 0, spec.vocab)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


@partial(jax.jit, static_argnames=("spec", "n_workers", "batch_per_worker",
                                   "length"))
def sample_token_epoch(key: jax.Array, spec: TokenSpec, n_workers: int,
                       batch_per_worker: int, length: int):
    """``length`` stacked token batches from one device-side call. Walks the
    same key chain as :func:`token_stream` (one split per step, identical
    sampling), so the concatenation of successive calls is bit-identical to
    the host generator's batch sequence. Returns ``(next_key, batches)`` with
    leaves ``[L, n_w, b, seq]``."""
    def split_one(k, _):
        k, kb = jax.random.split(k)
        return k, kb

    key, kbs = lax.scan(split_one, key, None, length=length)
    batches = jax.vmap(lambda kb: sample_token_batch(
        kb, spec, n_workers, batch_per_worker))(kbs)
    return key, batches


class DeviceTokenStream:
    """Device-resident LM data stream with the :class:`DeviceBatchStream`
    interface (``next``/``skip``/``eval_set``), so the fused protocol engine
    drives token models exactly like the mixture task. Same seed => the
    concatenation of ``next`` calls equals :func:`token_stream`'s sequence."""

    def __init__(self, seed: int, spec: TokenSpec, n_workers: int,
                 batch_per_worker: int):
        self.spec = spec
        self.n_workers = n_workers
        self.batch_per_worker = batch_per_worker
        self._key = jax.random.PRNGKey(seed)

    def next(self, length: int, n_workers: int | None = None):
        nw = self.n_workers if n_workers is None else n_workers
        self._key, batches = sample_token_epoch(
            self._key, self.spec, nw, self.batch_per_worker, length)
        return batches

    def skip(self, length: int):
        if length:
            self._key = _advance_key(self._key, length)

    def eval_set(self, n: int = 256, eval_seed: int = 10_007):
        """Held-out eval batch: ``(tokens [n, seq], labels [n, seq])``."""
        b = sample_token_batch(jax.random.PRNGKey(eval_seed), self.spec, 1, n)
        return b["tokens"][0], b["labels"][0]


def token_stream(seed: int, vocab: int, n_workers: int, batch_per_worker: int,
                 seq_len: int, steps: int, zipf: float = 1.2):
    """Deterministic LM token batches: dict(tokens, labels) with leaves
    [n_w, b, L]. Labels are next-token shifted. Tokens are Zipf-distributed
    (zipf > 0) so the unigram statistics are learnable (uniform tokens pin the
    loss at ln V); zipf=0 gives uniform."""
    key = jax.random.PRNGKey(seed)
    if zipf > 0:
        logits = -zipf * jnp.log(jnp.arange(1, vocab + 1, dtype=jnp.float32))
    for _ in range(steps):
        key, kb = jax.random.split(key)
        shape = (n_workers, batch_per_worker, seq_len + 1)
        if zipf > 0:
            toks = jax.random.categorical(kb, logits, shape=shape).astype(jnp.int32)
        else:
            toks = jax.random.randint(kb, shape, 0, vocab)
        yield {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def host_token_batch(seed: int, vocab: int, batch: int, seq_len: int):
    """Single unsharded batch (numpy) for smoke tests."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (batch, seq_len + 1), dtype=np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
