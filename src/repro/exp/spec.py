"""The :class:`Experiment` spec — one frozen, fully-serializable object that
*names* a ByzSGD experiment.

Every comparative claim in the paper (async vs sync §5, GAR vs GAR under
attack §6, uniform vs adversarial delivery) is a pair of experiments that
differ in one field. Before this module each benchmark hand-wired a
``ByzSGDConfig``, a data stream, a model factory, a schedule and one of three
run paths; an ``Experiment`` carries all of it declaratively:

  * cluster shape + message schedule (``n_workers`` … ``T``, ``variant``),
  * threat model (a :class:`repro.core.attacks.ByzantineSpec`),
  * delivery model (``"uniform"`` = Assumption 7, ``"trace"`` = a realized
    ``repro.netsim`` schedule from the named ``scenario``),
  * per-role GARs (``gar``/``pull_gar``/``gather_gar``/``worker_gar`` — the
    comm-optimized schedules of arXiv:1911.07537 are just field choices),
  * model / data / schedule referenced **by registry name** (``MODELS`` /
    ``DATA`` / ``SCHEDULES`` below), never by closure,
  * the runner (``stepwise`` oracle loop, ``fused`` epoch engine, or
    ``netsim`` trace-driven) and backend knobs.

Specs are plain values: ``to_dict``/``from_dict`` round-trip exactly
(including through JSON), ``spec_hash`` is stable under dict key order, and
invalid combinations fail at construction, not at run time. ``Experiment``
*lowers* to the internal carriers — :meth:`to_config` (``ByzSGDConfig``) and
:meth:`to_scenario` (netsim ``Scenario``) — and the lowering cross-validates
that the round trip preserved every shared field.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Callable

from ..configs.paper_models import make_mlp_problem
from ..core.attacks import GRADIENT_ATTACKS, MODEL_ATTACKS, ByzantineSpec
from ..core.membership import MembershipPlan, epoch_config
from ..core.simulator import ByzSGDConfig
from ..data.pipeline import MixtureSpec, TokenSpec
from ..optim import schedules as _schedules

# ---------------------------------------------------------------------------
# named resources: models / data / lr schedules
# ---------------------------------------------------------------------------

#: model registry. Two kinds of entry:
#:
#: * ``{"hidden", "depth"}`` — MLP width (depth-2 MLPs mirror the paper's
#:   CPU-scale testbed models; see configs/paper_models.py), trainable by
#:   every runner;
#: * ``{"arch", "reduced", ...overrides}`` — a ``models/`` zoo architecture,
#:   lowered via :func:`repro.models.registry.get_bundle` (extra keys are
#:   ``ArchConfig.reduced`` overrides). Arch entries train through the
#:   distributed protocol only (``runner="protocol"``): their sharded-pytree
#:   states, activation sharding rules and token batches are protocol-engine
#:   capabilities the single-host simulator does not carry.
MODELS: dict[str, dict[str, Any]] = {
    "mlp_h32": {"hidden": 32, "depth": 2},
    "mlp_h64": {"hidden": 64, "depth": 2},
    "mlp_h128": {"hidden": 128, "depth": 2},
    "mlp_h256": {"hidden": 256, "depth": 2},
    "mlp_h1024": {"hidden": 1024, "depth": 2},
    # reduced zoo archs — one per trainable model family (dense transformer
    # with the flash-attention hot path, MoE, RWKV6 SSM)
    "tfm_tiny": {"arch": "phi4-mini-3.8b", "reduced": True},
    "moe_tiny": {"arch": "qwen3-moe-235b-a22b", "reduced": True},
    "rwkv_tiny": {"arch": "rwkv6-3b", "reduced": True},
}


def is_arch_model(name: str) -> bool:
    """True iff the MODELS entry lowers through the models/ zoo registry."""
    return "arch" in MODELS[name]

#: data registry: name -> synthetic task. MixtureSpec entries feed the MLP
#: models (see data/pipeline.py for why MNIST/CIFAR are substituted);
#: TokenSpec entries feed the arch-registry LM models (Zipf-distributed
#: next-token batches).
DATA: dict[str, MixtureSpec | TokenSpec] = {
    # the benchmark default (harder task: close centres, high noise)
    "mixture10": MixtureSpec(n_classes=10, dim=32, sep=1.0, noise=1.2),
    # the quickstart/example task (well-separated, converges in ~100 steps)
    "mixture10_easy": MixtureSpec(n_classes=10, dim=32),
    # tiny task for smoke presets and netsim walkthroughs
    "mixture5_small": MixtureSpec(n_classes=5, dim=16, sep=2.5),
    # LM token task matching the reduced zoo vocab (ArchConfig.reduced)
    "tokens_tiny": TokenSpec(vocab=512, seq=64),
}

#: lr-schedule registry: name -> factory(lr0, decay) (paper condition B.1)
SCHEDULES: dict[str, Callable] = {
    "inverse_linear": lambda lr0, decay: _schedules.inverse_linear(lr0, decay),
    "inverse_sqrt": lambda lr0, decay: _schedules.inverse_sqrt(lr0),
    "constant": lambda lr0, decay: _schedules.constant(lr0),
}

#: schedules whose factory actually consumes ``decay`` — setting decay on any
#: other schedule is rejected at construction (it would change spec_hash and
#: provenance without changing the run)
SCHEDULES_WITH_DECAY = frozenset({"inverse_linear"})

RUNNERS = ("stepwise", "fused", "netsim", "protocol", "elastic")
DELIVERIES = ("uniform", "trace")
PROTOCOL_ENGINES = ("naive", "sharded")


@dataclass(frozen=True)
class Experiment:
    """One serializable experiment spec; see the module docstring."""
    name: str = "experiment"
    # -- cluster shape + message schedule (paper Table 1 preconditions)
    n_workers: int = 9
    f_workers: int = 2
    n_servers: int = 5
    f_servers: int = 1
    q_workers: int | None = None
    q_servers: int | None = None
    T: int = 10
    variant: str = "async"            # "async" | "sync"
    # -- per-role GARs (any repro.agg registry name with pytree support)
    gar: str = "mda"
    pull_gar: str = "median"
    gather_gar: str = "median"
    worker_gar: str = "meamed"
    # -- threat model
    byz: ByzantineSpec = field(default_factory=ByzantineSpec)
    # -- delivery model
    delivery: str = "uniform"         # "uniform" | "trace"
    scenario: str | None = None       # netsim scenario name (delivery="trace")
    model_d: int | None = None        # netsim payload size override (scalars)
    # -- model / data / optimizer by registry name
    model: str = "mlp_h64"
    data: str = "mixture10"
    schedule: str = "inverse_linear"
    optimizer: str = "sgd"            # repro.optim registry ref; non-sgd is a
                                      # protocol/elastic-runner capability
    lr0: float = 0.05
    decay: float = 0.005
    l2: float = 1e-4
    # -- run shape
    runner: str = "fused"     # "stepwise" | "fused" | "netsim" | "protocol"
    steps: int = 150
    batch: int = 25
    seed: int = 0
    metrics_every: int = 10
    eval_n: int = 2048
    track_delta: bool = False
    # -- protocol + backend knobs
    lip_horizon: int = 128
    mda_exact_limit: int = 200_000
    agg_backend: str | None = None    # None = process default (env/auto)
    sort_network: bool = True
    epoch_steps: int | None = None    # fused scan chunk (None = T)
    protocol_engine: str = "sharded"  # runner="protocol" collective engine
    # -- checkpointing (runner="protocol"): emit the replica-stacked ByzState
    # every ckpt_every steps into ckpt_dir (repro.checkpoint format; serve
    # restores it via repro.serve.ReplicaPool.from_checkpoint). Presets may
    # set ckpt_every with ckpt_dir=None — callers pass ckpt_dir at run time.
    ckpt_every: int | None = None
    ckpt_dir: str | None = None
    # -- elastic membership (runner="elastic"): a declarative join/leave
    # schedule in virtual steps (core/membership.py). None with
    # runner="elastic" means: lower the plan from the named netsim scenario's
    # realized crash windows (scenario set), or run statically (no scenario —
    # bit-identical to runner="protocol").
    membership_plan: MembershipPlan | None = None

    # -- construction-time validation -------------------------------------
    def __post_init__(self):
        if not isinstance(self.byz, ByzantineSpec):
            raise TypeError("byz must be a ByzantineSpec "
                            f"(got {type(self.byz).__name__})")
        # normalize attack_kwargs to a tuple-of-pairs so equality and hashing
        # are representation-independent (JSON round-trips lists)
        kw = tuple((str(k), v) for k, v in self.byz.attack_kwargs)
        if kw != self.byz.attack_kwargs:
            object.__setattr__(self, "byz",
                               dataclasses.replace(self.byz, attack_kwargs=kw))
        if self.runner not in RUNNERS:
            raise ValueError(f"unknown runner {self.runner!r}; "
                             f"choose from {RUNNERS}")
        if self.delivery not in DELIVERIES:
            raise ValueError(f"unknown delivery {self.delivery!r}; "
                             f"choose from {DELIVERIES}")
        if self.runner == "netsim" and self.delivery != "trace":
            object.__setattr__(self, "delivery", "trace")
        if self.membership_plan is not None:
            mp = self.membership_plan
            if isinstance(mp, dict):
                mp = MembershipPlan.from_dict(mp)
                object.__setattr__(self, "membership_plan", mp)
            if not isinstance(mp, MembershipPlan):
                raise TypeError("membership_plan must be a MembershipPlan "
                                f"(got {type(mp).__name__})")
            if self.runner != "elastic":
                raise ValueError(
                    'membership_plan is a runner="elastic" knob (only the '
                    "elastic runner re-forms the mesh at membership "
                    f"boundaries); got runner={self.runner!r}")
        if self.runner == "elastic" and self.delivery == "trace":
            raise ValueError(
                'runner="elastic" needs delivery="uniform": trace delivery '
                "tables are staged at the launch fleet width and cannot "
                "follow a membership change (a scenario still drives the "
                'elastic run — its realized crash windows become the '
                "membership plan)")
        if self.delivery == "trace" and self.scenario is None:
            raise ValueError('delivery="trace" needs a netsim scenario '
                             "name (Experiment.scenario)")
        if self.scenario is not None:
            from ..netsim import scenarios as _scen
            if self.scenario not in _scen.SCENARIOS:
                raise ValueError(f"unknown netsim scenario {self.scenario!r}; "
                                 f"have {sorted(_scen.SCENARIOS)}")
        for reg, key in ((MODELS, "model"), (DATA, "data"),
                         (SCHEDULES, "schedule")):
            val = getattr(self, key)
            if val not in reg:
                raise ValueError(f"unknown {key} {val!r}; "
                                 f"registered: {sorted(reg)}")
        if is_arch_model(self.model):
            if self.runner != "protocol":
                raise ValueError(
                    f"model {self.model!r} is an arch-registry model and "
                    'trains through runner="protocol" only (sharded states, '
                    "activation sharding rules and token batches are "
                    f"protocol-engine capabilities); got {self.runner!r}")
            if not isinstance(DATA[self.data], TokenSpec):
                raise ValueError(
                    f"arch model {self.model!r} needs token data (a TokenSpec "
                    f"DATA entry); {self.data!r} is "
                    f"{type(DATA[self.data]).__name__}")
            vocab = self.build_bundle().cfg.vocab
            if DATA[self.data].vocab != vocab:
                raise ValueError(
                    f"data {self.data!r} has vocab {DATA[self.data].vocab} "
                    f"but model {self.model!r} has vocab {vocab}")
        elif isinstance(DATA[self.data], TokenSpec):
            raise ValueError(
                f"MLP model {self.model!r} needs mixture data (a MixtureSpec "
                f"DATA entry); {self.data!r} is a TokenSpec")
        from .. import optim as _optim
        if self.optimizer not in _optim.OPTIMIZERS:
            raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                             f"registered: {sorted(_optim.OPTIMIZERS)}")
        if self.optimizer != "sgd" and self.runner not in ("protocol",
                                                           "elastic"):
            raise ValueError(
                f"optimizer={self.optimizer!r} needs the protocol/elastic "
                "runner (the single-host simulator implements the paper's "
                f"Eq. 2 SGD only); got runner={self.runner!r}")
        default_decay = type(self).__dataclass_fields__["decay"].default
        if self.schedule not in SCHEDULES_WITH_DECAY \
                and self.decay != default_decay:
            raise ValueError(
                f"schedule {self.schedule!r} ignores decay — setting "
                f"decay={self.decay} would change the spec_hash without "
                f"changing the run (leave it at the default {default_decay})")
        wa, sa = self.byz.worker_attack, self.byz.server_attack
        if wa is not None and wa not in GRADIENT_ATTACKS:
            raise ValueError(f"unknown worker_attack {wa!r}; "
                             f"have {sorted(GRADIENT_ATTACKS)}")
        if sa is not None and sa not in MODEL_ATTACKS:
            raise ValueError(f"unknown server_attack {sa!r}; "
                             f"have {sorted(MODEL_ATTACKS)}")
        for key, lo in (("steps", 1), ("batch", 1), ("metrics_every", 1),
                        ("eval_n", 1), ("T", 1)):
            if getattr(self, key) < lo:
                raise ValueError(f"{key} must be >= {lo}, "
                                 f"got {getattr(self, key)}")
        if self.agg_backend not in (None, "auto", "jnp", "pallas"):
            raise ValueError(f"unknown agg_backend {self.agg_backend!r}")
        if self.ckpt_every is not None:
            if self.runner not in ("protocol", "elastic"):
                raise ValueError(
                    'ckpt_every is a runner="protocol"/"elastic" knob (those '
                    "engines own the replica-stacked ByzState that "
                    f"checkpoints save); got runner={self.runner!r}")
            if self.ckpt_every < 1:
                raise ValueError(f"ckpt_every must be >= 1, "
                                 f"got {self.ckpt_every}")
        elif self.ckpt_dir is not None and self.runner != "elastic":
            # the elastic runner reads ckpt_dir without ckpt_every: it resumes
            # from the latest checkpoint and still saves at every membership
            # boundary (+ the final step) even without a periodic cadence
            raise ValueError("ckpt_dir without ckpt_every does nothing; "
                             "set ckpt_every to emit checkpoints")
        if self.protocol_engine not in PROTOCOL_ENGINES:
            raise ValueError(f"unknown protocol_engine "
                             f"{self.protocol_engine!r}; "
                             f"choose from {PROTOCOL_ENGINES}")
        # the cluster-shape / GAR / threat-model preconditions: lowering to
        # ByzSGDConfig runs the paper's Table-1 validation + registry checks
        self.to_config()
        if self.runner in ("protocol", "elastic"):
            # the distributed path maps G co-located worker+server groups
            # onto 'rep' failure domains: shape + rule capabilities validated
            # by lowering to ProtocolConfig at construction, not at run time
            pcfg = self.to_protocol_config()
            if self.runner == "elastic" and self.membership_plan is not None:
                # every membership epoch must satisfy Table 1 for its shrunk/
                # regrown fleet — a below-floor plan fails HERE, not mid-run
                for seg in self.membership_plan.epochs(self.n_workers,
                                                       self.steps):
                    epoch_config(pcfg, seg.active,
                                 synchronous=(self.variant == "sync"))

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        """Nested plain-value dict (JSON-compatible; tuples become lists on a
        JSON round trip, which :meth:`from_dict` normalizes back)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Experiment":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Experiment fields: {sorted(unknown)}")
        byz = d.get("byz")
        if isinstance(byz, dict):
            byz = dict(byz)
            byz["attack_kwargs"] = tuple(
                (str(k), v) for k, v in byz.get("attack_kwargs", ()))
            d["byz"] = ByzantineSpec(**byz)
        mp = d.get("membership_plan")
        if isinstance(mp, dict):
            d["membership_plan"] = MembershipPlan.from_dict(mp)
        return cls(**d)

    @property
    def spec_hash(self) -> str:
        """Stable content hash: canonical JSON (sorted keys) of
        :meth:`to_dict`, independent of field/dict ordering."""
        blob = json.dumps(self.to_dict(), sort_keys=True, default=list)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def replace(self, **kw) -> "Experiment":
        return dataclasses.replace(self, **kw)

    # -- lowering to the internal carriers ---------------------------------
    def to_config(self) -> ByzSGDConfig:
        """Lower to the simulator's ``ByzSGDConfig`` and cross-validate that
        the lowering round-trips (every shared field preserved)."""
        cfg = ByzSGDConfig(
            n_workers=self.n_workers, f_workers=self.f_workers,
            n_servers=self.n_servers, f_servers=self.f_servers,
            q_workers=self.q_workers, q_servers=self.q_servers, T=self.T,
            gar=self.gar, pull_gar=self.pull_gar,
            gather_gar=self.gather_gar, worker_gar=self.worker_gar,
            variant=self.variant, mda_exact_limit=self.mda_exact_limit,
            lip_horizon=self.lip_horizon, byz=self.byz)
        for key in ("n_workers", "f_workers", "n_servers", "f_servers", "T",
                    "gar", "pull_gar", "gather_gar", "worker_gar", "variant",
                    "byz"):
            if getattr(cfg, key) != getattr(self, key):
                raise ValueError(f"lowering to ByzSGDConfig changed {key}: "
                                 f"{getattr(self, key)!r} -> "
                                 f"{getattr(cfg, key)!r}")
        for key in ("q_workers", "q_servers"):
            mine = getattr(self, key)
            if mine is not None and getattr(cfg, key) != mine:
                raise ValueError(f"lowering to ByzSGDConfig changed {key}")
        return cfg

    def to_protocol_config(self):
        """Lower to the distributed :class:`~repro.core.protocol.ProtocolConfig`
        (``runner="protocol"``), cross-validated like :meth:`to_config`.

        The protocol's failure domains are G co-located worker+server groups,
        so the spec must declare ``n_workers == n_servers`` (= G); quorum
        defaults come from the ``ByzSGDConfig`` lowering so the 1-device
        protocol draws the same quorums as the single-host oracle. The
        ``variant`` maps onto the protocol's pull schedule: async → masked
        ``pull_gar`` over the delivered quorum (oracle-matched against the
        fused runner on a 1-device mesh), sync → the protocol's own §5
        round-robin pull + distance filter — a collective formulation that is
        a *documented deviation* from the single-host sync filter variant
        (different filters, no per-worker model state), so sync protocol runs
        are not equivalence-gated against the fused runner."""
        from ..core.protocol import ProtocolConfig
        if self.n_workers != self.n_servers:
            raise ValueError(
                f'runner="protocol" maps co-located worker+server groups '
                f"onto 'rep' failure domains and needs "
                f"n_workers == n_servers (= G); got "
                f"{self.n_workers} != {self.n_servers}")
        cfg = self.to_config()
        pcfg = ProtocolConfig.derive(
            self.n_workers, T=self.T, engine=self.protocol_engine,
            pull=("roundrobin" if self.variant == "sync" else "median"),
            f_workers=self.f_workers, f_servers=self.f_servers,
            q_workers=cfg.q_workers, q_servers=cfg.q_servers,
            gar=self.gar, pull_gar=self.pull_gar,
            gather_gar=self.gather_gar, optimizer=self.optimizer,
            mda_exact_limit=self.mda_exact_limit, byz=self.byz)
        for key, mine in (("n_groups", self.n_workers),
                          ("f_workers", self.f_workers),
                          ("f_servers", self.f_servers),
                          ("q_workers", cfg.q_workers),
                          ("q_servers", cfg.q_servers), ("T", self.T),
                          ("gar", self.gar), ("pull_gar", self.pull_gar),
                          ("gather_gar", self.gather_gar),
                          ("optimizer", self.optimizer),
                          ("byz", self.byz)):
            if getattr(pcfg, key) != mine:
                raise ValueError(f"lowering to ProtocolConfig changed {key}: "
                                 f"{mine!r} -> {getattr(pcfg, key)!r}")
        return pcfg

    def to_scenario(self, **overrides):
        """Lower to the netsim ``Scenario`` (via its factory registry),
        cross-validated: shape, schedule, GAR and threat-model fields must
        survive the factory unchanged. ``overrides`` are forwarded to the
        factory (e.g. ``model_d=…`` for payload sizing)."""
        from ..netsim import scenarios as _scen
        if self.scenario is None:
            raise ValueError(f"experiment {self.name!r} names no netsim "
                             "scenario")
        kw = dict(n_workers=self.n_workers, f_workers=self.f_workers,
                  n_servers=self.n_servers, f_servers=self.f_servers,
                  q_workers=self.q_workers, q_servers=self.q_servers,
                  T=self.T, steps=self.steps, seed=self.seed, gar=self.gar,
                  variant=self.variant,
                  worker_attack=self.byz.worker_attack,
                  server_attack=self.byz.server_attack,
                  n_byz_workers=self.byz.n_byz_workers,
                  n_byz_servers=self.byz.n_byz_servers)
        if self.model_d is not None:
            kw["model_d"] = self.model_d
        kw.update(overrides)
        sc = _scen.build(self.scenario, **kw)
        for key in ("n_workers", "f_workers", "n_servers", "f_servers", "T",
                    "gar", "variant", "worker_attack", "server_attack",
                    "n_byz_workers", "n_byz_servers"):
            if getattr(sc, key) != kw[key]:
                raise ValueError(f"lowering to Scenario changed {key}: "
                                 f"{kw[key]!r} -> {getattr(sc, key)!r}")
        return sc

    # -- resource construction ---------------------------------------------
    @property
    def mixture(self) -> MixtureSpec:
        return DATA[self.data]

    def build_problem(self):
        """(init_fn, loss_fn, accuracy_fn) for the named model on the named
        data spec (MLP models; arch models lower via :meth:`build_bundle`)."""
        if is_arch_model(self.model):
            raise ValueError(
                f"model {self.model!r} is an arch-registry model; it lowers "
                "through build_bundle() (a ModelBundle), not the MLP "
                "(init, loss, acc) problem triple")
        mix = self.mixture
        m = MODELS[self.model]
        return make_mlp_problem(dim=mix.dim, hidden=m["hidden"],
                                n_classes=mix.n_classes, depth=m["depth"],
                                l2=self.l2)

    def build_bundle(self):
        """The protocol-ready bundle for the named model: the zoo
        :class:`~repro.models.registry.ModelBundle` for arch entries
        (registry overrides applied on the reduced config), or the MLP
        problem wrapped in a
        :class:`~repro.core.protocol.ProblemBundle`."""
        m = MODELS[self.model]
        if "arch" in m:
            from ..models.registry import get_bundle
            kw = {k: v for k, v in m.items() if k not in ("arch", "reduced")}
            return get_bundle(m["arch"], reduced=m.get("reduced", False),
                              **kw)
        from ..core.protocol import ProblemBundle
        init, loss, _ = self.build_problem()
        return ProblemBundle(init=init, loss=loss)

    def build_schedule(self):
        return SCHEDULES[self.schedule](self.lr0, self.decay)

    def build_sim(self, delivery=None):
        """A ready :class:`~repro.core.simulator.ByzSGDSimulator` (delivery
        defaults to ``UniformDelivery``; pass a ``TraceDelivery`` for
        trace-driven runs)."""
        from ..core.simulator import ByzSGDSimulator
        init, loss, _ = self.build_problem()
        return ByzSGDSimulator(self.to_config(), init, loss,
                               self.build_schedule(), delivery=delivery)
