"""Named experiment presets — the registry :func:`repro.exp.run` resolves.

A preset is a frozen :class:`~repro.exp.spec.Experiment`; :func:`get` applies
field overrides with ``dataclasses.replace`` (re-validating), so every CLI
(``benchmarks/run.py --exp NAME --override key=val``) and test shrinks or
scales presets without bespoke wiring.

The ``netsim/*`` presets mirror — and subsume — the ``repro.netsim.scenarios``
factories: each names its scenario and the matching threat model, with
``runner="netsim"`` so :func:`repro.exp.run` simulates the cluster and trains
over the realized trace. ``python -m repro.exp`` prints the table below for
the README.
"""
from __future__ import annotations

from ..core.attacks import ByzantineSpec
from ..core.membership import MembershipEvent, MembershipPlan
from .spec import Experiment

_PRESETS: dict[str, Experiment] = {}


def register(exp: Experiment, *, replace: bool = False) -> Experiment:
    """Register a preset under ``exp.name`` (third parties included)."""
    if exp.name in _PRESETS and not replace:
        raise ValueError(f"experiment preset {exp.name!r} already registered")
    _PRESETS[exp.name] = exp
    return exp


def get(name: str, **overrides) -> Experiment:
    """Preset by name, with field overrides applied (and re-validated)."""
    try:
        base = _PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown experiment preset {name!r}; "
                       f"have {sorted(_PRESETS)}") from None
    return base.replace(**overrides) if overrides else base


def names() -> tuple[str, ...]:
    return tuple(sorted(_PRESETS))


def specs() -> tuple[Experiment, ...]:
    return tuple(_PRESETS[n] for n in names())


# ---------------------------------------------------------------------------
# built-in presets
# ---------------------------------------------------------------------------

# the CI/`make exp` smoke spec: small enough to run through every runner in
# seconds, shaped to exercise a gather boundary and a tail (steps % T != 0).
# n_workers == n_servers so the same spec also sweeps onto the distributed
# protocol runner (G = 5 co-located groups) unchanged.
register(Experiment(
    name="smoke", n_workers=5, f_workers=1, n_servers=5, f_servers=1, T=5,
    steps=12, batch=8, model="mlp_h32", data="mixture5_small",
    scenario="baseline_uniform", metrics_every=5, eval_n=256))

# clean baselines (Fig. 3): async and sync ByzSGD without adversaries
register(Experiment(name="clean_async", variant="async", steps=120))
register(Experiment(name="clean_sync", variant="sync", n_workers=5,
                    f_workers=1, steps=120))

# the quickstart: 2/9 workers mounting ALIE, converges anyway (§6 headline)
register(Experiment(
    name="quickstart", data="mixture10_easy",
    byz=ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                      equivocate=True)))

# Fig. 6 operating point: max declared f_w, all of it actually Byzantine
register(Experiment(
    name="alie_workers", n_workers=13, f_workers=4, steps=120,
    byz=ByzantineSpec(worker_attack="alie", n_byz_workers=4,
                      equivocate=True)))

# Fig. 5 operating points: one Byzantine server
register(Experiment(
    name="lie_server", steps=120, track_delta=True,
    byz=ByzantineSpec(server_attack="lie", n_byz_servers=1,
                      equivocate=True)))
register(Experiment(
    name="reversed_server", steps=120, track_delta=True,
    byz=ByzantineSpec(server_attack="reversed", n_byz_servers=1,
                      equivocate=True)))

# sync filter variant under a Byzantine server (Fig. 10 operating point)
register(Experiment(
    name="sync_filters", variant="sync", n_workers=5, f_workers=1, T=20,
    steps=100, batch=100, lip_horizon=32, l2=3e-2, decay=0.001,
    byz=ByzantineSpec(server_attack="reversed", n_byz_servers=1,
                      equivocate=True)))

# netsim presets: one per scenario factory, trained over the realized trace
_NETSIM_COMMON = dict(
    runner="netsim", T=5, steps=30, batch=16, model="mlp_h32",
    data="mixture5_small", metrics_every=10, eval_n=512)
for _scen in ("baseline_uniform", "heavy_tail_stragglers", "partitioned_dmc",
              "crash_storm", "membership_churn"):
    register(Experiment(name=f"netsim/{_scen}", scenario=_scen,
                        **_NETSIM_COMMON))
# the compound adversary: netsim makes the Byzantine workers slow, the
# simulator's injection makes them malicious (mirrors the factory's defaults)
register(Experiment(
    name="netsim/byzantine_plus_slow", scenario="byzantine_plus_slow",
    byz=ByzantineSpec(worker_attack="alie", n_byz_workers=2, equivocate=True),
    **_NETSIM_COMMON))

# serve presets: protocol-runner training that emits replica-stacked
# checkpoints for repro.serve (ckpt_dir comes from the caller at run time:
# exp.run("serve/ckpt_smoke", ckpt_dir=...)). G=5 satisfies Table 1's
# n_ps >= 3f+2 for training; serving reads tolerate f=1 of any 2f+1 subset.
_SERVE_COMMON = dict(
    runner="protocol", n_workers=5, f_workers=1, n_servers=5, f_servers=1,
    T=5, steps=10, batch=8, model="mlp_h32", data="mixture5_small",
    metrics_every=5, eval_n=256, ckpt_every=5)
register(Experiment(name="serve/ckpt_smoke", **_SERVE_COMMON))
# same training run with a lie-attacking server: the checkpoint carries the
# corrupted replica, which quorum reads (or a consolidated restore) outvote
register(Experiment(
    name="serve/ckpt_lie_server",
    byz=ByzantineSpec(server_attack="lie", n_byz_servers=1, equivocate=True),
    **_SERVE_COMMON))


# elastic presets: join/leave-tolerant protocol training (core/membership).
# G=5 launches at the declared Table-1 point (f_w=f_ps=1); while a group is
# down (G'=4) the churn-driven resilience caps f_ps' at 0, so these presets
# stay honest (no Byzantine servers) — a Byz-server spec with a shrink event
# is rejected at construction (MembershipFloorError).
_ELASTIC_COMMON = dict(
    runner="elastic", n_workers=5, f_workers=1, n_servers=5, f_servers=1,
    T=5, steps=24, batch=8, model="mlp_h32", data="mixture5_small",
    metrics_every=4, eval_n=256)
# static fleet: bit-identical to runner="protocol" on the same spec (the
# elastic equivalence gate, tests/test_membership.py)
register(Experiment(name="elastic/static", **_ELASTIC_COMMON))
# authored plan: group 4 leaves at step 8 (G 5->4) and rejoins at step 16,
# re-seeded from the DMC median of the survivors
register(Experiment(
    name="elastic/planned_churn",
    membership_plan=MembershipPlan(events=(
        MembershipEvent(step=8, kind="leave", group=4),
        MembershipEvent(step=16, kind="join", group=4))),
    **_ELASTIC_COMMON))
# scenario-driven plan: the membership_churn crash windows realize through
# the netsim engine and lower to leave/join events (plan_from_trace)
register(Experiment(name="elastic/netsim_churn", scenario="membership_churn",
                    **_ELASTIC_COMMON))


# lm presets: zoo architectures through the distributed protocol — one per
# trainable model family (dense transformer / MoE / RWKV6 SSM), reduced
# configs on the Zipf token task. G=4 co-located groups satisfy Table 1
# (n_w >= 3·1+1 = 4 workers, n_ps >= 3·0+2 = 2 servers) AND split 2D on an
# 8-device fleet: make_protocol_mesh lights up (rep=4, fsdp=2, model=1), so
# these presets are the repo's paper-scale 2D-sharding acceptance path. The
# "acc" metric is the NEGATIVE eval loss (higher is better; README §Models).
_LM_COMMON = dict(
    runner="protocol", n_workers=4, f_workers=1, n_servers=4, f_servers=0,
    T=5, steps=12, batch=4, data="tokens_tiny", schedule="constant",
    lr0=0.02, metrics_every=4, eval_n=64)
register(Experiment(name="lm/tfm_tiny", model="tfm_tiny", **_LM_COMMON))
register(Experiment(name="lm/moe_tiny", model="moe_tiny", **_LM_COMMON))
register(Experiment(name="lm/rwkv_tiny", model="rwkv_tiny", **_LM_COMMON))


# ---------------------------------------------------------------------------
# registry-derived documentation (README preset table)
# ---------------------------------------------------------------------------


def runners_table() -> str:
    """README "Runners" table (``python -m repro.exp`` regenerates it).

    One row per ``Experiment.runner`` value; the collective-volume column
    models the per-step cross-'rep' exchange of the protocol's two collective
    engines (P = model parameters; see
    ``repro.core.protocol.collective_volume_bytes``)."""
    rows = [
        ("stepwise", "per-step jitted oracle loop (`ByzSGDSimulator.run`)",
         "uniform or trace", "one host, replica-stacked `[n_ps, ...]`", "—"),
        ("fused", "donated `lax.scan` epochs (`EpochEngine`)",
         "uniform or trace", "one host, replica-stacked `[n_ps, ...]`", "—"),
        ("netsim", "fused epochs over the realized netsim trace "
         "(+ cluster accounting in the result)", "trace",
         "one host, replica-stacked `[n_ps, ...]`", "—"),
        ("protocol", "donated `lax.scan` epochs (`ProtocolEngine`)",
         "uniform or trace",
         "`[G, ...]` sharded over the ('rep','fsdp','model') mesh",
         "2(G−1)·P/K either engine, K = fsdp axis size (HLO-audited; the "
         "engines differ in temp memory, not ring traffic)"),
        ("elastic", "protocol epochs chunked at membership boundaries "
         "(`core/membership.py`): mesh/quorums re-formed per epoch, "
         "checkpointed resume, DMC-seeded re-admission", "uniform",
         "`[G', ...]` re-stacked per membership epoch", "as protocol, "
         "per-epoch G′"),
    ]
    out = ["| runner | loop | delivery | state layout | "
           "per-step collective volume |",
           "|---|---|---|---|---|"]
    for name, loop, deliv, layout, vol in rows:
        out.append(f"| `{name}` | {loop} | {deliv} | {layout} | {vol} |")
    return "\n".join(out)


def models_table() -> str:
    """README "Models" table (``python -m repro.exp`` regenerates it).

    One row per ``repro.exp.spec.MODELS`` registry entry. Zoo archs lower
    through ``models.registry.get_bundle`` and train only on the protocol
    runner (they need the mesh + activation-sharding rules); their "acc"
    metric is the NEGATIVE eval loss, so higher is better everywhere."""
    from ..models.registry import get_bundle
    from .spec import MODELS, is_arch_model
    out = ["| model | definition | family | runners | `acc` metric |",
           "|---|---|---|---|---|"]
    for name in sorted(MODELS):
        m = MODELS[name]
        if is_arch_model(name):
            cfg = get_bundle(m["arch"],
                             reduced=m.get("reduced", False)).cfg
            defn = f"zoo `{m['arch']}`"
            if m.get("reduced"):
                defn += " (reduced)"
            fam, runners = cfg.family, "`protocol`"
            metric = "negative eval loss (higher is better)"
        else:
            defn = f"MLP (hidden {m['hidden']}, depth {m['depth']})"
            fam, runners = "mlp", "all"
            metric = "eval accuracy"
        out.append(f"| `{name}` | {defn} | {fam} | {runners} | {metric} |")
    return "\n".join(out)


def markdown_table() -> str:
    """README preset table (``python -m repro.exp`` regenerates it)."""
    head = ("| preset | runner | variant | cluster (n_w/f_w, n_ps/f_ps, T) | "
            "gar | attack | steps |")
    out = [head, "|---|---|---|---|---|---|---|"]
    for e in specs():
        atk = "—"
        if e.byz.worker_attack:
            atk = f"{e.byz.worker_attack} ×{e.byz.n_byz_workers} (workers)"
        elif e.byz.server_attack:
            atk = f"{e.byz.server_attack} ×{e.byz.n_byz_servers} (servers)"
        out.append(
            f"| `{e.name}` | {e.runner} | {e.variant} | "
            f"{e.n_workers}/{e.f_workers}, {e.n_servers}/{e.f_servers}, "
            f"T={e.T} | `{e.gar}` | {atk} | {e.steps} |")
    return "\n".join(out)
