"""repro.exp — the declarative Experiment API.

One serializable spec, one ``run()``, every runner::

    import repro.exp as exp

    res = exp.run("quickstart")                    # named preset
    res = exp.run("smoke", runner="netsim")        # preset + overrides
    e = exp.Experiment(gar="krum", steps=60)       # or build a spec
    res = exp.run(e.replace(runner="stepwise"))    # oracle loop
    exp.Experiment.from_dict(e.to_dict()) == e     # exact round trip
    e.spec_hash                                    # stable content hash

An :class:`Experiment` names everything a run needs — cluster shape, threat
model, delivery model, per-role GARs, model/data/schedule registry refs,
runner, backend knobs — and lowers to the internal carriers (``ByzSGDConfig``,
netsim ``Scenario``) with round-trip cross-validation. :func:`run` returns a
uniform :class:`RunResult` (metrics + provenance) for the stepwise oracle,
the fused epoch engine, and netsim trace-driven runs alike.

``python -m repro.exp`` prints the preset table (the README section);
``python -m benchmarks.run --exp NAME --override key=val`` runs any preset.
"""
from __future__ import annotations

from . import presets, runners, spec  # noqa: F401
from .presets import (get, markdown_table, models_table, names, register,
                      runners_table)
from .runners import RunResult, git_sha, provenance, run, write_result
from .spec import DATA, MODELS, SCHEDULES, Experiment

__all__ = [
    "DATA", "Experiment", "MODELS", "RunResult", "SCHEDULES", "get",
    "git_sha", "markdown_table", "models_table", "names", "presets",
    "provenance", "register", "run", "runners", "runners_table", "spec",
    "write_result",
]
