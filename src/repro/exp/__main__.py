"""Print the registry-derived README tables (runners + models + presets).

    PYTHONPATH=src python -m repro.exp
"""
from .presets import markdown_table, models_table, runners_table

if __name__ == "__main__":
    print(runners_table())
    print()
    print(models_table())
    print()
    print(markdown_table())
