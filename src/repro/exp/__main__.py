"""Print the registry-derived experiment preset table (the README section).

    PYTHONPATH=src python -m repro.exp
"""
from .presets import markdown_table

if __name__ == "__main__":
    print(markdown_table())
