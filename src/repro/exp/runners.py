"""``repro.exp.run`` — one entry point, every runner.

Dispatches an :class:`~repro.exp.spec.Experiment` to

  * ``stepwise`` — the per-step ``ByzSGDSimulator.run`` reference loop (the
    debugging/correctness oracle; host batch iterator, host metrics),
  * ``fused``    — the compiled :class:`repro.core.engine.EpochEngine` hot
    path (device batch stream, donated ``lax.scan`` epochs, one host
    transfer),
  * ``netsim``   — a trace-driven run: the named netsim scenario is simulated
    first, the realized quorums/staleness replay through ``TraceDelivery``,
    and the cluster's accounting rides along in the result,
  * ``protocol`` — the genuinely-distributed path: the same spec lowered to
    ``ProtocolConfig`` (G = n_workers = n_servers co-located groups) and run
    through :class:`repro.core.protocol.ProtocolEngine` fused epochs over a
    ('rep', 'fsdp', 'model') mesh built from the available devices (down to
    one device, where the fused runner is its oracle); the mesh shape and
    collective engine land in the result's provenance,

and returns a uniform :class:`RunResult`: strided metric ``logs``, ``final``
metrics, wall seconds, and a ``provenance`` block (spec hash + git sha +
jax/device info) that ``benchmarks/run.py`` writes verbatim into
``results/benchmarks/*.json``. The runners train the *same* experiment:
stepwise and fused are equivalence-tested (params allclose) in
``tests/test_exp.py``, and protocol against both in
``tests/test_protocol_engine.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from ..agg import default_backend
from ..agg.dispatch import backend_override
from ..agg.rules import use_sort_network
from ..core.engine import EpochEngine
from ..core.simulator import coordinatewise_diameter_sum, l2_diameter
from ..data.pipeline import (DeviceBatchStream, DeviceTokenStream,
                             classification_stream)
from . import presets
from .spec import Experiment


def git_sha() -> str | None:
    """Current repo revision, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance(spec_hash: str | None = None) -> dict[str, Any]:
    """The provenance block every result JSON carries."""
    dev = jax.devices()[0]
    return {"spec_hash": spec_hash, "git_sha": git_sha(),
            "jax_version": jax.__version__, "device": dev.platform,
            "device_kind": getattr(dev, "device_kind", None),
            "agg_backend": default_backend()}


@dataclass
class RunResult:
    """Uniform result of :func:`run` across the three runners.

    ``logs``/``final``/``wall_s``/``provenance``/``netsim`` serialize via
    :meth:`to_dict`; ``state`` (the final ``SimState``) and ``buffers`` (the
    dense per-step device metric buffers, host numpy) are runtime attachments
    for tests and notebook analysis, never written to JSON.
    """
    experiment: Experiment
    logs: list[dict]
    final: dict
    wall_s: float
    provenance: dict
    netsim: dict | None = None
    state: Any = field(default=None, repr=False, compare=False)
    buffers: dict | None = field(default=None, repr=False, compare=False)

    def to_dict(self) -> dict[str, Any]:
        out = {"experiment": self.experiment.to_dict(),
               "logs": self.logs, "final": self.final, "wall_s": self.wall_s,
               "provenance": self.provenance}
        if self.netsim is not None:
            out["netsim"] = self.netsim
        return out

    def summary(self) -> str:
        e = self.experiment
        bits = [f"[{e.name}] runner={e.runner}", f"steps={e.steps}",
                f"final acc {self.final.get('acc', float('nan')):.3f}",
                f"wall {self.wall_s:.1f}s", f"spec {e.spec_hash}"]
        if self.netsim is not None:
            bits.append(f"virtual {self.netsim['virtual_ms']:.0f}ms "
                        f"(shortfalls {self.netsim['shortfalls']})")
        return "  ".join(bits)


def run(experiment: Experiment | str, **overrides) -> RunResult:
    """Run an experiment (or a preset name) through its declared runner."""
    if isinstance(experiment, str):
        e = presets.get(experiment, **overrides)
    else:
        e = experiment.replace(**overrides) if overrides else experiment
    with backend_override(e.agg_backend), use_sort_network(e.sort_network):
        # delivery is orthogonal to the runner: a "trace" experiment can
        # train stepwise or fused; runner="netsim" is fused + trace with
        # the cluster accounting attached (delivery normalized at
        # construction).
        delivery, info = (_trace_delivery(e) if e.delivery == "trace"
                          else (None, None))
        if e.runner == "stepwise":
            return _run_stepwise(e, delivery, info)
        if e.runner == "protocol":
            return _run_protocol(e, delivery, info)
        if e.runner == "elastic":
            return _run_elastic(e)
        return _run_fused(e, delivery, info)


# ---------------------------------------------------------------------------
# runner implementations
# ---------------------------------------------------------------------------


def _trace_delivery(e: Experiment):
    """Simulate the named scenario; return (TraceDelivery, netsim dict)."""
    from ..netsim import ClusterSim
    sc = e.to_scenario()
    trace = ClusterSim(sc).run()
    step_ms = np.diff(np.maximum.accumulate(trace.step_done_ms), prepend=0.0)
    info = {
        "scenario": sc.name, "steps": int(sc.steps),
        "virtual_ms": float(trace.step_done_ms[-1]),
        "mean_step_ms": float(step_ms.mean()),
        "p95_step_ms": float(np.percentile(step_ms, 95)),
        "mean_pull_staleness_ms": float(trace.pull_stale.mean()),
        "events": int(trace.events), "shortfalls": int(trace.shortfalls),
        "totals": trace.ledger.totals(),
        "summary": trace.ledger.summary(sc),
    }
    return trace.to_delivery(), info


def _final_metrics(e: Experiment, state, acc, eval_set, mbuf=None) -> dict:
    p0 = jax.tree.map(lambda l: l[0], state.params)
    cfg = e.to_config()
    final = {"acc": float(acc(p0, *eval_set))}
    if e.track_delta:
        final["delta"] = float(coordinatewise_diameter_sum(state.params,
                                                           cfg.h_servers))
        final["l2_diam"] = float(l2_diameter(state.params, cfg.h_servers))
    if mbuf is not None and "rejects" in mbuf:
        final["rejects"] = int(np.asarray(mbuf["rejects"][-1]).sum())
    return final


def _run_stepwise(e: Experiment, delivery=None, netsim=None) -> RunResult:
    sim = e.build_sim(delivery)
    cfg = sim.cfg
    _, _, acc = e.build_problem()
    state = sim.init_state(jax.random.PRNGKey(e.seed))
    stream, eval_fn = classification_stream(e.seed, e.mixture, cfg.n_workers,
                                            e.batch, e.steps)
    ex, ey = eval_fn(e.eval_n)

    def metrics(s):
        m = {"acc": float(acc(jax.tree.map(lambda l: l[0], s.params), ex, ey))}
        if e.track_delta:
            m["delta"] = float(coordinatewise_diameter_sum(s.params,
                                                           cfg.h_servers))
            m["l2_diam"] = float(l2_diameter(s.params, cfg.h_servers))
        return m

    t0 = time.time()
    state, logs = sim.run(state, stream, metrics_fn=metrics,
                          metrics_every=e.metrics_every)
    wall = time.time() - t0
    final = _final_metrics(e, state, acc, (ex, ey))
    return RunResult(e, logs, final, wall, provenance(e.spec_hash),
                     netsim=netsim, state=state)


def _run_fused(e: Experiment, delivery=None, netsim=None) -> RunResult:
    sim = e.build_sim(delivery)
    cfg = sim.cfg
    _, _, acc = e.build_problem()
    state = sim.init_state(jax.random.PRNGKey(e.seed))
    stream = DeviceBatchStream(e.seed, e.mixture, cfg.n_workers, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    eng = EpochEngine(sim, acc_fn=acc, eval_set=(ex, ey),
                      track_delta=e.track_delta,
                      metrics_every=e.metrics_every)
    t0 = time.time()
    state, mbuf = eng.run(state, stream=stream, steps=e.steps,
                          epoch_steps=e.epoch_steps)
    wall = time.time() - t0

    logs = []
    for i in range(0, e.steps, e.metrics_every):
        m = {"step": i, "acc": float(mbuf["acc"][i])}
        if e.track_delta:
            m["delta"] = float(mbuf["delta"][i])
            m["l2_diam"] = float(mbuf["l2_diam"][i])
        if "rejects" in mbuf:
            m["rejects"] = int(np.asarray(mbuf["rejects"][i]).sum())
        stal = sim.delivery.staleness(i)
        if stal:
            m.update(stal)
        logs.append(m)
    final = _final_metrics(e, state, acc, (ex, ey), mbuf)
    return RunResult(e, logs, final, wall, provenance(e.spec_hash),
                     netsim=netsim, state=state, buffers=mbuf)


# (G, device_count) -> protocol mesh. Reusing the SAME Mesh object across
# runs (and across the elastic runner's membership epochs with equal G) keeps
# the engines' semantic compile cache hot: the epoch cache keys the mesh by
# identity, so a fresh Mesh per run would force a re-trace every time.
_MESH_CACHE: dict[tuple, Any] = {}


def _protocol_mesh(G: int):
    key = (G, jax.device_count())
    mesh = _MESH_CACHE.get(key)
    if mesh is None:
        from ..launch.mesh import make_protocol_mesh
        mesh = _MESH_CACHE[key] = make_protocol_mesh(G)
    return mesh


def _lm_acc(bundle):
    """LM metric under the runners' uniform ``acc`` key: NEGATIVE eval loss
    (higher is better, like accuracy; documented in README §Models)."""

    def acc(params, tokens, labels):
        return -bundle.loss(params, {"tokens": tokens, "labels": labels})

    return acc


def _run_protocol(e: Experiment, delivery=None, netsim=None) -> RunResult:
    from ..core import protocol as _protocol
    from ..launch.mesh import use_mesh
    from .spec import DATA, is_arch_model
    pcfg = e.to_protocol_config()
    G = pcfg.n_groups
    bundle = e.build_bundle()
    mesh = _protocol_mesh(G)
    if is_arch_model(e.model):
        # zoo arch through the protocol: token stream, activation sharding
        # rules from the launch layer, negative-eval-loss metric
        from ..launch.steps import train_rules
        stream = DeviceTokenStream(e.seed, DATA[e.data], G, e.batch)
        ex, ey = stream.eval_set(e.eval_n)
        acc = _lm_acc(bundle)
        rules = train_rules(mesh, bundle.cfg)
    else:
        _, _, acc = e.build_problem()
        stream = DeviceBatchStream(e.seed, e.mixture, G, e.batch)
        ex, ey = stream.eval_set(e.eval_n)
        rules = None
    with_attack = bool(e.byz.worker_attack or e.byz.server_attack)
    with use_mesh(mesh):
        eng = _protocol.ProtocolEngine(
            bundle, pcfg, e.build_schedule(), mesh=mesh, delivery=delivery,
            with_attack=with_attack, acc_fn=acc, eval_set=(ex, ey),
            track_delta=e.track_delta, metrics_every=e.metrics_every,
            rules=rules)
        state = eng.init_state(jax.random.PRNGKey(e.seed))
        t0 = time.time()
        if e.ckpt_every:
            # chunk the fused run at checkpoint boundaries: the engine's
            # gather cadence rides on the step counter carried in the state,
            # so chunking is training-equivalent to one eng.run call
            if not e.ckpt_dir:
                raise ValueError(
                    f"experiment {e.name!r} sets ckpt_every={e.ckpt_every} "
                    "but no ckpt_dir; pass one at run time, e.g. "
                    'exp.run(name, ckpt_dir="...")')
            from ..checkpoint import checkpointer as ck
            bufs, done = [], 0
            while done < e.steps:
                n = min(e.ckpt_every, e.steps - done)
                state, b = eng.run(state, stream=stream, steps=n,
                                   epoch_steps=e.epoch_steps)
                bufs.append(b)
                done += n
                ck.save(e.ckpt_dir, done, state)
            mbuf = {k: np.concatenate([b[k] for b in bufs])
                    for k in bufs[0]}
        else:
            state, mbuf = eng.run(state, stream=stream, steps=e.steps,
                                  epoch_steps=e.epoch_steps)
        wall = time.time() - t0

    logs = []
    for i in range(0, e.steps, e.metrics_every):
        m = {"step": i, "acc": float(mbuf["acc"][i])}
        if e.track_delta:
            m["delta"] = float(mbuf["delta"][i])
            m["l2_diam"] = float(mbuf["l2_diam"][i])
        stal = eng.delivery.staleness(i)
        if stal:
            m.update(stal)
        logs.append(m)
    final = _final_metrics(e, state, acc, (ex, ey), mbuf)
    prov = provenance(e.spec_hash)
    prov["mesh"] = dict(zip(mesh.axis_names,
                            (int(n) for n in mesh.devices.shape)))
    prov["protocol_engine"] = pcfg.engine
    return RunResult(e, logs, final, wall, prov, netsim=netsim, state=state,
                     buffers=mbuf)


class _GroupView:
    """Width-adapted view of a :class:`DeviceBatchStream`: draws batches for
    the epoch's active-group count while advancing the base stream's key chain
    one split per step — exactly as the full-width stream would — so the data
    sequence stays aligned with the global step counter across membership
    changes."""

    def __init__(self, base: DeviceBatchStream, n_groups: int):
        self.base = base
        self.n_groups = n_groups

    def next(self, length: int):
        return self.base.next(length, n_workers=self.n_groups)


def _run_elastic(e: Experiment) -> RunResult:
    """Join/leave-tolerant protocol training (``runner="elastic"``).

    The run is chunked at every membership boundary of the plan (authored in
    the spec, or lowered from the named netsim scenario's realized crash
    windows). At each boundary the replica-stacked ``ByzState`` is
    checkpointed (when a ckpt_dir is given), the mesh and resilience
    parameters are re-formed for the new fleet
    (:func:`repro.core.membership.epoch_config` — Table-1 re-validated, hard
    :class:`~repro.core.membership.MembershipFloorError` below the floor),
    and re-admitted groups are seeded from the DMC median of the survivors.
    With an empty plan the run is bit-identical to ``runner="protocol"``."""
    import dataclasses as _dc

    from ..checkpoint import checkpointer as ck
    from ..core import membership as _membership
    from ..core import protocol as _protocol
    from ..launch.mesh import use_mesh

    pcfg0 = e.to_protocol_config()
    G0 = pcfg0.n_groups
    sync = e.variant == "sync"

    plan, plan_source, netsim = e.membership_plan, "spec", None
    if plan is None and e.scenario is not None:
        from ..netsim import ClusterSim
        sc = e.to_scenario()
        trace = ClusterSim(sc).run()
        plan = _membership.plan_from_trace(sc, trace)
        plan_source = f"scenario:{e.scenario}"
        netsim = {"scenario": sc.name, "steps": int(sc.steps),
                  "virtual_ms": float(trace.step_done_ms[-1]),
                  "events": int(trace.events),
                  "shortfalls": int(trace.shortfalls)}
    if plan is None:
        plan = _membership.MembershipPlan()
    if not plan.events:
        plan_source = "static" if plan_source == "spec" else plan_source
    segs = plan.epochs(G0, e.steps)

    init_fn, loss_fn, acc = e.build_problem()
    bundle = _protocol.ProblemBundle(init=init_fn, loss=loss_fn)
    stream = DeviceBatchStream(e.seed, e.mixture, G0, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    with_attack = bool(e.byz.worker_attack or e.byz.server_attack)

    if e.ckpt_every and not e.ckpt_dir:
        raise ValueError(
            f"experiment {e.name!r} sets ckpt_every={e.ckpt_every} "
            "but no ckpt_dir; pass one at run time, e.g. "
            'exp.run(name, ckpt_dir="...")')

    # resume: the latest checkpoint's manifest meta names the active set it
    # was saved under (absent for runner="protocol" checkpoints -> launch G)
    start, resume_active = 0, None
    if e.ckpt_dir:
        latest = ck.latest_step(e.ckpt_dir)
        if latest is not None:
            start = int(latest)
            if start > e.steps:
                raise ValueError(
                    f"checkpoint at step {start} under {e.ckpt_dir!r} is "
                    f"beyond this run (steps={e.steps}); wrong ckpt_dir?")
            meta = ck.read_manifest(e.ckpt_dir, start).get("meta") or {}
            resume_active = tuple(int(g) for g in
                                  meta.get("active", range(G0)))

    def _save(step: int, state, active) -> None:
        ck.save(e.ckpt_dir, step, state,
                meta={"elastic": True, "active": [int(g) for g in active],
                      "n_groups_launch": G0, "spec_hash": e.spec_hash})

    def _shardings(pcfg, mesh):
        return _protocol.state_shardings(
            jax.eval_shape(_protocol.make_init_fn(bundle, pcfg),
                           jax.random.PRNGKey(0)),
            mesh, overrides=_protocol.attn_overrides(bundle.cfg, mesh))

    state, prev_active, bufs = None, None, []
    pcfg = pcfg0
    mesh = _protocol_mesh(G0)
    t0 = time.time()
    for seg in segs:
        if seg.stop <= start and seg.stop < e.steps:
            continue  # fully replayed by the checkpoint (keep the last seg)
        pcfg = _membership.epoch_config(pcfg0, seg.active, synchronous=sync)
        mesh = _protocol_mesh(pcfg.n_groups)
        with use_mesh(mesh):
            eng = _protocol.ProtocolEngine(
                bundle, pcfg, e.build_schedule(), mesh=mesh,
                with_attack=with_attack, acc_fn=acc, eval_set=(ex, ey),
                track_delta=e.track_delta, metrics_every=e.metrics_every)
            if state is None:
                if start > 0:
                    if resume_active != seg.active:
                        raise ValueError(
                            f"checkpoint at step {start} was saved with "
                            f"active groups {resume_active}, but this plan's "
                            f"epoch there has {seg.active} — the checkpoint "
                            "does not belong to this membership plan")
                    like = jax.eval_shape(
                        _protocol.make_init_fn(bundle, pcfg),
                        jax.random.PRNGKey(0))
                    state, _ = ck.restore(e.ckpt_dir, start, like,
                                          _shardings(pcfg, mesh))
                    stream.skip(start)
                else:
                    state = eng.init_state(jax.random.PRNGKey(e.seed))
            elif prev_active != seg.active:
                params = _membership.reform_params(state.params, prev_active,
                                                   seg.active)
                # re-stack per-replica optimizer moments alongside the params
                # (scalars — adamw's step count — ride through untouched)
                opt = jax.tree.map(
                    lambda l: _membership.reform_params(l, prev_active,
                                                        seg.active)
                    if getattr(l, "ndim", 0) >= 1
                    and l.shape[0] == len(prev_active) else l,
                    state.opt)
                state = _protocol.ByzState(params=params, t=state.t,
                                           key=state.key, opt=opt)
                state = jax.tree.map(jax.device_put, state,
                                     _shardings(pcfg, mesh))
                if e.ckpt_dir:
                    # the boundary save overwrites the chunk save at the same
                    # step: the post-re-form state (new G) is what a resume
                    # of THIS epoch must restore
                    _save(seg.start, state, seg.active)
            prev_active = seg.active

            seg_stream = _GroupView(stream, pcfg.n_groups)
            done = max(seg.start, start)
            while done < seg.stop:
                n = seg.stop - done
                if e.ckpt_every:
                    n = min(n, e.ckpt_every - done % e.ckpt_every)
                state, b = eng.run(state, stream=seg_stream, steps=n,
                                   epoch_steps=e.epoch_steps)
                bufs.append(b)
                done += n
                if e.ckpt_every:
                    _save(done, state, seg.active)
    if e.ckpt_dir and not e.ckpt_every and start < e.steps:
        _save(e.steps, state, prev_active)
    wall = time.time() - t0

    mbuf = ({k: np.concatenate([b[k] for b in bufs]) for k in bufs[0]}
            if bufs else {})
    logs = []
    if "acc" in mbuf:
        # buffer index j is global step start + j; acc lands where the global
        # step hits the metrics_every stride
        for j in range((-start) % e.metrics_every, len(mbuf["acc"]),
                       e.metrics_every):
            m = {"step": start + j, "acc": float(mbuf["acc"][j])}
            if e.track_delta:
                m["delta"] = float(mbuf["delta"][j])
                m["l2_diam"] = float(mbuf["l2_diam"][j])
            logs.append(m)

    p0 = jax.tree.map(lambda l: l[0], state.params)
    final = {"acc": float(acc(p0, ex, ey))}
    if e.track_delta:
        from ..core.simulator import coordinatewise_diameter_sum, l2_diameter
        h = pcfg.n_groups - e.byz.n_byz_servers
        final["delta"] = float(coordinatewise_diameter_sum(state.params, h))
        final["l2_diam"] = float(l2_diameter(state.params, h))

    prov = provenance(e.spec_hash)
    prov["mesh"] = dict(zip(mesh.axis_names,
                            (int(n) for n in mesh.devices.shape)))
    prov["protocol_engine"] = pcfg0.engine
    prov["membership"] = {
        "plan_source": plan_source,
        "events": [_dc.asdict(ev) for ev in plan.events],
        "epochs": [{"start": s.start, "stop": s.stop,
                    "active": list(s.active)} for s in segs],
        "resumed_at": start or None,
    }
    return RunResult(e, logs, final, wall, prov, netsim=netsim, state=state,
                     buffers=mbuf)


def write_result(res: RunResult, out_dir: str = "results/benchmarks",
                 name: str | None = None) -> str:
    """Write a RunResult verbatim as JSON; returns the path."""
    os.makedirs(out_dir, exist_ok=True)
    base = name or f"exp_{res.experiment.name.replace('/', '_')}" \
                   f"_{res.experiment.runner}"
    path = os.path.join(out_dir, base + ".json")
    with open(path, "w") as fh:
        json.dump(res.to_dict(), fh, indent=1, default=float)
    return path
