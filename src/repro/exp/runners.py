"""``repro.exp.run`` — one entry point, every runner.

Dispatches an :class:`~repro.exp.spec.Experiment` to

  * ``stepwise`` — the per-step ``ByzSGDSimulator.run`` reference loop (the
    debugging/correctness oracle; host batch iterator, host metrics),
  * ``fused``    — the compiled :class:`repro.core.engine.EpochEngine` hot
    path (device batch stream, donated ``lax.scan`` epochs, one host
    transfer),
  * ``netsim``   — a trace-driven run: the named netsim scenario is simulated
    first, the realized quorums/staleness replay through ``TraceDelivery``,
    and the cluster's accounting rides along in the result,
  * ``protocol`` — the genuinely-distributed path: the same spec lowered to
    ``ProtocolConfig`` (G = n_workers = n_servers co-located groups) and run
    through :class:`repro.core.protocol.ProtocolEngine` fused epochs over a
    ('rep', 'fsdp', 'model') mesh built from the available devices (down to
    one device, where the fused runner is its oracle); the mesh shape and
    collective engine land in the result's provenance,

and returns a uniform :class:`RunResult`: strided metric ``logs``, ``final``
metrics, wall seconds, and a ``provenance`` block (spec hash + git sha +
jax/device info) that ``benchmarks/run.py`` writes verbatim into
``results/benchmarks/*.json``. The runners train the *same* experiment:
stepwise and fused are equivalence-tested (params allclose) in
``tests/test_exp.py``, and protocol against both in
``tests/test_protocol_engine.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from ..agg import default_backend
from ..agg.dispatch import backend_override
from ..agg.rules import use_sort_network
from ..core.engine import EpochEngine
from ..core.simulator import coordinatewise_diameter_sum, l2_diameter
from ..data.pipeline import DeviceBatchStream, classification_stream
from . import presets
from .spec import Experiment


def git_sha() -> str | None:
    """Current repo revision, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance(spec_hash: str | None = None) -> dict[str, Any]:
    """The provenance block every result JSON carries."""
    dev = jax.devices()[0]
    return {"spec_hash": spec_hash, "git_sha": git_sha(),
            "jax_version": jax.__version__, "device": dev.platform,
            "device_kind": getattr(dev, "device_kind", None),
            "agg_backend": default_backend()}


@dataclass
class RunResult:
    """Uniform result of :func:`run` across the three runners.

    ``logs``/``final``/``wall_s``/``provenance``/``netsim`` serialize via
    :meth:`to_dict`; ``state`` (the final ``SimState``) and ``buffers`` (the
    dense per-step device metric buffers, host numpy) are runtime attachments
    for tests and notebook analysis, never written to JSON.
    """
    experiment: Experiment
    logs: list[dict]
    final: dict
    wall_s: float
    provenance: dict
    netsim: dict | None = None
    state: Any = field(default=None, repr=False, compare=False)
    buffers: dict | None = field(default=None, repr=False, compare=False)

    def to_dict(self) -> dict[str, Any]:
        out = {"experiment": self.experiment.to_dict(),
               "logs": self.logs, "final": self.final, "wall_s": self.wall_s,
               "provenance": self.provenance}
        if self.netsim is not None:
            out["netsim"] = self.netsim
        return out

    def summary(self) -> str:
        e = self.experiment
        bits = [f"[{e.name}] runner={e.runner}", f"steps={e.steps}",
                f"final acc {self.final.get('acc', float('nan')):.3f}",
                f"wall {self.wall_s:.1f}s", f"spec {e.spec_hash}"]
        if self.netsim is not None:
            bits.append(f"virtual {self.netsim['virtual_ms']:.0f}ms "
                        f"(shortfalls {self.netsim['shortfalls']})")
        return "  ".join(bits)


def run(experiment: Experiment | str, **overrides) -> RunResult:
    """Run an experiment (or a preset name) through its declared runner."""
    if isinstance(experiment, str):
        e = presets.get(experiment, **overrides)
    else:
        e = experiment.replace(**overrides) if overrides else experiment
    with backend_override(e.agg_backend), use_sort_network(e.sort_network):
        # delivery is orthogonal to the runner: a "trace" experiment can
        # train stepwise or fused; runner="netsim" is fused + trace with
        # the cluster accounting attached (delivery normalized at
        # construction).
        delivery, info = (_trace_delivery(e) if e.delivery == "trace"
                          else (None, None))
        if e.runner == "stepwise":
            return _run_stepwise(e, delivery, info)
        if e.runner == "protocol":
            return _run_protocol(e, delivery, info)
        return _run_fused(e, delivery, info)


# ---------------------------------------------------------------------------
# runner implementations
# ---------------------------------------------------------------------------


def _trace_delivery(e: Experiment):
    """Simulate the named scenario; return (TraceDelivery, netsim dict)."""
    from ..netsim import ClusterSim
    sc = e.to_scenario()
    trace = ClusterSim(sc).run()
    step_ms = np.diff(np.maximum.accumulate(trace.step_done_ms), prepend=0.0)
    info = {
        "scenario": sc.name, "steps": int(sc.steps),
        "virtual_ms": float(trace.step_done_ms[-1]),
        "mean_step_ms": float(step_ms.mean()),
        "p95_step_ms": float(np.percentile(step_ms, 95)),
        "mean_pull_staleness_ms": float(trace.pull_stale.mean()),
        "events": int(trace.events), "shortfalls": int(trace.shortfalls),
        "totals": trace.ledger.totals(),
        "summary": trace.ledger.summary(sc),
    }
    return trace.to_delivery(), info


def _final_metrics(e: Experiment, state, acc, eval_set, mbuf=None) -> dict:
    p0 = jax.tree.map(lambda l: l[0], state.params)
    cfg = e.to_config()
    final = {"acc": float(acc(p0, *eval_set))}
    if e.track_delta:
        final["delta"] = float(coordinatewise_diameter_sum(state.params,
                                                           cfg.h_servers))
        final["l2_diam"] = float(l2_diameter(state.params, cfg.h_servers))
    if mbuf is not None and "rejects" in mbuf:
        final["rejects"] = int(np.asarray(mbuf["rejects"][-1]).sum())
    return final


def _run_stepwise(e: Experiment, delivery=None, netsim=None) -> RunResult:
    sim = e.build_sim(delivery)
    cfg = sim.cfg
    _, _, acc = e.build_problem()
    state = sim.init_state(jax.random.PRNGKey(e.seed))
    stream, eval_fn = classification_stream(e.seed, e.mixture, cfg.n_workers,
                                            e.batch, e.steps)
    ex, ey = eval_fn(e.eval_n)

    def metrics(s):
        m = {"acc": float(acc(jax.tree.map(lambda l: l[0], s.params), ex, ey))}
        if e.track_delta:
            m["delta"] = float(coordinatewise_diameter_sum(s.params,
                                                           cfg.h_servers))
            m["l2_diam"] = float(l2_diameter(s.params, cfg.h_servers))
        return m

    t0 = time.time()
    state, logs = sim.run(state, stream, metrics_fn=metrics,
                          metrics_every=e.metrics_every)
    wall = time.time() - t0
    final = _final_metrics(e, state, acc, (ex, ey))
    return RunResult(e, logs, final, wall, provenance(e.spec_hash),
                     netsim=netsim, state=state)


def _run_fused(e: Experiment, delivery=None, netsim=None) -> RunResult:
    sim = e.build_sim(delivery)
    cfg = sim.cfg
    _, _, acc = e.build_problem()
    state = sim.init_state(jax.random.PRNGKey(e.seed))
    stream = DeviceBatchStream(e.seed, e.mixture, cfg.n_workers, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    eng = EpochEngine(sim, acc_fn=acc, eval_set=(ex, ey),
                      track_delta=e.track_delta,
                      metrics_every=e.metrics_every)
    t0 = time.time()
    state, mbuf = eng.run(state, stream=stream, steps=e.steps,
                          epoch_steps=e.epoch_steps)
    wall = time.time() - t0

    logs = []
    for i in range(0, e.steps, e.metrics_every):
        m = {"step": i, "acc": float(mbuf["acc"][i])}
        if e.track_delta:
            m["delta"] = float(mbuf["delta"][i])
            m["l2_diam"] = float(mbuf["l2_diam"][i])
        if "rejects" in mbuf:
            m["rejects"] = int(np.asarray(mbuf["rejects"][i]).sum())
        stal = sim.delivery.staleness(i)
        if stal:
            m.update(stal)
        logs.append(m)
    final = _final_metrics(e, state, acc, (ex, ey), mbuf)
    return RunResult(e, logs, final, wall, provenance(e.spec_hash),
                     netsim=netsim, state=state, buffers=mbuf)


def _run_protocol(e: Experiment, delivery=None, netsim=None) -> RunResult:
    from ..core import protocol as _protocol
    from ..launch.mesh import make_protocol_mesh, use_mesh
    pcfg = e.to_protocol_config()
    G = pcfg.n_groups
    init_fn, loss_fn, acc = e.build_problem()
    bundle = _protocol.ProblemBundle(init=init_fn, loss=loss_fn)
    mesh = make_protocol_mesh(G)
    stream = DeviceBatchStream(e.seed, e.mixture, G, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    with_attack = bool(e.byz.worker_attack or e.byz.server_attack)
    with use_mesh(mesh):
        eng = _protocol.ProtocolEngine(
            bundle, pcfg, e.build_schedule(), mesh=mesh, delivery=delivery,
            with_attack=with_attack, acc_fn=acc, eval_set=(ex, ey),
            track_delta=e.track_delta, metrics_every=e.metrics_every)
        state = eng.init_state(jax.random.PRNGKey(e.seed))
        t0 = time.time()
        if e.ckpt_every:
            # chunk the fused run at checkpoint boundaries: the engine's
            # gather cadence rides on the step counter carried in the state,
            # so chunking is training-equivalent to one eng.run call
            if not e.ckpt_dir:
                raise ValueError(
                    f"experiment {e.name!r} sets ckpt_every={e.ckpt_every} "
                    "but no ckpt_dir; pass one at run time, e.g. "
                    'exp.run(name, ckpt_dir="...")')
            from ..checkpoint import checkpointer as ck
            bufs, done = [], 0
            while done < e.steps:
                n = min(e.ckpt_every, e.steps - done)
                state, b = eng.run(state, stream=stream, steps=n,
                                   epoch_steps=e.epoch_steps)
                bufs.append(b)
                done += n
                ck.save(e.ckpt_dir, done, state)
            mbuf = {k: np.concatenate([b[k] for b in bufs])
                    for k in bufs[0]}
        else:
            state, mbuf = eng.run(state, stream=stream, steps=e.steps,
                                  epoch_steps=e.epoch_steps)
        wall = time.time() - t0

    logs = []
    for i in range(0, e.steps, e.metrics_every):
        m = {"step": i, "acc": float(mbuf["acc"][i])}
        if e.track_delta:
            m["delta"] = float(mbuf["delta"][i])
            m["l2_diam"] = float(mbuf["l2_diam"][i])
        stal = eng.delivery.staleness(i)
        if stal:
            m.update(stal)
        logs.append(m)
    final = _final_metrics(e, state, acc, (ex, ey), mbuf)
    prov = provenance(e.spec_hash)
    prov["mesh"] = dict(zip(mesh.axis_names,
                            (int(n) for n in mesh.devices.shape)))
    prov["protocol_engine"] = pcfg.engine
    return RunResult(e, logs, final, wall, prov, netsim=netsim, state=state,
                     buffers=mbuf)


def write_result(res: RunResult, out_dir: str = "results/benchmarks",
                 name: str | None = None) -> str:
    """Write a RunResult verbatim as JSON; returns the path."""
    os.makedirs(out_dir, exist_ok=True)
    base = name or f"exp_{res.experiment.name.replace('/', '_')}" \
                   f"_{res.experiment.runner}"
    path = os.path.join(out_dir, base + ".json")
    with open(path, "w") as fh:
        json.dump(res.to_dict(), fh, indent=1, default=float)
    return path
