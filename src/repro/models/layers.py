"""Shared layer library (pure JAX, functional params-as-pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init_* return params, apply_* are pure.
  * activations compute in bf16 (configurable), params stored f32 (the ByzSGD
    server replicas do f32 SGD math; casts happen on entry).
  * attention is *blocked* (online-softmax over KV chunks) so 32k-prefill
    never materialises an [S, S] score matrix — required for the dry-run
    memory envelope and the production memory roofline.
  * decode KV caches are stored chunk-sharded: [B, kvH, n_chunks, chunk, hd]
    with n_chunks mapped to the 'model' mesh axis (flash-decode with
    log-sum-exp merge across chunks => works for any kv-head count, incl.
    archs whose kv heads don't divide the TP degree).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def _init_dense(key, fan_in, *shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(key, -2, 2, shape)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (incl. M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """x: [B, S, H, hd]. positions: [B, S] (standard) or [3, B, S] (M-RoPE:
    temporal/height/width position ids; frontend stub emits equal ids for text).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # [hd/2]
    if positions.ndim == 3:  # M-RoPE: interleave per-section frequencies
        if mrope_sections is None:
            n = inv.shape[0]
            s0 = n - 2 * (n // 4)
            mrope_sections = (s0, n // 4, n // 4)
        sec_id = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                                  for i, s in enumerate(mrope_sections)])  # [hd/2]
        pos = positions.astype(jnp.float32)  # [3, B, S]
        # per frequency j, use the position component sec_id[j]
        pos_sel = jnp.take(pos, sec_id, axis=0)  # [hd/2, B, S]
        ang = jnp.einsum("kbs,k->bsk", pos_sel, inv)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # [B, S, hd/2]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)  # [B, S, 1, hd/2]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# blocked causal attention (training / prefill)
# ---------------------------------------------------------------------------

NEG = jnp.float32(-1e30)


def _naive_attention(q, k, v, *, causal, window, cross):
    """Reference/full attention. Identical FLOP count to the blocked path
    (which also computes every masked block) but loop-free — used as the
    dry-run cost-probe path (unroll_ctx) so cost_analysis sees all the work,
    and as the test oracle."""
    B, Sq, H, hd = q.shape
    Skv, kvH = k.shape[1], k.shape[2]
    rep = H // kvH
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if causal and not cross:
        off = Skv - Sq
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Skv)[None, :]
        mask = ki <= (qi + off)
        if window > 0:
            mask &= ki > (qi + off - window)
        s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_block: int = 512, kv_block: int = 512,
                      cross: bool = False) -> jax.Array:
    """Online-softmax blocked attention.

    q: [B, Sq, H, hd]; k, v: [B, Skv, kvH, hd] (GQA: H % kvH == 0).
    window > 0 => sliding-window causal attention (h2o-danube SWA).
    cross => no causal mask (whisper cross-attention / encoder).
    Never materialises more than [B, H, q_block, kv_block] scores.
    """
    from .unroll_ctx import active as _unroll_active
    if _unroll_active():
        return _naive_attention(q, k, v, causal=causal, window=window,
                                cross=cross)
    import os as _os
    if ((jax.default_backend() == "tpu"
         and _os.environ.get("REPRO_NO_FLASH") != "1")
            or _os.environ.get("REPRO_FLASH") == "1"):
        # production TPU path: fused Pallas flash attention, forward AND
        # backward (VMEM-resident scores — removes the O(S^2) HBM traffic
        # that dominates the memory roofline term; kernels/flash_attention
        # pairs the kernels via custom_vjp, so the training hot path runs
        # them too). REPRO_NO_FLASH=1 falls back to the blocked path;
        # REPRO_FLASH=1 forces the kernels elsewhere (Pallas interpret mode
        # off-TPU — the CI hot-path smoke).
        from ..kernels.flash_attention.ops import flash_attention as _fa
        return _fa(q, k, v, causal=causal and not cross, window=window,
                   q_block=q_block, kv_block=kv_block)
    B, Sq, H, hd = q.shape
    Skv, kvH = k.shape[1], k.shape[2]
    rep = H // kvH
    scale = 1.0 / np.sqrt(hd)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    nq, nk = -(-Sq // q_block), -(-Skv // kv_block)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_block - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_block - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_block - Skv), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, q_block, H, hd)
    kb = kp.reshape(B, nk, kv_block, kvH, hd)
    vb = vp.reshape(B, nk, kv_block, kvH, hd)

    q_pos_base = jnp.arange(q_block)
    k_pos_base = jnp.arange(kv_block)

    def q_chunk(qi, qc):  # qc: [B, q_block, H, hd]
        qc = qc * scale

        def kv_step(carry, ki_kc_vc):
            m, l, acc = carry
            ki, kc, vc = ki_kc_vc
            kcr = jnp.repeat(kc, rep, axis=2)  # [B, kv_block, H, hd]
            vcr = jnp.repeat(vc, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kcr,
                           preferred_element_type=jnp.float32)
            qpos = qi * q_block + q_pos_base  # [q_block]
            kpos = ki * kv_block + k_pos_base
            mask = (kpos[None, :] <= Skv - 1) & (qpos[:, None] <= Sq - 1)
            if causal and not cross:
                off = Skv - Sq  # prefix (cache) length for decode-with-cache
                mask &= kpos[None, :] <= (qpos[:, None] + off)
                if window > 0:
                    mask &= kpos[None, :] > (qpos[:, None] + off - window)
            s = jnp.where(mask[None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vcr.dtype), vcr,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)  # [B, q_block, H, hd]

    outs = jax.lax.map(lambda args: q_chunk(*args),
                       (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# chunk-sharded decode cache + flash-decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """k/v: [B, kvH, n_chunks, chunk, hd]; length: scalar tokens written."""
    k: jax.Array
    v: jax.Array
    length: jax.Array

    @staticmethod
    def create(batch, kv_heads, max_len, head_dim, n_chunks, dtype=jnp.bfloat16):
        chunk = max_len // n_chunks
        z = jnp.zeros((batch, kv_heads, n_chunks, chunk, head_dim), dtype)
        return KVCache(z, z, jnp.zeros((), jnp.int32))


def cache_insert(cache: KVCache, k_new, v_new) -> KVCache:
    """Append one token's k/v ([B, 1, kvH, hd]) at position cache.length."""
    B, kvH, nc, ck, hd = cache.k.shape
    pos = cache.length
    ci, co = pos // ck, pos % ck
    kn = k_new[:, 0].astype(cache.k.dtype)  # [B, kvH, hd]
    vn = v_new[:, 0].astype(cache.v.dtype)
    k = jax.lax.dynamic_update_slice(cache.k, kn[:, :, None, None],
                                     (0, 0, ci, co, 0))
    v = jax.lax.dynamic_update_slice(cache.v, vn[:, :, None, None],
                                     (0, 0, ci, co, 0))
    return KVCache(k, v, pos + 1)


def cache_prefill(cache: KVCache, k_all, v_all) -> KVCache:
    """Bulk-write a prefill of S tokens ([B, S, kvH, hd]) from position 0."""
    B, kvH, nc, ck, hd = cache.k.shape
    S = k_all.shape[1]
    k = k_all.transpose(0, 2, 1, 3)  # [B, kvH, S, hd]
    v = v_all.transpose(0, 2, 1, 3)
    pad = nc * ck - S
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(B, kvH, nc, ck, hd)
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(B, kvH, nc, ck, hd)
    return KVCache(k.astype(cache.k.dtype), v.astype(cache.v.dtype),
                   jnp.asarray(S, jnp.int32))


def flash_decode(q, cache: KVCache, *, window: int = 0) -> jax.Array:
    """One-token decode attention against a chunk-sharded cache.

    q: [B, 1, H, hd] -> [B, 1, H, hd]. Each chunk computes a partial softmax
    (out, lse); merging across the chunk axis is a small reduction — when the
    chunk axis is sharded over 'model', XLA lowers the merge to an all-reduce
    of [B, H, hd]-sized partials instead of gathering the whole cache.
    """
    B, _, H, hd = q.shape
    kvH = cache.k.shape[1]
    rep = H // kvH
    nc, ck = cache.k.shape[2], cache.k.shape[3]
    scale = 1.0 / np.sqrt(hd)
    qh = (q[:, 0] * scale)  # [B, H, hd]
    kr = jnp.repeat(cache.k, rep, axis=1)  # [B, H, nc, ck, hd]
    vr = jnp.repeat(cache.v, rep, axis=1)
    s = jnp.einsum("bhd,bhnkd->bhnk", qh, kr,
                   preferred_element_type=jnp.float32)  # [B, H, nc, ck]
    pos = jnp.arange(nc * ck).reshape(nc, ck)
    valid = pos < cache.length
    if window > 0:
        valid &= pos > (cache.length - window)
    s = jnp.where(valid[None, None], s, NEG)
    m = jnp.max(s, axis=-1)                              # [B, H, nc]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                              # [B, H, nc]
    part = jnp.einsum("bhnk,bhnkd->bhnd", p.astype(vr.dtype), vr,
                      preferred_element_type=jnp.float32)
    # merge partials over the (sharded) chunk axis
    g = jnp.max(m, axis=-1, keepdims=True)               # [B, H, 1]
    w = jnp.exp(m - g) * l                               # [B, H, nc]
    den = jnp.sum(w, axis=-1)
    num = jnp.sum(part * jnp.exp(m - g)[..., None], axis=2)  # [B, H, hd]
    out = num / jnp.maximum(den[..., None], 1e-30)
    return out[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block + SwiGLU MLP
# ---------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv_heads, head_dim):
    ks = jax.random.split(key, 4)
    return {
        "wq": _init_dense(ks[0], d_model, d_model, n_heads * head_dim),
        "wk": _init_dense(ks[1], d_model, d_model, n_kv_heads * head_dim),
        "wv": _init_dense(ks[2], d_model, d_model, n_kv_heads * head_dim),
        "wo": _init_dense(ks[3], n_heads * head_dim, n_heads * head_dim, d_model),
    }


def attention_qkv(p, x, n_heads, n_kv_heads, head_dim, positions, theta,
                  mrope: bool = False, dtype=jnp.bfloat16):
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(dtype)).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"].astype(dtype)).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ p["wv"].astype(dtype)).reshape(B, S, n_kv_heads, head_dim)
    if positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_out(p, attn, dtype=jnp.bfloat16):
    B, S, H, hd = attn.shape
    return attn.reshape(B, S, H * hd) @ p["wo"].astype(dtype)


def init_swiglu(key, d_model, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init_dense(ks[0], d_model, d_model, d_ff),
        "w_up": _init_dense(ks[1], d_model, d_model, d_ff),
        "w_down": _init_dense(ks[2], d_ff, d_ff, d_model),
    }


def swiglu(p, x, dtype=jnp.bfloat16):
    g = x @ p["w_gate"].astype(dtype)
    u = x @ p["w_up"].astype(dtype)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dtype)


def init_gelu_mlp(key, d_model, d_ff):
    ks = jax.random.split(key, 2)
    return {"w_up": _init_dense(ks[0], d_model, d_model, d_ff),
            "b_up": jnp.zeros((d_ff,), jnp.float32),
            "w_down": _init_dense(ks[1], d_ff, d_ff, d_model),
            "b_down": jnp.zeros((d_model,), jnp.float32)}


def gelu_mlp(p, x, dtype=jnp.bfloat16):
    h = jax.nn.gelu(x @ p["w_up"].astype(dtype) + p["b_up"].astype(dtype))
    return h @ p["w_down"].astype(dtype) + p["b_down"].astype(dtype)


# ---------------------------------------------------------------------------
# embeddings / lm head / loss
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d_model):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(jnp.float32)}


def embed(p, tokens, dtype=jnp.bfloat16):
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed(p, x):
    return jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, labels):
    """logits [B,S,V] f32, labels [B,S] -> mean NLL."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def cross_entropy_chunked(hidden, table_params, labels, chunk: int = 512):
    """Sequence-chunked CE: [B,S,D] hidden x [V,D] table -> mean NLL without
    ever materialising the [B,S,V] logits (remat per chunk). This is what
    keeps the train-step memory envelope vocab-independent."""
    from .sharding import shard as _shard
    from .unroll_ctx import scan as _uscan
    B, S, D = hidden.shape
    table = table_params["table"]
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lbl = jnp.pad(labels, ((0, 0), (0, pad)))
    valid = jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
    hb = jnp.moveaxis(h.reshape(B, nc, chunk, D), 1, 0)      # [nc, B, c, D]
    lb = jnp.moveaxis(lbl.reshape(B, nc, chunk), 1, 0)
    vb = jnp.moveaxis(valid.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def chunk_nll(hc, lc, vc):
        logits = jnp.einsum("bcd,vd->bcv", hc, table.astype(hc.dtype),
                            preferred_element_type=jnp.float32)
        logits = _shard(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * vc)

    from .unroll_ctx import active as _unroll_active
    if _unroll_active():  # cost-probe: loop-free, flop-identical
        tot = jnp.sum(jax.vmap(chunk_nll)(hb, lb, vb))
        return tot / (B * S)

    def body(acc, xs):
        return acc + chunk_nll(*xs), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (hb, lb, vb))
    return tot / (B * S)
