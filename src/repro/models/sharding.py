"""Logical sharding-rule indirection.

Models annotate activations with *logical* names; the launch layer installs a
rule table mapping names to NamedShardings for the active mesh. Outside a mesh
context (unit tests, the single-host simulator) the rules are empty and
``shard`` is the identity.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_RULES: dict = {}


@contextmanager
def sharding_rules(rules: dict):
    global _RULES
    old = _RULES
    _RULES = dict(rules)
    try:
        yield
    finally:
        _RULES = old


def shard(x, name: str):
    spec = _RULES.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
