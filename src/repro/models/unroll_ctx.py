"""Unroll context for dry-run cost probes.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count, so
the depth-probe compiles (launch/dryrun.py) run with unrolling enabled: every
layer scan / streaming loop in the package goes through ``scan``/``map_1``
below, which fully unroll under this context. Production lowering keeps rolled
loops (compile time, code size).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_UNROLL = False


@contextmanager
def unrolled(on: bool = True):
    global _UNROLL
    old = _UNROLL
    _UNROLL = on
    try:
        yield
    finally:
        _UNROLL = old


def active() -> bool:
    return _UNROLL


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length, unroll=True if _UNROLL else 1)


def map_1(f, xs):
    """lax.map replacement honouring the unroll context."""
    def body(_, x):
        return None, f(x)
    _, out = jax.lax.scan(body, None, xs, unroll=True if _UNROLL else 1)
    return out
