"""Mamba2 (SSD) block — chunked selective-state-space scan in pure JAX.

State recurrence (per head h, headdim P, state N):
    h_t = a_t * h_{t-1} + (dt_t * x_t) outer B_t,   a_t = exp(-exp(A_log)*dt_t)
    y_t = C_t . h_t + D_skip * x_t
Chunked closed form: lax.scan over chunks carrying the [B, H, P, N] state; the
intra-chunk term is a masked [C, C] decay matrix per head (scalar decay => no
K-dim blowup), the inter-chunk term a single state contraction. Per-chunk
transients stay at tens of MB (DESIGN.md §Arch notes).

Decode is the O(1)-state single-token recurrence — this is what makes
long_500k serve_step sub-quadratic for zamba2 (and rwkv6, see rwkv6.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L
from .unroll_ctx import scan as uscan
from .config import ArchConfig
from .sharding import shard

LOG_DECAY_FLOOR = -20.0  # exp(-20) ~ 2e-9: numerically zero decay, overflow-safe


class MambaCache(NamedTuple):
    conv: jax.Array   # [B, convw-1, conv_channels] rolling window
    ssm: jax.Array    # [B, H, P, N]


def dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_mamba_block(key, cfg: ArchConfig):
    d_inner, H, P, N = dims(cfg)
    D = cfg.d_model
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "ln": L.init_rmsnorm(D),
        "in_proj": L._init_dense(ks[0], D, D, 2 * d_inner + 2 * N + H),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch))).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # exp(0)=1 decay rate
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus(-2) ~ 0.13
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_ln": L.init_rmsnorm(d_inner),
        "out_proj": L._init_dense(ks[2], d_inner, d_inner, D),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv via static shifts. x: [B, S, C]; w: [K, C].
    state: [B, K-1, C] previous tokens (decode) or None (train, zero history).
    Returns (y, new_state)."""
    K = w.shape[0]
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # [B, S+K-1, C]
    y = sum(xx[:, i:i + S] * w[K - 1 - i].astype(x.dtype) for i in range(K))
    new_state = xx[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y + b.astype(x.dtype)), new_state


def _split_proj(p, x, cfg: ArchConfig, dtype):
    d_inner, H, P, N = dims(cfg)
    proj = x @ p["in_proj"].astype(dtype)
    z = proj[..., :d_inner]
    xc = proj[..., d_inner:2 * d_inner]
    Bm = proj[..., 2 * d_inner:2 * d_inner + N]
    Cm = proj[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return z, xc, Bm, Cm, dt


def ssd_chunked(xh, la, Bm, Cm, h0, chunk: int):
    """Chunked SSD scan.
    xh: [B, S, H, P] (dt-scaled inputs); la: [B, S, H] log decays (<= 0);
    Bm, Cm: [B, S, N]; h0: [B, H, P, N]. Returns (y [B,S,H,P], h_final)."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))  # log-decay 0 = no decay
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    xh = xh.reshape(Bsz, nch, chunk, H, P).transpose(1, 0, 2, 3, 4)
    la = la.reshape(Bsz, nch, chunk, H).transpose(1, 0, 2, 3)
    Bm = Bm.reshape(Bsz, nch, chunk, N).transpose(1, 0, 2, 3)
    Cm = Cm.reshape(Bsz, nch, chunk, N).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h, xs):
        u, lac, Bc, Cc = xs          # [B,C,H,P], [B,C,H], [B,C,N], [B,C,N]
        Lc = jnp.cumsum(lac, axis=1)  # inclusive [B,C,H]
        # inter-chunk: y_t += (C_t . h0) * exp(L_t)   (y reads the *inclusive*
        # state h_t, so the full decay through step t applies to h0)
        tmp = jnp.einsum("bcn,bhpn->bchp", Cc, h)
        y_inter = tmp * jnp.exp(Lc)[..., None]
        # intra-chunk: M[t,j] = (C_t.B_j) exp(L_t - L_j), j<=t
        G = jnp.einsum("bin,bjn->bij", Cc, Bc)          # [B,C,C]
        Dm = Lc[:, :, None, :] - Lc[:, None, :, :]       # [B,C,C,H]
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
        M = G[..., None] * jnp.exp(Dm)                   # [B,C,C,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, u)
        # state update: h' = exp(L_C) h0 + sum_j exp(L_C - L_j) B_j x u_j
        wdec = jnp.exp(Lc[:, -1:, :] - Lc)               # [B,C,H]
        h_new = (jnp.exp(Lc[:, -1, :])[..., None, None] * h
                 + jnp.einsum("bjn,bjhp,bjh->bhpn", Bc, u, wdec))
        return h_new, (y_inter + y_intra)

    from .unroll_ctx import active as _unroll_active
    if _unroll_active():
        # COST-PROBE PATH: see rwkv6.wkv_chunked — flop-exact, value-wrong.
        _, ys = jax.vmap(body, in_axes=(None, 0))(
            h0.astype(jnp.float32),
            (xh.astype(jnp.float32), la, Bm.astype(jnp.float32),
             Cm.astype(jnp.float32)))
        h_final = h0.astype(jnp.float32)
    else:
        h_final, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                                   (xh.astype(jnp.float32), la,
                                    Bm.astype(jnp.float32),
                                    Cm.astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nch * chunk, H, P)
    return y[:, :S], h_final


def mamba_block(p, x, cfg: ArchConfig, dtype, cache: MambaCache | None = None,
                chunk: int = 64):
    """x: [B, S, D] -> ([B, S, D], new_cache). cache==None => training (no cache
    out); cache given => decode/prefill with state carry."""
    d_inner, H, P, N = dims(cfg)
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xc, Bm, Cm, dt = _split_proj(p, h, cfg, dtype)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_state = cache.conv if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xc = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + N]
    Cm = conv_out[..., d_inner + N:]

    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    la = jnp.maximum(-jnp.exp(p["A_log"]) * dt_act, LOG_DECAY_FLOOR)  # log decay
    xh = xc.reshape(*xc.shape[:2], H, P)
    u = xh.astype(jnp.float32) * dt_act[..., None]

    B_, S = x.shape[0], x.shape[1]
    h0 = cache.ssm if cache is not None else jnp.zeros((B_, H, P, N), jnp.float32)
    if S == 1 and cache is not None:  # decode fast path: single-step recurrence
        a = jnp.exp(la[:, 0])                                # [B,H]
        h_new = (a[..., None, None] * h0
                 + jnp.einsum("bhp,bn->bhpn", u[:, 0], Bm[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h_new)[:, None]
        h_fin = h_new
    else:
        y, h_fin = ssd_chunked(u, la, Bm, Cm, h0, chunk)
    y = y + p["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner).astype(dtype)
    y = L.rmsnorm(p["gate_ln"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"].astype(dtype)
    new_cache = MambaCache(new_conv, h_fin) if cache is not None else None
    return x + shard(out, "act_btd"), new_cache


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N
    return MambaCache(jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
                      jnp.zeros((batch, H, P, N), jnp.float32))
