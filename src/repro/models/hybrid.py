"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every `shared_attn_every` SSM layers (arXiv:2411.15242).

The shared block's *parameters* are reused at every application site, but each
site keeps its own KV cache (different depths see different activations).
long_500k decode: SSM state is O(1); the shared-attention sites keep
seq-length caches — chunk-sharded over 'model', so the per-chip footprint is
(sites * 500k * d_kv / 16), which is what makes this arch long-context-serveable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L
from .unroll_ctx import scan as uscan
from . import mamba2 as M
from .config import ArchConfig
from .sharding import shard


def n_shared_sites(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def _attn_cfg_dims(cfg: ArchConfig):
    heads = cfg.shared_attn_heads or cfg.n_heads
    return heads, cfg.d_model // heads


def init_shared_block(key, cfg: ArchConfig):
    heads, hd = _attn_cfg_dims(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, heads, heads, hd),  # MHA
        "ln_mlp": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_swiglu(k2, cfg.d_model, cfg.shared_attn_d_ff or cfg.d_ff),
    }


def init(key, cfg: ArchConfig):
    ke, km, ks = jax.random.split(key, 3)
    mkeys = jax.random.split(km, cfg.n_layers)
    mamba_blocks = jax.vmap(lambda k: M.init_mamba_block(k, cfg))(mkeys)
    return {"embed": L.init_embedding(ke, cfg.vocab, cfg.d_model),
            "mamba": mamba_blocks,
            "shared": init_shared_block(ks, cfg),
            "ln_f": L.init_rmsnorm(cfg.d_model)}


def _shared_apply_train(p, x, cfg: ArchConfig, dtype):
    heads, hd = _attn_cfg_dims(cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = L.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = L.attention_qkv(p["attn"], h, heads, heads, hd, positions,
                              cfg.rope_theta, dtype=dtype)
    attn = L.blocked_attention(q, k, v, causal=True, q_block=cfg.q_block,
                               kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(p["attn"], attn, dtype), "act_btd")
    h = L.rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    return x + shard(L.swiglu(p["mlp"], h, dtype), "act_btd")


def _segment_scan(params, x, cfg: ArchConfig, dtype, remat: bool):
    """Scan mamba layers in groups of `shared_attn_every`, interleaving the
    shared attention block between groups."""
    every = cfg.shared_attn_every
    n_full = cfg.n_layers // every
    rest = cfg.n_layers - n_full * every

    def mamba_body(blk, x):
        return M.mamba_block(blk, x, cfg, dtype)[0]

    if remat:
        mamba_body = jax.checkpoint(mamba_body)

    def seg_scan(x, blocks_slice):
        def sb(x, blk):
            return mamba_body(blk, x), None
        x, _ = uscan(sb, x, blocks_slice)
        return x

    take = lambda tree, lo, hi: jax.tree.map(lambda l: l[lo:hi], tree)
    for g in range(n_full):
        x = seg_scan(x, take(params["mamba"], g * every, (g + 1) * every))
        x = _shared_apply_train(params["shared"], x, cfg, dtype)
    if rest:
        x = seg_scan(x, take(params["mamba"], n_full * every, cfg.n_layers))
    return x


def forward(params, tokens, *, cfg: ArchConfig, remat: bool = True):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], tokens, dtype), "act_btd")
    x = _segment_scan(params, x, cfg, dtype, remat)
    return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)


def loss(params, batch, *, cfg: ArchConfig):
    hidden = forward(params, batch["tokens"], cfg=cfg)
    return L.cross_entropy_chunked(hidden, params["embed"], batch["labels"])


class HybridCaches(NamedTuple):
    mamba: M.MambaCache          # leaves [L, ...]
    attn: L.KVCache              # leaves [n_sites, ...]


def init_caches(cfg: ArchConfig, batch: int, max_len: int, n_chunks: int,
                dtype=jnp.bfloat16) -> HybridCaches:
    heads, hd = _attn_cfg_dims(cfg)
    mam = jax.vmap(lambda _: M.init_cache(cfg, batch, dtype))(
        jnp.arange(cfg.n_layers))
    sites = max(n_shared_sites(cfg), 1)
    att = jax.vmap(lambda _: L.KVCache.create(batch, heads, max_len, hd,
                                              n_chunks, dtype))(jnp.arange(sites))
    return HybridCaches(mam, att)


def _shared_apply_cached(p, x, cfg: ArchConfig, dtype, cache: L.KVCache,
                         prefill_mode: bool):
    heads, hd = _attn_cfg_dims(cfg)
    B, S, _ = x.shape
    if prefill_mode:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        positions = jnp.broadcast_to(cache.length[None, None], (B, 1)).astype(jnp.int32)
    h = L.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = L.attention_qkv(p["attn"], h, heads, heads, hd, positions,
                              cfg.rope_theta, dtype=dtype)
    if prefill_mode:
        cache = L.cache_prefill(cache, k, v)
        attn = L.blocked_attention(q, k, v, causal=True, q_block=cfg.q_block,
                                   kv_block=cfg.kv_block)
    else:
        cache = L.cache_insert(cache, k, v)
        attn = L.flash_decode(q, cache)
    x = x + L.attention_out(p["attn"], attn, dtype)
    h = L.rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    return x + L.swiglu(p["mlp"], h, dtype), cache


def _run_cached(params, x, caches: HybridCaches, cfg: ArchConfig, dtype,
                prefill_mode: bool):
    every = cfg.shared_attn_every
    n_full = cfg.n_layers // every
    rest = cfg.n_layers - n_full * every
    take = lambda tree, lo, hi: jax.tree.map(lambda l: l[lo:hi], tree)
    put = lambda tree, sub, lo: jax.tree.map(
        lambda l, s: l.at[lo:lo + s.shape[0]].set(s), tree, sub)

    def seg(x, pslice, cslice):
        def sb(xc, blk_cache):
            blk, cache = blk_cache
            xc, cache = M.mamba_block(blk, xc, cfg, dtype, cache)
            return xc, cache
        x, new_caches = uscan(sb, x, (pslice, cslice))
        return x, new_caches

    mam, att = caches.mamba, caches.attn
    for g in range(n_full):
        lo, hi = g * every, (g + 1) * every
        x, seg_c = seg(x, take(params["mamba"], lo, hi), take(mam, lo, hi))
        mam = put(mam, seg_c, lo)
        site = jax.tree.map(lambda l: l[g], att)
        x, site = _shared_apply_cached(params["shared"], x, cfg, dtype, site,
                                       prefill_mode)
        att = jax.tree.map(lambda l, s: l.at[g].set(s), att, site)
    if rest:
        lo = n_full * every
        x, seg_c = seg(x, take(params["mamba"], lo, cfg.n_layers),
                       take(mam, lo, cfg.n_layers))
        mam = put(mam, seg_c, lo)
    return x, HybridCaches(mam, att)


def prefill(params, batch, caches: HybridCaches, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], batch["tokens"], dtype), "act_btd")
    x, caches = _run_cached(params, x, caches, cfg, dtype, prefill_mode=True)
    hidden = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    lg = L.unembed(params["embed"], hidden)
    return lg[:, 0], caches


def decode_step(params, caches: HybridCaches, batch, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    x = L.embed(params["embed"], batch["token"], dtype)
    x, caches = _run_cached(params, x, caches, cfg, dtype, prefill_mode=False)
    hidden = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    lg = L.unembed(params["embed"], hidden)
    return lg[:, 0], caches
