"""Architecture registry: --arch <id> -> ModelBundle with a uniform interface.

Bundle methods (all pure, jit/vmap-able):
    init(key) -> params
    loss(params, batch) -> scalar             (train_step inner)
    prefill(params, batch, caches) -> (logits, caches)
    decode(params, caches, batch) -> (logits, caches)
    init_caches(batch, max_len, n_chunks) -> caches
    make_batch(kind, B, S, key) -> concrete batch    (smoke tests / examples)
    batch_specs(kind, B, S) -> dict of ShapeDtypeStruct (dry-run input_specs)
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig

ARCH_IDS = [
    "dbrx-132b", "qwen3-moe-235b-a22b", "zamba2-1.2b", "h2o-danube-3-4b",
    "phi3-medium-14b", "phi4-mini-3.8b", "internlm2-20b", "rwkv6-3b",
    "qwen2-vl-7b", "whisper-small",
]

_CONFIG_MODULES = {
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "whisper-small": "repro.configs.whisper_small",
}

_FAMILY_MODULES = {
    "dense": "repro.models.transformer",
    "vlm": "repro.models.transformer",
    "moe": "repro.models.moe",
    "hybrid": "repro.models.hybrid",
    "ssm": "repro.models.rwkv6",
    "audio": "repro.models.encdec",
}


def get_config(arch_id: str) -> ArchConfig:
    return importlib.import_module(_CONFIG_MODULES[arch_id]).CONFIG


@dataclass
class ModelBundle:
    cfg: ArchConfig

    def __post_init__(self):
        self.mod = importlib.import_module(_FAMILY_MODULES[self.cfg.family])

    # -- core fns ----------------------------------------------------------
    def init(self, key):
        return self.mod.init(key, self.cfg)

    def loss(self, params, batch):
        return self.mod.loss(params, batch, cfg=self.cfg)

    def prefill(self, params, batch, caches):
        return self.mod.prefill(params, batch, caches, cfg=self.cfg)

    def decode(self, params, caches, batch):
        return self.mod.decode_step(params, caches, batch, cfg=self.cfg)

    def init_caches(self, batch: int, max_len: int, n_chunks: int = 16,
                    dtype=jnp.bfloat16):
        return self.mod.init_caches(self.cfg, batch, max_len, n_chunks, dtype)

    # -- batch construction --------------------------------------------------
    def _token_specs(self, B, S):
        i32 = jnp.int32
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}

    def batch_specs(self, kind: str, B: int, S: int) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        bf16, i32 = jnp.bfloat16, jnp.int32
        if kind == "train" or kind == "prefill":
            if cfg.family == "vlm":
                return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                        "positions": jax.ShapeDtypeStruct((3, B, S), i32),
                        "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "audio":
                half = S // 2
                return {"enc_frames": jax.ShapeDtypeStruct((B, half, cfg.d_model), bf16),
                        "tokens": jax.ShapeDtypeStruct((B, half), i32),
                        "labels": jax.ShapeDtypeStruct((B, half), i32)}
            return self._token_specs(B, S)
        if kind == "decode":
            if cfg.family == "vlm":
                return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), bf16),
                        "positions": jax.ShapeDtypeStruct((3, B, 1), i32)}
            return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
        raise ValueError(kind)

    def make_batch(self, kind: str, B: int, S: int, key) -> dict:
        """Concrete random batch matching batch_specs (smoke tests)."""
        specs = self.batch_specs(kind, B, S)
        out = {}
        for i, (name, sds) in enumerate(sorted(specs.items())):
            k = jax.random.fold_in(key, i)
            if jnp.issubdtype(sds.dtype, jnp.integer):
                hi = self.cfg.vocab if name in ("tokens", "labels", "token") else max(S, 2)
                out[name] = jax.random.randint(k, sds.shape, 0, hi, sds.dtype)
            else:
                out[name] = (0.02 * jax.random.normal(k, sds.shape)).astype(sds.dtype)
        return out

    # -- shape-cell helpers ----------------------------------------------------
    def supports_cell(self, shape_name: str) -> tuple[bool, str]:
        """Spec-mandated skips: long_* needs sub-quadratic serve; encoder-only
        (none here — whisper is enc-dec) would skip decode."""
        if shape_name.startswith("long_") and not self.cfg.subquadratic:
            return False, ("full quadratic attention: 500k-context serve_step "
                           "skipped per assignment (see DESIGN.md)")
        return True, ""


def get_bundle(arch_id: str, reduced: bool = False, depth: int | None = None,
               **overrides) -> ModelBundle:
    """depth: override n_layers only (dry-run cost probes — everything else
    stays full-size; encoder depth scales with it for enc-dec archs)."""
    import dataclasses
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced(**overrides)
    if depth is not None:
        upd = {"n_layers": depth}
        if cfg.encoder_layers:
            upd["encoder_layers"] = depth
        cfg = dataclasses.replace(cfg, **upd)
    return ModelBundle(cfg)
