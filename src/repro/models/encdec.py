"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_enc, D] (what Whisper's 2x-strided conv
stack would emit). Sinusoidal positions on the encoder, learned positions on
the decoder, pre-LN, GELU MLPs, MHA (kv = heads), tied decoder embedding.

Serving: prefill builds the decoder self-attn cache AND per-layer cross-attn
K/V (computed once from the encoder output); decode_step then runs pure
decoder steps (flash-decode on self-attn, fixed cross K/V).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .unroll_ctx import scan as uscan
from .config import ArchConfig
from .sharding import shard


def sinusoids(length: int, d: int) -> jax.Array:
    lt = np.log(10000.0) / (d // 2 - 1)
    inv = np.exp(-lt * np.arange(d // 2))
    ang = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       jnp.float32)


def init_enc_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln_attn": L.init_layernorm(cfg.d_model),
            "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                     cfg.hd),
            "ln_mlp": L.init_layernorm(cfg.d_model),
            "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff)}


def init_dec_block(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln_self": L.init_layernorm(cfg.d_model),
            "self_attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                          cfg.n_heads, cfg.hd),
            "ln_cross": L.init_layernorm(cfg.d_model),
            "cross_attn": L.init_attention(k2, cfg.d_model, cfg.n_heads,
                                           cfg.n_heads, cfg.hd),
            "ln_mlp": L.init_layernorm(cfg.d_model),
            "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff)}


def init(key, cfg: ArchConfig):
    ke, kE, kD, kp = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(
        jax.random.split(kE, cfg.encoder_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(
        jax.random.split(kD, cfg.n_layers))
    max_dec = 65536  # learned positional table (decode positions up to 64k)
    return {"embed": L.init_embedding(ke, cfg.vocab, cfg.d_model),
            "pos_dec": (0.01 * jax.random.normal(kp, (max_dec, cfg.d_model))
                        ).astype(jnp.float32),
            "enc_blocks": enc, "dec_blocks": dec,
            "ln_enc": L.init_layernorm(cfg.d_model),
            "ln_f": L.init_layernorm(cfg.d_model)}


def encode(params, frames, *, cfg: ArchConfig, remat: bool = True):
    """frames: [B, S_enc, D] stub embeddings -> [B, S_enc, D]."""
    dtype = jnp.dtype(cfg.act_dtype)
    S = frames.shape[1]
    x = (frames.astype(dtype) + sinusoids(S, cfg.d_model).astype(dtype))
    x = shard(x, "act_btd")

    def body(blk, x):
        h = L.layernorm(blk["ln_attn"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_heads,
                                  cfg.hd, None, cfg.rope_theta, dtype=dtype)
        attn = L.blocked_attention(q, k, v, causal=False, cross=True,
                                   q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + shard(L.attention_out(blk["attn"], attn, dtype), "act_btd")
        h = L.layernorm(blk["ln_mlp"], x, cfg.norm_eps)
        return x + shard(L.gelu_mlp(blk["mlp"], h, dtype), "act_btd")

    if remat:
        body = jax.checkpoint(body)

    def sb(x, blk):
        return body(blk, x), None

    x, _ = uscan(sb, x, params["enc_blocks"])
    return L.layernorm(params["ln_enc"], x, cfg.norm_eps)


def _dec_block_train(blk, x, enc_out, cfg: ArchConfig, dtype):
    h = L.layernorm(blk["ln_self"], x, cfg.norm_eps)
    q, k, v = L.attention_qkv(blk["self_attn"], h, cfg.n_heads, cfg.n_heads,
                              cfg.hd, None, cfg.rope_theta, dtype=dtype)
    attn = L.blocked_attention(q, k, v, causal=True, q_block=cfg.q_block,
                               kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(blk["self_attn"], attn, dtype), "act_btd")
    h = L.layernorm(blk["ln_cross"], x, cfg.norm_eps)
    qc, _, _ = L.attention_qkv(blk["cross_attn"], h, cfg.n_heads, cfg.n_heads,
                               cfg.hd, None, cfg.rope_theta, dtype=dtype)
    B, Se, D = enc_out.shape
    kc = (enc_out @ blk["cross_attn"]["wk"].astype(dtype)).reshape(
        B, Se, cfg.n_heads, cfg.hd)
    vc = (enc_out @ blk["cross_attn"]["wv"].astype(dtype)).reshape(
        B, Se, cfg.n_heads, cfg.hd)
    cattn = L.blocked_attention(qc, kc, vc, causal=False, cross=True,
                                q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(blk["cross_attn"], cattn, dtype), "act_btd")
    h = L.layernorm(blk["ln_mlp"], x, cfg.norm_eps)
    return x + shard(L.gelu_mlp(blk["mlp"], h, dtype), "act_btd")


def decode_train(params, tokens, enc_out, *, cfg: ArchConfig,
                 remat: bool = True):
    dtype = jnp.dtype(cfg.act_dtype)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], 0, S).astype(dtype)
    x = shard(x, "act_btd")
    from functools import partial
    body = partial(_dec_block_train, enc_out=enc_out, cfg=cfg, dtype=dtype)
    if remat:
        body = jax.checkpoint(body)

    def sb(x, blk):
        return body(blk, x), None

    x, _ = uscan(sb, x, params["dec_blocks"])
    return L.layernorm(params["ln_f"], x, cfg.norm_eps)


def loss(params, batch, *, cfg: ArchConfig):
    enc_out = encode(params, batch["enc_frames"], cfg=cfg)
    hidden = decode_train(params, batch["tokens"], enc_out, cfg=cfg)
    return L.cross_entropy_chunked(hidden, params["embed"], batch["labels"])


# -- serving ------------------------------------------------------------------

class EncDecCaches(NamedTuple):
    self_kv: L.KVCache      # leaves [L, ...]
    cross_k: jax.Array      # [L, B, Se, H, hd]
    cross_v: jax.Array


def init_caches(cfg: ArchConfig, batch: int, max_len: int, n_chunks: int,
                dtype=jnp.bfloat16) -> EncDecCaches:
    Ld = cfg.n_layers
    kv = jax.vmap(lambda _: L.KVCache.create(batch, cfg.n_heads, max_len,
                                             cfg.hd, n_chunks, dtype))(
        jnp.arange(Ld))
    Se = cfg.max_source_len
    z = jnp.zeros((Ld, batch, Se, cfg.n_heads, cfg.hd), dtype)
    return EncDecCaches(kv, z, z)


def prefill(params, batch, caches: EncDecCaches, *, cfg: ArchConfig):
    """Encodes frames, precomputes cross K/V, prefills decoder self-attn with
    ``batch['tokens']``. Returns (last logits, caches)."""
    dtype = jnp.dtype(cfg.act_dtype)
    enc_out = encode(params, batch["enc_frames"], cfg=cfg, remat=False)
    B, Se, D = enc_out.shape
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = L.embed(params["embed"], tokens, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], 0, S).astype(dtype)
    x = shard(x, "act_btd")

    def sb(x, blk_cache):
        blk, kvcache = blk_cache
        h = L.layernorm(blk["ln_self"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(blk["self_attn"], h, cfg.n_heads, cfg.n_heads,
                                  cfg.hd, None, cfg.rope_theta, dtype=dtype)
        kvcache = L.cache_prefill(kvcache, k, v)
        attn = L.blocked_attention(q, k, v, causal=True, q_block=cfg.q_block,
                                   kv_block=cfg.kv_block)
        x = x + L.attention_out(blk["self_attn"], attn, dtype)
        h = L.layernorm(blk["ln_cross"], x, cfg.norm_eps)
        qc, _, _ = L.attention_qkv(blk["cross_attn"], h, cfg.n_heads,
                                   cfg.n_heads, cfg.hd, None, cfg.rope_theta,
                                   dtype=dtype)
        kc = (enc_out @ blk["cross_attn"]["wk"].astype(dtype)).reshape(
            B, Se, cfg.n_heads, cfg.hd)
        vc = (enc_out @ blk["cross_attn"]["wv"].astype(dtype)).reshape(
            B, Se, cfg.n_heads, cfg.hd)
        cattn = L.blocked_attention(qc, kc, vc, causal=False, cross=True,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + L.attention_out(blk["cross_attn"], cattn, dtype)
        h = L.layernorm(blk["ln_mlp"], x, cfg.norm_eps)
        x = x + L.gelu_mlp(blk["mlp"], h, dtype)
        return x, (kvcache, kc.astype(dtype), vc.astype(dtype))

    x, (kv, ck, cv) = uscan(sb, x, (params["dec_blocks"], caches.self_kv))
    hidden = L.layernorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    lg = L.unembed(params["embed"], hidden)
    return lg[:, 0], EncDecCaches(kv, ck, cv)


def decode_step(params, caches: EncDecCaches, batch, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    tok = batch["token"]
    B = tok.shape[0]
    pos = caches.self_kv.length[0]
    x = L.embed(params["embed"], tok, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1).astype(dtype)

    def sb(x, blk_cache):
        blk, kvcache, kc, vc = blk_cache
        h = L.layernorm(blk["ln_self"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(blk["self_attn"], h, cfg.n_heads, cfg.n_heads,
                                  cfg.hd, None, cfg.rope_theta, dtype=dtype)
        kvcache = L.cache_insert(kvcache, k, v)
        attn = L.flash_decode(q, kvcache)
        x = x + L.attention_out(blk["self_attn"], attn, dtype)
        h = L.layernorm(blk["ln_cross"], x, cfg.norm_eps)
        qc, _, _ = L.attention_qkv(blk["cross_attn"], h, cfg.n_heads,
                                   cfg.n_heads, cfg.hd, None, cfg.rope_theta,
                                   dtype=dtype)
        cattn = L.blocked_attention(qc, kc, vc, causal=False, cross=True,
                                    q_block=1, kv_block=cfg.kv_block)
        x = x + L.attention_out(blk["cross_attn"], cattn, dtype)
        h = L.layernorm(blk["ln_mlp"], x, cfg.norm_eps)
        x = x + L.gelu_mlp(blk["mlp"], h, dtype)
        return x, kvcache

    x, kv = uscan(
        sb, x, (params["dec_blocks"], caches.self_kv, caches.cross_k,
                caches.cross_v))
    hidden = L.layernorm(params["ln_f"], x, cfg.norm_eps)
    lg = L.unembed(params["embed"], hidden)
    return lg[:, 0], EncDecCaches(kv, caches.cross_k, caches.cross_v)
