"""RWKV6 ("Finch") — attention-free RNN with data-dependent per-channel decay.

Per head (K = V = head dim):
    y_t = r_t . (S_{t-1} + diag(u * k_t) v_t),   S_t = diag(d_t) S_{t-1} + k_t (x) v_t
with d_t = exp(-exp(w_t)) and w_t = w0 + tanh(x_t A_w) B_w — the paper-defining
*data-dependent decay* (arXiv:2404.05892). Training uses a chunked scan: the
intra-chunk pairwise decay tensor is computed exactly in log-space
(exp(L_{t-1}-L_j) <= 1 for j < t, so no overflow), chunk=16 keeps the
[B,H,C,C,K] transient at tens of MB. Decode is the O(1)-state recurrence =>
long_500k serve_step is sub-quadratic.

Simplification vs the reference implementation (documented): the five token-
shift interpolation weights (mu_r/k/v/w/g) are static per-channel parameters
(RWKV6 makes them data-dependent via a small LoRA as well); the decay LoRA —
the architecturally defining piece — is implemented in full.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L
from .unroll_ctx import scan as uscan
from .config import ArchConfig
from .sharding import shard

LOG_DECAY_FLOOR = -20.0
DECAY_LORA = 64


class RwkvCache(NamedTuple):
    shift_t: jax.Array   # [B, D] last token entering time-mix
    shift_c: jax.Array   # [B, D] last token entering channel-mix
    wkv: jax.Array       # [B, H, K, V] state


def dims(cfg: ArchConfig):
    K = cfg.ssm_head_dim
    H = cfg.d_model // K
    return H, K


def init_block(key, cfg: ArchConfig):
    D, F = cfg.d_model, cfg.d_ff
    H, K = dims(cfg)
    ks = jax.random.split(key, 10)
    mu = lambda k: jax.random.uniform(k, (D,), jnp.float32)
    return {
        "ln1": L.init_layernorm(D),
        "ln2": L.init_layernorm(D),
        "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
        "mu_w": mu(ks[3]), "mu_g": mu(ks[4]),
        "Wr": L._init_dense(ks[5], D, D, D),
        "Wk": L._init_dense(ks[6], D, D, D),
        "Wv": L._init_dense(ks[7], D, D, D),
        "Wg": L._init_dense(ks[8], D, D, D),
        "w0": jnp.full((D,), 1.0, jnp.float32),   # exp(1) ~ strong decay init
        "wA": L._init_dense(ks[9], D, D, DECAY_LORA),
        "wB": jnp.zeros((DECAY_LORA, D), jnp.float32),
        "u": (0.1 * jax.random.normal(jax.random.fold_in(key, 11), (H, K))).astype(jnp.float32),
        "ln_x": L.init_layernorm(D),
        "Wo": L._init_dense(jax.random.fold_in(key, 12), D, D, D),
        # channel mix
        "mu_ck": mu(jax.random.fold_in(key, 13)),
        "mu_cr": mu(jax.random.fold_in(key, 14)),
        "cWk": L._init_dense(jax.random.fold_in(key, 15), D, D, F),
        "cWv": L._init_dense(jax.random.fold_in(key, 16), F, F, D),
        "cWr": L._init_dense(jax.random.fold_in(key, 17), D, D, D),
    }


def _shift(x, last):
    """Token shift: [B,S,D] -> previous token per position; last: [B,D]."""
    return jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, lw, u, s0, chunk: int = 16):
    """r,k,v: [B,S,H,K]; lw: [B,S,H,K] log decays (<=0); u: [H,K];
    s0: [B,H,K,V]. Returns (y [B,S,H,K], s_final)."""
    Bsz, S, H, K = r.shape
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v, lw = (jnp.pad(a, z4) for a in (r, k, v, lw))
    resh = lambda a: a.reshape(Bsz, nch, chunk, H, K).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)  # [nch,B,H,C,K]

    mask_lt = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict j < t

    def body(s, xs):
        rr, kk, vv, ww = xs                      # [B,H,C,K]
        Lc = jnp.cumsum(ww, axis=2)              # inclusive [B,H,C,K]
        # inter: y_t += (r_t * exp(L_{t-1})) @ s ; L_{t-1} = L_t - w_t
        q_t = rr * jnp.exp(Lc - ww)
        y_inter = jnp.einsum("bhck,bhkv->bhcv", q_t, s)
        # intra (j < t): A[t,j] = sum_k r_t k_j exp(L_{t-1}-L_j)  (exp arg <= 0)
        Dk = (Lc - ww)[:, :, :, None, :] - Lc[:, :, None, :, :]  # [B,H,C,C,K]
        Dk = jnp.where(mask_lt[None, None, :, :, None], Dk, -jnp.inf)
        A = jnp.einsum("bhtk,bhjk,bhtjk->bhtj", rr, kk, jnp.exp(Dk))
        y_intra = jnp.einsum("bhtj,bhjv->bhtv", A, vv)
        # current-token bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bhck,bhck->bhc", rr, u[None, :, None, :] * kk)
        y_bonus = bonus[..., None] * vv
        # state: s' = diag(exp(L_C)) s + sum_j diag(exp(L_C - L_j)) k_j (x) v_j
        wtail = jnp.exp(Lc[:, :, -1:, :] - Lc)   # [B,H,C,K]
        s_new = (jnp.exp(Lc[:, :, -1, :])[..., None] * s
                 + jnp.einsum("bhjk,bhjv->bhkv", kk * wtail, vv))
        return s_new, y_inter + y_intra + y_bonus

    from .unroll_ctx import active as _unroll_active
    if _unroll_active():
        # COST-PROBE PATH (dry-run only): vmap the chunk bodies with a dummy
        # state. Operation count per chunk is identical to the sequential
        # scan; OUTPUT VALUES ARE WRONG (state not propagated). Never taken
        # outside launch/dryrun.py probes.
        _, ys = jax.vmap(body, in_axes=(None, 0))(
            s0.astype(jnp.float32),
            (rc.astype(jnp.float32), kc.astype(jnp.float32),
             vc.astype(jnp.float32), lwc))
        s_fin = s0.astype(jnp.float32)
    else:
        s_fin, ys = jax.lax.scan(body, s0.astype(jnp.float32),
                                 (rc.astype(jnp.float32), kc.astype(jnp.float32),
                                  vc.astype(jnp.float32), lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bsz, nch * chunk, H, K)
    return y[:, :S], s_fin


def time_mix(p, x, cfg: ArchConfig, dtype, cache: RwkvCache | None):
    B, S, D = x.shape
    H, K = dims(cfg)
    last = cache.shift_t if cache is not None else jnp.zeros((B, D), x.dtype)
    xp = _shift(x, last)
    lerp = lambda mu: x + (xp - x) * mu.astype(dtype)
    r = (lerp(p["mu_r"]) @ p["Wr"].astype(dtype)).reshape(B, S, H, K)
    k = (lerp(p["mu_k"]) @ p["Wk"].astype(dtype)).reshape(B, S, H, K)
    v = (lerp(p["mu_v"]) @ p["Wv"].astype(dtype)).reshape(B, S, H, K)
    g = lerp(p["mu_g"]) @ p["Wg"].astype(dtype)
    xw = lerp(p["mu_w"]).astype(jnp.float32)
    wlog = p["w0"] + jnp.tanh(xw @ p["wA"]) @ p["wB"]          # [B,S,D]
    lw = jnp.maximum(-jnp.exp(wlog), LOG_DECAY_FLOOR).reshape(B, S, H, K)

    s0 = (cache.wkv if cache is not None
          else jnp.zeros((B, H, K, K), jnp.float32))
    if S == 1 and cache is not None:  # decode: exact single-step recurrence
        rr, kk, vv = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
        y = jnp.einsum("bhk,bhkv->bhv", rr,
                       s0 + p["u"][None, :, :, None] * jnp.einsum(
                           "bhk,bhv->bhkv", kk, vv))
        s_fin = (jnp.exp(lw[:, 0])[..., None] * s0
                 + jnp.einsum("bhk,bhv->bhkv", kk, vv))
        y = y[:, None]
    else:
        y, s_fin = wkv_chunked(r, k, v, lw, p["u"], s0)
    y = y.reshape(B, S, D).astype(dtype)
    y = L.layernorm(p["ln_x"], y, cfg.norm_eps)  # group-norm stand-in
    out = (y * jax.nn.silu(g)) @ p["Wo"].astype(dtype)
    new_shift = x[:, -1]
    return out, new_shift, s_fin


def channel_mix(p, x, dtype, cache: RwkvCache | None):
    B, S, D = x.shape
    last = cache.shift_c if cache is not None else jnp.zeros((B, D), x.dtype)
    xp = _shift(x, last)
    xk = x + (xp - x) * p["mu_ck"].astype(dtype)
    xr = x + (xp - x) * p["mu_cr"].astype(dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cWk"].astype(dtype)))
    out = jax.nn.sigmoid(xr @ p["cWr"].astype(dtype)) * (k @ p["cWv"].astype(dtype))
    return out, x[:, -1]


def block(p, x, cfg: ArchConfig, dtype, cache: RwkvCache | None = None):
    att, shift_t, wkv = time_mix(p, L.layernorm(p["ln1"], x, cfg.norm_eps),
                                 cfg, dtype, cache)
    x = x + shard(att, "act_btd")
    ffn, shift_c = channel_mix(p, L.layernorm(p["ln2"], x, cfg.norm_eps),
                               dtype, cache)
    x = x + shard(ffn, "act_btd")
    new_cache = (RwkvCache(shift_t.astype(x.dtype), shift_c.astype(x.dtype), wkv)
                 if cache is not None else None)
    return x, new_cache


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> RwkvCache:
    H, K = dims(cfg)
    return RwkvCache(jnp.zeros((batch, cfg.d_model), dtype),
                     jnp.zeros((batch, cfg.d_model), dtype),
                     jnp.zeros((batch, H, K, K), jnp.float32))


# -- full model ---------------------------------------------------------------

def init(key, cfg: ArchConfig):
    ke, kb = jax.random.split(key)
    bkeys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(bkeys)
    return {"embed": L.init_embedding(ke, cfg.vocab, cfg.d_model),
            "blocks": blocks, "ln_f": L.init_layernorm(cfg.d_model)}


def forward(params, tokens, *, cfg: ArchConfig, remat: bool = True):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], tokens, dtype), "act_btd")

    def body(blk, x):
        return block(blk, x, cfg, dtype)[0]

    if remat:
        body = jax.checkpoint(body)

    def scan_body(x, blk):
        return body(blk, x), None

    x, _ = uscan(scan_body, x, params["blocks"])
    return L.layernorm(params["ln_f"], x, cfg.norm_eps)


def loss(params, batch, *, cfg: ArchConfig):
    hidden = forward(params, batch["tokens"], cfg=cfg)
    return L.cross_entropy_chunked(hidden, params["embed"], batch["labels"])


def init_caches(cfg: ArchConfig, batch: int, max_len: int, n_chunks: int,
                dtype=jnp.bfloat16):
    del max_len, n_chunks  # O(1) state — the point of the architecture
    return jax.vmap(lambda _: init_cache(cfg, batch, dtype))(
        jnp.arange(cfg.n_layers))


def _run_with_cache(params, x, caches, cfg: ArchConfig, dtype):
    def scan_body(x, blk_cache):
        blk, cache = blk_cache
        x, cache = block(blk, x, cfg, dtype, cache)
        return x, cache

    x, caches = uscan(scan_body, x, (params["blocks"], caches))
    return L.layernorm(params["ln_f"], x, cfg.norm_eps), caches


def prefill(params, batch, caches, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], batch["tokens"], dtype), "act_btd")
    hidden, caches = _run_with_cache(params, x, caches, cfg, dtype)
    lg = L.unembed(params["embed"], hidden[:, -1:])
    return lg[:, 0], caches


def decode_step(params, caches, batch, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    x = L.embed(params["embed"], batch["token"], dtype)
    hidden, caches = _run_with_cache(params, x, caches, cfg, dtype)
    lg = L.unembed(params["embed"], hidden)
    return lg[:, 0], caches
