"""Mixture-of-Experts decoder (dbrx-132b: 16e top-4; qwen3-moe: 128e top-8).

Routing: token-choice top-k with per-expert capacity (C = ceil(T * top_k / E *
capacity_factor)); tokens beyond capacity are dropped (standard practice, keeps
compute static for the dry-run). Dispatch is index-gather based (no [T, E, C]
one-hot tensors — at 1M tokens those are infeasible): for each expert we take
the top-C tokens by router weight, process [E, C, D] with batched per-expert
matmuls, and scatter-add back.

Expert parallelism: expert weights carry a leading E axis annotated with the
'expert_weights' logical rule -> sharded over the 'model' mesh axis (EP reuses
the TP axis; dbrx 16e/16 = 1 expert per chip, qwen3 128e/16 = 8). The combine
scatter-add reduces over the model axis (XLA lowers it to the EP all-reduce).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .unroll_ctx import scan as uscan

from . import layers as L
from . import transformer as TF
from .config import ArchConfig
from .sharding import shard


def init_moe_ffn(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": L._init_dense(k1, D, D, E),
        "w_gate": (0.02 * jax.random.normal(k2, (E, D, F))).astype(jnp.float32),
        "w_up": (0.02 * jax.random.normal(k3, (E, D, F))).astype(jnp.float32),
        "w_down": (0.02 * jax.random.normal(k4, (E, F, D))).astype(jnp.float32),
    }


MOE_CHUNK_TOKENS = 131_072  # dispatch in token chunks beyond this (prefill)


def moe_ffn(p, x, cfg: ArchConfig, dtype):
    """x: [B, S, D] -> [B, S, D].

    Long-prefill inputs are dispatched in token chunks (capacity enforced
    per chunk — standard practice; keeps the [E, C, D] gather transient
    bounded instead of O(T) — the 120 GiB dbrx-prefill buffer of the §Perf
    log)."""
    B, S, D = x.shape
    T = B * S
    if T > MOE_CHUNK_TOKENS:
        from .unroll_ctx import active as _unroll_active
        nc = -(-T // MOE_CHUNK_TOKENS)
        while T % nc:
            nc += 1
        xt = x.reshape(nc, T // nc, 1, D)  # chunks as [b=Tc, s=1] pseudo-batch
        if _unroll_active():  # cost-probe: loop-free, flop-identical
            out = jax.vmap(lambda c: _moe_tokens(p, c, cfg, dtype))(xt)
        else:
            def body(_, c):
                return None, _moe_tokens(p, c, cfg, dtype)
            _, out = jax.lax.scan(body, None, xt)
        return out.reshape(B, S, D)
    return _moe_tokens(p, x, cfg, dtype)


def _moe_tokens(p, x, cfg: ArchConfig, dtype):
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    cap = max(int(T * K / E * cfg.capacity_factor), 1)
    cap = min(cap, T)
    xt = x.reshape(T, D)

    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                            # [T, K]
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # dense [T, E] weight map of the top-k choices (0 elsewhere)
    wmap = jnp.zeros((T, E), jnp.float32)
    wmap = wmap.at[jnp.arange(T)[:, None], topi].set(topw)          # [T, E]

    # per-expert capacity selection: top-C tokens by routing weight
    wcap, tok_idx = jax.lax.top_k(wmap.T, cap)                      # [E, C]
    keep = wcap > 0.0

    we_g = shard(p["w_gate"].astype(dtype), "expert_w_in")   # F over 'model'
    we_u = shard(p["w_up"].astype(dtype), "expert_w_in")
    we_d = shard(p["w_down"].astype(dtype), "expert_w_out")  # F over 'model'

    gathered = jnp.take(xt, tok_idx.reshape(-1), axis=0).reshape(E, cap, D)
    gathered = shard(gathered, "expert_tokens")  # D over 'fsdp' = w_gate's D
    g = jnp.einsum("ecd,edf->ecf", gathered, we_g)
    u = jnp.einsum("ecd,edf->ecf", gathered, we_u)
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, we_d)                       # [E, C, D]
    out = out * (wcap * keep)[..., None].astype(dtype)

    # combine: scatter-add expert outputs back to token positions
    flat_idx = jnp.where(keep, tok_idx, T).reshape(-1)              # dropped -> OOB
    combined = jnp.zeros((T + 1, D), dtype).at[flat_idx].add(
        out.reshape(E * cap, D))[:T]
    return combined.reshape(B, S, D)


# -- blocks ------------------------------------------------------------------

def init_block(key, cfg: ArchConfig):
    init_norm, _ = TF._norm_fns(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": init_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd),
        "ln_mlp": init_norm(cfg.d_model),
        "moe": init_moe_ffn(k2, cfg),
    }


def init(key, cfg: ArchConfig):
    ke, kb = jax.random.split(key)
    init_norm, _ = TF._norm_fns(cfg)
    bkeys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(bkeys)
    return {"embed": L.init_embedding(ke, cfg.vocab, cfg.d_model),
            "blocks": blocks, "ln_f": init_norm(cfg.d_model)}


def _block_train(blk, x, positions, cfg: ArchConfig, dtype):
    _, norm = TF._norm_fns(cfg)
    h = norm(blk["ln_attn"], x)
    q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, positions, cfg.rope_theta, dtype=dtype)
    q, k, v = shard(q, "act_heads"), shard(k, "act_kv_heads"), shard(v, "act_kv_heads")
    attn = L.blocked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(blk["attn"], attn, dtype), "act_btd")
    h = norm(blk["ln_mlp"], x)
    x = x + shard(moe_ffn(blk["moe"], h, cfg, dtype), "act_btd")
    return x


def forward(params, tokens, *, cfg: ArchConfig, remat: bool = True):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], tokens, dtype), "act_btd")
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    body = partial(_block_train, positions=positions, cfg=cfg, dtype=dtype)
    if remat:
        body = jax.checkpoint(body)

    def scan_body(x, blk):
        return body(blk, x), None

    x, _ = uscan(scan_body, x, params["blocks"])
    _, norm = TF._norm_fns(cfg)
    return norm(params["ln_f"], x)


def loss(params, batch, *, cfg: ArchConfig):
    hidden = forward(params, batch["tokens"], cfg=cfg)
    return L.cross_entropy_chunked(hidden, params["embed"], batch["labels"])


init_caches = TF.init_caches


def prefill(params, batch, caches, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    tokens = batch["tokens"]
    x = shard(L.embed(params["embed"], tokens, dtype), "act_btd")
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, norm = TF._norm_fns(cfg)

    def scan_body(x, blk_cache):
        blk, cache = blk_cache
        h = norm(blk["ln_attn"], x)
        q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.hd, positions, cfg.rope_theta, dtype=dtype)
        cache = L.cache_prefill(cache, k, v)
        cache = L.KVCache(shard(cache.k, "kv_cache"), shard(cache.v, "kv_cache"),
                          cache.length)
        attn = L.blocked_attention(q, k, v, causal=True,
                                   window=cfg.sliding_window,
                                   q_block=cfg.q_block, kv_block=cfg.kv_block)
        x = x + shard(L.attention_out(blk["attn"], attn, dtype), "act_btd")
        h = norm(blk["ln_mlp"], x)
        x = x + shard(moe_ffn(blk["moe"], h, cfg, dtype), "act_btd")
        return x, cache

    x, caches = uscan(scan_body, x, (params["blocks"], caches))
    hidden = norm(params["ln_f"], x[:, -1:])
    lg = TF.logits_fn(params, hidden, cfg)
    return lg[:, 0], caches


def decode_step(params, caches, batch, *, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    x = shard(L.embed(params["embed"], batch["token"], dtype), "act_btd")
    B = x.shape[0]
    pos_scalar = batch.get("pos")
    if pos_scalar is None:
        pos_scalar = caches.length[0]
    positions = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)
    _, norm = TF._norm_fns(cfg)

    def scan_body(x, blk_cache):
        blk, cache = blk_cache
        h = norm(blk["ln_attn"], x)
        q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.hd, positions, cfg.rope_theta, dtype=dtype)
        cache = L.cache_insert(cache, k, v)
        attn = L.flash_decode(q, cache, window=cfg.sliding_window)
        x = x + L.attention_out(blk["attn"], attn, dtype)
        h = norm(blk["ln_mlp"], x)
        x = x + moe_ffn(blk["moe"], h, cfg, dtype)
        return x, cache

    x, caches = jax.lax.scan(scan_body, x, (params["blocks"], caches))
    hidden = norm(params["ln_f"], x)
    lg = TF.logits_fn(params, hidden, cfg)
    return lg[:, 0], caches
