"""Architecture configuration schema (one instance per assigned arch)."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / rwkv6 blocks)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # hybrid (zamba2): apply the shared attention block every k ssm layers
    shared_attn_every: int = 0
    shared_attn_heads: int = 0
    shared_attn_d_ff: int = 0
    # attention details
    sliding_window: int = 0      # SWA (h2o-danube)
    rope_theta: float = 1e6
    mrope: bool = False          # qwen2-vl
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    max_source_len: int = 0
    # norm & misc
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    act_dtype: str = "bfloat16"
    # attention blocking (memory envelope of prefill/train)
    q_block: int = 512
    kv_block: int = 1024
    # long-context capability: True iff serve_step cost is sub-quadratic in ctx
    subquadratic: bool = False
    # ByzSGD group policy: n_groups = R // byz_group_divisor (failure domains;
    # >1 for archs whose per-replica memory forces fewer, larger server groups)
    byz_group_divisor: int = 1
    # hard cap on n_groups (0 = none). qwen3 multi-pod: the XLA SPMD
    # partitioner SIGFPEs at G=4/K=8 (b/433785288); G=2 compiles. The
    # intended config is G=4 — revisit on a Shardy toolchain.
    byz_group_cap: int = 0
    # replica storage dtype: f32 (paper-faithful SGD) unless replica memory
    # forces bf16 (dbrx/qwen3 — documented deviation, DESIGN.md)
    param_dtype: str = "float32"
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test-sized sibling: same family/topology, tiny dims."""
        import dataclasses
        small = dict(
            n_layers=min(self.n_layers, 2 if self.shared_attn_every == 0
                         else self.shared_attn_every + 1),
            d_model=128, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256, vocab=512, head_dim=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=min(self.shared_attn_every, 2) if self.shared_attn_every else 0,
            shared_attn_heads=4 if self.shared_attn_every else 0,
            shared_attn_d_ff=256 if self.shared_attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            max_source_len=min(self.max_source_len, 64) if self.max_source_len else 0,
            q_block=64, kv_block=64,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
