"""Dense decoder-only transformer (GQA + RoPE/M-RoPE + SwiGLU + optional SWA).

Covers: phi3-medium-14b, phi4-mini-3.8b, internlm2-20b, h2o-danube-3-4b (SWA),
qwen2-vl-7b (M-RoPE + stub patch-embedding inputs). Also the attention/FFN
backbone reused by the MoE and hybrid families.

Layers are scanned (single-block compile) with remat on the block body for
training. Decode uses the chunk-sharded flash-decode cache from layers.py.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .unroll_ctx import scan as uscan

from . import layers as L
from .config import ArchConfig
from .sharding import shard


def _norm_fns(cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return L.init_layernorm, partial(L.layernorm, eps=cfg.norm_eps)
    return L.init_rmsnorm, partial(L.rmsnorm, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig):
    init_norm, _ = _norm_fns(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": init_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd),
        "ln_mlp": init_norm(cfg.d_model),
        "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff),
    }


def init(key, cfg: ArchConfig):
    ke, kb, kf = jax.random.split(key, 3)
    init_norm, _ = _norm_fns(cfg)
    bkeys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(bkeys)  # leaves [L, ...]
    params = {"embed": L.init_embedding(ke, cfg.vocab, cfg.d_model),
              "blocks": blocks,
              "ln_f": init_norm(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": L._init_dense(kf, cfg.d_model, cfg.vocab,
                                                    cfg.d_model)}
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _block_train(blk, x, positions, cfg: ArchConfig, dtype):
    _, norm = _norm_fns(cfg)
    h = norm(blk["ln_attn"], x)
    q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, positions, cfg.rope_theta, dtype=dtype)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    attn = L.blocked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(blk["attn"], attn, dtype), "act_btd")
    h = norm(blk["ln_mlp"], x)
    x = x + shard(L.swiglu(blk["mlp"], h, dtype), "act_btd")
    return x


def forward(params, tokens=None, *, cfg: ArchConfig, embeds=None,
            positions=None, remat: bool = True):
    """[B, S] tokens (or [B, S, D] stub embeds for VLM) -> [B, S, D] hidden."""
    dtype = jnp.dtype(cfg.act_dtype)
    if embeds is None:
        x = L.embed(params["embed"], tokens, dtype)
    else:
        x = embeds.astype(dtype)
    x = shard(x, "act_btd")
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    body = partial(_block_train, positions=positions, cfg=cfg, dtype=dtype)
    if remat:
        body = jax.checkpoint(body)

    def scan_body(x, blk):
        return body(blk, x), None

    x, _ = uscan(scan_body, x, params["blocks"])
    _, norm = _norm_fns(cfg)
    return norm(params["ln_f"], x)


def logits_fn(params, hidden, cfg: ArchConfig):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    out = L.unembed(table, hidden)
    return shard(out, "logits")


def loss(params, batch, *, cfg: ArchConfig):
    hidden = forward(params, batch.get("tokens"), cfg=cfg,
                     embeds=batch.get("embeds"), positions=batch.get("positions"))
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.cross_entropy_chunked(hidden, table, batch["labels"])


# ---------------------------------------------------------------------------
# inference: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int, n_chunks: int,
                dtype=jnp.bfloat16):
    def one(_):
        return L.KVCache.create(batch, cfg.n_kv_heads, max_len, cfg.hd,
                                n_chunks, dtype)
    return jax.vmap(one)(jnp.arange(cfg.n_layers))  # leaves [L, ...]


def _block_prefill(blk, x, positions, cfg: ArchConfig, dtype, cache: L.KVCache):
    _, norm = _norm_fns(cfg)
    h = norm(blk["ln_attn"], x)
    q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, positions, cfg.rope_theta, dtype=dtype)
    cache = L.cache_prefill(cache, k, v)
    cache = L.KVCache(shard(cache.k, "kv_cache"), shard(cache.v, "kv_cache"),
                      cache.length)
    attn = L.blocked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + shard(L.attention_out(blk["attn"], attn, dtype), "act_btd")
    h = norm(blk["ln_mlp"], x)
    x = x + shard(L.swiglu(blk["mlp"], h, dtype), "act_btd")
    return x, cache


def prefill(params, batch, caches, *, cfg: ArchConfig):
    """Returns (last-token logits [B, V], filled caches)."""
    dtype = jnp.dtype(cfg.act_dtype)
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    x = L.embed(params["embed"], tokens, dtype) if embeds is None else embeds.astype(dtype)
    x = shard(x, "act_btd")
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def scan_body(x, blk_cache):
        blk, cache = blk_cache
        x, cache = _block_prefill(blk, x, positions, cfg, dtype, cache)
        return x, cache

    x, caches = uscan(scan_body, x, (params["blocks"], caches))
    _, norm = _norm_fns(cfg)
    hidden = norm(params["ln_f"], x[:, -1:])
    lg = logits_fn(params, hidden, cfg)
    return lg[:, 0], caches


def _block_decode(blk, x, positions, cfg: ArchConfig, dtype, cache: L.KVCache):
    _, norm = _norm_fns(cfg)
    h = norm(blk["ln_attn"], x)
    q, k, v = L.attention_qkv(blk["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, positions, cfg.rope_theta, dtype=dtype)
    cache = L.cache_insert(cache, k, v)
    attn = L.flash_decode(q, cache, window=cfg.sliding_window)
    x = x + L.attention_out(blk["attn"], attn, dtype)
    h = norm(blk["ln_mlp"], x)
    x = x + L.swiglu(blk["mlp"], h, dtype)
    return x, cache


def decode_step(params, caches, batch, *, cfg: ArchConfig):
    """batch: {"token": [B,1] (or "embeds" [B,1,D]), optional "positions"}.
    Returns (logits [B, V], updated caches). One new token vs the KV cache."""
    dtype = jnp.dtype(cfg.act_dtype)
    tok = batch.get("token")
    embeds = batch.get("embeds")
    x = L.embed(params["embed"], tok, dtype) if embeds is None else embeds.astype(dtype)
    x = shard(x, "act_btd")
    B = x.shape[0]
    pos_scalar = batch.get("pos")
    if pos_scalar is None:
        # use cache length of layer 0
        pos_scalar = caches.length[0]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(pos_scalar[None, None], (B, 1)).astype(jnp.int32)

    def scan_body(x, blk_cache):
        blk, cache = blk_cache
        x, cache = _block_decode(blk, x, positions, cfg, dtype, cache)
        return x, cache

    x, caches = jax.lax.scan(scan_body, x, (params["blocks"], caches))
    _, norm = _norm_fns(cfg)
    hidden = norm(params["ln_f"], x)
    lg = logits_fn(params, hidden, cfg)
    return lg[:, 0], caches
