"""Layer-1 driver: walk the lint roots, parse, run file/repo rules.

Purely static — this module never imports the code it checks. Fixture
trees (``tests/``) are excluded from the default roots so rule-tripping
fixtures in ``tests/test_analyze.py`` don't flag the repo; the analyzer
package itself IS linted (rules quote sync-call names as strings, not
calls, precisely so they pass their own checks).
"""
from __future__ import annotations

import ast
import os

from .findings import Finding, is_suppressed, scan_suppressions
from .registry import rules

LINT_ROOTS = ("src/repro", "benchmarks", "examples")
_SKIP_DIRS = {"__pycache__", ".git", "results"}


def lint_paths(root: str) -> list[str]:
    out = []
    for lr in LINT_ROOTS:
        base = os.path.join(root, lr)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)


def lint_file(path: str, root: str, source: str | None = None,
              scoped_rules=None) -> list[Finding]:
    """Run every file-scope rule on one file; apply inline suppressions."""
    if source is None:
        with open(path) as f:
            source = f.read()
    rel = os.path.relpath(path, root)
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding("REPRO-PARSE", rel, e.lineno or 0,
                        f"file does not parse: {e.msg}")]
    sups, bad_sups = scan_suppressions(source, rel)
    found: list[Finding] = list(bad_sups)
    for rule in (scoped_rules if scoped_rules is not None
                 else rules(scope="file")):
        for f in rule.check(tree, source, rel):
            if not is_suppressed(f, sups):
                found.append(f)
    return found


def lint_repo(root: str, include_repo_rules: bool = True,
              only_files: set[str] | None = None) -> list[Finding]:
    """Layer 1 over the whole tree: all file rules + repo-scope rules.

    Repo-scope findings honor inline suppressions too: each finding is
    attributed to a file:line (e.g. a preset registration line), and a
    ``# analyze: ignore[RULE-ID] why`` on that line suppresses it.

    ``only_files`` (rel paths) restricts the *file-scope* pass — the
    ``--fast`` pre-commit lane lints only the changed files; repo-scope
    rules are whole-tree invariants and always see everything.
    """
    found: list[Finding] = []
    for path in lint_paths(root):
        if (only_files is not None
                and os.path.relpath(path, root) not in only_files):
            continue
        found.extend(lint_file(path, root))
    if include_repo_rules:
        sup_cache: dict[str, list] = {}
        for rule in rules(scope="repo"):
            for f in rule.check(root):
                if f.path not in sup_cache:
                    fpath = os.path.join(root, f.path)
                    try:
                        with open(fpath) as fh:
                            src = fh.read()
                        sup_cache[f.path], _ = scan_suppressions(src, f.path)
                    except OSError:
                        sup_cache[f.path] = {}
                if not is_suppressed(f, sup_cache[f.path]):
                    found.append(f)
    return found


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def call_name(node: ast.Call) -> str:
    """Dotted name of a call target: ``jax.lax.scan`` -> 'jax.lax.scan'."""
    return dotted_name(node.func)


def dotted_name(node: ast.AST) -> str:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def literal_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def self_attr_reads(node: ast.AST) -> set[str]:
    """All ``self.X`` attribute names read anywhere under ``node``."""
    out: set[str] = set()
    for n in ast.walk(node):
        if (isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name)
                and n.value.id == "self"):
            out.add(n.attr)
    return out


def self_method_calls(node: ast.AST) -> set[str]:
    """Names of ``self.m(...)`` calls under ``node``."""
    out: set[str] = set()
    for n in ast.walk(node):
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and isinstance(n.func.value, ast.Name)
                and n.func.value.id == "self"):
            out.add(n.func.attr)
    return out
