"""CLI: ``python -m repro.analyze [--hlo] [--table] [--json PATH]
[--update-baseline] [--root DIR]``.

Layer 1 (AST lint + repo invariants) always runs and never imports the
checked code. ``--hlo`` adds layer 2: before jax is imported the CLI
forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (unless the
caller already set XLA_FLAGS) so the protocol mesh audits run genuinely
multi-device on CPU. Exit status 1 iff any finding is neither inline-
suppressed nor in the committed baseline — the ``make lint`` contract.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="repo-invariant lint (layer 1) + compiled-artifact "
                    "audit (layer 2, --hlo)")
    ap.add_argument("--hlo", action="store_true",
                    help="also run the HLO-scope rules (imports jax on a "
                         "forced 8-device CPU topology)")
    ap.add_argument("--table", action="store_true",
                    help="print the rule table (README format) and exit")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the findings report JSON here")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite results/analyze/baseline.json from the "
                         "current findings, pruning stale entries and "
                         "keeping scopes not run (keep it short; prefer "
                         "fixes)")
    ap.add_argument("--fast", action="store_true",
                    help="lint only the git-changed files (file-scope "
                         "rules) and scope the interprocedural taint "
                         "analysis to their call-graph component — the "
                         "`make lint-fast` pre-commit lane")
    ap.add_argument("--root", default=None,
                    help="repo root (default: cwd, or the checkout "
                         "containing this package)")
    args = ap.parse_args(argv)

    if args.hlo:
        # must precede any jax import anywhere in the process
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    from . import findings as F
    from . import registry
    from .astlint import lint_paths, lint_repo

    if args.table:
        print(registry.markdown_table())
        return 0

    root = args.root or _find_root()
    changed = _changed_files(root) if args.fast else None
    if args.fast:
        from .rules import taint_byz
        taint_byz.scope_to(changed)
    found = lint_repo(root, only_files=changed)
    scopes = {"file", "repo"}
    if args.hlo:
        scopes.add("hlo")
        for rule in registry.rules(scope="hlo"):
            found.extend(rule.check(root))

    baseline = F.load_baseline(os.path.join(root, F.BASELINE_PATH))
    new, known = F.split_baselined(found, baseline)
    stats = {"rules_run": [r.rule_id for r in registry.rules()
                           if r.scope in scopes],
             "files_linted": len(lint_paths(root)),
             "hlo": bool(args.hlo)}

    if args.update_baseline:
        rule_scopes = {r.rule_id: r.scope for r in registry.rules()}
        path, pruned = F.refresh_baseline(
            found, os.path.join(root, F.BASELINE_PATH), root, scopes,
            rule_scopes)
        note = f" ({len(pruned)} stale entries pruned)" if pruned else ""
        print(f"baseline: {len(found)} finding(s) -> {path}{note}")
        return 0

    if args.json:
        F.write_report(F.to_report(new, known, stats), args.json)

    for f in new:
        print(f.format())
    if known:
        print(f"({len(known)} baselined finding(s) suppressed)")
    if new:
        print(f"\n{len(new)} violation(s)"
              + ("" if args.hlo else " (layer 1 only; --hlo for layer 2)"))
        return 1
    print("clean"
          + ("" if args.hlo else " (layer 1 only; --hlo for layer 2)"))
    return 0


def _changed_files(root: str) -> set[str] | None:
    """Rel paths changed vs HEAD (`--fast` scope); None -> full analysis."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"],
            cwd=root, capture_output=True, text=True, check=True,
            timeout=10).stdout
    except Exception:
        return None
    return {ln.strip() for ln in out.splitlines()
            if ln.strip().endswith(".py")}


def _find_root() -> str:
    """cwd if it holds the lint roots, else the checkout above src/."""
    cwd = os.getcwd()
    if os.path.isdir(os.path.join(cwd, "src", "repro")):
        return cwd
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


if __name__ == "__main__":
    sys.exit(main())
