"""Pluggable rule registry for ``repro.analyze``.

A :class:`Rule` couples an id with a checker:

* ``scope="file"`` — ``check(tree, source, path) -> [Finding]`` runs once
  per linted file with its parsed AST (layer 1; never imports the checked
  code).
* ``scope="repo"`` — ``check(root) -> [Finding]`` runs once against the
  repo root (cross-file invariants: presets vs quorum bounds, registry vs
  tests parity).
* ``scope="hlo"`` — ``check(root) -> [Finding]`` runs only under
  ``--hlo`` (layer 2; imports jax, lowers runners, audits compiled text).

Rules register at import of :mod:`repro.analyze.rules`. The table printed
by ``python -m repro.analyze --table`` (and embedded in the README) is
derived from this registry, so it cannot go stale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

_RULES: dict[str, "Rule"] = {}


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    scope: str                      # 'file' | 'repo' | 'hlo'
    description: str                # one line, for the table
    check: Callable
    fix_hint: str = ""


def register(rule: Rule) -> Rule:
    if rule.scope not in ("file", "repo", "hlo"):
        raise ValueError(f"bad scope {rule.scope!r} for {rule.rule_id}")
    if rule.rule_id in _RULES:
        raise ValueError(f"duplicate rule id {rule.rule_id}")
    _RULES[rule.rule_id] = rule
    return rule


def get(rule_id: str) -> Rule:
    _ensure_loaded()
    return _RULES[rule_id]


def rules(scope: str | None = None) -> list[Rule]:
    _ensure_loaded()
    out = sorted(_RULES.values(), key=lambda r: r.rule_id)
    if scope is not None:
        out = [r for r in out if r.scope == scope]
    return out


def _ensure_loaded() -> None:
    # registration side effect. importlib, not `from . import rules`: the
    # package re-exports the rules() *function*, which would shadow the
    # subpackage in an attribute-style import and silently skip loading.
    import importlib
    importlib.import_module(".rules", __package__)


def markdown_table() -> str:
    """Rule table for --table / README (derived, never hand-maintained)."""
    _ensure_loaded()
    lines = ["| rule | layer | checks |", "|---|---|---|"]
    layer = {"file": "1 (AST)", "repo": "1 (AST)", "hlo": "2 (HLO)"}
    for r in rules():
        lines.append(f"| `{r.rule_id}` | {layer[r.scope]} | {r.description} |")
    return "\n".join(lines)
