"""Layer 2 — compiled-artifact audit (``python -m repro.analyze --hlo``).

Layer 1 reads source; this layer reads what XLA actually compiled. Each
rule lowers real repo entry points (the ``smoke`` preset through the fused
and protocol engines, one serve decode step) on the current devices and
audits the artifacts:

* **REPRO-HLO-DONATION** — every donated buffer must survive to the
  executable's ``input_output_alias`` table (parsed by
  ``repro.launch.hlo_analysis.donation_aliases``). A donation XLA silently
  drops is a 2x state-memory regression that no test fails on.
* **REPRO-HLO-HOST-TRANSFER** — ``EpochRunner.run`` promises ONE
  device->host transfer per run (PR 3); counted by patching
  ``jax.device_get``, and the per-epoch body is additionally run under
  ``jax.transfer_guard_device_to_host("disallow")``.
* **REPRO-HLO-RECOMPILE** — the semantic compile cache must dedupe
  identical engine configs and split distinct ones; swept against the
  ``repro.core.epochs.epoch_build_count()`` sentinel.
* **REPRO-HLO-COLLECTIVES** — ``collective_volume_bytes``'s modeled
  exchange bytes must match ring-model traffic measured from the compiled
  HLO of the exchange primitives (``masked_pull`` + ``aggregate_gradients``)
  within 10%, for BOTH collective engines. This audit is how the original
  "sharded moves ~2·P" model was caught being 4x off. On >= 8 devices the
  donation/transfer/collective rules each add a 2D lane (G=4 -> mesh
  (rep=4, fsdp=2)): donation must survive the per-leaf fsdp layouts and
  the model's ``fsdp=K`` term must match the fsdp-sharded exchange.

Rules run meaningfully only on a multi-device mesh: the CLI's ``--hlo``
flag forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before
jax imports. Under fewer devices each rule reports one setup finding
rather than pretending to pass. All jax imports live inside the checks so
layer 1 stays import-free.
"""
from __future__ import annotations

from .findings import Finding
from .registry import Rule, register

#: the audited preset: G=5 co-located groups, mlp_h32 / mixture5_small
_PRESET = "smoke"
_MIN_DEVICES = 5
_COLLECTIVE_RTOL = 0.10
_HLO = "<hlo-audit>"        # findings are about artifacts, not one file
#: overrides that drop the smoke preset to G=4 so ``make_protocol_mesh``
#: lights up the 'fsdp' axis on the forced-8-device lane: (rep=4, fsdp=2)
_2D_OVERRIDES = dict(n_workers=4, f_workers=1, n_servers=4, f_servers=0)


def _device_guard(rule_id: str) -> list[Finding]:
    """One setup finding when the forced-device lane isn't active."""
    import jax
    n = len(jax.devices())
    if n >= _MIN_DEVICES:
        return []
    return [Finding(
        rule_id, _HLO, 0,
        f"audit needs >= {_MIN_DEVICES} devices for the protocol mesh, "
        f"have {n}",
        "run via `python -m repro.analyze --hlo` (forces "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8)")]


def _spec_flags(e):
    """The spec's backend/sort-network knobs, applied around engine
    construction exactly as ``repro.exp.runners.run`` applies them (the
    compile-cache key reads both at build time)."""
    from contextlib import ExitStack

    from ..agg.dispatch import backend_override
    from ..agg.rules import use_sort_network
    stack = ExitStack()
    stack.enter_context(backend_override(e.agg_backend))
    stack.enter_context(use_sort_network(e.sort_network))
    return stack


def _protocol_engine(engine: str, **overrides):
    """The smoke preset on the protocol runner: (exp, pcfg, mesh, eng,
    state, stream)."""
    import jax
    from ..core import protocol as _protocol
    from ..data.pipeline import DeviceBatchStream
    from ..exp import presets
    from ..launch.mesh import make_protocol_mesh, use_mesh
    e = presets.get(_PRESET, runner="protocol", protocol_engine=engine,
                    **overrides)
    pcfg = e.to_protocol_config()
    init_fn, loss_fn, acc = e.build_problem()
    bundle = _protocol.ProblemBundle(init=init_fn, loss=loss_fn)
    mesh = make_protocol_mesh(pcfg.n_groups)
    stream = DeviceBatchStream(e.seed, e.mixture, pcfg.n_groups, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    with _spec_flags(e), use_mesh(mesh):
        eng = _protocol.ProtocolEngine(
            bundle, pcfg, e.build_schedule(), mesh=mesh, acc_fn=acc,
            eval_set=(ex, ey), metrics_every=e.metrics_every)
        state = eng.init_state(jax.random.PRNGKey(e.seed))
    return e, pcfg, mesh, eng, state, stream


def _fused_engine(**overrides):
    """The smoke preset on the fused runner: (exp, eng, state, stream)."""
    import jax
    from ..core.engine import EpochEngine
    from ..data.pipeline import DeviceBatchStream
    from ..exp import presets
    e = presets.get(_PRESET, runner="fused", **overrides)
    sim = e.build_sim(None)
    _, _, acc = e.build_problem()
    state = sim.init_state(jax.random.PRNGKey(e.seed))
    stream = DeviceBatchStream(e.seed, e.mixture, sim.cfg.n_workers, e.batch)
    ex, ey = stream.eval_set(e.eval_n)
    with _spec_flags(e):
        eng = EpochEngine(sim, acc_fn=acc, eval_set=(ex, ey),
                          metrics_every=e.metrics_every)
    return e, eng, state, stream


def _epoch_compiled_text(eng, state, stream, n_steps: int = 4) -> str:
    """Compile one epoch without running it; returns executable HLO text."""
    batches = stream.next(n_steps)
    lowered = eng._epoch.lower(state, batches, *eng._extra_args())
    return lowered.compile().as_text()


def _alias_gap(txt: str, donated_params: range) -> list[int]:
    from ..launch import hlo_analysis
    aliased = hlo_analysis.aliased_param_numbers(txt)
    return sorted(set(donated_params) - aliased)


# ---------------------------------------------------------------------------
# REPRO-HLO-DONATION
# ---------------------------------------------------------------------------


def check_donation(root) -> list[Finding]:
    import jax
    found = _device_guard("REPRO-HLO-DONATION")
    if found:
        return found

    def audit(label, path, txt, donated_params):
        gap = _alias_gap(txt, donated_params)
        if gap:
            found.append(Finding(
                "REPRO-HLO-DONATION", path, 0,
                f"{label}: donated buffers dropped from input_output_alias "
                f"(param numbers {gap} of {donated_params.start}.."
                f"{donated_params.stop - 1})",
                "keep donated leaves' shape/dtype equal to the matching "
                "outputs; check donate_argnums still names the state arg"))

    # fused + both protocol engines: the whole carried state is donated
    e, eng, state, stream = _fused_engine()
    n_state = len(jax.tree.leaves(state))
    audit("fused epoch", "src/repro/core/engine.py",
          _epoch_compiled_text(eng, state, stream), range(n_state))
    lanes = [("naive", {}), ("sharded", {})]
    if jax.device_count() >= 8:
        # the 2D lane: G=4 lights up (rep=4, fsdp=2) — donation must
        # survive the per-leaf fsdp layouts too
        lanes.append(("sharded[rep,fsdp]", _2D_OVERRIDES))
    for label, overrides in lanes:
        from ..launch.mesh import use_mesh
        engine = label.split("[", 1)[0]
        _, _, mesh, peng, pstate, pstream = _protocol_engine(
            engine, **overrides)
        if overrides:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            assert sizes["fsdp"] > 1, sizes
        n_state = len(jax.tree.leaves(pstate))
        with use_mesh(mesh):
            txt = _epoch_compiled_text(peng, pstate, pstream)
        audit(f"protocol[{label}] epoch", "src/repro/core/protocol.py",
              txt, range(n_state))

    # serve decode: the [R, n_slots, ...] cache stack is donated (arg 1)
    from ..models.registry import get_bundle
    from ..serve import QuorumService, ReplicaPool
    bundle = get_bundle("phi4-mini-3.8b", reduced=True)
    pool = ReplicaPool.from_params(bundle.init(jax.random.PRNGKey(0)), 3, f=1)
    svc = QuorumService(pool, bundle, n_slots=2, max_len=32)
    n_p = len(jax.tree.leaves(pool.params))
    n_c = len(jax.tree.leaves(svc.caches))
    audit("serve decode", "src/repro/serve/service.py",
          svc.lowered_decode().compile().as_text(), range(n_p, n_p + n_c))
    return found


# ---------------------------------------------------------------------------
# REPRO-HLO-HOST-TRANSFER
# ---------------------------------------------------------------------------


def check_host_transfers(root) -> list[Finding]:
    import jax
    found = _device_guard("REPRO-HLO-HOST-TRANSFER")
    if found:
        return found

    def audit(label, path, eng, state, stream, steps, mesh=None):
        from contextlib import nullcontext

        from ..launch.mesh import use_mesh
        ctx = use_mesh(mesh) if mesh is not None else nullcontext()
        counter = {"n": 0}
        real = jax.device_get

        def counting(x):
            counter["n"] += 1
            return real(x)

        with ctx:
            # the run loop: exactly ONE device_get regardless of chunking
            jax.device_get = counting
            try:
                state, _ = eng.run(state, stream=stream, steps=steps,
                                   epoch_steps=max(1, steps // 2))
            finally:
                jax.device_get = real
            if counter["n"] != 1:
                found.append(Finding(
                    "REPRO-HLO-HOST-TRANSFER", path, 0,
                    f"{label}: run() made {counter['n']} device_get calls "
                    f"over {steps} steps (contract: exactly 1)",
                    "keep metrics in on-device buffers; concatenate on host "
                    "only once after the last epoch"))
            # the epoch body itself: zero implicit transfers
            try:
                with jax.transfer_guard_device_to_host("disallow"):
                    eng.run_epoch(state, stream.next(2))
            except Exception as err:  # jax raises on guarded transfer
                found.append(Finding(
                    "REPRO-HLO-HOST-TRANSFER", path, 0,
                    f"{label}: epoch body transfers device->host under "
                    f"transfer_guard ({type(err).__name__})",
                    "the compiled epoch must not sync; move host reads "
                    "outside run_epoch"))

    e, eng, state, stream = _fused_engine()
    audit("fused", "src/repro/core/engine.py", eng, state, stream, e.steps)
    _, _, mesh, peng, pstate, pstream = _protocol_engine("sharded")
    audit("protocol[sharded]", "src/repro/core/protocol.py",
          peng, pstate, pstream, 6, mesh=mesh)
    if jax.device_count() >= 8:
        _, _, mesh, peng, pstate, pstream = _protocol_engine(
            "sharded", **_2D_OVERRIDES)
        audit("protocol[sharded, rep x fsdp]", "src/repro/core/protocol.py",
              peng, pstate, pstream, 6, mesh=mesh)
    return found


# ---------------------------------------------------------------------------
# REPRO-HLO-RECOMPILE
# ---------------------------------------------------------------------------


def check_recompiles(root) -> list[Finding]:
    found = _device_guard("REPRO-HLO-RECOMPILE")
    if found:
        return found
    from ..core import epochs

    def builds(fn):
        before = epochs.epoch_build_count()
        fn()
        return epochs.epoch_build_count() - before

    # deterministic start: other audits have already populated the cache
    # with these very configs
    epochs.clear_epoch_cache()

    first = builds(lambda: _fused_engine())
    if first != 1:
        found.append(Finding(
            "REPRO-HLO-RECOMPILE", "src/repro/core/epochs.py", 0,
            f"fresh fused config after cache clear produced {first} builds "
            "(expected exactly 1)",
            "the build-count sentinel in epochs._get_or_build is broken"))
    # identical semantic config -> cache hit (no rebuild, no retrace)
    dup = builds(lambda: _fused_engine())
    if dup != 0:
        found.append(Finding(
            "REPRO-HLO-RECOMPILE", "src/repro/core/epochs.py", 0,
            f"identical fused configs rebuilt the epoch ({dup} builds; "
            "expected a cache hit)",
            "make _cache_key cover exactly the semantic config — an "
            "id()/object part in the key splits identical sweeps"))
    # each semantically-distinct knob -> exactly one rebuild
    for knob in ({"T": 3}, {"sort_network": False}, {"metrics_every": 1}):
        n = builds(lambda: _fused_engine(**knob))
        if n != 1:
            found.append(Finding(
                "REPRO-HLO-RECOMPILE", "src/repro/core/epochs.py", 0,
                f"distinct fused config {knob} produced {n} builds "
                "(expected exactly 1)",
                "a knob missing from _cache_key reuses a stale executable "
                "(0 builds); >1 means the engine builds eagerly twice"))
    # the two protocol engines must not share an executable
    _protocol_engine("naive")
    n = builds(lambda: _protocol_engine("sharded"))
    if n != 1:
        found.append(Finding(
            "REPRO-HLO-RECOMPILE", "src/repro/core/protocol.py", 0,
            f"protocol engine flip naive->sharded produced {n} builds "
            "(expected exactly 1)",
            "ProtocolConfig.engine must stay in the _cache_key tuple"))
    return found


# ---------------------------------------------------------------------------
# REPRO-HLO-COLLECTIVES
# ---------------------------------------------------------------------------


def measure_exchange_bytes(engine: str, *, two_d: bool = False):
    """Ring-model bytes/device of the compiled exchange primitives vs the
    ``collective_volume_bytes`` model: (measured, modeled, n_params).

    Lowers ``masked_pull`` (the Median pull of the replica stacks) and
    ``aggregate_gradients`` (the weighted push) on a rep-sharded ``[G, ...]``
    parameter stack with replicated masks/weights — the exchange pattern of
    one scatter step, minus the distance/Gram traffic that the model
    deliberately excludes. With ``two_d`` the stack is additionally
    fsdp-sharded per the engine's own leaf-layout table (G=4 on 8 devices
    -> mesh (rep=4, fsdp=2)) and the model gets ``fsdp=K``."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..core import protocol as _protocol
    from ..exp import presets
    from ..launch import hlo_analysis
    from ..launch.mesh import make_protocol_mesh, use_mesh

    e = presets.get(_PRESET, runner="protocol", protocol_engine=engine,
                    **(_2D_OVERRIDES if two_d else {}))
    pcfg = e.to_protocol_config()
    G = pcfg.n_groups
    init_fn, _, _ = e.build_problem()
    p0 = init_fn(jax.random.PRNGKey(0))
    n_params = sum(l.size for l in jax.tree.leaves(p0))
    mesh = make_protocol_mesh(G)
    K = dict(zip(mesh.axis_names, mesh.devices.shape))["fsdp"]
    if two_d and K <= 1:
        raise RuntimeError(
            f"2D exchange audit needs an fsdp>1 mesh, got {K} "
            f"(G={G} on {jax.device_count()} devices)")
    repl = NamedSharding(mesh, P())
    stacked = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (G,) + l.shape), p0)
    if two_d:
        shardings = _protocol._named_tree_shardings(
            jax.eval_shape(lambda: stacked), mesh)
    else:
        shardings = jax.tree.map(
            lambda l: NamedSharding(mesh, P("rep")), stacked)
    params = jax.tree.map(jax.device_put, stacked, shardings)
    masks = jax.device_put(jnp.ones((G, G), bool), repl)
    weights = jax.device_put(jnp.full((G, G), 1.0 / G, jnp.float32), repl)

    with use_mesh(mesh):
        pull = jax.jit(
            lambda p, m: _protocol.masked_pull(p, m, pcfg, mesh=mesh))
        push = jax.jit(
            lambda g, w: _protocol.aggregate_gradients(g, w, pcfg, mesh=mesh))
        texts = [pull.lower(params, masks).compile().as_text(),
                 push.lower(params, weights).compile().as_text()]
    measured = sum(
        hlo_analysis.collective_traffic(t, G).bytes_per_device for t in texts)
    return measured, _protocol.collective_volume_bytes(
        pcfg, n_params, fsdp=K), n_params


def check_collectives(root) -> list[Finding]:
    import jax
    found = _device_guard("REPRO-HLO-COLLECTIVES")
    if found:
        return found
    # the 2D lane needs a full (rep=4, fsdp=2) split, i.e. >= 8 devices
    lanes = [("naive", False), ("sharded", False)]
    if jax.device_count() >= 8:
        lanes += [("naive", True), ("sharded", True)]
    for engine, two_d in lanes:
        label = f"{engine}[rep,fsdp]" if two_d else engine
        measured, modeled, n_params = measure_exchange_bytes(
            engine, two_d=two_d)
        if measured <= 0:
            found.append(Finding(
                "REPRO-HLO-COLLECTIVES", "src/repro/core/protocol.py", 0,
                f"{label}: no collectives found in the compiled exchange "
                "primitives (mesh not applied?)",
                "audit must run on a multi-device 'rep' mesh"))
            continue
        err = abs(measured - modeled) / modeled
        if err > _COLLECTIVE_RTOL:
            found.append(Finding(
                "REPRO-HLO-COLLECTIVES", "src/repro/core/protocol.py", 0,
                f"{label}: modeled exchange {modeled}B vs HLO ring-model "
                f"{measured:.0f}B ({err:.0%} off, P={n_params}, tol "
                f"{_COLLECTIVE_RTOL:.0%})",
                "re-derive collective_volume_bytes from the compiled "
                "artifact, not from the intended sharding"))
    return found


for _rule in (
    Rule("REPRO-HLO-COLLECTIVES", "hlo",
         "`collective_volume_bytes` model within 10% of ring-model bytes "
         "measured from compiled exchange-primitive HLO, both engines, "
         "1D and (rep x fsdp) 2D lanes",
         check_collectives,
         "fix the model to match the artifact"),
    Rule("REPRO-HLO-DONATION", "hlo",
         "donated state survives to `input_output_alias` in every compiled "
         "epoch/decode executable (fused, protocol x2, serve)",
         check_donation,
         "keep donated leaves shape/dtype-stable"),
    Rule("REPRO-HLO-HOST-TRANSFER", "hlo",
         "`run()` makes exactly one device->host transfer; epoch bodies "
         "pass `transfer_guard_device_to_host('disallow')`",
         check_host_transfers,
         "keep metrics on device until the final concatenate"),
    Rule("REPRO-HLO-RECOMPILE", "hlo",
         "semantic compile cache dedupes identical engine configs and "
         "splits every distinct knob (build-count sentinel)",
         check_recompiles,
         "keep _cache_key in lockstep with _build's closure"),
):
    register(_rule)
