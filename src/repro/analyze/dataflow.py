"""Layer-1+ dataflow: interprocedural, flow-sensitive taint over ASTs.

Two reusable pieces live here, both purely static (nothing checked is
ever imported):

* the **sensitivity fixpoint** that ``rules/host_sync.py`` introduced
  (which functions end up inside a trace: jit-decorated, passed to
  tracer calls, lexically nested in or called by name from a sensitive
  function), generalized so other rules (REPRO-DETERMINISM) can ask the
  same question;
* a **taint engine** (:class:`TaintEngine`) — an abstract interpreter
  over a whole set of modules with a small lattice
  ``CLEAN < WEIGHTS < TAINTED`` plus two non-data payloads (closures and
  aggregator specs). Functions are analyzed flow-sensitively statement
  by statement; calls to local closures, sibling methods and uniquely
  named top-level functions in *other* modules are inlined (depth- and
  cycle-guarded), so a source in ``core/attacks.py`` is tracked through
  ``protocol.masked_pull`` -> ``_leaf_stream`` -> a vmapped inner
  closure to wherever it lands.

The lattice is policy-parameterized (:class:`Policy`): *sources* mint
``TAINTED`` values with a provenance trace, *sanitizers* return
``CLEAN``, *weight fns* return ``WEIGHTS`` (robust selection weights —
contracting them against a tainted stack via ``dot_general``/``@`` is
the selection-based sanitization pattern of ``agg.registry`` and yields
``CLEAN``), and *sinks* report any ``TAINTED`` argument together with
the recorded file:line witness path. REPRO-TAINT-BYZ instantiates the
policy from the live ``repro.agg`` registry's AST (see
``rules/taint_byz.py``).
"""
from __future__ import annotations

import ast
import dataclasses

# ---------------------------------------------------------------------------
# sensitivity fixpoint (the host_sync machinery, made reusable)
# ---------------------------------------------------------------------------

#: call targets that hand a function into a traced context
TRACERS = {
    "jax.jit", "jit", "pjit",
    "lax.scan", "jax.lax.scan", "scan",
    "lax.cond", "jax.lax.cond", "cond",
    "lax.while_loop", "jax.lax.while_loop",
    "lax.fori_loop", "jax.lax.fori_loop", "fori_loop",
    "lax.switch", "jax.lax.switch",
    "jax.vmap", "vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "checkpoint", "jax.remat", "remat",
    "lax.associative_scan", "jax.lax.associative_scan",
}

_JIT_NAMES = {"jit", "jax.jit", "pjit"}

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _dotted(node: ast.AST) -> str:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def func_defs(tree: ast.AST) -> list[ast.AST]:
    """Every function-ish node, in ast.walk (breadth-first) order."""
    return [n for n in ast.walk(tree) if isinstance(n, _FUNC_NODES)]


def lexical_parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    """Inner function -> nearest enclosing function."""
    parents: dict[ast.AST, ast.AST] = {}
    for fn in func_defs(tree):
        for child in ast.walk(fn):
            if child is not fn and isinstance(child, _FUNC_NODES):
                parents.setdefault(child, fn)
    return parents


def defs_by_name(tree: ast.AST) -> dict[str, list[ast.AST]]:
    by_name: dict[str, list[ast.AST]] = {}
    for fn in func_defs(tree):
        if hasattr(fn, "name"):
            by_name.setdefault(fn.name, []).append(fn)
    return by_name


def owner_map(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    """Node -> innermost enclosing function. func_defs walks outer defs
    before their inner defs, so plain assignment lets the innermost win."""
    owner: dict[ast.AST, ast.AST] = {}
    for fn in func_defs(tree):
        body = fn.body if not isinstance(fn, ast.Lambda) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                owner[node] = fn
    return owner


def is_jit_decorated(fn: ast.AST) -> bool:
    for deco in getattr(fn, "decorator_list", []):
        if isinstance(deco, (ast.Name, ast.Attribute)):
            if ast.unparse(deco) in _JIT_NAMES:
                return True
        elif isinstance(deco, ast.Call):  # @jax.jit(...) / @partial(jax.jit,)
            head = ast.unparse(deco.func)
            if head in _JIT_NAMES:
                return True
            if (head in ("partial", "functools.partial") and deco.args
                    and ast.unparse(deco.args[0]) in _JIT_NAMES):
                return True
    return False


def sensitive_functions(tree: ast.AST) -> set[ast.AST]:
    """Functions that end up inside a jax trace, to a fixpoint: jitted,
    passed into tracer calls, nested in or called by name from one.

    Memoized on the tree object itself — several rules (host-sync,
    determinism) ask the same question of the same parse, and the
    fixpoint dominates layer-1 wall time when recomputed per rule.
    """
    cached = getattr(tree, "_repro_sensitive", None)
    if cached is not None:
        return cached
    parents = lexical_parents(tree)
    by_name = defs_by_name(tree)
    sensitive: set[ast.AST] = {fn for fn in func_defs(tree)
                               if is_jit_decorated(fn)}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _dotted(node.func) not in TRACERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                sensitive.add(arg)
            elif isinstance(arg, ast.Name):
                sensitive.update(by_name.get(arg.id, []))
    changed = True
    while changed:
        changed = False
        for fn in func_defs(tree):
            if fn in sensitive:
                continue
            p = parents.get(fn)
            if p is not None and p in sensitive:
                sensitive.add(fn)
                changed = True
        for s in list(sensitive):
            for node in ast.walk(s):
                if (node is not s and isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)):
                    for cand in by_name.get(node.func.id, []):
                        if cand not in sensitive:
                            sensitive.add(cand)
                            changed = True
    tree._repro_sensitive = sensitive
    return sensitive


# ---------------------------------------------------------------------------
# the taint lattice
# ---------------------------------------------------------------------------

CLEAN, WEIGHTS, TAINTED = 0, 1, 2

_TRACE_CAP = 10


@dataclasses.dataclass(frozen=True)
class Val:
    """One abstract value: a lattice point plus optional payloads.

    ``trace`` carries the provenance of a TAINTED value as
    ``(("path", line, "desc"), ...)``. ``func`` holds a closure
    ``(def-node, env-snapshot, path)``; ``spec`` an aggregator handle
    ``(robust, masked_ok, name)`` minted by ``agg.get(...)``.
    """
    kind: int = CLEAN
    trace: tuple = ()
    func: tuple | None = None
    spec: tuple | None = None


_CLEAN = Val()


def join(*vals: Val) -> Val:
    out = _CLEAN
    for v in vals:
        if v.kind > out.kind or (out.func is None and v.func is not None) \
                or (out.spec is None and v.spec is not None):
            out = Val(max(out.kind, v.kind),
                      v.trace if v.kind >= out.kind else out.trace,
                      out.func or v.func, out.spec or v.spec)
    return out


def _extend(val: Val, path: str, line: int, desc: str) -> Val:
    if val.kind != TAINTED or len(val.trace) >= _TRACE_CAP:
        return val
    if val.trace and val.trace[-1][:2] == (path, line):
        return val
    return dataclasses.replace(val, trace=val.trace + ((path, line, desc),))


@dataclasses.dataclass(frozen=True)
class Policy:
    """What taints, what launders, what must stay clean."""
    sources: frozenset            # call names minting TAINTED
    sanitizers: frozenset         # call names returning CLEAN
    weight_fns: frozenset         # call names returning WEIGHTS
    robust_rules: dict            # rule name -> supports_masked_delivery
    all_rules: frozenset = frozenset()   # every registered rule name
    spec_getters: frozenset = frozenset({"agg.get", "registry.get"})
    sink_ctors: frozenset = frozenset()       # ctor names with sink kwargs
    sink_kwargs: frozenset = frozenset()      # kwarg names that are sinks
    sink_calls: frozenset = frozenset()       # calls whose args are sinks


@dataclasses.dataclass(frozen=True)
class SinkHit:
    path: str
    line: int
    sink: str                     # human description of the sink
    trace: tuple                  # provenance of the tainted value

    def witness(self) -> str:
        hops = [f"{p}:{ln} {d}" for p, ln, d in self.trace]
        hops.append(f"{self.path}:{self.line} sink {self.sink}")
        return " -> ".join(hops)


# combinators that *return* the function they are given (possibly wrapped)
_WRAPPERS = {"jax.jit", "jit", "pjit", "jax.vmap", "vmap", "jax.grad",
             "jax.value_and_grad", "jax.checkpoint", "checkpoint",
             "jax.remat", "remat", "partial", "functools.partial"}
# combinators that *run* the function(s) they are given
_RUNNERS = {"lax.scan", "jax.lax.scan", "scan", "lax.cond", "jax.lax.cond",
            "cond", "lax.while_loop", "jax.lax.while_loop", "lax.fori_loop",
            "jax.lax.fori_loop", "fori_loop", "lax.switch", "jax.lax.switch",
            "lax.associative_scan", "jax.lax.associative_scan"}
# dot-like contractions where WEIGHTS x TAINTED is the selection-based
# sanitization pattern (robust convex combination)
_DOT_CALLS = {"dot_general", "dot", "matmul", "einsum", "tensordot"}

_DEPTH_CAP = 24


class TaintEngine:
    """Whole-program taint over ``modules``: rel-path -> ast.Module."""

    def __init__(self, modules: dict[str, ast.Module], policy: Policy):
        self.modules = modules
        self.policy = policy
        self.hits: list[SinkHit] = []
        self._stack: list[int] = []      # active funcdef ids (cycle guard)
        self._entered: set[int] = set()  # funcdefs analyzed as entries
        self._pending: list[Val] = []    # closures defined but never applied
        self._seen_sinks: set[tuple] = set()
        # unambiguous top-level defs across all modules, for cross-module
        # inlining by bare name
        counts: dict[str, int] = {}
        self._global_defs: dict[str, tuple] = {}
        for path, tree in modules.items():
            for node in tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    counts[node.name] = counts.get(node.name, 0) + 1
                    self._global_defs[node.name] = (node, path)
        for name, n in counts.items():
            if n > 1:
                del self._global_defs[name]

    # -- public -----------------------------------------------------------
    def run(self, entry_paths: set[str] | None = None) -> list[SinkHit]:
        # entry points are TOP-LEVEL functions and class methods only;
        # nested defs are reached as closures (with their captured env)
        # via the pending queue, never with an empty env.
        for path, tree in sorted(self.modules.items()):
            if entry_paths is not None and path not in entry_paths:
                continue
            for node in tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._entry(Val(func=(node, {}, path)))
                elif isinstance(node, ast.ClassDef):
                    ms = {m.name: m for m in node.body
                          if isinstance(m, ast.FunctionDef)}
                    for m in ms.values():
                        self._entry(Val(func=(m, {}, path)), ms)
        # drain closures that were defined but never called: their bodies
        # still hold flows (step builders returning step fns)
        while self._pending:
            self._entry(self._pending.pop())
        return self.hits

    # -- entry/closure machinery ------------------------------------------
    def _entry(self, fval: Val, siblings: dict | None = None):
        node = fval.func[0]
        if id(node) in self._entered:
            return
        self._entered.add(id(node))
        self._apply(fval, [], {}, siblings=siblings or {})

    def _apply(self, fval: Val, args: list[Val], kwargs: dict[str, Val],
               siblings: dict | None = None) -> Val:
        node, env0, path = fval.func
        if id(node) in self._stack or len(self._stack) >= _DEPTH_CAP:
            return join(*args, *kwargs.values())
        env = dict(env0)
        a = node.args
        pos = list(a.posonlyargs) + list(a.args)
        if len(args) == len(pos) or (args and not a.vararg
                                     and len(args) <= len(pos)):
            for p, v in zip(pos, args):
                env[p.arg] = v
            rest = args[len(pos):]
        else:  # combinator application / arity mismatch: smear the join
            smear = join(*args, *kwargs.values())
            for p in pos + list(a.kwonlyargs):
                env[p.arg] = smear
            rest = args
        if a.vararg:
            env[a.vararg.arg] = join(*rest) if rest else _CLEAN
        for name, v in kwargs.items():
            env[name] = v
        if a.kwarg:
            env[a.kwarg.arg] = join(*kwargs.values()) if kwargs else _CLEAN
        self._stack.append(id(node))
        try:
            frame = _Frame(self, path, env,
                           siblings if siblings is not None else {})
            if isinstance(node, ast.Lambda):
                ret = frame.eval(node.body)
            else:
                frame.exec_block(node.body)
                ret = frame.ret
            self._entered.add(id(node))
        finally:
            self._stack.pop()
        for c in frame.defined:
            if id(c.func[0]) not in self._entered:
                self._pending.append(c)
        return ret

    def _sink(self, path: str, line: int, sink: str, val: Val):
        key = (path, line, sink)
        if key in self._seen_sinks:
            return
        self._seen_sinks.add(key)
        self.hits.append(SinkHit(path, line, sink, val.trace))


class _Frame:
    """Flow-sensitive walk of one function body."""

    def __init__(self, engine: TaintEngine, path: str, env: dict,
                 siblings: dict):
        self.e = engine
        self.path = path
        self.env = env
        self.siblings = siblings      # same-class methods, for self.m(...)
        self.ret = _CLEAN
        self.defined: list[Val] = []  # closures defined in this frame

    # -- statements -------------------------------------------------------
    def exec_block(self, body):
        for stmt in body:
            self.exec(stmt)

    def exec(self, stmt):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fv = Val(func=(stmt, dict(self.env), self.path))
            self.env[stmt.name] = fv
            self.defined.append(fv)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.ret = join(self.ret, self.eval(stmt.value))
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = (stmt.value if not isinstance(stmt, ast.AugAssign)
                     else stmt.value)
            if value is None:
                return
            val = self.eval(value)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                self._assign(t, val, stmt.lineno,
                             aug=isinstance(stmt, ast.AugAssign))
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.If):
            self.eval(stmt.test)
            self.exec_block(stmt.body)
            self.exec_block(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            it = self.eval(stmt.iter)
            self._assign(stmt.target, it, stmt.lineno)
            self.exec_block(stmt.body)   # twice: crude loop fixpoint
            self.exec_block(stmt.body)
            self.exec_block(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self.eval(stmt.test)
            self.exec_block(stmt.body)
            self.exec_block(stmt.body)
            self.exec_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                v = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars, v, stmt.lineno)
            self.exec_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.exec_block(stmt.body)
            for h in stmt.handlers:
                self.exec_block(h.body)
            self.exec_block(stmt.orelse)
            self.exec_block(stmt.finalbody)
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.eval(child)
        # Import/Global/Pass/Delete/ClassDef: no dataflow tracked

    def _assign(self, target, val: Val, lineno: int, aug: bool = False):
        if isinstance(target, ast.Name):
            if aug:
                val = join(self.env.get(target.id, _CLEAN), val)
            if val.kind == TAINTED:
                val = _extend(val, self.path, lineno,
                              f"`{target.id} = ...`")
            self.env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._assign(el, val, lineno)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, val, lineno)
        # Attribute/Subscript targets: object fields are not tracked

    # -- expressions ------------------------------------------------------
    def eval(self, node) -> Val:
        if node is None or isinstance(node, ast.Constant):
            return _CLEAN
        if isinstance(node, ast.Name):
            return self.env.get(node.id, _CLEAN)
        if isinstance(node, ast.Attribute):
            # array metadata is trace-time static in jax: a Byzantine
            # peer controls values, never shapes/dtypes — reading them
            # off a tainted array yields a clean scalar
            if node.attr in ("shape", "dtype", "ndim", "size", "itemsize"):
                return _CLEAN
            return self.eval(node.value)
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.Lambda):
            return Val(func=(node, dict(self.env), self.path))
        if isinstance(node, ast.BinOp):
            lv, rv = self.eval(node.left), self.eval(node.right)
            if isinstance(node.op, ast.MatMult) and \
                    {lv.kind, rv.kind} == {WEIGHTS, TAINTED}:
                return _CLEAN          # robust convex combination
            return join(lv, rv)
        if isinstance(node, ast.Subscript):
            return join(self.eval(node.value), self.eval(node.slice))
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return join(*(self.eval(el) for el in node.elts)) \
                if node.elts else _CLEAN
        if isinstance(node, ast.Dict):
            parts = [self.eval(v) for v in node.values if v is not None]
            return join(*parts) if parts else _CLEAN
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            for gen in node.generators:
                self._assign(gen.target, self.eval(gen.iter), node.lineno)
                for cond in gen.ifs:
                    self.eval(cond)
            if isinstance(node, ast.DictComp):
                return join(self.eval(node.key), self.eval(node.value))
            return self.eval(node.elt)
        if isinstance(node, (ast.IfExp,)):
            self.eval(node.test)
            return join(self.eval(node.body), self.eval(node.orelse))
        if isinstance(node, (ast.BoolOp,)):
            return join(*(self.eval(v) for v in node.values))
        if isinstance(node, (ast.Compare,)):
            return join(self.eval(node.left),
                        *(self.eval(c) for c in node.comparators))
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.eval(node.value)
        if isinstance(node, ast.Yield):
            return self.eval(node.value) if node.value else _CLEAN
        if isinstance(node, ast.JoinedStr):
            return _CLEAN
        if isinstance(node, ast.NamedExpr):
            v = self.eval(node.value)
            self._assign(node.target, v, node.lineno)
            return v
        return _CLEAN

    # -- calls ------------------------------------------------------------
    def _call(self, node: ast.Call) -> Val:
        pol = self.e.policy
        name = _dotted(node.func)
        terminal = name.split(".")[-1] if name else ""
        args = [self.eval(a) for a in node.args]
        kwargs = {kw.arg: self.eval(kw.value) for kw in node.keywords
                  if kw.arg}
        star_kw = [self.eval(kw.value) for kw in node.keywords
                   if kw.arg is None]
        allv = args + list(kwargs.values()) + star_kw
        recv = (self.eval(node.func.value)
                if isinstance(node.func, ast.Attribute) else _CLEAN)

        self._check_sinks(node, terminal, args, kwargs, recv)

        # 1. sources mint taint
        if terminal in pol.sources:
            return Val(TAINTED,
                       ((self.path, node.lineno, f"source `{terminal}(...)`"),))
        # 2. registry spec getters: agg.get("median") -> spec handle
        is_getter = name in pol.spec_getters or (
            terminal == "get" and node.args
            and isinstance(node.args[0], ast.Constant)
            and node.args[0].value in pol.all_rules)
        if is_getter:
            rule = None
            if node.args and isinstance(node.args[0], ast.Constant):
                rule = node.args[0].value
            if rule is not None:
                robust = rule in pol.robust_rules
                masked_ok = pol.robust_rules.get(rule, False)
                return Val(spec=(robust, masked_ok, rule))
            return Val(spec=(True, True, None))   # dynamic name: runtime
                                                  # validate() owns the bound
        # 3. resolve the callee expression to a closure / spec handle
        if isinstance(node.func, ast.Call):
            fv = self.eval(node.func)     # e.g. agg.get("median")(x, mask=m)
        elif isinstance(node.func, ast.Attribute):
            fv = recv if (recv.func or recv.spec) else _CLEAN
        elif isinstance(node.func, ast.Name):
            fv = self.env.get(node.func.id, _CLEAN)
        else:
            fv = _CLEAN
        # calling a spec handle: the sanitization point
        if fv.spec is not None and fv.func is None:
            robust, masked_ok, rule = fv.spec
            tainted_in = join(*allv)
            if not robust:
                return _extend(tainted_in, self.path, node.lineno,
                               f"non-robust rule `{rule}` does not launder")
            if "mask" in kwargs and not masked_ok:
                return _extend(tainted_in, self.path, node.lineno,
                               f"`{rule}` lacks masked-delivery support; "
                               "traced mask not laundered")
            return _CLEAN
        # 4. direct sanitizer / weight-fn calls by name
        if terminal in pol.sanitizers:
            return _CLEAN
        if terminal in pol.weight_fns:
            return Val(WEIGHTS)
        # 5. combinators
        if name in _WRAPPERS or terminal in _WRAPPERS:
            for v in allv:
                if v.func is not None:
                    return v            # vmap(f)/jit(f)/partial(f,..): still f
            return join(*allv)
        if name in _RUNNERS or terminal in _RUNNERS:
            closures = [v for v in allv if v.func is not None]
            data = [v for v in allv if v.func is None]
            out = [self.e._apply(c, data, {}) for c in closures]
            # the closures saw the data as args, so their joined result
            # models the combinator output — including any laundering
            if out:
                return join(*out)
            return join(*data) if data else _CLEAN
        # 6. local closure / sibling method / unambiguous global function
        if fv.func is not None:
            return self.e._apply(fv, args, kwargs, siblings=self.siblings)
        if (isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and terminal in self.siblings):
            m = self.siblings[terminal]
            return self.e._apply(Val(func=(m, dict(self.env), self.path)),
                                 [_CLEAN] + args, kwargs,
                                 siblings=self.siblings)
        if isinstance(node.func, ast.Name) and \
                terminal in self.e._global_defs:
            gdef, gpath = self.e._global_defs[terminal]
            return self.e._apply(Val(func=(gdef, {}, gpath)), args, kwargs)
        # 7. unknown call: propagate; apply any closure-valued args so
        #    combinators like jax.tree.map(op, tree) still flow through
        closures = [v for v in allv if v.func is not None]
        data = [v for v in allv if v.func is None] + [recv]
        if closures:
            # jax.tree.map(op, tree) and friends: the applied closures'
            # result models the output (they received the data as args)
            return join(*(self.e._apply(c, data, {}) for c in closures))
        if terminal in _DOT_CALLS:
            kinds = {v.kind for v in allv}
            if {WEIGHTS, TAINTED} <= kinds:
                return _CLEAN           # robust convex combination
        return join(*data) if data else _CLEAN

    def _check_sinks(self, node: ast.Call, terminal: str, args, kwargs,
                     recv: Val):
        pol = self.e.policy
        is_ctor = terminal in pol.sink_ctors
        is_replace = terminal in ("_replace", "replace") and \
            recv.kind != TAINTED  # a wholly-tainted obj is reported upstream
        if is_ctor or is_replace:
            for kw, val in kwargs.items():
                if kw in pol.sink_kwargs and val.kind == TAINTED:
                    self.e._sink(self.path, node.lineno,
                                 f"`{terminal}({kw}=...)`", val)
        if terminal in pol.sink_calls:
            for val in args + list(kwargs.values()):
                if val.kind == TAINTED:
                    self.e._sink(self.path, node.lineno,
                                 f"`{terminal}(...)`", val)
                    break
