"""Findings, suppressions, baselines and report writers for ``repro.analyze``.

A :class:`Finding` is one violation: rule id + file:line + message + fix
hint. Three mechanisms keep the repo at zero *reported* violations:

* **inline suppression** — a ``# analyze: ignore[RULE-ID] <justification>``
  comment on the flagged line (or the line above it). The justification is
  mandatory; a bare ``ignore[...]`` is itself reported (REPRO-SUPPRESS).
* **baseline** — ``results/analyze/baseline.json`` holds known findings
  (keyed on rule id + path + message, NOT line numbers, so unrelated edits
  don't churn it). ``python -m repro.analyze --update-baseline`` rewrites
  it from the current findings and prunes stale entries (vanished files,
  unregistered rule ids), keeping entries from scopes the run skipped.
  The committed baseline carries exactly the tracked REPRO-DEAD-SEED
  debt — seeded-but-unwired modules pending their roadmap items.
* the fix itself, which is always preferred.

Reports: ``to_report()`` builds the JSON document written to
``results/analyze/report.json`` (with a provenance block) and
``markdown_report()`` the human table.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import re
import tokenize

BASELINE_PATH = os.path.join("results", "analyze", "baseline.json")
REPORT_PATH = os.path.join("results", "analyze", "report.json")

_SUPPRESS_RE = re.compile(
    r"#\s*analyze:\s*ignore\[(?P<rules>[A-Z0-9\-,\s]+)\]\s*(?P<why>.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation. ``line`` is 1-based; 0 means whole-file/repo scope."""
    rule_id: str
    path: str
    line: int
    message: str
    fix_hint: str = ""

    @property
    def key(self) -> str:
        """Baseline identity: stable across unrelated line-number churn."""
        return f"{self.rule_id}::{self.path}::{self.message}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: [{self.rule_id}] {self.message}"
        if self.fix_hint:
            out += f"\n    fix: {self.fix_hint}"
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# inline suppressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]
    justification: str


def scan_suppressions(source: str, path: str) -> tuple[dict, list[Finding]]:
    """Map line -> Suppression from ``# analyze: ignore[...]`` comments.

    Comments are found with :mod:`tokenize` (not a regex over the raw line)
    so string literals that merely *contain* the marker don't suppress.
    A suppression with an empty justification yields a REPRO-SUPPRESS
    finding — suppressing without saying why is itself a violation.
    """
    sups: dict[int, Suppression] = {}
    bad: list[Finding] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
            why = m.group("why").strip()
            sup = Suppression(tok.start[0], rules, why)
            sups[tok.start[0]] = sup
            if not why:
                bad.append(Finding(
                    "REPRO-SUPPRESS", path, tok.start[0],
                    f"suppression of {', '.join(rules)} has no justification",
                    "append a reason: `# analyze: ignore[RULE] because ...`"))
    except tokenize.TokenError:
        pass
    return sups, bad


def is_suppressed(finding: Finding, sups: dict) -> bool:
    """A finding is suppressed by a marker on its line or the line above."""
    for ln in (finding.line, finding.line - 1):
        sup = sups.get(ln)
        if sup and sup.justification and finding.rule_id in sup.rules:
            return True
    return False


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def load_baseline(path: str = BASELINE_PATH) -> set[str]:
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        doc = json.load(f)
    return {e["key"] for e in doc.get("findings", [])}


def write_baseline(findings: list[Finding], path: str = BASELINE_PATH) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {
        "comment": "Known repro.analyze findings grandfathered out of the "
                   "exit-code gate. Keep this empty; prefer fixes or inline "
                   "`# analyze: ignore[RULE] why` suppressions.",
        "findings": [{"key": f.key, "fix_hint": f.fix_hint}
                     for f in sorted(findings, key=lambda f: f.key)],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def refresh_baseline(findings: list[Finding], path: str, root: str,
                     scopes_run: set[str],
                     rule_scopes: dict[str, str]) -> tuple[str, list[str]]:
    """Rewrite the baseline from the current findings, keeping entries
    from scopes that were not run this invocation (e.g. hlo without
    ``--hlo``) and pruning stale ones whose rule id is no longer
    registered or whose file no longer exists.

    Returns ``(path, pruned_keys)``.
    """
    kept: list[dict] = []
    pruned: list[str] = []
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        for e in doc.get("findings", []):
            rid, _, rest = e["key"].partition("::")
            fpath, _, _ = rest.partition("::")
            scope = rule_scopes.get(rid)
            if scope is None or not os.path.exists(
                    os.path.join(root, fpath)):
                pruned.append(e["key"])
                continue
            if scope not in scopes_run:
                kept.append(e)
    entries = {e["key"]: e for e in kept}
    for f in findings:
        entries[f.key] = {"key": f.key, "fix_hint": f.fix_hint}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    doc = {
        "comment": "Known repro.analyze findings grandfathered out of the "
                   "exit-code gate. Keep this short; prefer fixes or inline "
                   "`# analyze: ignore[RULE] why` suppressions.",
        "findings": [entries[k] for k in sorted(entries)],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path, pruned


def split_baselined(findings: list[Finding],
                    baseline: set[str]) -> tuple[list[Finding], list[Finding]]:
    """(new, known) partition against the baseline key set."""
    new = [f for f in findings if f.key not in baseline]
    known = [f for f in findings if f.key in baseline]
    return new, known


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------


def to_report(findings: list[Finding], known: list[Finding],
              stats: dict | None = None) -> dict:
    """report.json document. Provenance matches the benchmark lanes'."""
    try:
        import repro.exp as exp
        import hashlib
        blob = json.dumps({"lane": "analyze"}, sort_keys=True)
        prov = exp.provenance(hashlib.sha256(blob.encode()).hexdigest()[:16])
    except Exception:  # jax-free invocation keeps working
        prov = {}
    return {
        "violations": [f.to_dict() for f in findings],
        "baselined": [f.to_dict() for f in known],
        "stats": stats or {},
        "clean": not findings,
        "provenance": prov,
    }


def write_report(doc: dict, path: str = REPORT_PATH) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    return path


def markdown_report(findings: list[Finding]) -> str:
    if not findings:
        return "no violations"
    lines = ["| rule | location | message |", "|---|---|---|"]
    for f in sorted(findings, key=lambda f: (f.rule_id, f.path, f.line)):
        loc = f"{f.path}:{f.line}" if f.line else f.path
        lines.append(f"| {f.rule_id} | `{loc}` | {f.message} |")
    return "\n".join(lines)
