"""``repro.analyze`` — repo-invariant lint + compiled-artifact audit.

The repo's claims live in two places: the source (no host syncs inside
compiled bodies, cache keys covering every knob, Table-1 bounds in every
preset, registry/test parity) and the compiled artifacts (donation kept,
one host transfer per run, modeled collective bytes matching what XLA
emits). ``python -m repro.analyze`` checks the first set by parsing —
never importing — the tree (layer 1); ``--hlo`` additionally lowers the
real engines on a forced multi-device CPU mesh and audits the executables
(layer 2). CI gates on a zero-violation committed baseline
(``results/analyze/baseline.json``); see the README "Static analysis"
section for the rule table and suppression syntax.
"""
from __future__ import annotations

from .astlint import LINT_ROOTS, lint_file, lint_paths, lint_repo
from .findings import (BASELINE_PATH, REPORT_PATH, Finding, load_baseline,
                       markdown_report, split_baselined, to_report,
                       write_baseline, write_report)
from .registry import Rule, get, markdown_table, register, rules

__all__ = [
    "BASELINE_PATH", "Finding", "LINT_ROOTS", "REPORT_PATH", "Rule", "get",
    "lint_file", "lint_paths", "lint_repo", "load_baseline",
    "markdown_report", "markdown_table", "register", "rules",
    "split_baselined", "to_report", "write_baseline", "write_report",
]
