"""Rule modules register themselves on import (see ``analyze.registry``).

Layer-1 rules (AST, jax-free) import eagerly; the layer-2 HLO audit
(``analyze.hlo``) registers its rule here too but defers every jax import
to check time, so ``python -m repro.analyze`` stays fast and runnable
before any accelerator runtime is up.
"""
from . import (cache_keys, dead_seed, determinism,  # noqa: F401
               env_hygiene, host_sync, membership_floor, pallas_audit,
               preconditions, registry_parity, taint_byz)
from .. import hlo  # noqa: F401  (registers the REPRO-HLO-* rules)
