"""REPRO-HOST-SYNC: no host-synchronising calls inside traced code.

A ``float(x)`` / ``.item()`` / ``np.asarray`` / ``.block_until_ready()`` /
``jax.device_get`` inside a ``lax.scan``/``lax.cond``/``fori_loop`` body or
a jitted step function forces a device->host transfer per trace (or a
ConcretizationTypeError), breaking the one-transfer-per-epoch contract the
fused engines are built on (PR 3).

"Sensitive" functions come from the shared sensitivity fixpoint in
``analyze.dataflow`` (jit-decorated, passed to tracer calls, lexically
nested in or called by name from a sensitive function — see
:func:`repro.analyze.dataflow.sensitive_functions`).

``float(<numeric literal>)`` and calls in default-argument position are
exempt (evaluated at definition time, not in-trace).
"""
from __future__ import annotations

import ast

from ..astlint import call_name
from ..dataflow import lexical_parents, owner_map, sensitive_functions
from ..findings import Finding
from ..registry import Rule, register

# host-sync call names (module-qualified or bare)
_SYNC_CALLS = {
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.device_get", "device_get", "onp.asarray", "onp.array",
}
_SYNC_METHODS = {"item", "block_until_ready", "tolist", "__array__"}


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    parents = lexical_parents(tree)
    sensitive = sensitive_functions(tree)
    owner = owner_map(tree)

    found: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = owner.get(node)
        # attribute the call to the innermost sensitive enclosing fn
        while fn is not None and fn not in sensitive:
            fn = parents.get(fn)
        if fn is None:
            continue
        name = call_name(node)
        hit = None
        if name in _SYNC_CALLS:
            hit = name
        elif name == "float" and node.args and not isinstance(
                node.args[0], ast.Constant):
            hit = "float()"
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in _SYNC_METHODS):
            hit = f".{node.func.attr}()"
        if hit:
            fname = getattr(fn, "name", "<lambda>")
            found.append(Finding(
                "REPRO-HOST-SYNC", path, node.lineno,
                f"host-sync call {hit} inside traced function "
                f"`{fname}`",
                "keep values on device (jnp ops); sync once after the "
                "epoch via the runner's single device_get"))
    return found


register(Rule(
    rule_id="REPRO-HOST-SYNC",
    scope="file",
    description="no `float()`/`.item()`/`np.asarray`/`.block_until_ready()`"
                "/`jax.device_get` inside scan/cond bodies or jitted steps",
    check=check,
    fix_hint="keep the value on device; one device_get per epoch run",
))
