"""REPRO-HOST-SYNC: no host-synchronising calls inside traced code.

A ``float(x)`` / ``.item()`` / ``np.asarray`` / ``.block_until_ready()`` /
``jax.device_get`` inside a ``lax.scan``/``lax.cond``/``fori_loop`` body or
a jitted step function forces a device->host transfer per trace (or a
ConcretizationTypeError), breaking the one-transfer-per-epoch contract the
fused engines are built on (PR 3).

"Sensitive" functions are found statically, to a fixpoint:

* decorated with ``jit`` / ``jax.jit`` / ``partial(jax.jit, ...)``;
* passed by name to ``jax.jit``, ``lax.scan``, ``lax.cond``,
  ``lax.while_loop``, ``lax.fori_loop``, ``lax.switch``, ``jax.vmap``,
  ``jax.grad``, ``jax.value_and_grad``, ``checkpoint``/``remat``;
* defined lexically inside a sensitive function (closures: scan bodies are
  almost always inner defs);
* called by simple name from a sensitive function.

``float(<numeric literal>)`` and calls in default-argument position are
exempt (evaluated at definition time, not in-trace).
"""
from __future__ import annotations

import ast

from ..astlint import call_name
from ..findings import Finding
from ..registry import Rule, register

# call targets that hand a function into a traced context
_TRACERS = {
    "jax.jit", "jit", "pjit",
    "lax.scan", "jax.lax.scan", "scan",
    "lax.cond", "jax.lax.cond", "cond",
    "lax.while_loop", "jax.lax.while_loop",
    "lax.fori_loop", "jax.lax.fori_loop", "fori_loop",
    "lax.switch", "jax.lax.switch",
    "jax.vmap", "vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "checkpoint", "jax.remat", "remat",
    "lax.associative_scan", "jax.lax.associative_scan",
}

# host-sync call names (module-qualified or bare)
_SYNC_CALLS = {
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.device_get", "device_get", "onp.asarray", "onp.array",
}
_SYNC_METHODS = {"item", "block_until_ready", "tolist", "__array__"}


_JIT_NAMES = {"jit", "jax.jit", "pjit"}


def _is_jit_decorated(fn: ast.AST) -> bool:
    for deco in getattr(fn, "decorator_list", []):
        if isinstance(deco, (ast.Name, ast.Attribute)):
            if ast.unparse(deco) in _JIT_NAMES:
                return True
        elif isinstance(deco, ast.Call):  # @jax.jit(...) / @partial(jax.jit,)
            head = ast.unparse(deco.func)
            if head in _JIT_NAMES:
                return True
            if (head in ("partial", "functools.partial") and deco.args
                    and ast.unparse(deco.args[0]) in _JIT_NAMES):
                return True
    return False


def _func_defs(tree: ast.AST) -> list[ast.AST]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda))]


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    # annotate lexical parent functions
    parents: dict[ast.AST, ast.AST] = {}
    for fn in _func_defs(tree):
        for child in ast.walk(fn):
            if child is not fn and isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
                parents.setdefault(child, fn)

    by_name: dict[str, list[ast.AST]] = {}
    for fn in _func_defs(tree):
        if hasattr(fn, "name"):
            by_name.setdefault(fn.name, []).append(fn)

    sensitive: set[ast.AST] = set()
    for fn in _func_defs(tree):
        if _is_jit_decorated(fn):
            sensitive.add(fn)
    # functions passed (by name or inline lambda) into tracer calls
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or call_name(node) not in _TRACERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                sensitive.add(arg)
            elif isinstance(arg, ast.Name):
                sensitive.update(by_name.get(arg.id, []))

    # fixpoint: nesting inside a sensitive fn, or being called by name
    # from one, marks a fn sensitive too
    changed = True
    while changed:
        changed = False
        for fn in _func_defs(tree):
            if fn in sensitive:
                continue
            p = parents.get(fn)
            if p is not None and p in sensitive:
                sensitive.add(fn)
                changed = True
        for s in list(sensitive):
            for node in ast.walk(s):
                if (node is not s and isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)):
                    for cand in by_name.get(node.func.id, []):
                        if cand not in sensitive:
                            sensitive.add(cand)
                            changed = True

    # ownership: map each node to its nearest enclosing function.
    # _func_defs walks breadth-first (outer defs before their inner defs),
    # so plain assignment lets the innermost function win.
    owner: dict[ast.AST, ast.AST] = {}
    for fn in _func_defs(tree):
        body = fn.body if not isinstance(fn, ast.Lambda) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                owner[node] = fn

    found: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = owner.get(node)
        # attribute the call to the innermost sensitive enclosing fn
        while fn is not None and fn not in sensitive:
            fn = parents.get(fn)
        if fn is None:
            continue
        name = call_name(node)
        hit = None
        if name in _SYNC_CALLS:
            hit = name
        elif name == "float" and node.args and not isinstance(
                node.args[0], ast.Constant):
            hit = "float()"
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in _SYNC_METHODS):
            hit = f".{node.func.attr}()"
        if hit:
            fname = getattr(fn, "name", "<lambda>")
            found.append(Finding(
                "REPRO-HOST-SYNC", path, node.lineno,
                f"host-sync call {hit} inside traced function "
                f"`{fname}`",
                "keep values on device (jnp ops); sync once after the "
                "epoch via the runner's single device_get"))
    return found


register(Rule(
    rule_id="REPRO-HOST-SYNC",
    scope="file",
    description="no `float()`/`.item()`/`np.asarray`/`.block_until_ready()`"
                "/`jax.device_get` inside scan/cond bodies or jitted steps",
    check=check,
    fix_hint="keep the value on device; one device_get per epoch run",
))
