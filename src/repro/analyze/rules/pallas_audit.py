"""REPRO-PALLAS-*: static audit of the Pallas kernel packages.

Each package under ``src/repro/kernels/<name>/`` couples a ``kernel.py``
(the ``pl.pallas_call`` grids/BlockSpecs and kernel bodies) with an
``ops.py`` (the jitted wrappers that pad operands). Four checks, all
pure-AST over the package's files (never importing jax):

* **REPRO-PALLAS-GRID** — every ``X // B`` in a ``grid=`` must be backed
  by divisibility evidence for ``X`` w.r.t. ``B`` somewhere in the
  package: the ceil-div pad idiom ``X = -(-d // B) * B`` or an
  ``assert X % B == 0``. A non-divisible grid silently truncates the
  trailing tile.
* **REPRO-PALLAS-OOB** — provable out-of-bounds ref indexing: an integer
  literal row index (direct subscript, ``pl.load``/``pl.store``, or a
  ``range(k)`` loop/comprehension bound) that reaches or exceeds the
  literal leading BlockSpec extent. Symbolic shapes are skipped — the
  rule only reports what it can prove.
* **REPRO-PALLAS-ACC** — accumulation dtype: MXU contractions
  (``dot_general``/``pl.dot``/``jnp.dot``/``einsum``) must pin
  ``preferred_element_type`` (f32 accumulators for f32-or-wider inputs),
  and ``o_ref[...] += ...`` accumulation requires an f32 (or wider)
  ``out_shape`` dtype — accumulating in bf16/f16 loses low bits per
  grid step.
* **REPRO-PALLAS-MASK** — packages whose kernels run a bitonic
  compare-exchange network must map NaN payloads and padding lanes to
  the finite ``_BIG`` sentinel before the network (cf.
  ``agg/rules.py::sort_stack``): NaN poisons ``jnp.minimum``/``maximum``
  compare-exchanges and +/-inf pads break windowed arithmetic, so the
  pad site needs an ``isnan``->sentinel rewrite with a finite
  ``_BIG``-style constant.
"""
from __future__ import annotations

import ast
import os
import re

from ..findings import Finding
from ..registry import Rule, register

_KERNELS_DIR = os.path.join("src", "repro", "kernels")
_DOT_CALLS = {"dot_general", "dot", "einsum"}
_BIG_MIN = 1e38          # finite sentinel magnitude (f32 max is ~3.4e38)


def _packages(root: str):
    """Yield (pkg_rel_dir, {filename: (tree, source)}) per kernel package."""
    base = os.path.join(root, _KERNELS_DIR)
    if not os.path.isdir(base):
        return
    for d in sorted(os.listdir(base)):
        pdir = os.path.join(base, d)
        if not os.path.isfile(os.path.join(pdir, "kernel.py")):
            continue
        files = {}
        for fn in sorted(os.listdir(pdir)):
            if fn.endswith(".py"):
                with open(os.path.join(pdir, fn)) as f:
                    src = f.read()
                try:
                    files[fn] = (ast.parse(src), src)
                except SyntaxError:
                    continue            # REPRO-PARSE reports it
        yield os.path.join(_KERNELS_DIR, d), files


def _call_tail(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _pallas_calls(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_tail(node) == "pallas_call":
            yield node


def _kw(call: ast.Call, name: str):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


# -- GRID -------------------------------------------------------------------


_CEIL_DIV = r"^-\(-\w+\s*//\s*{b}\)\s*\*\s*{b}$"


def _has_divisibility_evidence(files: dict, x: str, b: str) -> bool:
    pat = re.compile(_CEIL_DIV.format(b=re.escape(b)))
    for tree, _src in files.values():
        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == x
                    and pat.match(ast.unparse(node.value).replace(" ", ""))):
                return True
            if isinstance(node, ast.Assert):
                t = ast.unparse(node.test).replace(" ", "")
                if f"{x}%{b}==0" in t:
                    return True
    return False


def _grid_divs(tree: ast.Module, call: ast.Call):
    """FloorDiv (X, B) name pairs reachable from the call's grid kwarg."""
    grid = _kw(call, "grid")
    if grid is None:
        return
    exprs = [grid]
    names = {n.id for n in ast.walk(grid) if isinstance(n, ast.Name)}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id in names):
            exprs.append(node.value)
    for e in exprs:
        for node in ast.walk(e):
            if (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.FloorDiv)
                    and isinstance(node.left, ast.Name)
                    and isinstance(node.right, ast.Name)):
                yield node.left.id, node.right.id, node.lineno


def _check_grid(pkg: str, files: dict) -> list[Finding]:
    found = []
    for fn, (tree, _src) in files.items():
        rel = os.path.join(pkg, fn)
        for call in _pallas_calls(tree):
            for x, b, line in _grid_divs(tree, call):
                if not _has_divisibility_evidence(files, x, b):
                    found.append(Finding(
                        "REPRO-PALLAS-GRID", rel, line,
                        f"grid uses `{x} // {b}` but the package shows no "
                        f"divisibility evidence for `{x}` (ceil-div pad or "
                        f"`assert {x} % {b} == 0`) — a ragged trailing tile "
                        "is silently dropped",
                        f"pad with `{x} = -(-d // {b}) * {b}` in the ops "
                        "wrapper (see kernels/*/ops.py)"))
    return found


# -- OOB --------------------------------------------------------------------


def _literal_leading_dims(tree: ast.Module) -> list[int]:
    dims = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_tail(node) == "BlockSpec":
            shape = node.args[0] if node.args else _kw(node, "block_shape")
            if isinstance(shape, (ast.Tuple, ast.List)) and shape.elts:
                lead = shape.elts[0]
                if isinstance(lead, ast.Constant) and \
                        isinstance(lead.value, int):
                    dims.append(lead.value)
    return dims


def _check_oob(pkg: str, files: dict) -> list[Finding]:
    found = []
    for fn, (tree, _src) in files.items():
        if fn != "kernel.py":
            continue
        rel = os.path.join(pkg, fn)
        dims = _literal_leading_dims(tree)
        if not dims:
            continue                    # symbolic shapes: nothing provable
        bound = max(dims)

        def idx_of(node):
            if isinstance(node, ast.Subscript):
                base = node.value
                sl = node.slice
                head = sl.elts[0] if isinstance(sl, ast.Tuple) and sl.elts \
                    else sl
                if (isinstance(base, ast.Name) and base.id.endswith("_ref")
                        and isinstance(head, ast.Constant)
                        and isinstance(head.value, int)):
                    return head.value
            if isinstance(node, ast.Call) and \
                    _call_tail(node) in ("load", "store") and len(node.args) > 1:
                sl = node.args[1]
                head = sl.elts[0] if isinstance(sl, ast.Tuple) and sl.elts \
                    else sl
                if isinstance(head, ast.Constant) and \
                        isinstance(head.value, int):
                    return head.value
            return None

        # range(k) bounds whose loop var indexes a ref
        range_bounds = {}
        for node in ast.walk(tree):
            it = None
            tgt = None
            if isinstance(node, ast.For):
                it, tgt = node.iter, node.target
            elif isinstance(node, ast.comprehension):
                it, tgt = node.iter, node.target
            if (it is not None and isinstance(it, ast.Call)
                    and _call_tail(it) == "range" and len(it.args) == 1
                    and isinstance(it.args[0], ast.Constant)
                    and isinstance(tgt, ast.Name)):
                range_bounds[tgt.id] = (it.args[0].value, it.lineno)

        for node in ast.walk(tree):
            k = idx_of(node)
            if k is not None and k >= bound:
                found.append(Finding(
                    "REPRO-PALLAS-OOB", rel, node.lineno,
                    f"ref index {k} is out of bounds for the largest "
                    f"declared BlockSpec leading extent {bound}",
                    "index within the block shape; pad the operand if the "
                    "logical shape is larger"))
            if (isinstance(node, ast.Subscript)
                    and isinstance(node.value, ast.Name)
                    and node.value.id.endswith("_ref")):
                sl = node.slice
                head = sl.elts[0] if isinstance(sl, ast.Tuple) and sl.elts \
                    else sl
                if isinstance(head, ast.Name) and head.id in range_bounds:
                    rb, rline = range_bounds[head.id]
                    if rb > bound:
                        found.append(Finding(
                            "REPRO-PALLAS-OOB", rel, node.lineno,
                            f"loop over range({rb}) (line {rline}) indexes "
                            f"a ref whose largest BlockSpec leading extent "
                            f"is {bound}",
                            "bound the loop by the block shape"))
    return found


# -- ACC --------------------------------------------------------------------


_NARROW_DTYPES = ("bfloat16", "float16")


def _out_dtype_names(tree: ast.Module) -> set[str]:
    out = set()
    for call in _pallas_calls(tree):
        shape = _kw(call, "out_shape")
        if shape is None:
            continue
        for node in ast.walk(shape):
            if isinstance(node, ast.Call) and \
                    _call_tail(node) == "ShapeDtypeStruct" and \
                    len(node.args) >= 2:
                dt = node.args[1]
                name = ast.unparse(dt)
                out.add(name.split(".")[-1])
    return out


def _check_acc(pkg: str, files: dict) -> list[Finding]:
    found = []
    for fn, (tree, _src) in files.items():
        if fn != "kernel.py":
            continue
        rel = os.path.join(pkg, fn)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _call_tail(node) in _DOT_CALLS:
                if _kw(node, "preferred_element_type") is None:
                    found.append(Finding(
                        "REPRO-PALLAS-ACC", rel, node.lineno,
                        f"`{_call_tail(node)}` without "
                        "`preferred_element_type` — the MXU accumulates in "
                        "the input dtype (bf16 partials for bf16 inputs)",
                        "pass preferred_element_type=jnp.float32"))
        narrow = {d for d in _out_dtype_names(tree) if d in _NARROW_DTYPES}
        if narrow:
            for node in ast.walk(tree):
                if (isinstance(node, ast.AugAssign)
                        and isinstance(node.op, ast.Add)
                        and isinstance(node.target, ast.Subscript)
                        and isinstance(node.target.value, ast.Name)
                        and node.target.value.id.endswith("_ref")):
                    found.append(Finding(
                        "REPRO-PALLAS-ACC", rel, node.lineno,
                        f"`+=` accumulation into a {'/'.join(sorted(narrow))} "
                        "output ref loses low bits every grid step",
                        "accumulate in an f32 VMEM scratch (or f32 "
                        "out_shape) and cast once at the end"))
    return found


# -- MASK -------------------------------------------------------------------


def _has_big_sentinel(files: dict) -> bool:
    for tree, src in files.values():
        if "isnan" not in src:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, float) and \
                    abs(node.value) >= _BIG_MIN:
                return True
            if isinstance(node, ast.Name) and "BIG" in node.id:
                return True
            if isinstance(node, ast.Attribute) and "BIG" in node.attr:
                return True
    return False


def _pad_site(files: dict):
    for fn, (tree, _src) in files.items():
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and \
                    _call_tail(node) in ("full", "pad", "full_like"):
                return fn, node.lineno
    return "kernel.py", 0


def _check_mask(pkg: str, files: dict) -> list[Finding]:
    ktree, ksrc = files.get("kernel.py", (None, ""))
    if "bitonic" not in ksrc:
        return []
    if _has_big_sentinel(files):
        return []
    fn, line = _pad_site(files)
    return [Finding(
        "REPRO-PALLAS-MASK", os.path.join(pkg, fn), line,
        "bitonic compare-exchange kernels without a NaN->sentinel rewrite "
        "at the pad site: NaN payloads poison jnp.minimum/maximum networks "
        "and +/-inf pads break windowed arithmetic",
        "map NaN (and padding lanes) to the finite `_BIG` sentinel before "
        "the network, as agg/rules.py::sort_stack does")]


# -- registration -----------------------------------------------------------


def _make_check(fn):
    def check(root: str) -> list[Finding]:
        found = []
        for pkg, files in _packages(root):
            found.extend(fn(pkg, files))
        return found
    return check


register(Rule(
    rule_id="REPRO-PALLAS-GRID",
    scope="repo",
    description="every `X // B` in a pallas_call grid has package-local "
                "divisibility evidence (ceil-div pad idiom or assert)",
    check=_make_check(_check_grid),
    fix_hint="pad the operand to a multiple of the block in ops.py",
))

register(Rule(
    rule_id="REPRO-PALLAS-OOB",
    scope="repo",
    description="no provable out-of-bounds ref indexing vs declared "
                "BlockSpec extents (literal indices and range() bounds)",
    check=_make_check(_check_oob),
    fix_hint="index within the block shape",
))

register(Rule(
    rule_id="REPRO-PALLAS-ACC",
    scope="repo",
    description="MXU contractions pin `preferred_element_type`; no `+=` "
                "accumulation into bf16/f16 output refs",
    check=_make_check(_check_acc),
    fix_hint="accumulate in f32 (preferred_element_type / VMEM scratch)",
))

register(Rule(
    rule_id="REPRO-PALLAS-MASK",
    scope="repo",
    description="bitonic sorting-network packages rewrite NaN/padding "
                "lanes to the finite `_BIG` sentinel before "
                "compare-exchange",
    check=_make_check(_check_mask),
    fix_hint="map NaN and pads to `_BIG` at the pad site (sort_stack idiom)",
))
