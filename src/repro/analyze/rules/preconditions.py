"""REPRO-BYZ-BOUNDS: Byzantine resilience preconditions on every preset.

The paper's Table-1 bounds, checked *symbolically* over
``exp/presets.py`` — every ``register(Experiment(...))`` call is
evaluated from the AST (literal kwargs, ``**_COMMON`` dict expansion,
dataclass defaults from ``exp/spec.py``) without importing the module:

* async: ``n_w >= 3 f_w + 1``;   sync: ``n_w >= 2 f_w + 1``
* servers: ``n_ps >= 3 f_ps + 2``  (Table 1's correct-majority quorum
  bound — one stronger than the naive ``3 f + 1`` replication bound)
* quorums: ``2 f_w + 1 <= q_w <= n_w - f_w`` and
  ``2 f_ps + 2 <= q_ps <= n_ps - f_ps`` (defaults as derived by
  ``ByzSGDConfig``)
* the DMC/serve read bound ``R >= 2 f + 1`` on the server replicas.

Runtime validation (``core/quorum.validate_counts``) already rejects bad
configs when they *run*; this rule rejects them when they're *written*,
and — because it re-derives the bounds instead of importing the
validator — it also catches the validator itself being edited out of
agreement with the presets.
"""
from __future__ import annotations

import ast
import os

from ..astlint import literal_str
from ..findings import Finding
from ..registry import Rule, register

_SPEC = os.path.join("src", "repro", "exp", "spec.py")
_PRESETS = os.path.join("src", "repro", "exp", "presets.py")
_FIELDS = ("n_workers", "f_workers", "n_servers", "f_servers",
           "q_workers", "q_servers", "variant")


def _experiment_defaults(root: str) -> dict:
    """Field defaults of the Experiment dataclass, read from spec.py's AST."""
    with open(os.path.join(root, _SPEC)) as f:
        tree = ast.parse(f.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "Experiment":
            out = {}
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign) and stmt.value is not None
                        and isinstance(stmt.target, ast.Name)):
                    try:
                        out[stmt.target.id] = ast.literal_eval(stmt.value)
                    except Exception:
                        pass
            return out
    raise LookupError("Experiment dataclass not found in exp/spec.py")


def _module_dicts(tree: ast.Module) -> dict[str, dict]:
    """Module-level ``NAME = dict(k=v, ...)`` / ``NAME = {...}`` literals
    (the ``**_NETSIM_COMMON`` expansion sources)."""
    out: dict[str, dict] = {}
    for stmt in tree.body:
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        val = stmt.value
        d: dict | None = None
        if isinstance(val, ast.Call) and isinstance(val.func, ast.Name) \
                and val.func.id == "dict" and not val.args:
            d = {}
            for kw in val.keywords:
                if kw.arg is None:
                    d = None
                    break
                try:
                    d[kw.arg] = ast.literal_eval(kw.value)
                except Exception:
                    d[kw.arg] = None  # non-literal: not bounds-relevant
        elif isinstance(val, ast.Dict):
            try:
                d = ast.literal_eval(val)
            except Exception:
                d = None
        if d is not None:
            out[stmt.targets[0].id] = d
    return out


def _preset_calls(tree: ast.Module):
    """(Experiment-call, lineno) under every ``register(...)`` call."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "register"):
            for arg in node.args:
                if (isinstance(arg, ast.Call)
                        and isinstance(arg.func, ast.Name)
                        and arg.func.id == "Experiment"):
                    yield arg, node.lineno


def _resolve_fields(call: ast.Call, defaults: dict, dicts: dict) -> dict:
    fields = {k: defaults.get(k) for k in _FIELDS}
    fields["name"] = None
    for kw in call.keywords:
        if kw.arg is None:  # **_COMMON expansion
            src = dicts.get(getattr(kw.value, "id", ""), {})
            for k in _FIELDS:
                if k in src:
                    fields[k] = src[k]
            if "name" in src:
                fields["name"] = src["name"]
            continue
        if kw.arg == "name":
            fields["name"] = literal_str(kw.value)
        elif kw.arg in _FIELDS:
            try:
                fields[kw.arg] = ast.literal_eval(kw.value)
            except Exception:
                pass  # non-literal (runtime value): leave the default
    return fields


def _bounds_violations(f: dict) -> list[str]:
    n_w, f_w = f["n_workers"], f["f_workers"]
    n_ps, f_ps = f["n_servers"], f["f_servers"]
    sync = f.get("variant") == "sync"
    q_w = f["q_workers"] or (n_w - f_w)
    q_ps = f["q_servers"] or max(n_ps - f_ps, 2 * f_ps + 2)
    probs = []
    if sync:
        if n_w < 2 * f_w + 1:
            probs.append(f"sync needs n_w >= 2f_w+1 ({n_w} < {2*f_w+1})")
    elif n_w < 3 * f_w + 1:
        probs.append(f"async needs n_w >= 3f_w+1 ({n_w} < {3*f_w+1})")
    if n_ps < 3 * f_ps + 2:
        probs.append(f"needs n_ps >= 3f_ps+2 ({n_ps} < {3*f_ps+2})")
    if not (2 * f_w + 1 <= q_w <= n_w - f_w):
        probs.append(f"needs 2f_w+1 <= q_w <= n_w-f_w (q_w={q_w})")
    if not (2 * f_ps + 2 <= q_ps <= n_ps - f_ps):
        probs.append(f"needs 2f_ps+2 <= q_ps <= n_ps-f_ps (q_ps={q_ps})")
    if n_ps < 2 * f_ps + 1:  # the R >= 2f+1 replicated-read bound
        probs.append(f"needs R >= 2f+1 server replicas ({n_ps} < {2*f_ps+1})")
    return probs


def check(root: str) -> list[Finding]:
    path = os.path.join(root, _PRESETS)
    if not os.path.exists(path):
        return [Finding("REPRO-BYZ-BOUNDS", _PRESETS, 0,
                        "exp/presets.py not found")]
    with open(path) as f:
        tree = ast.parse(f.read(), filename=_PRESETS)
    defaults = _experiment_defaults(root)
    dicts = _module_dicts(tree)
    found = []
    n_checked = 0
    for call, lineno in _preset_calls(tree):
        fields = _resolve_fields(call, defaults, dicts)
        n_checked += 1
        name = fields["name"] or f"<preset@{lineno}>"
        for prob in _bounds_violations(fields):
            found.append(Finding(
                "REPRO-BYZ-BOUNDS", _PRESETS, lineno,
                f"preset `{name}`: {prob}",
                "adjust the cluster shape; see core/quorum.validate_counts "
                "(Table 1)"))
    if n_checked == 0:
        found.append(Finding(
            "REPRO-BYZ-BOUNDS", _PRESETS, 0,
            "no register(Experiment(...)) calls found — preset structure "
            "changed under the rule",
            "update analyze/rules/preconditions.py to the new structure"))
    return found


register(Rule(
    rule_id="REPRO-BYZ-BOUNDS",
    scope="repo",
    description="Table-1 resilience bounds (`n_w>=3f_w+1` async / "
                "`2f_w+1` sync, `n_ps>=3f_ps+2`, quorum windows, "
                "`R>=2f+1`) hold symbolically for every preset",
    check=check,
    fix_hint="fix the preset's cluster shape",
))
