"""REPRO-CACHE-KEY: epoch compile-cache keys must cover what `_build` reads.

The engines cache jitted epoch executables in a module-level semantic
cache (``core/epochs.py``). An executable closes over everything its
``_build()`` read off ``self`` — so every ``self.X`` reachable from
``_build`` (transitively through same-class helper methods like
``_flags``) must also be reachable from ``_cache_key``/``_instance_key``.
A missed attribute means two engines that differ only in that attribute
share one compiled epoch: silently wrong numerics, the worst failure mode
a cache can have.

Purely structural: no imports of the checked code. Classes are selected
by base-class name (EpochRunner and its known subclasses), so third-party
runners added later are picked up as long as they subclass the
scaffolding.
"""
from __future__ import annotations

import ast

from ..astlint import self_attr_reads, self_method_calls
from ..findings import Finding
from ..registry import Rule, register

_RUNNER_BASES = {"EpochRunner", "EpochEngine", "ProtocolEngine"}
_BUILD = "_build"
_KEYS = ("_cache_key", "_instance_key")
# attrs that never leak into the executable: the cache slot itself, and
# the per-call extras consumed outside the jitted epoch
_EXEMPT = {"_epoch", "eval_set"}


def _methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}


def _is_property(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        name = dec.id if isinstance(dec, ast.Name) else \
            dec.attr if isinstance(dec, ast.Attribute) else ""
        if name in ("property", "cached_property"):
            return True
    return False


def _transitive_reads(cls_methods: dict, roots: list[str]) -> set[str]:
    """self.X reads reachable from the named methods through same-class
    self.m() calls; ``@property`` reads resolve one level into the
    property body's own field reads."""
    reads: set[str] = set()
    seen: set[str] = set()
    stack = [m for m in roots if m in cls_methods]
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        node = cls_methods[name]
        reads |= self_attr_reads(node)
        for callee in self_method_calls(node):
            if callee in cls_methods:
                stack.append(callee)
    # a `self.prop` read is really a read of whatever the property body
    # reads — resolve one level so derived properties don't mask (or
    # falsely add) the underlying config fields
    props = {n for n, fn in cls_methods.items() if _is_property(fn)}
    for p in sorted(reads & props):
        reads |= self_attr_reads(cls_methods[p])
    # called helper methods show up as attribute reads too; they're code,
    # not config — drop them
    return reads - set(seen) - set(cls_methods)


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    found: list[Finding] = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        base_names = {b.id if isinstance(b, ast.Name) else getattr(b, "attr", "")
                      for b in cls.bases}
        if not (base_names & _RUNNER_BASES):
            continue
        methods = _methods(cls)
        if _BUILD not in methods:
            continue
        build_reads = _transitive_reads(methods, [_BUILD]) - _EXEMPT
        if not any(k in methods for k in _KEYS):
            found.append(Finding(
                "REPRO-CACHE-KEY", path, cls.lineno,
                f"runner `{cls.name}` defines `_build` but neither "
                "`_cache_key` nor `_instance_key`",
                "add a `_cache_key` covering every self attribute "
                "`_build` closes over"))
            continue
        key_reads = _transitive_reads(methods, list(_KEYS)) - _EXEMPT
        missing = sorted(build_reads - key_reads)
        if missing:
            found.append(Finding(
                "REPRO-CACHE-KEY", path, methods[_BUILD].lineno,
                f"`{cls.name}._build` closes over self.{{{', '.join(missing)}}}"
                " not covered by `_cache_key`/`_instance_key` — engines "
                "differing only in these share one compiled epoch",
                "fold the attribute(s) into `_flags()`/`_cache_key()` "
                "(use fn_cache_key/delivery_cache_key for callables)"))
    return found


register(Rule(
    rule_id="REPRO-CACHE-KEY",
    scope="file",
    description="every `EpochRunner` subclass's cache key covers all "
                "`self.*` config its `_build` closes over",
    check=check,
    fix_hint="extend `_flags()`/`_cache_key()`",
))
