"""REPRO-ENV-IMPORT / REPRO-ENV-MUTATE: environment-flag hygiene.

REPRO-ENV-IMPORT — a module-level ``os.environ.get("REPRO_*")`` /
``os.getenv`` / ``os.environ[...]`` read freezes the flag at import time:
later mutation (tests, ``Experiment.run`` overrides) silently does
nothing, and any engine cache key derived from the frozen module global
stops distinguishing runs. ``agg/rules.py`` carried a live instance of
this until the PR that introduced this rule. Reads inside a function are
fine — that IS the fix (resolve at call time).

REPRO-ENV-MUTATE — a bare ``os.environ["REPRO_*"] = ...`` (or ``.pop`` /
``del`` / ``.setdefault``/``.update``) outside the sanctioned override
helpers leaks process-global state across runs on any exception path.
Use ``repro.agg.dispatch.backend_override()`` / the flag's own
contextmanager instead. ``agg/dispatch.py`` hosts the sanctioned
helpers and is exempt.
"""
from __future__ import annotations

import ast

from ..astlint import call_name, literal_str
from ..findings import Finding
from ..registry import Rule, register

_PREFIX = "REPRO_"
# modules allowed to mutate REPRO_* env vars (the override helpers live
# here; everything else must go through them)
_MUTATE_EXEMPT = ("agg/dispatch.py",)


def _env_key(node: ast.Call | ast.Subscript) -> str | None:
    """Literal env-var name of an os.environ/os.getenv access, else None."""
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in ("os.environ.get", "environ.get", "os.getenv", "getenv",
                    "os.environ.setdefault", "environ.setdefault",
                    "os.environ.pop", "environ.pop"):
            if node.args:
                return literal_str(node.args[0])
    if isinstance(node, ast.Subscript):
        base = ast.unparse(node.value)
        if base in ("os.environ", "environ"):
            return literal_str(node.slice)
    return None


def _module_level_nodes(tree: ast.Module):
    """Statements executed at import time (incl. class bodies, excl. any
    function body)."""
    stack = list(tree.body)
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(stmt, ast.ClassDef):
            stack.extend(stmt.body)
            continue
        yield stmt


def check_import(tree: ast.AST, source: str, path: str) -> list[Finding]:
    found = []
    for stmt in _module_level_nodes(tree):
        for node in ast.walk(stmt):
            if isinstance(node, ast.Lambda):
                continue
            if isinstance(node, (ast.Call, ast.Subscript)):
                key = _env_key(node)
                if key and key.startswith(_PREFIX):
                    found.append(Finding(
                        "REPRO-ENV-IMPORT", path, node.lineno,
                        f"{key} read at import time (frozen before tests/"
                        "overrides can set it; poisons compile-cache keys)",
                        "resolve inside a function at call time, e.g. a "
                        "`flag_enabled()` helper with an override hook"))
    return found


def check_mutate(tree: ast.AST, source: str, path: str) -> list[Finding]:
    norm = path.replace("\\", "/")
    if any(norm.endswith(e) for e in _MUTATE_EXEMPT):
        return []
    found = []
    for node in ast.walk(tree):
        key = None
        how = None
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Subscript):
                    key = _env_key(t)
                    how = "assignment to"
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    key = _env_key(t)
                    how = "del of"
        elif isinstance(node, ast.Call):
            name = call_name(node)
            if name in ("os.environ.pop", "environ.pop",
                        "os.environ.setdefault", "environ.setdefault"):
                if node.args:
                    key = literal_str(node.args[0])
                    how = f"{name.split('.')[-1]} on"
        if key and key.startswith(_PREFIX):
            found.append(Finding(
                "REPRO-ENV-MUTATE", path, node.lineno,
                f"bare {how} os.environ[{key!r}] (leaks global state on "
                "exception paths)",
                "use the exception-safe override contextmanager "
                "(agg.dispatch.backend_override / use_sort_network)"))
    return found


register(Rule(
    rule_id="REPRO-ENV-IMPORT",
    scope="file",
    description="no import-time reads of `REPRO_*` env flags",
    check=check_import,
    fix_hint="resolve the flag at call time",
))

register(Rule(
    rule_id="REPRO-ENV-MUTATE",
    scope="file",
    description="no bare `os.environ[\"REPRO_*\"]` mutation outside the "
                "sanctioned override helpers in `agg/dispatch.py`",
    check=check_mutate,
    fix_hint="wrap in an exception-safe contextmanager",
))
