"""REPRO-TAINT-BYZ: no unguarded Byzantine influence on model state.

ByzSGD's safety argument is that every value crossing a trust boundary is
laundered through a robust GAR before it touches model state. This rule
*statically proves* it over the whole ``src/repro`` tree with the
interprocedural taint engine (``analyze.dataflow``):

* **sources** — the cross-node ingress points: ``inject_gradients`` /
  ``inject_models`` (worker gradient stacks and server-model equivocation
  in ``core/simulator.py`` / ``core/protocol.py``) and
  ``ReplicaPool.corrupt`` (serve replica payloads);
* **sanitizers** — exactly the *robust* rules of the live ``repro.agg``
  registry, derived from its AST: every ``register(Aggregator(...))``
  with a nonzero breakdown point (``requires=(k, c)``, ``k >= 2``), its
  ``masked_fn``, its ``weights_from_d2`` (whose output contracted
  against the stack — ``dot_general`` / ``@`` — is the selection-based
  sanitization pattern), plus the registry-level entry points
  ``tree_agg`` / ``selection_weights`` and ``agg.get(...)`` handles.
  ``mean`` has ``requires=(0, 1)`` and is NOT a sanitizer; a literal
  ``agg.get(name)`` whose spec lacks ``supports_masked_delivery`` does
  not launder a ``mask=`` call either.
* **sinks** — writes into trusted model state: ``params=`` / ``w_model=``
  kwargs of ``SimState`` / ``ByzState`` constructions and ``._replace``
  calls, and checkpoint ``save(...)`` payloads. (``ReplicaPool`` is
  deliberately NOT a sink: replicas model the *untrusted* side; serve
  reads launder through the quorum rules instead.)

Every violation prints the witness path file:line by file:line.
"""
from __future__ import annotations

import ast
import os

from ..dataflow import Policy, TaintEngine
from ..findings import Finding
from ..registry import Rule, register

_AGG_REGISTRY = os.path.join("src", "repro", "agg", "registry.py")

#: when set (``--fast``), only these rel-paths seed the analysis
_SCOPE: set[str] | None = None


def scope_to(paths: set[str] | None) -> None:
    """Restrict taint entry points (``--fast`` changed-file SCC mode)."""
    global _SCOPE
    _SCOPE = set(paths) if paths is not None else None


def registry_policy(root: str) -> Policy:
    """Derive the taint policy from ``agg/registry.py``'s AST (never
    imported), mirroring ``Aggregator.supports_masked_delivery``."""
    sanitizers = {"tree_agg"}
    weight_fns = {"selection_weights"}
    robust: dict[str, bool] = {}
    all_rules: set[str] = set()
    path = os.path.join(root, _AGG_REGISTRY)
    if os.path.exists(path):
        with open(path) as f:
            tree = ast.parse(f.read(), filename=_AGG_REGISTRY)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "register"):
                continue
            for arg in node.args:
                if not (isinstance(arg, ast.Call)
                        and isinstance(arg.func, ast.Name)
                        and arg.func.id == "Aggregator"):
                    continue
                kw = {k.arg: k.value for k in arg.keywords if k.arg}
                try:
                    name = ast.literal_eval(kw["name"])
                    requires = tuple(ast.literal_eval(kw["requires"]))
                except Exception:
                    continue
                all_rules.add(name)
                masked_ok = "masked_fn" in kw or (
                    "selection_based" in kw
                    and "weights_from_d2" in kw)
                if requires[0] < 2:
                    continue            # mean: no breakdown point
                robust[name] = masked_ok
                sanitizers.add(name)
                for field, dest in (("masked_fn", sanitizers),
                                    ("weights_from_d2", weight_fns)):
                    if field in kw:
                        ref = ast.unparse(kw[field]).split(".")[-1]
                        dest.add(ref)
    return Policy(
        sources=frozenset({"inject_gradients", "inject_models", "corrupt"}),
        sanitizers=frozenset(sanitizers),
        weight_fns=frozenset(weight_fns),
        robust_rules=robust,
        all_rules=frozenset(all_rules),
        sink_ctors=frozenset({"SimState", "ByzState"}),
        sink_kwargs=frozenset({"params", "w_model"}),
        sink_calls=frozenset({"save"}),
    )


def taint_modules(root: str) -> dict[str, ast.Module]:
    """Parse the modules the taint engine reasons over (``src/repro``)."""
    from ..astlint import lint_paths
    modules: dict[str, ast.Module] = {}
    prefix = os.path.join("src", "repro")
    for path in lint_paths(root):
        rel = os.path.relpath(path, root)
        if not rel.startswith(prefix):
            continue
        try:
            with open(path) as f:
                modules[rel] = ast.parse(f.read(), filename=rel)
        except SyntaxError:
            continue                    # REPRO-PARSE reports it
    return modules


def scc_closure(modules: dict[str, ast.Module],
                changed: set[str]) -> set[str]:
    """Changed files plus their file-level call-graph component.

    Edges: file A — file B when A calls a name defined top-level in B
    (taken undirected, so callers of a changed file are re-checked too —
    a conservative superset of the strongly-connected component). The
    returned scope seeds ``make lint-fast``'s taint entry points.
    """
    defs: dict[str, str] = {}
    for path, tree in modules.items():
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, path)
    edges: dict[str, set[str]] = {p: set() for p in modules}
    for path, tree in modules.items():
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                fn = node.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    fn.id if isinstance(fn, ast.Name) else None
                tgt = defs.get(name or "")
                if tgt and tgt != path:
                    edges[path].add(tgt)
                    edges[tgt].add(path)    # undirected: callers re-check
    out: set[str] = set()
    stack = [p for p in changed if p in edges]
    while stack:
        p = stack.pop()
        if p in out:
            continue
        out.add(p)
        stack.extend(edges.get(p, ()))
    return out or set(changed)


def check(root: str) -> list[Finding]:
    modules = taint_modules(root)
    policy = registry_policy(root)
    engine = TaintEngine(modules, policy)
    entry = None
    if _SCOPE is not None:
        entry = scc_closure(modules, {p for p in _SCOPE if p in modules})
    found = []
    for hit in engine.run(entry_paths=entry):
        found.append(Finding(
            "REPRO-TAINT-BYZ", hit.path, hit.line,
            f"Byzantine-tainted value reaches {hit.sink} without a "
            f"registered robust GAR on the path; witness: {hit.witness()}",
            "launder through a robust `repro.agg` rule (or its masked_fn/"
            "weights_from_d2) before writing model state; if the guard is "
            "a deliberate non-GAR mechanism, suppress inline with the "
            "paper reference"))
    return found


register(Rule(
    rule_id="REPRO-TAINT-BYZ",
    scope="repo",
    description="interprocedural taint: every cross-node ingress "
                "(inject_*/corrupt) is laundered by a robust registry GAR "
                "before reaching params/w_model/checkpoint sinks; witness "
                "path printed per violation",
    check=check,
    fix_hint="insert the GAR, or suppress with the paper mechanism cited",
))
