"""REPRO-DETERMINISM: bit-identical-resume hazards.

PR 8's elastic membership guarantees bit-identical resume: replaying the
same event log over the same seed must reproduce the same parameters.
Three hazard classes break that silently — no functional test fails,
results just stop being reproducible:

* **unordered iteration** — a ``for``/comprehension/reduction driven by a
  ``set`` (literal or ``set(...)`` call) iterates in hash order, which
  varies across processes (PYTHONHASHSEED) — if that order feeds trace
  order, cache keys, or manifests, resumes diverge. Wrap in
  ``sorted(...)``.
* **unsorted hash payloads** — ``json.dumps`` without ``sort_keys=True``
  feeding a digest (``hashlib.*``/``hash``) keys the cache on dict
  insertion order.
* **host entropy in traced code** — ``random.*`` / ``np.random.*`` /
  ``time.*`` / ``datetime.now`` inside a traced-sensitive function (see
  :func:`repro.analyze.dataflow.sensitive_functions`) bakes a
  trace-time host value into the compiled computation. ``jax.random``
  (key-threaded, deterministic) is exempt, as is wall-clock timing in
  plain host code such as the epoch runners.
"""
from __future__ import annotations

import ast

from ..astlint import dotted_name
from ..dataflow import owner_map, sensitive_functions
from ..findings import Finding
from ..registry import Rule, register

_HASH_FNS = {"md5", "sha1", "sha256", "sha512", "blake2b", "blake2s",
             "hash", "update"}
_REDUCERS = {"sum", "min", "max", "reduce", "prod"}
_HOST_ENTROPY_PREFIXES = ("random.", "np.random.", "numpy.random.",
                          "time.", "datetime.")
_HOST_ENTROPY_EXACT = {"time", "datetime.now", "datetime.utcnow",
                       "perf_counter", "monotonic", "getrandbits", "urandom"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else \
            fn.attr if isinstance(fn, ast.Attribute) else ""
        if name in ("set", "frozenset"):
            return True
        # dict-view difference/union etc. still ordered; skip
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitAnd, ast.BitOr, ast.Sub, ast.BitXor)):
        # set algebra: a & b, a | b, a - b on sets — only flag when one
        # side is provably a set expression
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _iter_sites(tree: ast.AST):
    """Yield (iter_expr, lineno, context) for every iteration site."""
    for node in ast.walk(tree):
        if isinstance(node, ast.For):
            yield node.iter, node.lineno, "for loop"
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                               ast.DictComp)):
            for gen in node.generators:
                yield gen.iter, node.lineno, "comprehension"
        elif isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else ""
            if name in _REDUCERS and node.args:
                yield node.args[0], node.lineno, f"{name}() reduction"
            elif name == "list" and node.args:
                yield node.args[0], node.lineno, "list() materialization"


def _json_dumps_feeding_hash(tree: ast.AST):
    """Yield unsorted json.dumps calls that reach a digest function."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else \
            fn.id if isinstance(fn, ast.Name) else ""
        if name not in _HASH_FNS:
            continue
        for a in node.args:
            for arg in ast.walk(a):
                if (isinstance(arg, ast.Call)
                        and dotted_name(arg.func) in ("json.dumps", "dumps")):
                    kw = {k.arg for k in arg.keywords}
                    if "sort_keys" not in kw:
                        yield arg.lineno


def _host_entropy_calls(tree: ast.AST):
    sensitive = sensitive_functions(tree)
    if not sensitive:
        return
    owner = owner_map(tree)
    from ..dataflow import lexical_parents
    parents = lexical_parents(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = owner.get(node)
        while fn is not None and fn not in sensitive:
            fn = parents.get(fn)
        if fn is None:
            continue
        name = dotted_name(node.func) or ""
        if name.startswith(("jax.random", "jrandom", "jr.")):
            continue                    # key-threaded PRNG: deterministic
        if (name.startswith(_HOST_ENTROPY_PREFIXES)
                or name in _HOST_ENTROPY_EXACT):
            yield name, node.lineno, getattr(fn, "name", "<lambda>")


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    found: list[Finding] = []
    for it, line, ctx in _iter_sites(tree):
        if _is_set_expr(it):
            found.append(Finding(
                "REPRO-DETERMINISM", path, line,
                f"{ctx} iterates a set in hash order — feeding trace "
                "order, cache keys, or manifests from it breaks "
                "bit-identical resume",
                "wrap the iterable in sorted(...)"))
    for line in _json_dumps_feeding_hash(tree):
        found.append(Finding(
            "REPRO-DETERMINISM", path, line,
            "json.dumps without sort_keys=True feeds a digest — the key "
            "depends on dict insertion order",
            "pass sort_keys=True to json.dumps"))
    for name, line, fname in _host_entropy_calls(tree):
        found.append(Finding(
            "REPRO-DETERMINISM", path, line,
            f"host entropy `{name}` inside traced function `{fname}` "
            "bakes a trace-time value into the compiled computation",
            "thread a jax.random key (or hoist the read out of the "
            "traced region)"))
    return found


register(Rule(
    rule_id="REPRO-DETERMINISM",
    scope="file",
    description="no set-order iteration feeding traces/keys/manifests, "
                "no unsorted json.dumps into digests, no host "
                "random/time inside traced functions",
    check=check,
    fix_hint="sorted(...) the iterable / sort_keys=True / thread a PRNG key",
))
