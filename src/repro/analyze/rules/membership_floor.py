"""REPRO-MEMBERSHIP-FLOOR: membership shrinks must be floor-guarded.

Elastic membership (``core/membership.py``) and quorum serving
(``serve/replica.py``) both carry a notion of an *active* set that may
shrink at runtime — and every shrink must be checked against a resilience
floor (Table 1's ``n >= 3f+1`` / ``3f+2`` for training epochs, the
``2f+1`` read quorum for serving) before it takes effect. A shrink that
skips the check wedges the fleet silently: quorums become unsatisfiable
and every later aggregation under-collects without an error.

Two static checks, neither importing the checked code:

* **mask shrinks** (per file): an assignment of ``False`` into a
  subscript of an ``active``-named mask (``self.active[i] = False``,
  ``pool.active[i] = False``) or an in-place intersection
  (``active &= mask``) must sit in a function that shows floor-guard
  evidence — a name/attribute mentioning ``floor``, a call to a
  ``validate``/``epoch_config``-style checker, or an explicit
  ``2*f + c`` quorum-bound computation.
* **symbolic plans** (per file): every ``Experiment(...)`` call whose
  ``membership_plan`` is a literal ``MembershipPlan(events=...)`` (direct
  kwargs or ``**_COMMON`` dict expansion, same resolution as
  REPRO-BYZ-BOUNDS) is simulated: the realized active set must never
  shrink below 2 groups, and the churn-driven caps
  (``f_w' = (G'-1)//3``, ``f_ps' = (G'-2)//3`` — the quorum window
  binds before sync's cheaper worker bound) must still cover the
  declared-present Byzantine counts at every epoch. Calls whose shape or
  plan is not statically resolvable are skipped — the runtime validator
  (``membership.epoch_config``) still owns those.
"""
from __future__ import annotations

import ast

from ..astlint import dotted_name
from ..findings import Finding
from ..registry import Rule, register
from .preconditions import _module_dicts

#: substrings that mark a call as floor-checking within the enclosing fn
_GUARD_CALLS = ("floor", "validate", "epoch_config")


# ---------------------------------------------------------------------------
# part A: unguarded active-mask shrinks
# ---------------------------------------------------------------------------


def _mask_name(node: ast.AST) -> str:
    """The terminal name of a mask target: ``self.pool.active`` ->
    'active'; '' when the expression has no name tail."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _is_shrink(stmt: ast.AST):
    """(lineno, spelled-target) when ``stmt`` shrinks an active mask."""
    if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Subscript)
            and isinstance(stmt.value, ast.Constant)
            and stmt.value.value is False
            and "active" in _mask_name(stmt.targets[0].value)):
        return stmt.lineno, ast.unparse(stmt.targets[0])
    if (isinstance(stmt, ast.AugAssign) and isinstance(stmt.op, ast.BitAnd)):
        target = stmt.target
        base = target.value if isinstance(target, ast.Subscript) else target
        if "active" in _mask_name(base):
            return stmt.lineno, ast.unparse(target)
    return None


def _is_quorum_bound(node: ast.AST) -> bool:
    """``2 * f + c`` — the explicit quorum-floor arithmetic."""
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add)):
        return False
    terms = (node.left, node.right)
    has_mult = any(
        isinstance(t, ast.BinOp) and isinstance(t.op, ast.Mult)
        and any(isinstance(s, ast.Constant) and s.value in (2, 3)
                for s in (t.left, t.right))
        for t in terms)
    has_const = any(isinstance(t, ast.Constant) and isinstance(t.value, int)
                    for t in terms)
    return has_mult and has_const


def _guarded(fn: ast.AST) -> bool:
    """Floor-guard evidence anywhere in the enclosing function."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and "floor" in node.attr:
            return True
        if isinstance(node, ast.Name) and "floor" in node.id:
            return True
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if any(g in name for g in _GUARD_CALLS):
                return True
        if _is_quorum_bound(node):
            return True
    return False


def _shrink_findings(tree: ast.AST, path: str) -> list[Finding]:
    owner: dict[ast.AST, ast.AST] = {}
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        for node in ast.walk(fn):
            owner[node] = fn        # breadth-first: innermost fn wins
    found = []
    for node in ast.walk(tree):
        hit = _is_shrink(node)
        if hit is None:
            continue
        lineno, target = hit
        fn = owner.get(node)
        if fn is not None and _guarded(fn):
            continue
        found.append(Finding(
            "REPRO-MEMBERSHIP-FLOOR", path, lineno,
            f"active-mask shrink `{target}` without a resilience-floor "
            f"guard in the enclosing function",
            "check the post-shrink count against the quorum floor first "
            "(2f+1 reads / Table-1 training bounds; see "
            "ReplicaPool.deactivate, membership.epoch_config)"))
    return found


# ---------------------------------------------------------------------------
# part B: symbolic membership plans on Experiment(...) calls
# ---------------------------------------------------------------------------


def _called(node: ast.AST, name: str) -> bool:
    return (isinstance(node, ast.Call)
            and dotted_name(node.func).split(".")[-1] == name)


def _event_tuple(node: ast.AST):
    """One literal event -> (step, kind, group), else None."""
    if _called(node, "MembershipEvent"):
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        try:
            return (ast.literal_eval(kw["step"]),
                    ast.literal_eval(kw["kind"]),
                    ast.literal_eval(kw["group"]))
        except Exception:
            return None
    try:
        d = ast.literal_eval(node)
        return (d["step"], d["kind"], d["group"])
    except Exception:
        return None


def _plan_events(node: ast.AST):
    """Literal ``MembershipPlan(events=(...))`` -> [(step, kind, group)]
    sorted by step, or None when not statically resolvable."""
    if not _called(node, "MembershipPlan"):
        return None
    ev_node = None
    for k in node.keywords:
        if k.arg == "events":
            ev_node = k.value
    if ev_node is None and node.args:
        ev_node = node.args[0]
    if ev_node is None:
        return []                    # MembershipPlan() — empty plan
    if not isinstance(ev_node, (ast.Tuple, ast.List)):
        return None
    events = []
    for el in ev_node.elts:
        ev = _event_tuple(el)
        if ev is None:
            return None
        events.append(ev)
    return sorted(events)


def _byz_counts(node: ast.AST) -> tuple[int, int] | None:
    """Literal ``ByzantineSpec(...)`` -> (n_byz_workers, n_byz_servers)."""
    if not _called(node, "ByzantineSpec"):
        return None
    out = {"n_byz_workers": 0, "n_byz_servers": 0}
    for k in node.keywords:
        if k.arg in out:
            try:
                out[k.arg] = ast.literal_eval(k.value)
            except Exception:
                return None
    return out["n_byz_workers"], out["n_byz_servers"]


def _plan_findings(tree: ast.AST, path: str) -> list[Finding]:
    dicts = _module_dicts(tree) if isinstance(tree, ast.Module) else {}
    found = []
    for node in ast.walk(tree):
        if not _called(node, "Experiment"):
            continue
        fields: dict = {}
        plan = name = byz = None
        for kw in node.keywords:
            if kw.arg is None:       # **_COMMON expansion
                fields.update(dicts.get(getattr(kw.value, "id", ""), {}))
                continue
            if kw.arg == "membership_plan":
                plan = _plan_events(kw.value)
            elif kw.arg == "byz":
                byz = _byz_counts(kw.value)
            elif kw.arg == "name":
                try:
                    name = ast.literal_eval(kw.value)
                except Exception:
                    pass
            else:
                try:
                    fields[kw.arg] = ast.literal_eval(kw.value)
                except Exception:
                    pass
        n_groups = fields.get("n_workers")
        if plan is None or not isinstance(n_groups, int):
            continue                 # no plan, or not statically resolvable
        f_w = fields.get("f_workers", 0)
        f_ps = fields.get("f_servers", 0)
        bw, bs = byz if byz is not None else (0, 0)
        label = name or f"<Experiment@{node.lineno}>"
        active = set(range(n_groups))
        for step, kind, group in plan:
            active.discard(group) if kind == "leave" else active.add(group)
            Gp = len(active)
            if Gp < 2:
                found.append(Finding(
                    "REPRO-MEMBERSHIP-FLOOR", path, node.lineno,
                    f"`{label}`: membership plan shrinks the fleet to "
                    f"G'={Gp} at step {step} — below the 2-group protocol "
                    "floor",
                    "keep >= 2 groups active, or drop the leave event"))
                break
            fw_cap = (Gp - 1) // 3
            fps_cap = max((Gp - 2) // 3, 0)
            if bw > min(f_w, fw_cap) or bs > min(f_ps, fps_cap):
                found.append(Finding(
                    "REPRO-MEMBERSHIP-FLOOR", path, node.lineno,
                    f"`{label}`: at step {step} the shrunk fleet (G'={Gp}) "
                    f"caps tolerable faults at f_w'={min(f_w, fw_cap)}, "
                    f"f_ps'={min(f_ps, fps_cap)}, below the declared-present "
                    f"Byzantine counts ({bw} workers, {bs} servers)",
                    "shrink less, or declare fewer Byzantine nodes for the "
                    "elastic run (membership.epoch_config rejects this at "
                    "runtime too)"))
                break
    return found


def check(tree: ast.AST, source: str, path: str) -> list[Finding]:
    return _shrink_findings(tree, path) + _plan_findings(tree, path)


register(Rule(
    rule_id="REPRO-MEMBERSHIP-FLOOR",
    scope="file",
    description="active-set shrinks are resilience-floor-guarded; literal "
                "`membership_plan`s never shrink below 2 groups or under "
                "the declared Byzantine counts (symbolic, like "
                "REPRO-BYZ-BOUNDS)",
    check=check,
    fix_hint="guard the shrink with the quorum floor / fix the plan",
))
