"""REPRO-AGG-PARITY: every registered Aggregator is fully wired.

Cross-file consistency of the GAR registry, from ASTs alone:

* **backend parity** — a spec declaring ``backends=( .., "pallas")`` must
  route through a ``dispatch.<fn>`` entry point that exists in
  ``agg/dispatch.py`` (the registry's calling convention passes
  ``backend=``/``interpret=`` only to dispatch-level callables);
* **masked-delivery wiring** — a declared ``masked_fn``/
  ``weights_from_d2`` must exist in ``agg/rules.py``;
* **__main__ table row** — ``agg/__main__.py`` must print
  ``markdown_table``, and ``markdown_table`` must derive its rows from
  ``specs()`` (so a new rule cannot ship without a docs row);
* **masked-delivery property test** — ``tests/test_agg.py`` must either
  name the rule literally or build its rule list dynamically from the
  registry (``names()``/``specs()`` + ``supports_masked_delivery``), so
  a new masked-capable rule is automatically under test.
"""
from __future__ import annotations

import ast
import os

from ..astlint import dotted_name, literal_str
from ..findings import Finding
from ..registry import Rule, register

_REGISTRY = os.path.join("src", "repro", "agg", "registry.py")
_DISPATCH = os.path.join("src", "repro", "agg", "dispatch.py")
_RULES = os.path.join("src", "repro", "agg", "rules.py")
_MAIN = os.path.join("src", "repro", "agg", "__main__.py")
_TESTS = os.path.join("tests", "test_agg.py")


def _parse(root: str, rel: str) -> ast.Module | None:
    path = os.path.join(root, rel)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return ast.parse(f.read(), filename=rel)


def _top_level_defs(tree: ast.Module) -> set[str]:
    return {n.name for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _agg_specs(tree: ast.Module):
    """(kwargs-dict of ast nodes, lineno) per register(Aggregator(...))."""
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "register"):
            continue
        for arg in node.args:
            if (isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name)
                    and arg.func.id == "Aggregator"):
                yield {kw.arg: kw.value for kw in arg.keywords
                       if kw.arg}, arg.lineno


def check(root: str) -> list[Finding]:
    reg = _parse(root, _REGISTRY)
    if reg is None:
        return [Finding("REPRO-AGG-PARITY", _REGISTRY, 0,
                        "agg/registry.py not found")]
    dispatch_defs = _top_level_defs(_parse(root, _DISPATCH) or ast.Module([], []))
    rules_defs = _top_level_defs(_parse(root, _RULES) or ast.Module([], []))
    found: list[Finding] = []

    test_src = ""
    tpath = os.path.join(root, _TESTS)
    if os.path.exists(tpath):
        with open(tpath) as f:
            test_src = f.read()
    dynamic_tests = ("supports_masked_delivery" in test_src
                     and ("names()" in test_src or "specs()" in test_src))

    n_specs = 0
    for kw, lineno in _agg_specs(reg):
        n_specs += 1
        name = literal_str(kw.get("name")) or f"<spec@{lineno}>"
        backends = ()
        if "backends" in kw:
            try:
                backends = tuple(ast.literal_eval(kw["backends"]))
            except Exception:
                pass
        fn = dotted_name(kw.get("fn")) if "fn" in kw else ""
        if "pallas" in backends:
            head, _, attr = fn.rpartition(".")
            if head != "dispatch" or attr not in dispatch_defs:
                found.append(Finding(
                    "REPRO-AGG-PARITY", _REGISTRY, lineno,
                    f"aggregator `{name}` declares a pallas backend but "
                    f"fn={fn or '?'} is not a dispatch-level entry point",
                    "route fn through agg/dispatch.py (it owns the "
                    "backend=/interpret= calling convention)"))
        for field, defs, where in (("masked_fn", rules_defs, "agg/rules.py"),
                                   ("weights_from_d2", rules_defs,
                                    "agg/rules.py")):
            if field in kw:
                ref = dotted_name(kw[field])
                head, _, attr = ref.rpartition(".")
                if head == "rules" and attr not in defs:
                    found.append(Finding(
                        "REPRO-AGG-PARITY", _REGISTRY, lineno,
                        f"aggregator `{name}`: {field}={ref} not defined "
                        f"in {where}",
                        f"define {attr} in {where} or fix the reference"))
        # masked-delivery property-test coverage
        masked = ("masked_fn" in kw) or ("weights_from_d2" in kw)
        if masked and not dynamic_tests and f'"{name}"' not in test_src \
                and f"'{name}'" not in test_src:
            found.append(Finding(
                "REPRO-AGG-PARITY", _TESTS, 0,
                f"aggregator `{name}` supports masked delivery but "
                "tests/test_agg.py neither names it nor derives its rule "
                "list from the registry",
                "keep the dynamic MASKABLE = [... if "
                "agg.get(n).supports_masked_delivery] idiom"))

    if n_specs == 0:
        found.append(Finding(
            "REPRO-AGG-PARITY", _REGISTRY, 0,
            "no register(Aggregator(...)) calls found — registry structure "
            "changed under the rule",
            "update analyze/rules/registry_parity.py"))

    main = _parse(root, _MAIN)
    main_src = ast.unparse(main) if main else ""
    if "markdown_table" not in main_src:
        found.append(Finding(
            "REPRO-AGG-PARITY", _MAIN, 0,
            "agg/__main__.py no longer prints the registry markdown_table",
            "keep `python -m repro.agg` printing markdown_table()"))
    table_fns = [n for n in reg.body if isinstance(n, ast.FunctionDef)
                 and n.name == "markdown_table"]
    if not table_fns or "specs()" not in ast.unparse(table_fns[0]):
        found.append(Finding(
            "REPRO-AGG-PARITY", _REGISTRY,
            table_fns[0].lineno if table_fns else 0,
            "markdown_table does not derive its rows from specs() — new "
            "aggregators would ship without a docs row",
            "iterate `for s in specs():` inside markdown_table"))
    return found


register(Rule(
    rule_id="REPRO-AGG-PARITY",
    scope="repo",
    description="every `Aggregator` has matching backends (pallas ⇒ "
                "dispatch entry point), existing masked_fn wiring, a "
                "registry-derived `__main__` table row, and masked-delivery "
                "test coverage",
    check=check,
    fix_hint="wire the aggregator through dispatch/rules/tests",
))
