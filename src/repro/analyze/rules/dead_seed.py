"""REPRO-DEAD-SEED: seeded-but-unimported ``src/repro`` modules.

The growth seed lays down module stubs ahead of the roadmap (e.g.
``core/compression.py`` for the gradient-compression item). A stub
nobody imports is invisible debt: it rots silently, REPRO-AGG-PARITY
never sees it, and the roadmap item looks done because the file exists.
This repo rule lists every ``src/repro`` module that no file under the
lint roots imports — baselined, so tracked debt is explicit and *new*
dead modules fail CI.

What counts as "imported": static imports anywhere under the lint roots
(product code — a module only tests import is still dead product
surface), with relative imports resolved against the importing file's
package and function-body imports included (the registry lazy-loads rule
modules that way) — plus dynamic-import evidence: a string literal
``"repro.x.y"`` anywhere (the model/config registries route through
``importlib.import_module`` on such literals). Exempt: ``__init__.py`` /
``__main__.py``, modules with an ``if __name__ == "__main__"`` guard
(CLI entry points, run via ``python -m``), and the kernel packages'
``ref.py`` reference oracles (consumed by the tier-1 suite by
convention).
"""
from __future__ import annotations

import ast
import os
import re

from ..findings import Finding
from ..registry import Rule, register

_SRC_PREFIX = os.path.join("src", "repro")
_EXEMPT = {"__init__.py", "__main__.py", "ref.py"}
_MODULE_LIT = re.compile(r"^repro(\.\w+)+$")


def _module_name(rel: str) -> str:
    """src/repro/core/compression.py -> repro.core.compression"""
    no_src = os.path.relpath(rel, "src")
    return no_src[:-3].replace(os.sep, ".")


def _package_of(rel: str) -> str:
    """Dotted package containing the file (for relative-import resolve)."""
    return _module_name(rel).rsplit(".", 1)[0]


def _imports_of(tree: ast.Module, pkg: str) -> set[str]:
    """All dotted module names a file imports: absolute + resolved
    relative + string-literal dynamic-import evidence."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                parts = pkg.split(".")
                if node.level > 1:
                    parts = parts[:len(parts) - (node.level - 1)]
                base = ".".join(parts)
                mod = f"{base}.{node.module}" if node.module else base
            else:
                mod = node.module or ""
            out.add(mod)
            for alias in node.names:
                out.add(f"{mod}.{alias.name}")
        elif (isinstance(node, ast.Constant)
              and isinstance(node.value, str)
              and _MODULE_LIT.match(node.value)):
            out.add(node.value)         # importlib.import_module target
    return out


def _has_main_guard(tree: ast.Module) -> bool:
    for node in tree.body:
        if (isinstance(node, ast.If)
                and "__main__" in ast.unparse(node.test)):
            return True
    return False


def check(root: str) -> list[Finding]:
    from ..astlint import lint_paths
    seeded: dict[str, str] = {}          # dotted name -> rel path
    imported: set[str] = set()
    for path in lint_paths(root):
        rel = os.path.relpath(path, root)
        try:
            with open(path) as f:
                tree = ast.parse(f.read(), filename=rel)
        except SyntaxError:
            continue                    # REPRO-PARSE reports it
        if rel.startswith(_SRC_PREFIX):
            if (os.path.basename(rel) not in _EXEMPT
                    and not _has_main_guard(tree)):
                seeded[_module_name(rel)] = rel
            imported |= _imports_of(tree, _package_of(rel))
        else:
            imported |= _imports_of(tree, "")
    found = []
    for mod, rel in sorted(seeded.items()):
        if mod in imported:
            continue
        found.append(Finding(
            "REPRO-DEAD-SEED", rel, 1,
            f"module `{mod}` is seeded but never imported from the lint "
            "roots — tracked debt until its roadmap item lands",
            "wire it into its package (or delete it and drop the roadmap "
            "item); baseline it while the item is pending"))
    return found


register(Rule(
    rule_id="REPRO-DEAD-SEED",
    scope="repo",
    description="every src/repro module is imported somewhere under the "
                "lint roots; seeded-but-dead stubs are baselined debt",
    check=check,
    fix_hint="import the module where its roadmap item lands, or delete it",
))
