"""Production mesh builders.

``make_production_mesh`` is the spec-mandated entry: single-pod 16x16
('data','model') or multi-pod 2x16x16 ('pod','data','model'). It is a FUNCTION
(never a module-level constant) so importing this module never touches jax
device state.

``make_byz_mesh`` derives the ByzSGD training view over the *same* devices:
('rep', 'fsdp', 'model') where 'rep' indexes the n_groups co-located
worker/server groups (failure domains — DESIGN.md §Worker granularity) and
'fsdp' the ZeRO-style intra-group shard. Groups are consecutive dp slices, so
for n_groups >= n_pods every group nests inside one pod (DMC crosses pods,
scatter-phase traffic stays intra-pod).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

# --- JAX version compat -----------------------------------------------------
# Newer JAX exposes jax.sharding.AxisType / jax.make_mesh(axis_types=...) /
# jax.set_mesh; the pinned 0.4.x has none of these. All mesh construction and
# ambient-mesh scoping must go through the helpers below so the rest of the
# codebase stays version-agnostic.
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n if _HAS_AXIS_TYPE else None


def compat_make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis_types where supported."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))
    return jax.make_mesh(shape, axes)


def _mk_mesh(devs, names):
    if _HAS_AXIS_TYPE:
        return Mesh(devs, names, axis_types=_auto(len(names)))
    return Mesh(devs, names)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh: jax.set_mesh
    on new JAX, the Mesh resource-env context on 0.4.x (Mesh is its own
    context manager there)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def dp_size(mesh) -> int:
    """Total data-parallel slices R (pod x data)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes["data"]


def model_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def make_byz_mesh(mesh, n_groups: int) -> Mesh:
    """('rep', 'fsdp', 'model') view over the production mesh's devices."""
    R, M = dp_size(mesh), model_size(mesh)
    if R % n_groups:
        raise ValueError(f"n_groups={n_groups} must divide dp slices R={R}")
    K = R // n_groups
    devs = mesh.devices.reshape(n_groups, K, M)
    return _mk_mesh(devs, ("rep", "fsdp", "model"))


def make_protocol_mesh(n_groups: int, devices=None, *,
                       fsdp: int | None = None) -> Mesh:
    """('rep', 'fsdp', 'model') mesh over the *available* devices for a
    G-group protocol run (the ``Experiment.runner="protocol"`` path).

    Unlike :func:`make_byz_mesh` (which carves a production mesh whose dp
    slices must divide into the groups), this places 'rep' on the largest
    divisor of ``n_groups`` that the device count can host — down to a
    1-device (1,1,1) mesh, where all G replica stacks live on one chip and the
    protocol is oracle-checked against the single-host simulator. Devices left
    over after the 'rep' placement become the intra-group 'fsdp' (ZeRO) axis:
    8 devices at G=4 give a (4, 2, 1) mesh — each group's replica + optimizer
    state sharded over its 2 chips. ``fsdp`` overrides the inferred axis size
    (must fit ``rep * fsdp <= len(devices)``)."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if not devices:
        raise ValueError("no jax devices available for the protocol mesh")
    rep = max(d for d in range(1, min(n_groups, len(devices)) + 1)
              if n_groups % d == 0)
    K = len(devices) // rep if fsdp is None else fsdp
    if rep * K > len(devices):
        raise ValueError(f"fsdp={K} needs {rep * K} devices for rep={rep}, "
                         f"have {len(devices)}")
    devs = np.asarray(devices[:rep * K]).reshape(rep, K, 1)
    return _mk_mesh(devs, ("rep", "fsdp", "model"))


def make_serve_mesh(mesh) -> Mesh:
    """('data', 'model') flat view for serving (no replica axis)."""
    R, M = dp_size(mesh), model_size(mesh)
    return _mk_mesh(mesh.devices.reshape(R, M), ("data", "model"))
