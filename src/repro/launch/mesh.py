"""Production mesh builders.

``make_production_mesh`` is the spec-mandated entry: single-pod 16x16
('data','model') or multi-pod 2x16x16 ('pod','data','model'). It is a FUNCTION
(never a module-level constant) so importing this module never touches jax
device state.

``make_byz_mesh`` derives the ByzSGD training view over the *same* devices:
('rep', 'fsdp', 'model') where 'rep' indexes the n_groups co-located
worker/server groups (failure domains — DESIGN.md §Worker granularity) and
'fsdp' the ZeRO-style intra-group shard. Groups are consecutive dp slices, so
for n_groups >= n_pods every group nests inside one pod (DMC crosses pods,
scatter-phase traffic stays intra-pod).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def dp_size(mesh) -> int:
    """Total data-parallel slices R (pod x data)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes["data"]


def model_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def make_byz_mesh(mesh, n_groups: int) -> Mesh:
    """('rep', 'fsdp', 'model') view over the production mesh's devices."""
    R, M = dp_size(mesh), model_size(mesh)
    if R % n_groups:
        raise ValueError(f"n_groups={n_groups} must divide dp slices R={R}")
    K = R // n_groups
    devs = mesh.devices.reshape(n_groups, K, M)
    return Mesh(devs, ("rep", "fsdp", "model"), axis_types=_auto(3))


def make_serve_mesh(mesh) -> Mesh:
    """('data', 'model') flat view for serving (no replica axis)."""
    R, M = dp_size(mesh), model_size(mesh)
    return Mesh(mesh.devices.reshape(R, M), ("data", "model"),
                axis_types=_auto(2))
