"""Post-SPMD HLO analysis: collective traffic + cost extrapolation.

Collective bytes use a ring model on the *per-device* (post-partitioning)
shapes that appear in ``compiled.as_text()``:

    all-gather:          (g-1)/g * output_local_bytes
    all-reduce:          2 (g-1)/g * operand_local_bytes
    reduce-scatter:      (g-1)/g * operand_local_bytes
    all-to-all:          (g-1)/g * operand_local_bytes
    collective-permute:  operand_local_bytes

(g = replica-group size). Summing per-device traffic and dividing by the
per-chip link bandwidth is algebraically the spec's
``collective_bytes / (chips * link_bw)`` with collective_bytes = total traffic.

XLA's cost_analysis does NOT scale loop bodies by trip count (verified
empirically), so per-layer costs come from two depth probes:
    per_layer = (cost(L2) - cost(L1)) / (L2 - L1)
    total(L)  = cost(L1) + per_layer * (L - L1)
— exact for homogeneous layer stacks (all 10 archs; the zamba2 leftover
segment makes this an upper bound within <1%, see DESIGN.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_shapes(line: str):
    """(result_bytes, operand_bytes) from one HLO instruction line."""
    eq = line.find("=")
    op_start = line.find("(", eq)
    res = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line[:op_start]))
    # operands: shapes inside the call parens, before attribute list
    tail = line[op_start:]
    cut = tail.find("), ")
    operand_str = tail[: cut + 1 if cut >= 0 else len(tail)]
    ops = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operand_str))
    return res, ops


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    bytes_per_device: float = 0.0
    counts: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)
    bytes_by_group_size: dict = field(default_factory=dict)

    def add(self, kind, b, g=None):
        self.bytes_per_device += b
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + b
        if g is not None:
            key = str(g)
            self.bytes_by_group_size[key] =                 self.bytes_by_group_size.get(key, 0.0) + b


def collective_traffic(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device ring-model collective bytes from post-SPMD HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        res_b, op_b = _line_shapes(line)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        ring = (g - 1) / g
        if kind == "all-gather":
            b = ring * res_b
        elif kind == "all-reduce":
            b = 2.0 * ring * op_b
        elif kind == "reduce-scatter":
            b = ring * op_b
        elif kind == "all-to-all":
            b = ring * op_b
        else:  # collective-permute
            b = float(op_b)
        stats.add(kind, b, g)
    return stats


def extrapolate(v1: float, v2: float, l1: int, l2: int, total: int) -> float:
    """Two-point linear depth extrapolation."""
    per_layer = (v2 - v1) / max(l2 - l1, 1)
    return v1 + per_layer * (total - l1)


# ---------------------------------------------------------------------------
# donation / buffer-alias auditing (repro.analyze layer 2)
# ---------------------------------------------------------------------------

# compiled.as_text() header entry:
#   input_output_alias={ {0}: (0, {}, may-alias), {1}: (2, {}, must-alias) }
# one `{output_index}: (param_number, param_index, kind)` entry per aliased
# buffer. jax's donate_argnums lowers each donated pytree leaf to one entry
# (CPU included — donation there is may-alias, but the alias table is still
# emitted, which is what makes this statically checkable off-accelerator).
_ALIAS_ENTRY_RE = re.compile(
    r"\{(?P<out>[0-9,\s]*)\}:\s*\(\s*(?P<param>\d+)\s*,\s*"
    r"\{(?P<pidx>[0-9,\s]*)\}\s*,\s*(?P<kind>may-alias|must-alias)\s*\)")


@dataclass(frozen=True)
class AliasEntry:
    output_index: tuple
    param_number: int
    param_index: tuple
    kind: str


def donation_aliases(hlo_text: str) -> list[AliasEntry]:
    """Parse the ``input_output_alias`` table of compiled HLO text.

    Returns one :class:`AliasEntry` per aliased (donated) buffer; an empty
    list means XLA dropped every donation — the repo's donated-scan engines
    treat that as a violation (REPRO-HLO-DONATION)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    # the table is brace-nested: scan to the balanced close
    i = hlo_text.find("{", start)
    depth, j = 0, i
    while j < len(hlo_text):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    block = hlo_text[i:j + 1]
    out = []
    for m in _ALIAS_ENTRY_RE.finditer(block):
        def _tup(s):
            return tuple(int(p) for p in s.split(",") if p.strip())
        out.append(AliasEntry(_tup(m.group("out")), int(m.group("param")),
                              _tup(m.group("pidx")), m.group("kind")))
    return out


def aliased_param_numbers(hlo_text: str) -> set[int]:
    """Parameter numbers covered by the input_output_alias table."""
    return {e.param_number for e in donation_aliases(hlo_text)}
