"""End-to-end ByzSGD training driver (single-host; mesh = available devices).

Features exercised: the distributed protocol (pjit over the ('rep','fsdp',
'model') mesh), deterministic sharded data, checkpoint/restart (crash-safe,
elastic), Byzantine attack injection, DMC cadence, metrics logging.

Examples:
  # 8 fake devices, reduced arch, clean run
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch phi4-mini-3.8b --reduced \
      --steps 100 --groups 4 --mesh 4x2
  # with Byzantine workers
  ... --worker-attack alie --n-byz 1
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import checkpointer as ck
from ..core import protocol
from ..core.attacks import ByzantineSpec
from ..data.pipeline import token_stream
from ..models import sharding as shrules
from ..models.registry import get_bundle
from ..optim.schedules import inverse_linear
from .mesh import compat_make_mesh, make_byz_mesh, use_mesh
from .steps import train_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--groups", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 (data x model)")
    ap.add_argument("--batch-per-group", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--T", type=int, default=10)
    ap.add_argument("--engine", default="sharded")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--worker-attack", default=None)
    ap.add_argument("--server-attack", default=None)
    ap.add_argument("--n-byz", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    n_dev = jax.device_count()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        m = 1
        d = n_dev
    base = compat_make_mesh((d, m), ("data", "model"))
    G = args.groups or d
    bmesh = make_byz_mesh(base, G)

    bundle = get_bundle(args.arch, reduced=args.reduced)
    byz = ByzantineSpec(worker_attack=args.worker_attack,
                        server_attack=args.server_attack,
                        n_byz_workers=args.n_byz if args.worker_attack else 0,
                        n_byz_servers=args.n_byz if args.server_attack else 0)
    pcfg = protocol.ProtocolConfig.derive(d * 1, d // G if G else 1,
                                          T=args.T, engine=args.engine,
                                          byz=byz)
    # derive() computes G from R//divisor; force exact:
    pcfg = protocol.ProtocolConfig(
        n_groups=G, f_workers=max((G - 1) // 3, 0),
        f_servers=max((G - 2) // 3, 0), q_workers=G - max((G - 1) // 3, 0),
        q_servers=max(G - max((G - 2) // 3, 0),
                      min(2 * max((G - 2) // 3, 0) + 2, G)),
        T=args.T, engine=args.engine, byz=byz)

    init = protocol.make_init_fn(bundle, pcfg)
    step = protocol.make_train_step(
        bundle, pcfg, inverse_linear(args.lr, 0.005),
        with_attack=bool(args.worker_attack or args.server_attack),
        mesh=bmesh)
    rules = train_rules(bmesh, bundle.cfg)

    with use_mesh(bmesh):
        shardings = protocol.state_shardings(
            jax.eval_shape(init, jax.random.PRNGKey(0)), bmesh,
            overrides=protocol.attn_overrides(bundle.cfg, bmesh))
        state = jax.jit(init)(jax.random.PRNGKey(0))
        state = jax.tree.map(jax.device_put, state, shardings)

        start = 0
        if args.ckpt_dir:
            latest = ck.latest_step(args.ckpt_dir)
            if latest is not None:
                state, start = ck.restore(args.ckpt_dir, latest, state,
                                          shardings=shardings)
                print(f"[train] restored checkpoint at step {start} "
                      f"(elastic re-shard onto {n_dev} devices)")

        def wrapped(state, batch):
            with shrules.sharding_rules(rules):
                return step(state, batch)

        jstep = jax.jit(wrapped, donate_argnums=0)
        stream = token_stream(0, bundle.cfg.vocab, G, args.batch_per_group,
                              args.seq, args.steps)
        bshard = NamedSharding(bmesh, P("rep"))
        t0 = time.time()
        for i, batch in enumerate(stream):
            if i < start:
                continue
            batch = jax.tree.map(lambda l: jax.device_put(l, bshard), batch)
            state = jstep(state, batch)
            if i % args.log_every == 0:
                p0 = jax.tree.map(lambda l: l[0], state.params)
                with shrules.sharding_rules(rules):
                    loss = float(bundle.loss(
                        p0, jax.tree.map(lambda x: x[0], batch)))
                print(f"[train] step {i:5d} loss {loss:8.4f} "
                      f"({time.time()-t0:.1f}s)")
            if args.ckpt_dir and i > 0 and i % args.ckpt_every == 0:
                ck.save(args.ckpt_dir, i, state)
                print(f"[train] checkpoint @ {i}")
        if args.ckpt_dir:
            ck.save(args.ckpt_dir, args.steps, state)
        p0 = protocol.consolidate(state.params, pcfg)
        n = sum(l.size for l in jax.tree.leaves(p0))
        print(f"[train] done: {args.steps} steps, {n/1e6:.1f}M params, "
              f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
