"""Step builders: (arch x shape x mesh) -> jit-able fn + fully-specified specs.

Used by launch/dryrun.py (ShapeDtypeStruct lowering — no allocation) and by
launch/train.py / launch/serve.py (real execution). All sharding decisions live
here and in core/protocol.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.shapes import ShapeCell
from ..core import protocol
from ..models import sharding as shrules
from ..models.registry import ModelBundle, get_bundle
from ..optim.schedules import inverse_linear
from . import mesh as meshlib


# ---------------------------------------------------------------------------
# activation sharding rules for the model-internal constraints
# ---------------------------------------------------------------------------

def train_rules(bmesh, cfg):
    """Logical-name -> NamedSharding for the ByzSGD train mesh. The leading
    vmap (worker) axis prepends a 'rep' dim to every activation."""
    M = dict(zip(bmesh.axis_names, bmesh.devices.shape))["model"]
    r = {}
    def ns(*spec):
        return NamedSharding(bmesh, P(*spec))
    # NOTE: these apply INSIDE the per-worker vmap (spmd_axis_name='rep'
    # prepends the replica axis automatically), so specs are rank-matched to
    # the unbatched activations.
    # The residual stream shards its FEATURE dim over 'model' (d_model is
    # divisible by 16 for all 10 archs): the per-layer remat-saved scan
    # carries shrink 16x, and the qkv/ffn input projections contract the
    # sharded dim (partial matmul + reduce) without layout churn.
    # REPRO_RESID_REPLICATED=1 keeps the residual replicated over 'model'
    # instead (-10% collective bytes on the hillclimbed cell, +16x carry
    # memory — affordable post-micro-batching; §Perf iteration 12).
    import os as _os
    if _os.environ.get("REPRO_RESID_REPLICATED") == "1":
        r["act_btd"] = ns("fsdp", None, None)
    else:
        r["act_btd"] = ns("fsdp", None, "model")
    r["logits"] = ns("fsdp", None, "model")
    if cfg.n_heads % M == 0:
        r["act_heads"] = ns("fsdp", None, "model", None)
    if cfg.n_kv_heads % M == 0:
        r["act_kv_heads"] = ns("fsdp", None, "model", None)
    if cfg.n_experts and cfg.d_ff % M == 0:
        # TP-within-expert: F over 'model', matching the replica-state COL/ROW
        # layout; dispatch activations take D over 'fsdp' so the e,c,d x e,d,f
        # contraction is shard-aligned on BOTH sides (mismatch here made XLA
        # hoist full-stack expert-weight gathers: 150+ GiB on qwen3).
        r["expert_w_in"] = ns(None, "fsdp", "model")
        r["expert_w_out"] = ns(None, "model", "fsdp")
        r["expert_tokens"] = ns(None, None, "fsdp")
    r["kv_cache"] = ns("fsdp", None, "model", None, None)
    return r


def serve_rules(smesh, cfg):
    M = dict(zip(smesh.axis_names, smesh.devices.shape))["model"]
    def ns(*spec):
        return NamedSharding(smesh, P(*spec))
    r = {}
    r["act_btd"] = ns("data", None, None)
    r["logits"] = ns("data", None, "model")
    if cfg.n_heads % M == 0:
        r["act_heads"] = ns("data", None, "model", None)
    if cfg.n_kv_heads % M == 0:
        r["act_kv_heads"] = ns("data", None, "model", None)
    if cfg.n_experts and cfg.d_ff % M == 0:
        r["expert_w_in"] = ns(None, None, "model")
        r["expert_w_out"] = ns(None, "model", None)
        r["expert_tokens"] = ns(None, "data", None)  # capacity over 'data'
    r["kv_cache"] = ns("data", None, "model", None, None)
    return r


# ---------------------------------------------------------------------------
# serving param / cache specs
# ---------------------------------------------------------------------------

def serve_param_sharding(shapes_tree, smesh, cfg):
    """Consolidated-model sharding for serving: 'model' on TP dims; big models
    additionally ZeRO-shard over 'data' (per-layer gather at use)."""
    sizes = dict(zip(smesh.axis_names, smesh.devices.shape))
    M, Dax = sizes["model"], sizes["data"]
    total_bytes = sum(l.size * jnp.dtype(l.dtype).itemsize
                      for l in jax.tree.leaves(shapes_tree))
    shard_data = (total_bytes / M) > 4 * 2**30  # >4GB/chip after TP -> ZeRO

    def one(leaf):
        if leaf.ndim == 0 or leaf.size <= 2:
            return NamedSharding(smesh, P())
        body = list(leaf.shape)
        spec: list = [None] * len(body)
        order = sorted(range(len(body)), key=lambda i: -body[i])
        m_at = next((i for i in order if body[i] % M == 0 and body[i] >= M), None)
        if m_at is not None:
            spec[m_at] = "model"
        if shard_data:
            d_at = next((i for i in order
                         if i != m_at and body[i] % Dax == 0 and body[i] >= Dax),
                        None)
            if d_at is not None:
                spec[d_at] = "data"
        return NamedSharding(smesh, P(*spec))

    return jax.tree.map(one, shapes_tree)


def cache_sharding(cache_shapes, smesh):
    """KV caches: batch over 'data', chunk axis over 'model' (flash-decode).
    SSM/conv states: batch over 'data', largest divisible dim over 'model'."""
    sizes = dict(zip(smesh.axis_names, smesh.devices.shape))
    M, Dax = sizes["model"], sizes["data"]

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(smesh, P())
        body = list(leaf.shape)
        spec: list = [None] * len(body)
        if leaf.ndim == 6:  # stacked KVCache k/v: [L, B, kvH, nc, chunk, hd]
            if body[1] % Dax == 0 and body[1] >= Dax:
                spec[1] = "data"
            if body[3] % M == 0 and body[3] >= M:
                spec[3] = "model"
            return NamedSharding(smesh, P(*spec))
        if leaf.ndim == 1:  # lengths [L]
            return NamedSharding(smesh, P())
        # generic state: dim1 = batch -> data; largest other -> model
        if len(body) > 1 and body[1] % Dax == 0 and body[1] >= Dax:
            spec[1] = "data"
        order = sorted(range(len(body)), key=lambda i: -body[i])
        m_at = next((i for i in order
                     if spec[i] is None and i != 0 and body[i] % M == 0
                     and body[i] >= M), None)
        if m_at is not None:
            spec[m_at] = "model"
        return NamedSharding(smesh, P(*spec))

    return jax.tree.map(one, cache_shapes)


def _batch_sharding(name, sds, smesh):
    """'data' on the batch dim when divisible (long_500k B=1 stays replicated —
    a single-replica workload, noted in the roofline)."""
    Dax = dict(zip(smesh.axis_names, smesh.devices.shape))["data"]
    bdim = 1 if name == "positions" else 0
    spec = [None] * len(sds.shape)
    if sds.shape[bdim] % Dax == 0 and sds.shape[bdim] >= Dax:
        spec[bdim] = "data"
    return NamedSharding(smesh, P(*spec))


def _with_sharding(sds_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, sharding_tree)


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

@dataclass
class BuiltCell:
    fn: Callable               # jit-able step function
    in_specs: tuple            # ShapeDtypeStructs (with shardings) for .lower()
    mesh: Any                  # mesh to enter while lowering
    rules: dict                # activation sharding rules context
    meta: dict


def build_train_cell(arch: str, cell: ShapeCell, prod_mesh, *,
                     engine: str = "naive", exchange_dtype: str = "float32",
                     reduced: bool = False, T: int = 50, depth: int | None = None,
                     pull: str = "median",
                     include_gather: bool = False) -> BuiltCell:
    bundle = get_bundle(arch, reduced=reduced, depth=depth)
    cfg = bundle.cfg
    R = meshlib.dp_size(prod_mesh)
    G0 = R // cfg.byz_group_divisor
    if cfg.byz_group_cap:
        G0 = min(G0, cfg.byz_group_cap)
    B, S = cell.global_batch, cell.seq_len
    # micro-batching: bound per-worker tokens per fwd/bwd at ~16k
    per_group = B // G0
    K = R // G0  # fsdp axis size — the micro-batch must stay K-shardable
    n_micro = max(1, min(per_group, (per_group * S) // 8192))
    n_micro = min(n_micro, max(per_group // max(K, 1), 1))
    while per_group % n_micro or (per_group // n_micro) % max(K, 1):
        n_micro -= 1
    pcfg = protocol.ProtocolConfig.derive(
        R, R // G0, T=T, engine=engine, pull=pull,
        exchange_dtype=exchange_dtype, grad_microbatches=n_micro)
    bmesh = meshlib.make_byz_mesh(prod_mesh, pcfg.n_groups)
    G = pcfg.n_groups
    assert B % G == 0, (arch, cell.name, B, G)

    init = protocol.make_init_fn(bundle, pcfg)
    state_shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    state_shard = protocol.state_shardings(
        state_shapes, bmesh, overrides=protocol.attn_overrides(cfg, bmesh))
    state_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_shapes, state_shard)

    batch_specs = bundle.batch_specs("train", B, S)
    nm = pcfg.grad_microbatches

    def group_split(sds):
        b_m = sds.shape[0] // G // nm
        shape = (G, b_m) + sds.shape[1:]
        spec = ("rep", "fsdp") + (None,) * (len(sds.shape) - 1)
        if nm > 1:
            shape = (nm,) + shape
            spec = (None,) + spec
        return jax.ShapeDtypeStruct(shape, sds.dtype,
                                    sharding=NamedSharding(bmesh, P(*spec)))

    # leading-dim exception: vlm positions [3, B, S] -> [(nm,) 3, G, b, S]
    def split_one(name, sds):
        if name == "positions" and sds.shape[0] == 3:
            b_m = sds.shape[1] // G // nm
            shape = (3, G, b_m) + sds.shape[2:]
            spec = (None, "rep", "fsdp") + (None,) * (len(sds.shape) - 2)
            if nm > 1:
                shape = (nm,) + shape
                spec = (None,) + spec
            return jax.ShapeDtypeStruct(shape, sds.dtype,
                                        sharding=NamedSharding(bmesh, P(*spec)))
        return group_split(sds)

    gbatch = {k: split_one(k, v) for k, v in batch_specs.items()}

    rules = train_rules(bmesh, cfg)
    if cfg.family == "vlm":
        # batch carries positions [3, G, B/G, S]; model expects [3, b, S] per
        # worker — handled by the wrapper below.
        pass

    step_builder = protocol.make_train_step if include_gather else \
        protocol.make_scatter_step
    raw_step = step_builder(bundle, pcfg, inverse_linear(0.05, 0.01), mesh=bmesh)

    def step(state, batch):
        if "positions" in batch:
            batch = dict(batch)
            ax = 0 if pcfg.grad_microbatches == 1 else 1
            # [.., 3, G, b, S] -> [.., G, 3, b, S] so the worker vmap maps G
            batch["positions"] = jnp.moveaxis(batch["positions"], ax, ax + 1)
        with shrules.sharding_rules(rules):
            return raw_step(state, batch)

    return BuiltCell(fn=step, in_specs=(state_sds, gbatch), mesh=bmesh,
                     rules=rules,
                     meta={"arch": arch, "cell": cell.name, "kind": "train",
                           "G": G, "pcfg": pcfg, "bundle": bundle})


def build_gather_cell(arch: str, cell: ShapeCell, prod_mesh, *,
                      engine: str = "naive", reduced: bool = False,
                      depth: int | None = None) -> BuiltCell:
    """DMC gather step alone (amortised 1/T in the roofline)."""
    bundle = get_bundle(arch, reduced=reduced, depth=depth)
    cfg = bundle.cfg
    R = meshlib.dp_size(prod_mesh)
    G0 = meshlib.dp_size(prod_mesh) // cfg.byz_group_divisor
    if cfg.byz_group_cap:
        G0 = min(G0, cfg.byz_group_cap)
    pcfg = protocol.ProtocolConfig.derive(R, R // G0, engine=engine)
    bmesh = meshlib.make_byz_mesh(prod_mesh, pcfg.n_groups)
    init = protocol.make_init_fn(bundle, pcfg)
    state_shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    state_shard = protocol.state_shardings(
        state_shapes, bmesh, overrides=protocol.attn_overrides(cfg, bmesh))
    state_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_shapes, state_shard)
    raw = protocol.make_gather_step(pcfg, mesh=bmesh)
    return BuiltCell(fn=raw, in_specs=(state_sds,), mesh=bmesh, rules={},
                     meta={"arch": arch, "cell": cell.name, "kind": "gather",
                           "G": pcfg.n_groups, "pcfg": pcfg, "bundle": bundle})


def _serve_params_specs(bundle, smesh):
    p_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    act = jnp.dtype(bundle.cfg.param_dtype)
    p_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), p_shapes)
    shard = serve_param_sharding(p_shapes, smesh, bundle.cfg)
    return _with_sharding(p_shapes, shard)


def build_prefill_cell(arch: str, cell: ShapeCell, prod_mesh, *,
                       reduced: bool = False, depth: int | None = None) -> BuiltCell:
    bundle = get_bundle(arch, reduced=reduced, depth=depth)
    cfg = bundle.cfg
    smesh = meshlib.make_serve_mesh(prod_mesh)
    M = meshlib.model_size(prod_mesh)
    B, S = cell.global_batch, cell.seq_len
    params_sds = _serve_params_specs(bundle, smesh)
    caches_shapes = jax.eval_shape(
        lambda: bundle.init_caches(B, max_len=S, n_chunks=M))
    caches_sds = _with_sharding(caches_shapes, cache_sharding(caches_shapes, smesh))
    batch = bundle.batch_specs("prefill", B, S)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=_batch_sharding(k, v, smesh))
        for k, v in batch.items()}
    rules = serve_rules(smesh, cfg)

    def fn(params, batch, caches):
        with shrules.sharding_rules(rules):
            return bundle.prefill(params, batch, caches)

    return BuiltCell(fn=fn, in_specs=(params_sds, batch_sds, caches_sds),
                     mesh=smesh, rules=rules,
                     meta={"arch": arch, "cell": cell.name, "kind": "prefill",
                           "bundle": bundle})


def build_decode_cell(arch: str, cell: ShapeCell, prod_mesh, *,
                      reduced: bool = False, depth: int | None = None) -> BuiltCell:
    bundle = get_bundle(arch, reduced=reduced, depth=depth)
    cfg = bundle.cfg
    smesh = meshlib.make_serve_mesh(prod_mesh)
    M = meshlib.model_size(prod_mesh)
    B, S = cell.global_batch, cell.seq_len
    params_sds = _serve_params_specs(bundle, smesh)
    caches_shapes = jax.eval_shape(
        lambda: bundle.init_caches(B, max_len=S, n_chunks=M))
    caches_sds = _with_sharding(caches_shapes, cache_sharding(caches_shapes, smesh))
    batch = bundle.batch_specs("decode", B, S)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=_batch_sharding(k, v, smesh))
        for k, v in batch.items()}
    rules = serve_rules(smesh, cfg)

    def fn(params, caches, batch):
        with shrules.sharding_rules(rules):
            return bundle.decode(params, caches, batch)

    return BuiltCell(fn=fn, in_specs=(params_sds, caches_sds, batch_sds),
                     mesh=smesh, rules=rules,
                     meta={"arch": arch, "cell": cell.name, "kind": "decode",
                           "bundle": bundle})


def build_cell(arch: str, cell: ShapeCell, prod_mesh, **kw) -> BuiltCell:
    if cell.kind == "train":
        return build_train_cell(arch, cell, prod_mesh, **kw)
    if cell.kind == "prefill":
        kw.pop("engine", None); kw.pop("exchange_dtype", None); kw.pop("pull", None)
        return build_prefill_cell(arch, cell, prod_mesh, **kw)
    if cell.kind == "decode":
        kw.pop("engine", None); kw.pop("exchange_dtype", None); kw.pop("pull", None)
        return build_decode_cell(arch, cell, prod_mesh, **kw)
    raise ValueError(cell.kind)
