"""Batched serving driver: prefill + decode over a ByzSGD-trained model.

Three model sources, by flag:

  * default — fresh init, vanilla DP x TP single-model serving;
  * ``--ckpt-dir`` — restore a replica-stacked ByzSGD checkpoint and
    median-consolidate it to one model (a Byzantine-suspect replica is
    outvoted at load time — checkpoint/checkpointer.py semantics);
  * ``--ckpt-dir --quorum`` — keep ALL restored replicas live and serve
    through :class:`repro.serve.QuorumService`: every token is a quorum
    read, so up to f Byzantine replicas cannot corrupt a continuation.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch phi4-mini-3.8b --reduced \
      --batch 4 --prefill 64 --decode 32 --mesh 4x2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..models import sharding as shrules
from ..models.registry import get_bundle
from .mesh import compat_make_mesh, make_serve_mesh, use_mesh
from .steps import serve_rules


def _serve_quorum(args, bundle, pool, rules):
    """--quorum path: all restored replicas live, every token a quorum read."""
    from ..serve import QuorumService
    B, S = args.batch, args.prefill
    svc = QuorumService(pool, bundle, n_slots=B,
                        max_len=S + args.decode + 1, rules=rules)
    pf = bundle.make_batch("prefill", B, S, jax.random.PRNGKey(1))
    prompts = [row.tolist() for row in jax.device_get(pf["tokens"])]
    t0 = time.time()
    outs = svc.generate(prompts, max_new=args.decode)
    wall = time.time() - t0
    rep = svc.report()
    print(f"[serve] quorum ({rep['rule']}): {rep['committed_tokens']} tokens "
          f"across {rep['n_replicas']} replicas (f={rep['f']}, "
          f"{rep['n_active']} active) in {wall:.2f}s "
          f"({rep['tok_s']:.1f} tok/s) | disagreement "
          f"{rep['disagreement_rate']:.4f} | ejections {rep['ejections']} | "
          f"retries {rep['retries']}")
    print(f"[serve] sample continuation ids: {outs[0][:10]}")
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill", type=int, default=64)
    ap.add_argument("--decode", type=int, default=32)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore + median-consolidate a ByzSGD checkpoint")
    ap.add_argument("--quorum", action="store_true",
                    help="with --ckpt-dir: serve every restored replica "
                         "behind quorum reads instead of consolidating")
    args = ap.parse_args(argv)

    n_dev = jax.device_count()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n_dev, 1
    base = compat_make_mesh((d, m), ("data", "model"))
    smesh = make_serve_mesh(base)

    bundle = get_bundle(args.arch, reduced=args.reduced)
    rules = serve_rules(smesh, bundle.cfg)

    with use_mesh(smesh):
        pool = None
        if args.ckpt_dir:
            from ..serve import ReplicaPool, checkpoint_groups
            step, R = checkpoint_groups(args.ckpt_dir)
            f = (R - 1) // 3   # the protocol's server tolerance for R groups
            pool = ReplicaPool.from_checkpoint(args.ckpt_dir, bundle.init,
                                               step=step, f=f)
            print(f"[serve] restored step {step}: {R} replicas (f={f}) "
                  f"from {args.ckpt_dir}")
            if args.quorum:
                return _serve_quorum(args, bundle, pool, rules)
            params = pool.consolidated()
            print("[serve] median-consolidated to one serving model")
        else:
            params = bundle.init(jax.random.PRNGKey(0))
        params = jax.tree.map(lambda l: l.astype(jnp.bfloat16)
                              if l.dtype == jnp.float32 else l, params)

        B, S = args.batch, args.prefill
        max_len = S + args.decode + 1
        caches = bundle.init_caches(B, max_len=max_len, n_chunks=max(m, 1))
        pf = bundle.make_batch("prefill", B, S, jax.random.PRNGKey(1))

        def prefill_fn(p, b, c):
            with shrules.sharding_rules(rules):
                return bundle.prefill(p, b, c)

        def decode_fn(p, c, b):
            with shrules.sharding_rules(rules):
                return bundle.decode(p, c, b)

        jprefill = jax.jit(prefill_fn)
        jdecode = jax.jit(decode_fn, donate_argnums=1)

        t0 = time.time()
        logits, caches = jprefill(params, pf, caches)
        logits.block_until_ready()
        t_pf = time.time() - t0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.decode):
            batch = {"token": tok}
            if bundle.cfg.family == "vlm":
                batch = {"embeds": pf["embeds"][:, -1:],
                         "positions": pf["positions"][:, :, -1:] + i + 1}
            logits, caches = jdecode(params, caches, batch)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0
        total = B * args.decode
        print(f"[serve] {args.arch}: prefill {B}x{S} in {t_pf:.2f}s | "
              f"decode {args.decode} steps x batch {B} = {total} tokens in "
              f"{t_dec:.2f}s ({total / max(t_dec, 1e-9):.1f} tok/s on "
              f"{n_dev} host devices)")
        sample = jnp.concatenate(out_tokens, axis=1)[0, :10]
        print(f"[serve] sample continuation ids: {sample.tolist()}")


if __name__ == "__main__":
    main()
