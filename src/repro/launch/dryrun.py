import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. FULL-depth compile on the production mesh — proves the sharding config is
     coherent and the program fits (memory_analysis).
  2. Two shallow depth-probe compiles — exact per-layer cost extrapolation for
     HLO FLOPs / bytes / collective traffic (XLA's cost_analysis does not
     scale loop bodies by trip count; see hlo_analysis.py).
Results are cached as JSON under results/dryrun/<mesh>/ and consumed by
launch/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k \
      --multi-pod --engine naive
"""
import argparse
import json
import time
import traceback

import jax

from ..configs.shapes import SHAPES, SHAPE_ORDER
from ..models.registry import ARCH_IDS, get_bundle
from . import hlo_analysis as H
from .mesh import make_production_mesh, use_mesh
from .steps import build_cell, build_gather_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

PROBE_DEPTHS = {
    # (L1, L2): multiples of the hybrid segment for zamba2; pairs for whisper
    "zamba2-1.2b": (6, 12),
    "default": (2, 4),
}


def probe_depths(arch: str, full_layers: int):
    l1, l2 = PROBE_DEPTHS.get(arch, PROBE_DEPTHS["default"])
    if full_layers <= l2:
        return None  # tiny model: full compile is exact enough
    return l1, l2


def lower_compile(cell, unroll: bool = False):
    from repro.models import unroll_ctx
    donate = {"train": (0,), "gather": (0,), "prefill": (2,), "decode": (1,)}[
        cell.meta["kind"]]
    with use_mesh(cell.mesh):
        with unroll_ctx.unrolled(unroll):
            lowered = jax.jit(cell.fn, donate_argnums=donate).lower(*cell.in_specs)
        compiled = lowered.compile()
    return lowered, compiled


def measure(cell, n_devices: int, unroll: bool = False):
    t0 = time.time()
    lowered, compiled = lower_compile(cell, unroll)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = H.collective_traffic(txt, n_devices)
    return {
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll.bytes_per_device,
        "collective_counts": coll.counts,
        "collective_bytes_by_kind": coll.bytes_by_kind,
        "collective_bytes_by_group_size": coll.bytes_by_group_size,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, engine: str,
             include_gather: bool, exchange_dtype: str = "float32",
             pull: str = "median", probes: bool = True) -> dict:
    cell_cfg = SHAPES[shape_name]
    bundle = get_bundle(arch)
    ok, why = bundle.supports_cell(shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    kw = dict(engine=engine, exchange_dtype=exchange_dtype, pull=pull) \
        if cell_cfg.kind == "train" else {}

    out = {"arch": arch, "shape": shape_name, "kind": cell_cfg.kind,
           "mesh": "2x16x16" if multi_pod else "16x16", "engine": engine,
           "n_devices": n_dev, "layers": bundle.cfg.n_layers}

    # 1. full-depth compile (fit proof)
    cell = build_cell(arch, cell_cfg, mesh, **kw)
    out["full"] = measure(cell, n_dev)
    if cell_cfg.kind == "train":
        out["n_groups"] = cell.meta["G"]

    # 2. depth probes for loop-corrected cost
    pd = probe_depths(arch, bundle.cfg.n_layers)
    if probes and pd is not None:
        l1, l2 = pd
        m1 = measure(build_cell(arch, cell_cfg, mesh, depth=l1, **kw), n_dev,
                     unroll=True)
        m2 = measure(build_cell(arch, cell_cfg, mesh, depth=l2, **kw), n_dev,
                     unroll=True)
        L = bundle.cfg.n_layers
        out["probes"] = {"depths": [l1, l2], "m1": m1, "m2": m2}
        out["extrapolated"] = {
            k: H.extrapolate(m1[k], m2[k], l1, l2, L)
            for k in ("flops", "bytes_accessed", "collective_bytes_per_device")}
    else:
        out["extrapolated"] = {
            k: out["full"][k]
            for k in ("flops", "bytes_accessed", "collective_bytes_per_device")}

    # 3. DMC gather step (train cells only; amortised 1/T)
    if cell_cfg.kind == "train" and include_gather:
        gcell = build_gather_cell(arch, cell_cfg, mesh, engine=engine)
        out["gather"] = measure(gcell, n_dev)
    return out


def result_path(arch, shape, multi_pod, engine, tag=""):
    d = os.path.join(RESULTS_DIR, "2x16x16" if multi_pod else "16x16")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}__{engine}{tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--engine", default="naive", choices=["naive", "sharded"])
    ap.add_argument("--exchange-dtype", default="float32")
    ap.add_argument("--pull", default="median", choices=["median", "roundrobin"])
    ap.add_argument("--gather", action="store_true", default=True)
    ap.add_argument("--no-gather", dest="gather", action="store_false")
    ap.add_argument("--no-probes", dest="probes", action="store_false")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = SHAPE_ORDER if args.shape == "all" else args.shape.split(",")

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            tag = ""
            if args.pull != "median":
                tag += f"__{args.pull}"
            if args.exchange_dtype != "float32":
                tag += f"__{args.exchange_dtype}"
            path = result_path(arch, shape, args.multi_pod, args.engine, tag)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {arch} x {shape}")
                n_ok += 1
                continue
            t0 = time.time()
            try:
                res = run_cell(arch, shape, multi_pod=args.multi_pod,
                               engine=args.engine,
                               include_gather=args.gather,
                               exchange_dtype=args.exchange_dtype,
                               pull=args.pull, probes=args.probes)
            except Exception as e:  # noqa: BLE001 - report and continue
                res = {"arch": arch, "shape": shape, "error": str(e),
                       "traceback": traceback.format_exc()}
                n_fail += 1
                print(f"[FAIL]   {arch} x {shape}: {e}")
                with open(path + ".err", "w") as f:
                    json.dump(res, f, indent=1)
                continue
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if "skipped" in res:
                n_skip += 1
                print(f"[skip]   {arch} x {shape}: {res['skipped']}")
            else:
                n_ok += 1
                mem = res["full"]["memory"]
                per_dev = (mem["argument_bytes"] + mem["temp_bytes"]
                           + mem["output_bytes"] - mem["alias_bytes"])
                print(f"[ok]     {arch} x {shape} ({res['mesh']}, "
                      f"{args.engine}): flops={res['extrapolated']['flops']:.3e} "
                      f"coll={res['extrapolated']['collective_bytes_per_device']:.3e}B "
                      f"mem/dev={per_dev/2**30:.2f}GiB "
                      f"({time.time()-t0:.0f}s)")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
