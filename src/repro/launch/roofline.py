"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. cost_analysis() values are PER-DEVICE (verified
empirically), so:

    compute term    = HLO_FLOPs_per_device / 197e12              [s]
    memory term     = HLO_bytes_per_device / 819e9               [s]
    collective term = ring-model collective bytes per device / 50e9 [s]

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step across the whole
job; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/dispatch/
protocol overhead. DMC gather terms are amortised by 1/T.
"""
from __future__ import annotations

import json
import os

from ..configs.shapes import SHAPES
from ..models.registry import ARCH_IDS, get_bundle

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def param_counts(arch: str) -> tuple[float, float]:
    """(total params N, active params N_active)."""
    import jax
    bundle = get_bundle(arch)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    total = sum(l.size for l in jax.tree.leaves(shapes))
    cfg = bundle.cfg
    if cfg.n_experts:
        # active = total - (unused experts' share of MoE weights)
        E, K = cfg.n_experts, cfg.top_k
        moe = cfg.n_layers * E * 3 * cfg.d_model * cfg.d_ff
        active = total - moe * (1 - K / E)
        return float(total), float(active)
    return float(total), float(total)


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*tokens for train; 2*N_active*tokens for prefill/decode."""
    cell = SHAPES[shape_name]
    _, n_active = param_counts(arch)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        if arch == "whisper-small":  # enc S/2 + dec S/2 tokens
            tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def load_cell(arch: str, shape: str, mesh: str = "16x16",
              engine: str = "naive") -> dict | None:
    p = os.path.join(RESULTS_DIR, mesh, f"{arch}__{shape}__{engine}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def roofline_row(arch: str, shape: str, mesh: str = "16x16",
                 engine: str = "naive") -> dict | None:
    res = load_cell(arch, shape, mesh, engine)
    if res is None or "skipped" in res or "error" in res:
        return {"arch": arch, "shape": shape,
                "skipped": res.get("skipped") if res else "missing"}
    ex = res["extrapolated"]
    chips = res["n_devices"]
    t_comp = ex["flops"] / PEAK_FLOPS
    t_mem = ex["bytes_accessed"] / HBM_BW
    t_coll = ex["collective_bytes_per_device"] / LINK_BW
    # amortised DMC gather
    g = res.get("gather")
    T = 50
    if g:
        t_comp += g["flops"] / PEAK_FLOPS / T
        t_mem += g["bytes_accessed"] / HBM_BW / T
        t_coll += g["collective_bytes_per_device"] / LINK_BW / T
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(arch, shape)
    hlo_total = ex["flops"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model flops per second at the bound, vs peak
    step_time = bound
    mfu = mf / (step_time * chips * PEAK_FLOPS) if step_time > 0 else 0.0
    mem = res["full"]["memory"]
    per_dev_gib = (mem["argument_bytes"] + mem["temp_bytes"]
                   + mem["output_bytes"] - mem["alias_bytes"]) / 2**30
    lever = _lever(arch, res["kind"], dom)
    return {"arch": arch, "shape": shape, "mesh": mesh, "engine": engine,
            "lever": lever,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom,
            "est_step_s": step_time, "model_flops": mf,
            "useful_flops_ratio": useful, "roofline_fraction": mfu,
            "mem_per_dev_gib": per_dev_gib,
            "n_groups": res.get("n_groups")}


def _lever(arch: str, kind: str, dominant: str) -> str:
    """One sentence per cell: what would move the dominant term down."""
    cfg = get_bundle(arch).cfg
    if dominant == "collective":
        if kind == "train" and cfg.n_experts:
            return ("true all-to-all EP dispatch: the TP-in-expert down-proj "
                    "psum carries the 1.25*K capacity expansion (est 2-3x)")
        if kind == "train":
            return ("~19% is protocol traffic (sync round-robin pull cuts it "
                    "34%); the rest is TP activation traffic — COL-qkv once "
                    "the Shardy partitioner lands (est -40%)")
        return ("flash-decode already shards the cache; batch the requests "
                "deeper per chip or shrink TP for serve meshes")
    if dominant == "memory":
        if kind in ("train", "prefill") and not cfg.subquadratic:
            return ("fused Pallas flash attention keeps the S^2 scores in "
                    "VMEM (kernels/flash_attention, wired on TPU backend)")
        if kind == "decode":
            return ("int8 KV-cache quantisation halves cache streaming; "
                    "decode is cache-bandwidth-bound by nature")
        return ("larger per-chip microbatch amortises parameter streaming "
                "(state-based decode already has O(1) state)")
    return ("raise per-chip arithmetic intensity: bigger microbatch, less "
            "remat recompute (useful-flops ratio shows the headroom)")


def full_table(mesh: str = "16x16", engine: str = "naive"):
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            rows.append(roofline_row(arch, shape, mesh, engine))
    return [r for r in rows if r]


def format_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'MFU':>6s} {'useful':>7s} "
           f"{'GiB/dev':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']:24s} {r['shape']:12s} SKIP: {r['skipped']}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
            f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
            f"{r['dominant']:>10s} {r['roofline_fraction']:6.1%} "
            f"{r['useful_flops_ratio']:7.2f} {r['mem_per_dev_gib']:8.2f}")
        lines.append(f"{'':37s} -> {r['lever']}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--engine", default="naive")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = full_table(args.mesh, args.engine)
    print(format_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
