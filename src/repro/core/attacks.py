"""Byzantine attack library.

Gradient attacks (Byzantine *workers*) and model attacks (Byzantine *servers*),
matching the adversarial behaviours evaluated in the paper (§6 + Fig. 5/6):

  workers: reversed gradients, random, ALIE ("a little is enough", Baruch et
           al. 2019 — the paper's headline worker attack), sign-flip, zero.
  servers: Reversed, Partial Drop (10% weights zeroed), Random, LIE
           (per-weight multiplicative z, |z-1| small; z = 1.035 in the paper).

Every attack maps the *honest* stack [h, d] (what the adversary can observe —
the paper assumes an omniscient adversary) to a Byzantine payload. The
``equivocate`` wrapper yields per-destination payloads (a Byzantine node may
send different vectors to different receivers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from statistics import NormalDist


def reversed_attack(honest: jax.Array, key: jax.Array, *, scale: float = 1.0) -> jax.Array:
    """Send -scale * mean(honest): the classic divergence attack."""
    del key
    return -scale * jnp.mean(honest, axis=0)


def random_attack(honest: jax.Array, key: jax.Array, *, scale: float | None = None) -> jax.Array:
    """Replace the payload by Gaussian noise matched (by default) to the honest norm."""
    m = jnp.mean(honest, axis=0)
    s = jnp.linalg.norm(m) / jnp.sqrt(m.size) if scale is None else scale
    return s * jax.random.normal(key, m.shape, m.dtype)

def partial_drop_attack(honest: jax.Array, key: jax.Array, *, drop: float = 0.1) -> jax.Array:
    """Zero a random ``drop`` fraction of coordinates (paper: unreliable transport)."""
    m = jnp.mean(honest, axis=0)
    keep = jax.random.bernoulli(key, 1.0 - drop, m.shape)
    return m * keep


def lie_attack(honest: jax.Array, key: jax.Array, *, z: float = 1.035) -> jax.Array:
    """Server LIE: multiply each weight by z with |z-1| ~ 0 (paper Fig. 5)."""
    del key
    return z * jnp.mean(honest, axis=0)


def sign_flip_attack(honest: jax.Array, key: jax.Array, *, scale: float = 1.0) -> jax.Array:
    del key
    return -scale * jnp.sign(jnp.mean(honest, axis=0)) * jnp.mean(jnp.abs(honest), axis=0)


def zero_attack(honest: jax.Array, key: jax.Array) -> jax.Array:
    del key
    return jnp.zeros(honest.shape[1:], honest.dtype)


def alie_zmax(n: int, f: int) -> float:
    """ALIE amplitude: z s.t. the shifted vector still looks like a majority
    member. phi^-1((n - floor(n/2+1)) / (n - f)) per Baruch et al. 2019.
    Pure-Python (stdlib) so it stays a static constant under jit tracing."""
    s = (n // 2) + 1 - f
    frac = (n - f - s) / (n - f)
    frac = min(max(frac, 1e-6), 1 - 1e-6)
    return float(NormalDist().inv_cdf(frac))


def alie_attack(honest: jax.Array, key: jax.Array, *, n: int, f: int,
                z: float | None = None) -> jax.Array:
    """A-Little-Is-Enough: mean + z_max * per-coordinate std of honest inputs.

    The paper applies "the strongest possible change in gradients' coordinates"
    (§6, Byzantine workers) — this is that attack.
    """
    del key
    zv = alie_zmax(n, f) if z is None else z
    mu = jnp.mean(honest, axis=0)
    sd = jnp.std(honest, axis=0)
    return mu + zv * sd


GRADIENT_ATTACKS: dict[str, Callable] = {
    "reversed": reversed_attack,
    "random": random_attack,
    "alie": alie_attack,
    "sign_flip": sign_flip_attack,
    "zero": zero_attack,
}

MODEL_ATTACKS: dict[str, Callable] = {
    "reversed": reversed_attack,
    "partial_drop": partial_drop_attack,
    "random": random_attack,
    "lie": lie_attack,
}


@dataclass(frozen=True)
class ByzantineSpec:
    """Which slices are Byzantine and how they attack.

    ``n_byz_workers``/``n_byz_servers`` actual adversaries (<= declared f).
    Worker indices [n_w - n_byz_w, n_w) and server indices [n_ps - n_byz_s, n_ps)
    are Byzantine (w.l.o.g., as in the paper's notation §B.1).
    """
    worker_attack: str | None = None
    server_attack: str | None = None
    n_byz_workers: int = 0
    n_byz_servers: int = 0
    equivocate: bool = False  # per-destination payloads
    attack_kwargs: tuple = ()  # extra (name, value) pairs, hashable

    def kwargs(self) -> dict:
        return dict(self.attack_kwargs)

    @property
    def equivocates_models(self) -> bool:
        return bool(self.equivocate and self.server_attack and self.n_byz_servers)

    @property
    def equivocates_grads(self) -> bool:
        return bool(self.equivocate and self.worker_attack and self.n_byz_workers)


def _inject_stack(stack: jax.Array, fn, kw: dict, n_byz: int, key: jax.Array,
                  n_receivers: int | None) -> jax.Array:
    """Core injector for one leaf [n, ...] -> [n, ...] or [n_recv, n, ...]."""
    n = stack.shape[0]
    h = n - n_byz
    honest = stack[:h]

    def payload(k):
        return fn(honest, k, **kw)

    if n_receivers is not None:  # equivocation: distinct payload per receiver
        keys = jax.random.split(key, n_receivers * n_byz)
        keys = keys.reshape((n_receivers, n_byz) + keys.shape[1:])
        pl = jax.vmap(jax.vmap(payload))(keys)  # [n_recv, n_byz, ...]
        out = jnp.broadcast_to(stack, (n_receivers,) + stack.shape)
        return out.at[:, h:].set(pl.astype(stack.dtype))
    keys = jax.random.split(key, n_byz)
    pl = jax.vmap(payload)(keys)
    return stack.at[h:].set(pl.astype(stack.dtype))


def _inject_tree(tree, attack: str | None, registry: dict, kw: dict, n_byz: int,
                 key: jax.Array, n_receivers: int | None):
    """Tree-aware injection. Leaves carry a leading stack axis [n, ...].

    All attacks in the registries are coordinate-wise functions of the honest
    stack, so applying them leaf-by-leaf is *exactly* equivalent to applying
    them to the flattened vector (the only exception, random_attack's
    norm-matched scale, becomes per-leaf norm-matched — equally adversarial).
    """
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    if not attack or n_byz == 0:
        if n_receivers is not None:
            return jax.tree.map(
                lambda l: jnp.broadcast_to(l, (n_receivers,) + l.shape), tree)
        return tree
    fn = registry[attack]
    kw = dict(kw)
    if attack == "alie":
        kw.setdefault("n", n)
        kw.setdefault("f", n_byz)
    out = [_inject_stack(l, fn, kw, n_byz, jax.random.fold_in(key, i), n_receivers)
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def inject_gradients(grads, spec: ByzantineSpec, key: jax.Array,
                     n_receivers: int | None = None):
    """Replace the last n_byz_workers entries of the [n_w, ...] gradient stack
    (pytree-aware). With ``n_receivers`` (equivocation) returns leaves
    [n_recv, n_w, ...]."""
    return _inject_tree(grads, spec.worker_attack, GRADIENT_ATTACKS,
                        spec.kwargs(), spec.n_byz_workers, key, n_receivers)


def inject_models(models, spec: ByzantineSpec, key: jax.Array,
                  n_receivers: int | None = None):
    """Same for server parameter stacks [n_ps, ...] (pytree-aware)."""
    return _inject_tree(models, spec.server_attack, MODEL_ATTACKS,
                        spec.kwargs(), spec.n_byz_servers, key, n_receivers)
