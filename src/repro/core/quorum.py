"""Delivery models (asynchrony abstraction) — pluggable via ``DeliveryModel``.

The paper's asynchronous network is abstracted by *which* q-of-n messages a
receiver delivers each step (Assumption 7: every delivering configuration has
probability >= rho > 0). Two implementations of the ``DeliveryModel``
protocol feed the simulator:

  * :class:`UniformDelivery` — seeded uniform sampling over configurations
    (rho = 1/C(n,q), exactly the distribution S the contraction proof
    Lemma C.5 averages over). This is the original behaviour.
  * :class:`TraceDelivery` — *realized* quorums and staleness replayed from a
    ``repro.netsim`` discrete-event run (latency tails, stragglers, crashes,
    partitions), where delivery is biased toward fast nodes rather than
    uniform.

Masks double as the framework's **straggler-mitigation** policy at scale: a
slow slice is simply outside the delivered quorum for that step.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


def sample_quorum_mask(key: jax.Array, n: int, q: int,
                       include: int | None = None) -> jax.Array:
    """Bool [n] mask with exactly q True entries, optionally forcing ``include``.

    Uniform over configurations -> satisfies Assumption 7 with rho = 1/C(n,q).
    """
    scores = jax.random.uniform(key, (n,))
    if include is not None:
        scores = scores.at[include].set(-1.0)  # always delivered (own state)
    thresh = jnp.sort(scores)[q - 1]
    return scores <= thresh


def receiver_quorum_masks(key: jax.Array, n_recv: int, n_send: int, q: int,
                          include_self: bool = False) -> jax.Array:
    """[n_recv, n_send] bool; row r has exactly q True. include_self forces the
    diagonal (a server always "delivers" its own parameter vector)."""
    keys = jax.random.split(key, n_recv)
    if include_self:
        return jax.vmap(lambda k, i: sample_quorum_mask(k, n_send, q, include=i))(
            keys, jnp.arange(n_recv))
    return jax.vmap(lambda k: sample_quorum_mask(k, n_send, q))(keys)


def sample_quorum_indices(key: jax.Array, n: int, q: int,
                          include: int | None = None) -> jax.Array:
    """Int [q] delivered indices (uniform subset), optionally forcing ``include``."""
    scores = jax.random.uniform(key, (n,))
    if include is not None:
        scores = scores.at[include].set(-1.0)
    return jnp.argsort(scores)[:q]


def receiver_quorum_indices(key: jax.Array, n_recv: int, n_send: int, q: int,
                            include_self: bool = False) -> jax.Array:
    """[n_recv, q] delivered sender indices per receiver."""
    keys = jax.random.split(key, n_recv)
    if include_self:
        return jax.vmap(lambda k, i: sample_quorum_indices(k, n_send, q, include=i))(
            keys, jnp.arange(n_recv))
    return jax.vmap(lambda k: sample_quorum_indices(k, n_send, q))(keys)


def full_quorum(n_recv: int, n_send: int) -> jax.Array:
    """Synchronous full delivery (no asynchrony)."""
    return jnp.ones((n_recv, n_send), bool)


# --------------------------------------------------------------------------
# Pluggable delivery models


@runtime_checkable
class DeliveryModel(Protocol):
    """What the simulator needs from an asynchrony model: per-step delivered
    sender indices for the three communication patterns. ``t`` is the traced
    step counter (int32 scalar inside jit)."""

    def pull_indices(self, key: jax.Array, t: jax.Array) -> jax.Array:
        """[n_workers, q_servers] server ids each worker delivers at step t."""
        ...

    def push_indices(self, key: jax.Array, t: jax.Array) -> jax.Array:
        """[n_servers, q_workers] worker ids each server delivers at step t."""
        ...

    def gather_indices(self, key: jax.Array, t: jax.Array) -> jax.Array:
        """[n_servers, q_servers] server ids (incl. self) for the DMC gather
        entered when the step counter reaches ``t`` (a multiple of T)."""
        ...

    def staleness(self, t: int) -> dict[str, float] | None:
        """Mean per-message delivery staleness at step t (virtual ms), or
        None if the model has no notion of time (uniform sampling)."""
        ...


class UniformDelivery:
    """Assumption 7 as before: uniform q-of-n quorum sampling, seeded."""

    def __init__(self, n_workers: int, n_servers: int, q_workers: int,
                 q_servers: int):
        self.n_workers, self.n_servers = n_workers, n_servers
        self.q_workers, self.q_servers = q_workers, q_servers

    @classmethod
    def from_config(cls, cfg) -> "UniformDelivery":
        return cls(cfg.n_workers, cfg.n_servers, cfg.q_workers, cfg.q_servers)

    def pull_indices(self, key, t):
        del t
        return receiver_quorum_indices(key, self.n_workers, self.n_servers,
                                       self.q_servers)

    def push_indices(self, key, t):
        del t
        return receiver_quorum_indices(key, self.n_servers, self.n_workers,
                                       self.q_workers)

    def gather_indices(self, key, t):
        del t
        return receiver_quorum_indices(key, self.n_servers, self.n_servers,
                                       self.q_servers, include_self=True)

    def staleness(self, t):
        del t
        return None


class TraceDelivery:
    """Replay *realized* quorums from a netsim trace (repro.netsim).

    The quorum tables are staged as stacked device arrays at construction
    (``[T_total, n_recv, q]`` int32) and indexed by the traced step counter,
    so the lookups are scan-compatible: a fused ``lax.scan`` epoch (see
    repro.core.engine) indexes them with the carried ``t`` without any
    per-step host work. Steps beyond the trace wrap around (t mod trace
    length) — the graceful fallback when a training run outlives the
    simulated trace. The gather trace is indexed by round r = t/T - 1 — the
    simulator enters gather after the scatter step that brings the counter to
    a multiple of T.
    """

    def __init__(self, pull_idx, push_idx, gather_idx, T: int,
                 pull_stale=None, push_stale=None, gather_stale=None):
        self.pull = jnp.asarray(pull_idx, jnp.int32)
        self.push = jnp.asarray(push_idx, jnp.int32)
        self.gather = jnp.asarray(gather_idx, jnp.int32)
        if self.gather.ndim != 3 or self.gather.shape[0] == 0:
            raise ValueError("gather trace must be [n_gathers>0, n_ps, q_ps]; "
                             "simulate at least T steps")
        self.T = int(T)
        self.steps = int(self.pull.shape[0])
        self.n_gathers = int(self.gather.shape[0])
        # Per-step mean staleness is precomputed ONCE as host arrays: the
        # metrics loop calls staleness() every logged step and must not
        # trigger device reductions/transfers there.
        def _mean_per_step(a):
            a = np.asarray(a, np.float32)
            return a.reshape(a.shape[0], -1).mean(axis=1)

        self._pull_stale_ms = None if pull_stale is None else \
            _mean_per_step(pull_stale)
        self._push_stale_ms = None if push_stale is None else \
            _mean_per_step(push_stale)
        self._gather_stale_ms = None if gather_stale is None else \
            _mean_per_step(gather_stale)

    def pull_indices(self, key, t):
        del key
        return self.pull[t % self.steps]

    def push_indices(self, key, t):
        del key
        return self.push[t % self.steps]

    def gather_indices(self, key, t):
        del key
        r = t // self.T - 1
        return self.gather[r % self.gather.shape[0]]

    def staleness(self, t):
        """t: 0-based scatter step just executed (concrete int). Pure host
        lookup into the precomputed per-step means — no device work."""
        if self._pull_stale_ms is None:
            return None
        k = int(t) % self.steps
        out = {"staleness_pull_ms": float(self._pull_stale_ms[k]),
               "staleness_push_ms": float(self._push_stale_ms[k])}
        if (int(t) + 1) % self.T == 0 and self._gather_stale_ms is not None:
            r = ((int(t) + 1) // self.T - 1) % self.n_gathers
            out["staleness_gather_ms"] = float(self._gather_stale_ms[r])
        return out


def validate_counts(n_w: int, f_w: int, n_ps: int, f_ps: int,
                    q_w: int, q_ps: int, synchronous: bool = False) -> None:
    """Paper's resilience preconditions (Table 1 + §5)."""
    if synchronous:
        if n_w < 2 * f_w + 1:
            raise ValueError(f"sync ByzSGD needs n_w >= 2f_w+1 ({n_w} < {2*f_w+1})")
    else:
        if n_w < 3 * f_w + 1:
            raise ValueError(f"async ByzSGD needs n_w >= 3f_w+1 ({n_w} < {3*f_w+1})")
    if n_ps < 3 * f_ps + 2:
        raise ValueError(f"ByzSGD needs n_ps >= 3f_ps+2 ({n_ps} < {3*f_ps+2})")
    if not (2 * f_w + 1 <= q_w <= n_w - f_w):
        raise ValueError(f"need 2f_w+1 <= q_w <= n_w-f_w, got q_w={q_w}")
    if not (2 * f_ps + 2 <= q_ps <= n_ps - f_ps):
        raise ValueError(f"need 2f_ps+2 <= q_ps <= n_ps-f_ps, got q_ps={q_ps}")
