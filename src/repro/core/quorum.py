"""Delivery-configuration sampling (asynchrony model).

The paper's asynchronous network is abstracted by *which* q-of-n messages a
receiver delivers each step (Assumption 7: every delivering configuration has
probability >= rho > 0). We sample quorums with a seeded PRNG so runs are
reproducible and every configuration has positive probability — exactly the
distribution S the contraction proof (Lemma C.5) averages over.

Masks double as the framework's **straggler-mitigation** policy at scale: a
slow slice is simply outside the delivered quorum for that step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_quorum_mask(key: jax.Array, n: int, q: int,
                       include: int | None = None) -> jax.Array:
    """Bool [n] mask with exactly q True entries, optionally forcing ``include``.

    Uniform over configurations -> satisfies Assumption 7 with rho = 1/C(n,q).
    """
    scores = jax.random.uniform(key, (n,))
    if include is not None:
        scores = scores.at[include].set(-1.0)  # always delivered (own state)
    thresh = jnp.sort(scores)[q - 1]
    return scores <= thresh


def receiver_quorum_masks(key: jax.Array, n_recv: int, n_send: int, q: int,
                          include_self: bool = False) -> jax.Array:
    """[n_recv, n_send] bool; row r has exactly q True. include_self forces the
    diagonal (a server always "delivers" its own parameter vector)."""
    keys = jax.random.split(key, n_recv)
    if include_self:
        return jax.vmap(lambda k, i: sample_quorum_mask(k, n_send, q, include=i))(
            keys, jnp.arange(n_recv))
    return jax.vmap(lambda k: sample_quorum_mask(k, n_send, q))(keys)


def sample_quorum_indices(key: jax.Array, n: int, q: int,
                          include: int | None = None) -> jax.Array:
    """Int [q] delivered indices (uniform subset), optionally forcing ``include``."""
    scores = jax.random.uniform(key, (n,))
    if include is not None:
        scores = scores.at[include].set(-1.0)
    return jnp.argsort(scores)[:q]


def receiver_quorum_indices(key: jax.Array, n_recv: int, n_send: int, q: int,
                            include_self: bool = False) -> jax.Array:
    """[n_recv, q] delivered sender indices per receiver."""
    keys = jax.random.split(key, n_recv)
    if include_self:
        return jax.vmap(lambda k, i: sample_quorum_indices(k, n_send, q, include=i))(
            keys, jnp.arange(n_recv))
    return jax.vmap(lambda k: sample_quorum_indices(k, n_send, q))(keys)


def full_quorum(n_recv: int, n_send: int) -> jax.Array:
    """Synchronous full delivery (no asynchrony)."""
    return jnp.ones((n_recv, n_send), bool)


def validate_counts(n_w: int, f_w: int, n_ps: int, f_ps: int,
                    q_w: int, q_ps: int, synchronous: bool = False) -> None:
    """Paper's resilience preconditions (Table 1 + §5)."""
    if synchronous:
        if n_w < 2 * f_w + 1:
            raise ValueError(f"sync ByzSGD needs n_w >= 2f_w+1 ({n_w} < {2*f_w+1})")
    else:
        if n_w < 3 * f_w + 1:
            raise ValueError(f"async ByzSGD needs n_w >= 3f_w+1 ({n_w} < {3*f_w+1})")
    if n_ps < 3 * f_ps + 2:
        raise ValueError(f"ByzSGD needs n_ps >= 3f_ps+2 ({n_ps} < {3*f_ps+2})")
    if not (2 * f_w + 1 <= q_w <= n_w - f_w):
        raise ValueError(f"need 2f_w+1 <= q_w <= n_w-f_w, got q_w={q_w}")
    if not (2 * f_ps + 2 <= q_ps <= n_ps - f_ps):
        raise ValueError(f"need 2f_ps+2 <= q_ps <= n_ps-f_ps, got q_ps={q_ps}")
