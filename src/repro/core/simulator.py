"""Single-host ByzSGD simulator — the *faithful reproduction* of the paper.

Simulates n_ps parameter servers and n_w workers (both with Byzantine members)
on one host by carrying server replicas / worker states as stacked leading axes
and vmapping the model. Protocol semantics (quorums, GARs, scatter/gather
schedule, filters, attacks) are exact; the network is replaced by the delivery
distribution of Assumption 7 (see quorum.py).

This module powers the paper-claim validation experiments in benchmarks/ and
is the correctness oracle for the distributed shard_map protocol.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .. import agg
from .attacks import ByzantineSpec, inject_gradients, inject_models
from .filters import (LipschitzHistory, lipschitz_coefficient,
                      lipschitz_cutoff, outliers_bound, outliers_pass)
from .quorum import DeliveryModel, UniformDelivery, validate_counts


@dataclass(frozen=True)
class ByzSGDConfig:
    n_workers: int = 9
    f_workers: int = 2          # declared bound
    n_servers: int = 5
    f_servers: int = 1          # declared bound
    q_workers: int | None = None   # gradients a server waits for (async)
    q_servers: int | None = None   # models a node waits for (async)
    T: int = 10                 # scatter length (gather every T steps)
    gar: str = "mda"            # worker-gradient GAR at servers
    pull_gar: str = "median"    # model GAR at workers (async pull)
    gather_gar: str = "median"  # server-model GAR in the DMC gather
    worker_gar: str = "meamed"  # worker model refresh in the sync gather
    variant: str = "async"      # "async" | "sync"
    mda_exact_limit: int = 200_000
    lip_horizon: int = 128
    byz: ByzantineSpec = field(default_factory=ByzantineSpec)

    def __post_init__(self):
        qw = self.q_workers or (self.n_workers - self.f_workers)
        qs = self.q_servers or max(self.n_servers - self.f_servers,
                                   2 * self.f_servers + 2)
        object.__setattr__(self, "q_workers", qw)
        object.__setattr__(self, "q_servers", qs)
        validate_counts(self.n_workers, self.f_workers, self.n_servers,
                        self.f_servers, qw, qs,
                        synchronous=(self.variant == "sync"))
        # registry-time GAR validation: names resolve, f bounds hold for the
        # smallest stack each role ever aggregates, pytree support exists.
        for role, name, n, f in (("gar", self.gar, qw, self.f_workers),
                                 ("pull_gar", self.pull_gar, qs,
                                  self.f_servers),
                                 ("gather_gar", self.gather_gar, qs,
                                  self.f_servers),
                                 ("worker_gar", self.worker_gar,
                                  self.n_servers, self.f_servers)):
            spec = agg.get(name)
            if spec.tree_mode is None:
                raise ValueError(f"{role}={name!r} does not support pytree "
                                 "aggregation (tree_mode=None)")
            spec.validate(n, f)

    @property
    def h_servers(self) -> int:
        return self.n_servers - self.byz.n_byz_servers

    @property
    def h_workers(self) -> int:
        return self.n_workers - self.byz.n_byz_workers


class SimState(NamedTuple):
    params: Any            # pytree, leaves [n_ps, ...] — one replica per server
    t: jax.Array           # scalar int32
    key: jax.Array
    # --- sync-variant worker state (unused but carried in async for uniformity)
    w_model: Any           # pytree, leaves [n_w, ...]
    w_grad: Any            # pytree, leaves [n_w, ...]
    w_r: jax.Array         # [n_w] round-robin offsets
    lip: LipschitzHistory  # buf [n_w, H]
    anchor_eta: jax.Array    # eta at last gather (Outliers filter anchor)
    anchor_gnorm: jax.Array  # ||g|| at last gather


def _tree_stack_n(tree, n):
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), tree)


def _tree_take(tree, idx):
    return jax.tree.map(lambda l: l[idx], tree)


def tree_sub_scaled(a, b, s):
    return jax.tree.map(lambda x, y: (x - s * y).astype(x.dtype), a, b)


def tree_gnorm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree.leaves(tree)))


def coordinatewise_diameter_sum(params, h_servers: int) -> jax.Array:
    """Delta_t of Lemma 4.2: sum over coordinates of the max-min spread across
    *honest* server replicas."""
    tot = jnp.float32(0.0)
    for l in jax.tree.leaves(params):
        hl = l[:h_servers].astype(jnp.float32)
        tot += jnp.sum(jnp.max(hl, axis=0) - jnp.min(hl, axis=0))
    return tot


def l2_diameter(params, h_servers: int) -> jax.Array:
    """Max pairwise L2 distance between honest replicas."""
    n = h_servers
    flat = [l[:n].reshape(n, -1).astype(jnp.float32) for l in jax.tree.leaves(params)]
    x = jnp.concatenate(flat, axis=1)
    return jnp.sqrt(jnp.max(agg.pairwise_sqdists(x)))


class ByzSGDSimulator:
    """init_fn(key) -> params; loss_fn(params, batch) -> scalar.

    ``delivery`` plugs in the asynchrony model (quorum.DeliveryModel):
    UniformDelivery (Assumption 7, the default) or a netsim TraceDelivery
    replaying realized quorums + staleness from a simulated cluster.
    """

    def __init__(self, cfg: ByzSGDConfig, init_fn: Callable, loss_fn: Callable,
                 lr_schedule: Callable[[jax.Array], jax.Array],
                 delivery: DeliveryModel | None = None):
        self.cfg = cfg
        self.init_fn = init_fn
        self.loss_fn = loss_fn
        self.lr = lr_schedule
        self.grad_fn = jax.grad(loss_fn)
        self.delivery = delivery or UniformDelivery.from_config(cfg)
        self._jit_cache: dict[str, Callable] = {}

    def jitted(self, name: str) -> Callable:
        """Jitted step function, compiled once per simulator instance so
        repeated ``run()`` calls (parameter sweeps, warm restarts) reuse the
        executable instead of re-wrapping ``jax.jit`` per call."""
        fn = self._jit_cache.get(name)
        if fn is None:
            fn = jax.jit(getattr(self, name))
            self._jit_cache[name] = fn
        return fn

    # -- state ------------------------------------------------------------
    def init_state(self, key: jax.Array) -> SimState:
        cfg = self.cfg
        k_model, k_run = jax.random.split(key)
        params0 = self.init_fn(k_model)  # same seed on all correct servers (§3.3)
        return SimState(
            params=_tree_stack_n(params0, cfg.n_servers),
            t=jnp.zeros((), jnp.int32),
            key=k_run,
            w_model=_tree_stack_n(params0, cfg.n_workers),
            w_grad=jax.tree.map(jnp.zeros_like,
                                _tree_stack_n(params0, cfg.n_workers)),
            w_r=jnp.arange(cfg.n_workers) % cfg.n_servers,
            lip=LipschitzHistory(
                jnp.full((cfg.n_workers, cfg.lip_horizon), jnp.nan, jnp.float32),
                jnp.zeros((cfg.n_workers,), jnp.int32)),
            anchor_eta=jnp.asarray(self.lr(0), jnp.float32),
            anchor_gnorm=jnp.asarray(1.0, jnp.float32),
        )

    # -- async scatter step (Algorithms 1 & 2) ------------------------------
    def scatter_step(self, state: SimState, batch) -> SimState:
        """One asynchronous ByzSGD step. batch leaves: [n_w, per-worker, ...]."""
        cfg = self.cfg
        key, k_pull, k_matk, k_push, k_gatk = jax.random.split(state.key, 5)
        eta = self.lr(state.t)

        # 1. workers pull q_ps models, aggregate with Median ----------------
        pull_idx = self.delivery.pull_indices(k_pull, state.t)
        models_seen = inject_models(  # Byzantine servers may equivocate
            state.params, cfg.byz, k_matk,
            n_receivers=cfg.n_workers if cfg.byz.equivocates_models else None)

        def pull_one(widx, qidx):
            if cfg.byz.equivocates_models:
                seen = _tree_take(models_seen, widx)     # [n_ps, ...] for worker w
            else:
                seen = models_seen
            sub = _tree_take(seen, qidx)                 # [q_ps, ...]
            return agg.tree_agg(cfg.pull_gar, sub, cfg.f_servers)

        pulled = jax.vmap(pull_one)(jnp.arange(cfg.n_workers), pull_idx)

        # 2. workers compute gradients on their microbatch -------------------
        grads = jax.vmap(self.grad_fn)(pulled, batch)     # [n_w, ...]

        # 3. Byzantine workers replace their gradient ------------------------
        grads_seen = inject_gradients(
            grads, cfg.byz, k_gatk,
            n_receivers=cfg.n_servers if cfg.byz.equivocates_grads else None)

        # 4. servers aggregate q_w gradients with the GAR and update ---------
        push_idx = self.delivery.push_indices(k_push, state.t)

        def server_update(sidx, qidx, p):
            if cfg.byz.equivocates_grads:
                seen = _tree_take(grads_seen, sidx)
            else:
                seen = grads_seen
            sub = _tree_take(seen, qidx)                  # [q_w, ...]
            g_hat = agg.tree_agg(cfg.gar, sub, cfg.f_workers,
                                 exact_limit=cfg.mda_exact_limit)
            return tree_sub_scaled(p, g_hat, eta)

        new_params = jax.vmap(server_update)(
            jnp.arange(cfg.n_servers), push_idx, state.params)

        gnorm = tree_gnorm(_tree_take(grads, 0))
        anchor_eta = jnp.where(state.t % cfg.T == 0, eta, state.anchor_eta)
        anchor_gnorm = jnp.where(state.t % cfg.T == 0, gnorm, state.anchor_gnorm)
        return state._replace(params=new_params, t=state.t + 1, key=key,
                              w_grad=jax.tree.map(
                                  lambda a, b: b.astype(a.dtype), state.w_grad, grads),
                              anchor_eta=anchor_eta, anchor_gnorm=anchor_gnorm)

    # -- gather step (DMC, line 8-10 of Algorithm 2) -------------------------
    def gather_step(self, state: SimState) -> SimState:
        cfg = self.cfg
        key, k_q, k_atk = jax.random.split(state.key, 3)
        gather_idx = self.delivery.gather_indices(k_q, state.t)
        models_seen = inject_models(
            state.params, cfg.byz, k_atk,
            n_receivers=cfg.n_servers if cfg.byz.equivocates_models else None)

        def dmc_one(sidx, qidx):
            if cfg.byz.equivocates_models:
                seen = _tree_take(models_seen, sidx)
            else:
                seen = models_seen
            sub = _tree_take(seen, qidx)
            return agg.tree_agg(cfg.gather_gar, sub, cfg.f_servers)

        new_params = jax.vmap(dmc_one)(jnp.arange(cfg.n_servers), gather_idx)
        return state._replace(params=new_params, key=key)

    # -- sync-variant worker step (Algorithm 3) ------------------------------
    def sync_step(self, state: SimState, batch):
        """Synchronous variant: servers update as usual; each worker pulls ONE
        model (round-robin) and validates with Lipschitz + Outliers filters.
        Returns (new_state, diagnostics) with per-worker reject counts."""
        cfg = self.cfg
        key, k_matk, k_gatk = jax.random.split(state.key, 3)
        eta = self.lr(state.t)

        # servers update from *current worker* gradients (full delivery - sync)
        grads_seen = inject_gradients(
            state.w_grad, cfg.byz, k_gatk,
            n_receivers=cfg.n_servers if cfg.byz.equivocates_grads else None)

        def server_update(sidx, p):
            seen = (_tree_take(grads_seen, sidx)
                    if cfg.byz.equivocates_grads else grads_seen)
            g_hat = agg.tree_agg(cfg.gar, seen, cfg.f_workers,
                                 exact_limit=cfg.mda_exact_limit)
            return tree_sub_scaled(p, g_hat, eta)

        new_params = jax.vmap(server_update)(jnp.arange(cfg.n_servers), state.params)
        models_seen = inject_models(
            new_params, cfg.byz, k_matk,
            n_receivers=cfg.n_workers if cfg.byz.equivocates_models else None)

        # each worker: speculate local model, try servers in round-robin order,
        # accept the first model passing BOTH filters. The probes run as a
        # LAZY while_loop instead of evaluating all n_ps candidates up front:
        # each candidate costs a full gradient evaluation (the filter's
        # Lipschitz coefficient needs it), and on the honest path the FIRST
        # candidate almost always passes — so the batched loop (vmap lifts it
        # to "iterate until every worker accepted") does ~1 gradient per
        # worker per step instead of n_ps, closing most of the sync/async
        # throughput gap exposed in throughput.json. The step-invariant
        # Outliers bound and the per-worker Lipschitz cutoff (a history-buffer
        # sort) are hoisted out of the probe.
        bnd = outliers_bound(state.t, cfg.T, state.anchor_eta,
                             state.anchor_gnorm, cfg.n_workers, cfg.f_workers)

        def worker_step(w, model_w, grad_w, r_w, lip_w, batch_w):
            local = tree_sub_scaled(model_w, grad_w, eta)
            kp = lipschitz_cutoff(lip_w, cfg.n_servers, cfg.f_servers)

            def probe(off):
                sid = (r_w + state.t + 1 + off) % cfg.n_servers
                seen = (_tree_take(models_seen, w)
                        if cfg.byz.equivocates_models else models_seen)
                pulled = _tree_take(seen, sid)
                g_new = self.grad_fn(pulled, batch_w)
                k_coef = lipschitz_coefficient(g_new, grad_w, local, model_w)
                ok_lip = jnp.isnan(kp) | (k_coef <= kp)
                ok_out = outliers_pass(pulled, local, bnd)
                return pulled, g_new, k_coef, ok_lip & ok_out

            def cond(carry):
                off, done = carry[0], carry[1]
                return (off < cfg.n_servers) & ~done

            def body(carry):
                off, done, model_acc, grad_acc, k0, rej = carry
                pulled, g_new, k_coef, ok = probe(off)
                k0 = jnp.where(off == 0, k_coef, k0)
                take = ok & ~done
                model_acc = jax.tree.map(
                    lambda a, p: jnp.where(take, p, a), model_acc, pulled)
                grad_acc = jax.tree.map(
                    lambda a, g: jnp.where(take, g, a), grad_acc, g_new)
                rej = jnp.where(take, off, rej).astype(jnp.int32)
                return off + 1, done | ok, model_acc, grad_acc, k0, rej

            # fallbacks when no candidate passes: the speculated local model
            # and the previous gradient (a conservative, honest pair)
            init = (jnp.int32(0), jnp.bool_(False), local, grad_w,
                    jnp.float32(0.0), jnp.int32(cfg.n_servers))
            _, _, new_model, new_grad, k0, rejects = jax.lax.while_loop(
                cond, body, init)
            # record the FIRST examined coefficient unconditionally: the paper
            # keeps "all previous Lipschitz coefficients" — the (n-f)/n
            # quantile is what absorbs the Byzantine fraction. Recording only
            # accepted ks biases the cutoff down (rejection death-spiral).
            new_lip = LipschitzHistory(
                lip_w.buf.at[lip_w.idx % cfg.lip_horizon].set(k0),
                lip_w.idx + 1)
            return new_model, new_grad, new_lip, rejects

        new_wm, new_wg, new_lip, rejects = jax.vmap(worker_step)(
            jnp.arange(cfg.n_workers), state.w_model, state.w_grad, state.w_r,
            state.lip, batch)

        gnorm = tree_gnorm(_tree_take(new_wg, 0))
        anchor_eta = jnp.where(state.t % cfg.T == 0, eta, state.anchor_eta)
        anchor_gnorm = jnp.where(state.t % cfg.T == 0, gnorm, state.anchor_gnorm)
        # Algorithm 3 guards worker pulls with the Lipschitz + Outliers
        # filters (paper Sec. 4.2), not a GAR — the while_loop above IS
        # the sanitizer for the w_model write:
        # analyze: ignore[REPRO-TAINT-BYZ] Alg. 3 Lipschitz+Outliers filters guard this pull
        new_state = state._replace(params=new_params, t=state.t + 1, key=key,
                                   w_model=new_wm, w_grad=new_wg, lip=new_lip,
                                   anchor_eta=anchor_eta,
                                   anchor_gnorm=anchor_gnorm)
        return new_state, {"rejects": rejects}

    # -- sync gather: workers aggregate all servers with MeaMed --------------
    def sync_gather_step(self, state: SimState) -> SimState:
        cfg = self.cfg
        state = self.gather_step(state)  # server-side DMC
        key, k_atk = jax.random.split(state.key)
        models_seen = inject_models(
            state.params, cfg.byz, k_atk,
            n_receivers=cfg.n_workers if cfg.byz.equivocates_models else None)

        def refresh(w):
            seen = (_tree_take(models_seen, w)
                    if cfg.byz.equivocates_models else models_seen)
            return agg.tree_agg(cfg.worker_gar, seen, cfg.f_servers)

        new_wm = jax.vmap(refresh)(jnp.arange(cfg.n_workers))
        return state._replace(w_model=new_wm, key=key)

    # -- full training loop ---------------------------------------------------
    def run(self, state: SimState, batches, *, jit: bool = True,
            metrics_fn: Callable | None = None, metrics_every: int = 10):
        """batches: iterable of [n_w, ...] sharded batches. Returns final state
        and a list of metric dicts.

        This is the *stepwise* reference loop (one dispatch per step, host
        metrics every ``metrics_every``) — the debugging/correctness oracle.
        The compiled hot path is :class:`repro.core.engine.EpochEngine`, which
        fuses whole epochs into one ``lax.scan`` and is equivalence-tested
        against this loop."""
        cfg = self.cfg
        scatter = self.jitted("scatter_step") if jit else self.scatter_step
        gather = self.jitted("gather_step") if jit else self.gather_step
        sync = self.jitted("sync_step") if jit else self.sync_step
        sync_gather = (self.jitted("sync_gather_step") if jit
                       else self.sync_gather_step)
        logs = []
        for i, batch in enumerate(batches):
            if cfg.variant == "sync":
                if i > 0 and i % cfg.T == 0:
                    state = sync_gather(state)
                state, diag = sync(state, batch)
            else:
                state = scatter(state, batch)
                diag = {}
                if (i + 1) % cfg.T == 0:
                    state = gather(state)
            if metrics_fn is not None and i % metrics_every == 0:
                m = dict(metrics_fn(state))
                m["step"] = i
                if "rejects" in diag:
                    m["rejects"] = int(jnp.sum(diag["rejects"]))
                stal = self.delivery.staleness(i)
                if stal:
                    m.update(stal)
                logs.append(m)
        return state, logs
