"""Gradient compression hooks for the exchange path (beyond-paper).

Composable with MDA because MDA's subset selection needs only pairwise
distances: distances computed on compressed gradients preserve the
honest/Byzantine separation as long as compression is *unbiased on honest
inputs* (random-k) or sign-consistent (signSGD — itself majority-vote
Byzantine-tolerant, Bernstein et al. 2018, cited by the paper as [9]).

Provided operators (pytree-aware, jit-able):
  * topk_compress     — keep the k largest-|.| coordinates per leaf
  * randk_compress    — keep a random k-subset (unbiased w/ 1/p rescale)
  * sign_compress     — sign(g) * mean|g| per leaf
Each returns a same-structure pytree (dense representation with zeros — the
wire format on a real deployment would be (indices, values); the dense form
keeps the protocol path unchanged and lets the dry-run measure byte ratios
via the exchange dtype).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _leaf_topk(l, frac: float):
    n = l.size
    k = max(int(n * frac), 1)
    flat = l.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(flat) >= thresh, flat, 0).reshape(l.shape)


def topk_compress(grads, frac: float = 0.01):
    return jax.tree.map(partial(_leaf_topk, frac=frac), grads)


def randk_compress(grads, key, frac: float = 0.01):
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, l in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        keep = jax.random.bernoulli(k, frac, l.shape)
        out.append(jnp.where(keep, l / frac, 0).astype(l.dtype))  # unbiased
    return jax.tree.unflatten(treedef, out)


def sign_compress(grads):
    return jax.tree.map(
        lambda l: (jnp.sign(l) * jnp.mean(jnp.abs(l))).astype(l.dtype), grads)


COMPRESSORS = {"none": None, "topk": topk_compress, "randk": randk_compress,
               "sign": sign_compress}
