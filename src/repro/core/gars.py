"""DEPRECATED — ``repro.core.gars`` moved to :mod:`repro.agg`.

This shim keeps the old flat imports (``gars.mda``, ``gars.tree_gar``,
``gars.pairwise_sqdists``, …) working while every call site migrates to the
unified Aggregator API::

    import repro.agg as agg
    agg.get("mda")(x, f)                  # was: gars.mda(x, f)
    agg.tree_agg("mda", tree, f)          # was: gars.tree_gar(gars.mda, ...)

The legacy name->callable registry dict is gone — use ``repro.agg.get`` /
``repro.agg.names()`` instead.
"""
from __future__ import annotations

import warnings

from .. import agg as _agg
# Legacy flat namespace (unchanged numerics — these are re-exports).
from ..agg.rules import (_krum_scores, bulyan, coordinate_median, krum,
                         krum_variance_threshold, masked_coordinate_median,
                         mda, mda_select_exact, mda_select_greedy,
                         mda_selection, mda_variance_threshold, meamed, mean,
                         multi_krum, n_subsets, pairwise_sqdists,
                         sqdists_from_gram, subset_diameters, subset_masks,
                         trimmed_mean)

__all__ = [
    "bulyan", "coordinate_median", "krum", "krum_variance_threshold",
    "masked_coordinate_median", "mda", "mda_select_exact",
    "mda_select_greedy", "mda_selection", "mda_variance_threshold", "meamed",
    "mean", "multi_krum", "n_subsets", "pairwise_sqdists",
    "sqdists_from_gram", "subset_diameters", "subset_masks", "tree_gar",
    "trimmed_mean",
]

warnings.warn("repro.core.gars is deprecated; use repro.agg "
              "(get/aggregate/tree_agg and the Aggregator registry)",
              DeprecationWarning, stacklevel=2)

# old callable -> registry name, for tree_gar's legacy signature
_FN_TO_NAME = {
    mda: "mda",
    coordinate_median: "median",
    meamed: "meamed",
    trimmed_mean: "trimmed_mean",
    krum: "krum",
    multi_krum: "multi_krum",
    bulyan: "bulyan",
    mean: "mean",
}

def tree_gar(rule, stacked_tree, f: int, **kw):
    """Legacy pytree entry point: maps the old callable to its registry name
    and delegates to :func:`repro.agg.tree_agg`."""
    name = _FN_TO_NAME.get(rule)
    if name is None:
        raise ValueError(f"unsupported rule {rule}")
    return _agg.tree_agg(name, stacked_tree, f, **kw)
