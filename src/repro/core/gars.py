"""Byzantine-resilient Gradient Aggregation Rules (GARs).

All rules operate on a stack ``x`` of shape ``[n, d]`` (n vectors of dimension d)
with a *static* declared number of Byzantine inputs ``f``. They are pure jnp and
jit/vmap/grad-compatible. Pytree wrappers live at the bottom.

The paper's rules:
  * MDA   (Minimum-Diameter Averaging)  — tolerates f Byzantine among n >= 2f+1.
  * Median (coordinate-wise)            — tolerates f among n >= 2f+1.
  * MeaMed (mean-around-median)         — used by the synchronous worker gather.
Baselines the paper compares against / cites:
  * Krum, Multi-Krum (Blanchard et al. 2017), Bulyan, trimmed mean, plain mean.
"""
from __future__ import annotations

import itertools
import math
from functools import partial, lru_cache

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------


def pairwise_sqdists(x: jax.Array) -> jax.Array:
    """Exact pairwise squared L2 distances via the Gram matrix. [n,d] -> [n,n].

    The Gram formulation is what makes the *sharded* distributed MDA possible:
    partial Grams over coordinate shards sum to the full Gram (see protocol.py).
    """
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    gram = x @ x.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def sqdists_from_gram(gram: jax.Array) -> jax.Array:
    """[n,n] Gram -> [n,n] squared distances (used by the sharded protocol)."""
    sq = jnp.diagonal(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)


# ---------------------------------------------------------------------------
# MDA — Minimum-Diameter Averaging (the paper's worker-side GAR)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def subset_masks(n: int, f: int) -> np.ndarray:
    """All C(n, n-f) subsets of size n-f as a static bool mask array [S, n]."""
    if not 0 <= f < n:
        raise ValueError(f"need 0 <= f < n, got n={n} f={f}")
    masks = np.zeros((math.comb(n, n - f), n), dtype=bool)
    for i, c in enumerate(itertools.combinations(range(n), n - f)):
        masks[i, list(c)] = True
    return masks


def n_subsets(n: int, f: int) -> int:
    return math.comb(n, n - f)


def subset_diameters(d2: jax.Array, masks: jax.Array) -> jax.Array:
    """Max in-subset squared distance for each subset mask. [n,n],[S,n] -> [S]."""
    pair = masks[:, :, None] & masks[:, None, :]  # [S, n, n]
    return jnp.max(jnp.where(pair, d2[None], -jnp.inf), axis=(1, 2))


def mda_select_exact(d2: jax.Array, f: int) -> jax.Array:
    """Exact minimum-diameter subset selection -> bool mask [n]."""
    n = d2.shape[0]
    masks = jnp.asarray(subset_masks(n, f))
    diam = subset_diameters(d2, masks)
    return masks[jnp.argmin(diam)]


def mda_select_greedy(d2: jax.Array, f: int) -> jax.Array:
    """Greedy 2-approximation of the min-diameter subset -> bool mask [n].

    Seeds with the closest pair, then repeatedly adds the vector whose inclusion
    minimises the resulting diameter. O(n^2) selection given the distance matrix.
    Used when C(n, f) exceeds ``mda_exact_limit`` (e.g. the 32-worker multi-pod
    mesh). DESIGN.md §2 discusses why Lemma 4.6 still holds up to a factor 2.
    """
    n = d2.shape[0]
    big = jnp.inf
    d2m = jnp.where(jnp.eye(n, dtype=bool), big, d2)
    ij = jnp.argmin(d2m)
    i, j = ij // n, ij % n
    sel = jnp.zeros((n,), bool).at[i].set(True).at[j].set(True)
    for _ in range(n - f - 2):
        # new diameter if k joined = max(current max dist to sel, in-sel diameter)
        dist_to_sel = jnp.max(jnp.where(sel[None, :], d2, -big), axis=1)  # [n]
        cand = jnp.where(sel, big, dist_to_sel)
        k = jnp.argmin(cand)
        sel = sel.at[k].set(True)
    return sel


def mda(x: jax.Array, f: int, *, exact_limit: int = 200_000,
        d2: jax.Array | None = None) -> jax.Array:
    """Minimum-Diameter Averaging. [n,d] -> [d].

    Average of the size-(n-f) subset with minimal L2 diameter (exact when the
    subset count is tractable, greedy otherwise).
    """
    n = x.shape[0]
    if n < 2 * f + 1:
        raise ValueError(f"MDA needs n >= 2f+1 (n={n}, f={f})")
    if f == 0:
        return jnp.mean(x, axis=0)
    if d2 is None:
        d2 = pairwise_sqdists(x)
    if n_subsets(n, f) <= exact_limit:
        sel = mda_select_exact(d2, f)
    else:
        sel = mda_select_greedy(d2, f)
    w = sel.astype(x.dtype) / (n - f)
    return w @ x


def mda_selection(d2: jax.Array, f: int, *, exact_limit: int = 200_000) -> jax.Array:
    """Subset mask only (used by the sharded protocol where averaging is local)."""
    n = d2.shape[0]
    if f == 0:
        return jnp.ones((n,), bool)
    if n_subsets(n, f) <= exact_limit:
        return mda_select_exact(d2, f)
    return mda_select_greedy(d2, f)


# ---------------------------------------------------------------------------
# coordinate-wise rules
# ---------------------------------------------------------------------------


def coordinate_median(x: jax.Array) -> jax.Array:
    """Coordinate-wise median ("Median" in the paper). [n,d] -> [d]."""
    return jnp.median(x, axis=0)


def masked_coordinate_median(x: jax.Array, delivered: jax.Array) -> jax.Array:
    """Median over the delivered subset only (asynchrony). [n,d],[n] -> [d].

    Non-delivered entries are pushed to +/-inf in equal numbers so the median of
    the remaining q values is recovered exactly for any q (sort-based).
    """
    q = jnp.sum(delivered)
    big = jnp.asarray(3.4e38, x.dtype)
    mask = delivered.reshape((-1,) + (1,) * (x.ndim - 1))
    xs = jnp.sort(jnp.where(mask, x, big), axis=0)  # delivered entries sort first
    lo = ((q - 1) // 2).astype(jnp.int32)
    hi = (q // 2).astype(jnp.int32)
    return 0.5 * (jnp.take(xs, lo, axis=0) + jnp.take(xs, hi, axis=0))


def trimmed_mean(x: jax.Array, f: int) -> jax.Array:
    """Coordinate-wise trimmed mean: drop f lowest and f highest per coordinate."""
    n = x.shape[0]
    if n <= 2 * f:
        raise ValueError("trimmed_mean needs n > 2f")
    xs = jnp.sort(x, axis=0)
    return jnp.mean(xs[f:n - f], axis=0)


def meamed(x: jax.Array, f: int) -> jax.Array:
    """Mean-around-Median (Xie et al. 2018): per coordinate, mean of the n-f
    values closest to the coordinate median."""
    n = x.shape[0]
    med = jnp.median(x, axis=0, keepdims=True)
    dist = jnp.abs(x - med)
    idx = jnp.argsort(dist, axis=0)[: n - f]  # [n-f, d]
    vals = jnp.take_along_axis(x, idx, axis=0)
    return jnp.mean(vals, axis=0)


# ---------------------------------------------------------------------------
# Krum family (baselines)
# ---------------------------------------------------------------------------


def _krum_scores(d2: jax.Array, f: int) -> jax.Array:
    """Krum score: sum of the n-f-2 smallest squared distances to neighbours."""
    n = d2.shape[0]
    m = n - f - 2
    if m < 1:
        raise ValueError(f"Krum needs n >= f+3 (n={n}, f={f})")
    d2nd = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
    srt = jnp.sort(d2nd, axis=1)
    return jnp.sum(srt[:, :m], axis=1)


def krum(x: jax.Array, f: int) -> jax.Array:
    """Krum (Blanchard et al. 2017): the single vector with the best score."""
    scores = _krum_scores(pairwise_sqdists(x), f)
    return x[jnp.argmin(scores)]


def multi_krum(x: jax.Array, f: int, m: int | None = None) -> jax.Array:
    """Multi-Krum: average of the m best-scored vectors (default m = n - f)."""
    n = x.shape[0]
    m = n - f if m is None else m
    scores = _krum_scores(pairwise_sqdists(x), f)
    idx = jnp.argsort(scores)[:m]
    return jnp.mean(x[idx], axis=0)


def bulyan(x: jax.Array, f: int) -> jax.Array:
    """Bulyan (El Mhamdi et al. 2018): n-2f rounds of Krum selection, then
    coordinate-wise trimmed aggregation around the median. Needs n >= 4f+3."""
    n = x.shape[0]
    theta = n - 2 * f
    if theta < 1:
        raise ValueError(f"Bulyan needs n >= 4f+3 (n={n}, f={f})")
    d2 = pairwise_sqdists(x)
    alive = jnp.ones((n,), bool)
    picks = []
    for _ in range(theta):
        d2a = jnp.where(alive[None, :] & alive[:, None] & ~jnp.eye(n, dtype=bool),
                        d2, jnp.inf)
        srt = jnp.sort(d2a, axis=1)
        m = max(n - f - 2, 1)
        scores = jnp.sum(jnp.where(jnp.isinf(srt[:, :m]), 0.0, srt[:, :m]), axis=1)
        scores = jnp.where(alive, scores, jnp.inf)
        k = jnp.argmin(scores)
        picks.append(x[k])
        alive = alive.at[k].set(False)
    sel = jnp.stack(picks)  # [theta, d]
    beta = theta - 2 * f
    med = jnp.median(sel, axis=0, keepdims=True)
    idx = jnp.argsort(jnp.abs(sel - med), axis=0)[:max(beta, 1)]
    return jnp.mean(jnp.take_along_axis(sel, idx, axis=0), axis=0)


def mean(x: jax.Array, f: int = 0) -> jax.Array:  # noqa: ARG001 - uniform signature
    """Vanilla averaging (not Byzantine resilient — the paper's strawman)."""
    return jnp.mean(x, axis=0)


# ---------------------------------------------------------------------------
# variance-to-norm bounds (Appendix D / Fig. 7 reproduction)
# ---------------------------------------------------------------------------


def mda_variance_threshold(n: int, f: int) -> float:
    """Eq. (3)/(7): MDA is safe while stddev/||grad|| <= (n-f) / (2f)."""
    return float(n - f) / (2.0 * f) if f > 0 else float("inf")


def krum_variance_threshold(n: int, f: int) -> float:
    """Blanchard et al. 2017 condition: eta(n,f) * sigma < ||grad||, i.e. the
    usable stddev/norm ratio is 1/eta with
    eta(n,f) = sqrt(2 (n - f + f(n-f-2) + f^2 (n-f-1) / (n-2f-2)))."""
    if f == 0:
        return float("inf")
    if n - 2 * f - 2 <= 0:
        return 0.0
    eta2 = 2.0 * (n - f + (f * (n - f - 2) + f * f * (n - f - 1)) / (n - 2 * f - 2))
    return 1.0 / math.sqrt(eta2)


# ---------------------------------------------------------------------------
# pytree wrappers
# ---------------------------------------------------------------------------


def _stack_leaves(trees):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def tree_gar(rule, stacked_tree, f: int, **kw):
    """Apply a GAR to a pytree whose leaves carry a leading stack axis [n, ...].

    Coordinate-wise rules apply leaf-wise. Distance-based rules (MDA, Krum...)
    need global distances: we compute the distance matrix from per-leaf partial
    Grams (no full flatten/copy of the stack), select once, then average
    leaf-wise with the selection weights.
    """
    leaves = jax.tree.leaves(stacked_tree)
    n = leaves[0].shape[0]
    if rule in (coordinate_median, meamed, trimmed_mean, mean):
        if rule is coordinate_median:
            return jax.tree.map(lambda l: coordinate_median(l), stacked_tree)
        return jax.tree.map(lambda l: rule(l, f), stacked_tree)
    # distance-based: global Gram from leaf partials
    gram = sum(jnp.einsum("na,ma->nm", l.reshape(n, -1).astype(jnp.float32),
                          l.reshape(n, -1).astype(jnp.float32)) for l in leaves)
    d2 = sqdists_from_gram(gram)
    if rule is mda:
        sel = mda_selection(d2, f, **kw)
        w = sel.astype(jnp.float32) / (n - f if f else n)
        return jax.tree.map(
            lambda l: jnp.tensordot(w, l.astype(jnp.float32), axes=1).astype(l.dtype),
            stacked_tree)
    if rule is krum:
        k = jnp.argmin(_krum_scores(d2, f))
        return jax.tree.map(lambda l: l[k], stacked_tree)
    if rule is multi_krum:
        m = n - f
        idx = jnp.argsort(_krum_scores(d2, f))[:m]
        return jax.tree.map(lambda l: jnp.mean(l[idx], axis=0), stacked_tree)
    raise ValueError(f"unsupported rule {rule}")


GAR_REGISTRY = {
    "mda": mda,
    "median": coordinate_median,
    "meamed": meamed,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "multi_krum": multi_krum,
    "bulyan": bulyan,
    "mean": mean,
}
