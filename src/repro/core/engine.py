"""Fused scatter/gather epoch engine — the compiled hot path of the simulator.

``ByzSGDSimulator.run`` dispatches one jitted step per Python-loop iteration,
which on small (paper-scale) models is dominated by dispatch overhead rather
than the algorithm, and converts every metric to a host float as it goes.
:class:`EpochEngine` instead compiles ONE ``epoch_fn(state, batches[L]) ->
(state, metrics_buf)`` that ``lax.scan``s L protocol steps with the
gather/DMC step applied inline at the T-step boundary:

* **trace-closed epochs** — batches arrive as a device-resident
  ``[L, n_w, ...]`` tensor (see :class:`repro.data.pipeline.DeviceBatchStream`)
  and the delivery model is indexed by the *carried* step counter, so the whole
  epoch is a single XLA computation;
* **boundary semantics match the stepwise loop exactly** — the async variant
  gathers when the post-step counter hits a multiple of T (``(i+1) % T == 0``),
  the sync variant gathers *before* the step when ``i % T == 0 and i > 0``,
  both expressed as a ``lax.cond`` on the carried ``state.t`` so epochs of any
  length (including the tail of a run) stay correct;
* **donated buffers** — the carried state is donated to each epoch call, so
  server replicas / worker states are updated in place on accelerators;
* **on-device metrics** — per-step metrics (accuracy, coordinate-wise diameter
  Delta_t, L2 diameter, grad norm, per-worker sync reject counts) are stacked
  into the scan's output buffer; the host sees ONE transfer per ``run`` call;
* **compile-cache reuse** — epoch executables are cached at module level keyed
  on the *semantic* static config (ByzSGDConfig, loss/lr cache keys, delivery
  model), so parameter sweeps that rebuild simulators per point reuse the
  compiled epoch instead of re-tracing.

The cache machinery, the donated-epoch dispatch and the chunked ``run`` loop
live in :mod:`repro.core.epochs` (shared with the distributed
:class:`repro.core.protocol.ProtocolEngine`, which applies the same treatment
to the replica-sharded multi-device path); this module keeps the single-host
step body and its metric plumbing.

``benchmarks/exp_throughput.py`` measures the resulting steps/sec against the
per-step loop and records the repo's perf trajectory baseline.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..agg import dispatch as _agg_dispatch
from ..agg import rules as _agg_rules
from .epochs import (EpochRunner, clear_epoch_cache,  # noqa: F401 (re-export)
                     delivery_cache_key, epoch_cache_size, fn_cache_key,
                     stack_batches)
from .simulator import (ByzSGDSimulator, SimState, _tree_take,
                        coordinatewise_diameter_sum, l2_diameter, tree_gnorm)


def _make_epoch_fn(sim: ByzSGDSimulator, acc_fn: Callable | None,
                   track_delta: bool, track_gnorm: bool,
                   metrics_every: int) -> Callable:
    cfg = sim.cfg
    T = cfg.T
    is_sync = cfg.variant == "sync"

    def step_metrics(state: SimState, rejects, delta_pre, eval_x, eval_y):
        m = {}
        if acc_fn is not None:
            # the eval forward pass can cost more than the training step, so
            # it only runs on the logged stride (state.t is post-step = i+1;
            # buffer entries off the stride are 0)
            def ev(_):
                return acc_fn(_tree_take(state.params, 0), eval_x, eval_y)

            if metrics_every == 1:
                m["acc"] = ev(None)
            else:
                m["acc"] = lax.cond((state.t - 1) % metrics_every == 0,
                                    ev, lambda _: jnp.float32(0.0), None)
        if track_delta:
            m["delta_pre"] = delta_pre
            m["delta"] = coordinatewise_diameter_sum(state.params,
                                                     cfg.h_servers)
            m["l2_diam"] = l2_diameter(state.params, cfg.h_servers)
        if track_gnorm:
            m["gnorm"] = tree_gnorm(_tree_take(state.w_grad, 0))
        if is_sync:
            m["rejects"] = rejects
        return m

    def epoch(state: SimState, batches, eval_x, eval_y):
        def body(state, batch):
            if is_sync:
                # gather BEFORE the step when the counter is a non-zero
                # multiple of T (the stepwise loop's `i > 0 and i % T == 0`).
                delta_pre = (coordinatewise_diameter_sum(state.params,
                                                         cfg.h_servers)
                             if track_delta else None)
                state = lax.cond((state.t % T == 0) & (state.t > 0),
                                 sim.sync_gather_step, lambda s: s, state)
                state, diag = sim.sync_step(state, batch)
                rejects = diag["rejects"]
            else:
                state = sim.scatter_step(state, batch)
                # scatter_step advanced t, so t % T == 0 here is the stepwise
                # loop's `(i + 1) % T == 0`: gather closes the scatter phase.
                delta_pre = (coordinatewise_diameter_sum(state.params,
                                                         cfg.h_servers)
                             if track_delta else None)
                state = lax.cond(state.t % T == 0,
                                 sim.gather_step, lambda s: s, state)
                rejects = None
            return state, step_metrics(state, rejects, delta_pre,
                                       eval_x, eval_y)

        return lax.scan(body, state, batches)

    return jax.jit(epoch, donate_argnums=(0,))


class EpochEngine(EpochRunner):
    """Compiled epoch runner around a :class:`ByzSGDSimulator`.

    ``acc_fn(params, eval_x, eval_y)`` enables per-step accuracy against the
    ``eval_set=(ex, ey)`` pair; ``track_delta`` adds the Lemma 4.2/4.3
    diameters (``delta_pre`` is measured just before the boundary gather
    would apply, ``delta``/``l2_diam`` on the post-step state); ``track_gnorm``
    adds worker-0's gradient norm. The sync variant always reports per-worker
    ``rejects``. Metrics come back as one host numpy buffer per key, shaped
    ``[steps]`` (``[steps, n_w]`` for rejects). ``metrics_every`` strides the
    *accuracy* evaluation (the expensive metric) on device: off-stride entries
    of the ``acc`` buffer are 0; the cheap per-step metrics are always dense.
    """

    def __init__(self, sim: ByzSGDSimulator, *, acc_fn: Callable | None = None,
                 eval_set: tuple | None = None, track_delta: bool = False,
                 track_gnorm: bool = False, metrics_every: int = 1):
        if (acc_fn is None) != (eval_set is None):
            raise ValueError("acc_fn and eval_set must be given together")
        if metrics_every < 1:
            raise ValueError("metrics_every must be >= 1")
        self.sim = sim
        self.cfg = sim.cfg
        self.acc_fn = acc_fn
        self.eval_set = eval_set
        self.track_delta = track_delta
        self.track_gnorm = track_gnorm
        self.metrics_every = metrics_every
        self._epoch = self._get_or_build()

    def _flags(self):
        # the sort-network setting and the process-default agg backend change
        # the compiled trace of every order-statistic rule, so they must key
        # the executable too (repro.exp.run toggles both per experiment)
        return (fn_cache_key(self.acc_fn), self.track_delta, self.track_gnorm,
                self.metrics_every, _agg_rules.sort_network_enabled(),
                _agg_dispatch.default_backend())

    def _cache_key(self):
        return ("epoch", self.cfg,
                fn_cache_key(self.sim.loss_fn), fn_cache_key(self.sim.lr),
                delivery_cache_key(self.sim.delivery), *self._flags())

    def _instance_key(self):
        return ("epoch-inst", id(self.sim), *self._flags())

    def _build(self):
        return _make_epoch_fn(self.sim, self.acc_fn, self.track_delta,
                              self.track_gnorm, self.metrics_every)

    def _extra_args(self):
        if self.eval_set is not None:
            return self.eval_set
        return (jnp.zeros(()), jnp.zeros(()))
