"""Fused scatter/gather epoch engine — the compiled hot path of the simulator.

``ByzSGDSimulator.run`` dispatches one jitted step per Python-loop iteration,
which on small (paper-scale) models is dominated by dispatch overhead rather
than the algorithm, and converts every metric to a host float as it goes.
:class:`EpochEngine` instead compiles ONE ``epoch_fn(state, batches[L]) ->
(state, metrics_buf)`` that ``lax.scan``s L protocol steps with the
gather/DMC step applied inline at the T-step boundary:

* **trace-closed epochs** — batches arrive as a device-resident
  ``[L, n_w, ...]`` tensor (see :class:`repro.data.pipeline.DeviceBatchStream`)
  and the delivery model is indexed by the *carried* step counter, so the whole
  epoch is a single XLA computation;
* **boundary semantics match the stepwise loop exactly** — the async variant
  gathers when the post-step counter hits a multiple of T (``(i+1) % T == 0``),
  the sync variant gathers *before* the step when ``i % T == 0 and i > 0``,
  both expressed as a ``lax.cond`` on the carried ``state.t`` so epochs of any
  length (including the tail of a run) stay correct;
* **donated buffers** — the carried state is donated to each epoch call, so
  server replicas / worker states are updated in place on accelerators;
* **on-device metrics** — per-step metrics (accuracy, coordinate-wise diameter
  Delta_t, L2 diameter, grad norm, per-worker sync reject counts) are stacked
  into the scan's output buffer; the host sees ONE transfer per ``run`` call;
* **compile-cache reuse** — epoch executables are cached at module level keyed
  on the *semantic* static config (ByzSGDConfig, loss/lr cache keys, delivery
  model), so parameter sweeps that rebuild simulators per point reuse the
  compiled epoch instead of re-tracing.

``benchmarks/exp_throughput.py`` measures the resulting steps/sec against the
per-step loop and records the repo's perf trajectory baseline.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..agg import dispatch as _agg_dispatch
from ..agg import rules as _agg_rules
from .quorum import UniformDelivery
from .simulator import (ByzSGDSimulator, SimState, _tree_take,
                        coordinatewise_diameter_sum, l2_diameter, tree_gnorm)


def fn_cache_key(fn: Callable | None) -> tuple:
    """A hashable key identifying a callable's *semantics* for compile-cache
    reuse. ``functools.partial`` trees and callables exposing ``cache_key``
    (the repro.optim.schedules factories) key structurally — two sweep points
    built from the same factory with equal arguments share an executable.
    Anything else keys on object identity (always correct, never shared)."""
    if fn is None:
        return ("none",)
    ck = getattr(fn, "cache_key", None)
    if ck is not None:
        return ("ck", ck)
    if isinstance(fn, functools.partial):
        return ("partial", fn_cache_key(fn.func), fn.args,
                tuple(sorted(fn.keywords.items())))
    return ("fn", fn)


def delivery_cache_key(delivery) -> tuple:
    """UniformDelivery keys structurally; trace-backed models carry device
    arrays and key on identity."""
    if isinstance(delivery, UniformDelivery):
        return ("uniform", delivery.n_workers, delivery.n_servers,
                delivery.q_workers, delivery.q_servers)
    return (type(delivery).__name__, id(delivery))


# Semantic-key -> jitted epoch executable. Entries close over their simulator
# (and, for TraceDelivery, its staged trace arrays), so the cache is bounded:
# oldest entries are evicted past _EPOCH_CACHE_MAX to keep long sweeps over
# identity-keyed deliveries from pinning memory for the process lifetime.
_EPOCH_CACHE: dict[Any, Callable] = {}
_EPOCH_CACHE_MAX = 64


def epoch_cache_size() -> int:
    return len(_EPOCH_CACHE)


def clear_epoch_cache() -> None:
    _EPOCH_CACHE.clear()


def _make_epoch_fn(sim: ByzSGDSimulator, acc_fn: Callable | None,
                   track_delta: bool, track_gnorm: bool,
                   metrics_every: int) -> Callable:
    cfg = sim.cfg
    T = cfg.T
    is_sync = cfg.variant == "sync"

    def step_metrics(state: SimState, rejects, delta_pre, eval_x, eval_y):
        m = {}
        if acc_fn is not None:
            # the eval forward pass can cost more than the training step, so
            # it only runs on the logged stride (state.t is post-step = i+1;
            # buffer entries off the stride are 0)
            def ev(_):
                return acc_fn(_tree_take(state.params, 0), eval_x, eval_y)

            if metrics_every == 1:
                m["acc"] = ev(None)
            else:
                m["acc"] = lax.cond((state.t - 1) % metrics_every == 0,
                                    ev, lambda _: jnp.float32(0.0), None)
        if track_delta:
            m["delta_pre"] = delta_pre
            m["delta"] = coordinatewise_diameter_sum(state.params,
                                                     cfg.h_servers)
            m["l2_diam"] = l2_diameter(state.params, cfg.h_servers)
        if track_gnorm:
            m["gnorm"] = tree_gnorm(_tree_take(state.w_grad, 0))
        if is_sync:
            m["rejects"] = rejects
        return m

    def epoch(state: SimState, batches, eval_x, eval_y):
        def body(state, batch):
            if is_sync:
                # gather BEFORE the step when the counter is a non-zero
                # multiple of T (the stepwise loop's `i > 0 and i % T == 0`).
                delta_pre = (coordinatewise_diameter_sum(state.params,
                                                         cfg.h_servers)
                             if track_delta else None)
                state = lax.cond((state.t % T == 0) & (state.t > 0),
                                 sim.sync_gather_step, lambda s: s, state)
                state, diag = sim.sync_step(state, batch)
                rejects = diag["rejects"]
            else:
                state = sim.scatter_step(state, batch)
                # scatter_step advanced t, so t % T == 0 here is the stepwise
                # loop's `(i + 1) % T == 0`: gather closes the scatter phase.
                delta_pre = (coordinatewise_diameter_sum(state.params,
                                                         cfg.h_servers)
                             if track_delta else None)
                state = lax.cond(state.t % T == 0,
                                 sim.gather_step, lambda s: s, state)
                rejects = None
            return state, step_metrics(state, rejects, delta_pre,
                                       eval_x, eval_y)

        return lax.scan(body, state, batches)

    return jax.jit(epoch, donate_argnums=(0,))


class EpochEngine:
    """Compiled epoch runner around a :class:`ByzSGDSimulator`.

    ``acc_fn(params, eval_x, eval_y)`` enables per-step accuracy against the
    ``eval_set=(ex, ey)`` pair; ``track_delta`` adds the Lemma 4.2/4.3
    diameters (``delta_pre`` is measured just before the boundary gather
    would apply, ``delta``/``l2_diam`` on the post-step state); ``track_gnorm``
    adds worker-0's gradient norm. The sync variant always reports per-worker
    ``rejects``. Metrics come back as one host numpy buffer per key, shaped
    ``[steps]`` (``[steps, n_w]`` for rejects). ``metrics_every`` strides the
    *accuracy* evaluation (the expensive metric) on device: off-stride entries
    of the ``acc`` buffer are 0; the cheap per-step metrics are always dense.
    """

    def __init__(self, sim: ByzSGDSimulator, *, acc_fn: Callable | None = None,
                 eval_set: tuple | None = None, track_delta: bool = False,
                 track_gnorm: bool = False, metrics_every: int = 1):
        if (acc_fn is None) != (eval_set is None):
            raise ValueError("acc_fn and eval_set must be given together")
        if metrics_every < 1:
            raise ValueError("metrics_every must be >= 1")
        self.sim = sim
        self.cfg = sim.cfg
        self.acc_fn = acc_fn
        self.eval_set = eval_set
        self.track_delta = track_delta
        self.track_gnorm = track_gnorm
        self.metrics_every = metrics_every
        self._epoch = self._get_or_build()

    def _flags(self):
        # _SORT_NETWORK and the process-default agg backend change the
        # compiled trace of every order-statistic rule, so they must key the
        # executable too (repro.exp.run toggles both per experiment)
        return (fn_cache_key(self.acc_fn), self.track_delta, self.track_gnorm,
                self.metrics_every, _agg_rules._SORT_NETWORK,
                _agg_dispatch.default_backend())

    def _cache_key(self):
        return ("epoch", self.cfg,
                fn_cache_key(self.sim.loss_fn), fn_cache_key(self.sim.lr),
                delivery_cache_key(self.sim.delivery), *self._flags())

    def _get_or_build(self) -> Callable:
        try:
            key = self._cache_key()
            hash(key)
        except TypeError:  # unhashable closure args: private executable
            key = ("epoch-inst", id(self.sim), *self._flags())
        fn = _EPOCH_CACHE.get(key)
        if fn is None:
            fn = _make_epoch_fn(self.sim, self.acc_fn, self.track_delta,
                                self.track_gnorm, self.metrics_every)
            while len(_EPOCH_CACHE) >= _EPOCH_CACHE_MAX:
                _EPOCH_CACHE.pop(next(iter(_EPOCH_CACHE)))
            _EPOCH_CACHE[key] = fn
        return fn

    # -- epoch-at-a-time API -------------------------------------------------
    def run_epoch(self, state: SimState, batches) -> tuple[SimState, dict]:
        """One compiled epoch over ``batches`` (leaves ``[L, n_w, ...]``).
        ``state`` is donated. Metrics stay on device (dict of ``[L]`` bufs)."""
        ex, ey = self.eval_set if self.eval_set is not None else (
            jnp.zeros(()), jnp.zeros(()))
        with warnings.catch_warnings():
            # donation is a no-op on CPU; keep that per-executable warning out
            # of benchmark output without touching the global filter state
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return self._epoch(state, batches, ex, ey)

    # -- full-run API --------------------------------------------------------
    def run(self, state: SimState, batches=None, *, stream=None,
            steps: int | None = None, epoch_steps: int | None = None
            ) -> tuple[SimState, dict[str, np.ndarray]]:
        """Run ``steps`` protocol steps in compiled epochs.

        Feed either ``batches`` — a pytree with ``[steps, n_w, ...]`` leaves —
        or ``stream`` — an object with ``next(L)`` returning device batches
        (see ``DeviceBatchStream``). ``epoch_steps`` sets the scan length per
        dispatch (default: ``cfg.T``); any value is correct because the gather
        boundary is driven by the carried step counter, not the chunking.
        Returns the final state and the host metrics buffers (one transfer).
        """
        if (batches is None) == (stream is None):
            raise ValueError("provide exactly one of batches/stream")
        if steps is None:
            if batches is None:
                raise ValueError("steps is required with stream input")
            steps = jax.tree.leaves(batches)[0].shape[0]
        L = epoch_steps or self.cfg.T
        bufs, done = [], 0
        while done < steps:
            n = min(L, steps - done)
            if batches is not None:
                chunk = jax.tree.map(lambda l: l[done:done + n], batches)
            else:
                chunk = stream.next(n)
            state, mbuf = self.run_epoch(state, chunk)
            bufs.append(mbuf)
            done += n
        if not bufs or not bufs[0]:
            return state, {}
        host = jax.device_get(bufs)  # ONE device->host transfer
        metrics = {k: np.concatenate([np.asarray(b[k]) for b in host])
                   for k in host[0]}
        return state, metrics


def stack_batches(batch_iter) -> Any:
    """Stack a host batch iterable into the ``[steps, ...]`` pytree the engine
    consumes (for driving the engine from a legacy host stream in tests)."""
    batches = list(batch_iter)
    return jax.tree.map(lambda *ls: jnp.stack(ls), *batches)
