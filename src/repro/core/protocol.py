"""Distributed ByzSGD on a TPU mesh (pjit formulation).

Maps the paper's server/worker protocol onto the ('rep', 'fsdp', 'model') view
of the production mesh (launch/mesh.py):

  * 'rep' indexes G = n_groups co-located worker+server groups (the failure
    domains). Group g holds server replica theta^(g) (ZeRO-sharded over its
    'fsdp' x 'model' chips) and computes worker gradient g^(g) on its 1/G of
    the global batch.
  * scatter step  = pull (per-worker masked Median over delivered replicas)
                  -> per-group gradient (vmap over 'rep')
                  -> MDA per server group over its delivered gradient quorum
                  -> local SGD update.
  * gather step   = DMC: masked Median across server replicas (every T steps).

Asynchrony = per-step delivery quorums: every step builder takes a pluggable
``DeliveryModel`` (core/quorum.py) — ``UniformDelivery`` (Assumption 7, the
default, with the *same* PRNG chain as the single-host simulator so the
1-device protocol is oracle-checked against it) or a netsim ``TraceDelivery``
replaying realized quorums. Byzantine behaviour is injected for
tests/benchmarks and *excluded from roofline lowers* (a real adversary costs
nothing extra on the honest path).

Engines:
  * 'naive'   — baseline, paper-faithful collective volume: gradients/replicas
    are all-gathered across 'rep' (volume (G-1)/G * G * P per step, like the
    paper's broadcast-to-all message pattern), streamed layer-by-layer to bound
    transients.
  * 'sharded' — beyond-paper: aggregations stay as reductions over 'rep'
    (XLA lowers to reduce-scatter/all-reduce, ~2P per step) and the MDA subset
    selection is driven by the leaf-partial Gram matrix (exact distances, tiny
    [G,G] psum). See DESIGN.md §2 and EXPERIMENTS.md §Perf.

:class:`ProtocolEngine` gives the protocol the fused-epoch treatment of
``repro.core.engine`` (shared scaffolding in ``repro.core.epochs``): donated
``lax.scan`` epochs with the DMC gather at the T-boundary via ``lax.cond`` on
the carried counter, per-group metrics reduced on device, and the bounded
semantic compile cache. ``repro.exp.run(spec.replace(runner="protocol"))``
drives it; the single-host ``EpochEngine`` is its correctness oracle
(tests/test_protocol_engine.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import agg
from ..agg import dispatch as _agg_dispatch
from ..agg import rules as _agg_rules
from .attacks import ByzantineSpec, inject_gradients, inject_models
from .epochs import EpochRunner, delivery_cache_key, fn_cache_key
from .quorum import UniformDelivery

# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ProtocolConfig:
    n_groups: int                 # G = n_workers = n_servers (failure domains)
    f_workers: int
    f_servers: int
    q_workers: int
    q_servers: int
    T: int = 50                   # gather every T steps
    grad_microbatches: int = 1    # sequential accumulation per worker step
    engine: str = "sharded"       # 'naive' (paper volume) | 'sharded'
    pull: str = "median"          # 'median' (async variant) | 'roundrobin'
                                  # (sync variant §5: one model/step via
                                  # collective-permute + distance filter)
    gar: str = "mda"              # worker-gradient rule (selection-based:
                                  # aggregation = weights over 'rep')
    pull_gar: str = "median"      # model rule for the masked worker pull
    gather_gar: str = "median"    # model rule for the DMC gather
    optimizer: str = "sgd"        # repro.optim registry ref for the local
                                  # update (per-replica state in ByzState.opt)
    exchange_dtype: str = "float32"
    mda_exact_limit: int = 200_000
    chunk_bytes: int = 256 * 2**20   # stream leaves bigger than this over dim 1
    byz: ByzantineSpec = field(default_factory=ByzantineSpec)

    def __post_init__(self):
        # The sharded engine reduces gradients as weighted sums over 'rep',
        # so the gradient rule must be selection-based (convex weights); the
        # pull/DMC rule must take traced delivery masks.
        spec = agg.get(self.gar)
        if not spec.selection_based:
            raise ValueError(
                f"protocol gar={self.gar!r} must be selection-based; have "
                f"{[s.name for s in agg.specs() if s.selection_based]}")
        spec.validate(self.q_workers, self.f_workers)
        # masked_pull applies the rule per leaf chunk, so it must be a
        # coordinate-wise (leafwise) rule with a traced-mask implementation;
        # selection rules would pick a different sender subset per leaf.
        for role in ("pull_gar", "gather_gar"):
            name = getattr(self, role)
            pspec = agg.get(name)
            if pspec.tree_mode != "leafwise" or pspec.masked_fn is None:
                ok = [s.name for s in agg.specs()
                      if s.tree_mode == "leafwise" and s.masked_fn is not None]
                raise ValueError(f"{role}={name!r} must be a "
                                 f"coordinate-wise rule with traced-mask "
                                 f"support; have {ok}")
            pspec.validate(self.q_servers, self.f_servers)
        from .. import optim as _optim
        if self.optimizer not in _optim.OPTIMIZERS:
            raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                             f"have {sorted(_optim.OPTIMIZERS)}")

    @staticmethod
    def derive(R: int, divisor: int = 1, *, T: int = 50, engine: str = "sharded",
               exchange_dtype: str = "float32", grad_microbatches: int = 1,
               pull: str = "median", byz: ByzantineSpec | None = None,
               f_workers: int | None = None, f_servers: int | None = None,
               q_workers: int | None = None, q_servers: int | None = None,
               gar: str = "mda", pull_gar: str = "median",
               gather_gar: str = "median", optimizer: str = "sgd",
               mda_exact_limit: int = 200_000) -> "ProtocolConfig":
        """Resilience parameters for G = R // divisor groups.

        Defaults: f_w = (G-1)//3, f_ps = (G-2)//3 (the paper's
        asymptotically-optimal 1/3 bounds) and full-minus-f quorums. Explicit
        ``f_*``/``q_*``/GAR overrides let ``Experiment.to_protocol_config``
        lower a declared cluster shape exactly (so the 1-device protocol and
        the single-host simulator draw identical quorums)."""
        G = R // divisor
        f_w = max((G - 1) // 3, 0) if f_workers is None else f_workers
        f_ps = max((G - 2) // 3, 0) if f_servers is None else f_servers
        q_w = (G - f_w) if q_workers is None else q_workers
        q_ps = (max(G - f_ps, min(2 * f_ps + 2, G)) if q_servers is None
                else q_servers)
        return ProtocolConfig(n_groups=G, f_workers=f_w, f_servers=f_ps,
                              q_workers=q_w, q_servers=q_ps, T=T, engine=engine,
                              exchange_dtype=exchange_dtype,
                              grad_microbatches=grad_microbatches, pull=pull,
                              gar=gar, pull_gar=pull_gar,
                              gather_gar=gather_gar, optimizer=optimizer,
                              mda_exact_limit=mda_exact_limit,
                              byz=byz or ByzantineSpec())


class ByzState(NamedTuple):
    params: Any          # pytree, leaves [G, ...]
    t: jax.Array         # scalar int32
    key: jax.Array       # protocol PRNG (quorums / attacks)
    opt: Any = ()        # per-replica optimizer state (empty for sgd), leaves
                         # [G, ...] stacked/sharded like params


# ---------------------------------------------------------------------------
# sharding rules for replica-stacked leaves
# ---------------------------------------------------------------------------


# Explicit per-leaf layout table (Megatron conventions), matched by the leaf's
# final path component. COLUMN-parallel ([.., D_in, D_out]): 'model' on the
# OUTPUT dim (matches head-sharded attention activations and F-sharded MLP
# intermediates). ROW-parallel ([.., D_out_contraction, D]): 'model' on the
# contraction dim (output psum/reduce-scatter). Tables: 'model' on vocab.
# 'fsdp' (ZeRO intra-group axis, K>1 archs) takes the complementary dim.
# Heuristic placement caused layout churn ("involuntary full remat") — see
# EXPERIMENTS.md §Perf iteration log.
_COL_LEAVES = {"w_gate", "w_up", "cWk"}
# ROW for: contraction-sharded outputs (wo/w_down/...), projections whose
# outputs reshape across non-divisible head boundaries (rwkv mixers), and
# mamba's in_proj (its output is segment-sliced, so output sharding would cut
# across segment boundaries -> SPMD relayout churn / SIGFPE).
_ROW_LEAVES = {"wo", "w_down", "out_proj", "Wo", "cWv", "wB", "in_proj",
               "Wr", "Wk", "Wv", "Wg", "cWr", "wA"}
_TABLE_LEAVES = {"table", "pos_dec"}
# wq/wk/wv are COL iff the (kv-)head count divides |model| (else the head
# reshape fights the flat output sharding); decided per-arch via `overrides`.


def _place(body, picks, M, K):
    """picks: ((axis_name, dim_index), ...) — applied iff divisible."""
    spec = [None] * len(body)
    for name, idx in picks:
        size = M if name == "model" else K
        if size <= 1:
            continue
        i = idx % len(body)
        if spec[i] is None and body[i] % size == 0 and body[i] >= size:
            spec[i] = name
    return spec


def leaf_spec(shape: tuple[int, ...], mesh, *, leading_rep: bool = True,
              name: str = "", overrides: dict | None = None) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    M, K = sizes["model"], sizes["fsdp"]
    body = list(shape[1:]) if leading_rep else list(shape)
    mode = (overrides or {}).get(name)
    if mode == "col" and len(body) >= 2:
        spec = _place(body, (("model", -1), ("fsdp", -2)), M, K)
    elif mode == "row" and len(body) >= 2:
        spec = _place(body, (("model", -2), ("fsdp", -1)), M, K)
    elif name in _COL_LEAVES and len(body) >= 2:
        spec = _place(body, (("model", -1), ("fsdp", -2)), M, K)
    elif name in _ROW_LEAVES and len(body) >= 2:
        spec = _place(body, (("model", -2), ("fsdp", -1)), M, K)
    elif name in _TABLE_LEAVES and len(body) >= 2:
        spec = _place(body, (("model", -2), ("fsdp", -1)), M, K)
    else:
        # fallback: largest divisible dims (covers odd leaves). A size-1
        # axis never claims a dim — it would shard nothing while blocking
        # the other axis from the leaf's best dim. 'fsdp' DOES take
        # divisible 1D bodies (biases, norm scales): GSPMD propagates the
        # fsdp split onto them inside the epoch anyway, and an input left
        # replicated would mismatch that output layout and silently drop
        # the state donation (REPRO-HLO-DONATION, 2D lane).
        spec = [None] * len(body)
        order = sorted(range(len(body)), key=lambda i: -body[i])
        m_at = next((i for i in order if body[i] % M == 0 and body[i] >= M
                     and len(body) >= 2), None) if M > 1 else None
        if m_at is not None:
            spec[m_at] = "model"
        k_at = next((i for i in order
                     if i != m_at and body[i] % K == 0 and body[i] >= K), None)
        if k_at is not None and K > 1:
            spec[k_at] = "fsdp"
    if leading_rep:
        return P("rep", *spec)
    return P(*spec)


def attn_overrides(cfg, mesh) -> dict:
    """wq is COL-parallel when heads divide |model| (one x-gather feeds a
    local matmul with head-sharded output — ~3x cheaper than ROW's full-size
    output psum, §Perf iteration 11). wk/wv stay ROW-parallel always: COL +
    GQA kv reshapes trigger an XLA SPMD SIGFPE on this backend (iteration 9).
    """
    # COL wq re-triggers the SIGFPE even for divisible heads (iteration 11,
    # REFUTED) — all three stay ROW on this backend.
    del mesh
    return {"wq": "row", "wk": "row", "wv": "row"}


def _named_tree_shardings(shapes_tree, mesh, overrides: dict | None = None):
    """Per-leaf-name NamedShardings for a replica-stacked pytree. The leaf's
    final path component keys the layout table, so optimizer moment trees
    (which mirror the param tree's names) land on the same shards as their
    params."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
    out = []
    for path, leaf in flat:
        if leaf.ndim == 0 or leaf.size <= 2:
            out.append(NamedSharding(mesh, P()))
            continue
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        out.append(NamedSharding(mesh, leaf_spec(leaf.shape, mesh, name=name,
                                                 overrides=overrides)))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(state_shapes, mesh, overrides: dict | None = None):
    """NamedShardings for a ByzState shape-tree (per-leaf-name layout)."""
    params = _named_tree_shardings(state_shapes.params, mesh, overrides)
    opt = _named_tree_shardings(state_shapes.opt, mesh, overrides)
    scalar = NamedSharding(mesh, P())
    return ByzState(params=params, t=scalar, key=scalar, opt=opt)


def body_spec(body_shape: tuple[int, ...], mesh) -> tuple:
    """Sharding tuple for a replica-body (no leading axes): 'model' on the
    largest divisible dim, 'fsdp' on the next."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    M, K = sizes["model"], sizes["fsdp"]
    body = list(body_shape)
    spec: list = [None] * len(body)
    order = sorted(range(len(body)), key=lambda i: -body[i])
    m_at = next((i for i in order if body[i] % M == 0 and body[i] >= M),
                None) if M > 1 else None
    if m_at is not None:
        spec[m_at] = "model"
    k_at = next((i for i in order
                 if i != m_at and body[i] % K == 0 and body[i] >= K), None)
    if k_at is not None and K > 1:
        spec[k_at] = "fsdp"
    return tuple(spec)


def _replicaless_spec(shape, mesh) -> P:
    """Sharding for consolidated (serving) params: no 'rep' axis; combine
    ('rep','fsdp') on the fsdp-eligible dim for maximal spread."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    M, RK = sizes["model"], sizes["rep"] * sizes["fsdp"]
    body = list(shape)
    spec: list = [None] * len(body)
    order = sorted(range(len(body)), key=lambda i: -body[i])
    m_at = next((i for i in order if body[i] % M == 0 and body[i] >= M), None)
    if m_at is not None:
        spec[m_at] = "model"
    k_at = next((i for i in order
                 if i != m_at and body[i] % RK == 0 and body[i] >= RK), None)
    if k_at is not None:
        spec[k_at] = ("rep", "fsdp")
    return P(*spec)


# ---------------------------------------------------------------------------
# chunked leaf streaming (bounds all-gather transients on huge leaves)
# ---------------------------------------------------------------------------


def _map_dim1(fn, *leaves, mesh=None):
    """Apply fn across dim-1 slices of [G, L, ...] leaves.

    Implemented with fori_loop + dynamic_slice on the (unsharded) layer dim —
    NO transposes of sharded tensors (moveaxis of a ('rep', None, 'model')
    leaf triggers XLA SPMD "involuntary full rematerialization" = per-device
    replication of the whole stack). The loop-carried accumulator is
    explicitly constrained to the replica-stacked layout (otherwise XLA
    replicates it). Under the dry-run unroll context this becomes a python
    loop so cost_analysis counts every iteration.
    """
    from ..models import unroll_ctx
    L = leaves[0].shape[1]

    def slice_at(i):
        return tuple(jnp.squeeze(jax.lax.dynamic_slice_in_dim(l, i, 1, axis=1), 1)
                     for l in leaves)

    out0 = jax.eval_shape(fn, *(jax.eval_shape(lambda l: jnp.squeeze(l[:, :1], 1), l)
                                for l in leaves))
    if unroll_ctx.active():
        chunks = [fn(*slice_at(i)) for i in range(L)]
        return jnp.stack(chunks, axis=1)

    def body(i, acc):
        res = fn(*slice_at(i))
        return jax.lax.dynamic_update_slice_in_dim(acc, res[:, None], i, axis=1)

    init = jnp.zeros((out0.shape[0], L) + out0.shape[1:], out0.dtype)
    if mesh is not None:
        init = jax.lax.with_sharding_constraint(
            init, NamedSharding(mesh, P("rep", None,
                                        *body_spec(out0.shape[1:], mesh))))
    return jax.lax.fori_loop(0, L, body, init)


# streaming thresholds shared with the Gram path (repro.agg.tree)
_STREAM_MAX_DIM1 = agg.tree.STREAM_MAX_DIM1
_STREAM_N_CHUNKS = agg.tree.STREAM_N_CHUNKS


def _map_last_chunks(fn, *leaves, n_chunks: int, mesh=None):
    """Chunked streaming over the LAST (unsharded) dim — used for wide tables
    (embeddings: [G, V('model'), D]); slicing the sharded V dim would localise
    each chunk to a single device, so we slice D instead."""
    from ..models import unroll_ctx
    ax = leaves[0].ndim - 1
    D = leaves[0].shape[ax]
    csize = D // n_chunks

    def slice_at(i):
        return tuple(jax.lax.dynamic_slice_in_dim(l, i * csize, csize, axis=ax)
                     for l in leaves)

    out0 = jax.eval_shape(fn, *(jax.eval_shape(
        lambda l: jax.lax.slice_in_dim(l, 0, csize, axis=ax), l)
        for l in leaves))
    if unroll_ctx.active():
        return jnp.concatenate([fn(*slice_at(i)) for i in range(n_chunks)],
                               axis=ax)

    def body(i, acc):
        res = fn(*slice_at(i))
        return jax.lax.dynamic_update_slice_in_dim(acc, res, i * csize, axis=ax)

    full_shape = out0.shape[:ax] + (D,)
    init = jnp.zeros(full_shape, out0.dtype)
    if mesh is not None:
        init = jax.lax.with_sharding_constraint(
            init, NamedSharding(mesh, P("rep", *body_spec(full_shape[1:], mesh))))
    return jax.lax.fori_loop(0, n_chunks, body, init)


def _leaf_stream(fn, chunk_bytes: int, mesh=None):
    """Wrap a per-leaf op to stream over the layer-stack (or table-row) dim
    when large."""
    def apply(*leaves):
        l0 = leaves[0]
        big = l0.size * l0.dtype.itemsize > chunk_bytes
        if l0.ndim >= 3 and big and l0.shape[1] <= _STREAM_MAX_DIM1:
            return _map_dim1(fn, *leaves, mesh=mesh)
        if (l0.ndim >= 3 and big
                and l0.shape[-1] % _STREAM_N_CHUNKS == 0):
            return _map_last_chunks(fn, *leaves, n_chunks=_STREAM_N_CHUNKS,
                                    mesh=mesh)
        return fn(*leaves)
    return apply


# ---------------------------------------------------------------------------
# protocol ops
# ---------------------------------------------------------------------------


def masked_pull(params, masks, cfg: ProtocolConfig, mesh=None, rule=None):
    """Per-receiver masked aggregation over the replica axis.

    params leaves [G, ...]; masks [G_recv, G_send] bool. Returns leaves
    [G_recv, ...] — worker/server g's aggregated view of the replicas.
    The rule defaults to ``cfg.pull_gar`` (any registered rule with
    traced-mask support), the paper's Median; the DMC gather passes
    ``cfg.gather_gar``.
    """
    spec = agg.get(rule or cfg.pull_gar)

    def med_chunk(chunk):  # [G, ...]
        def one(mask):
            return spec(chunk.astype(jnp.float32), cfg.f_servers, mask=mask)
        out = jax.vmap(one)(masks).astype(chunk.dtype)
        if mesh is not None:
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P("rep", *body_spec(out.shape[1:], mesh))))
        return out

    op = _leaf_stream(med_chunk, cfg.chunk_bytes, mesh)
    return jax.tree.map(op, params)


# The [G, G] Gram over the full gradient is the shared streaming
# implementation in repro.agg.tree (leaf-partial dot_general + tiny psum,
# never a flattened [G, P] stack); re-exported here for the step builders.
tree_gram = agg.tree.tree_gram


def quorum_weights(d2: jax.Array, quorum_idx: jax.Array, f: int,
                   cfg: ProtocolConfig) -> jax.Array:
    """Per-server selection weights for the configured gradient rule.

    d2: [G, G] squared distances; quorum_idx: [G_recv, q] delivered worker
    indices per server. Restricts the distance matrix to each delivered
    quorum, asks the rule's ``weights_from_d2`` for averaging weights (rows
    sum to 1; one-hot for Krum), and scatters back to [G_recv, G_send]."""
    G = d2.shape[0]

    def one(idx):
        sub = d2[idx][:, idx]                       # [q, q]
        w = agg.selection_weights(cfg.gar, sub, f,
                                  exact_limit=cfg.mda_exact_limit)
        return jnp.zeros((G,), jnp.float32).at[idx].set(w)

    return jax.vmap(one)(quorum_idx)


def aggregate_gradients(grads, weights, cfg: ProtocolConfig, mesh=None):
    """G_hat[s] = sum_w weights[s, w] * grads[w]  (leaf-wise, streamed).

    naive engine: materialise the all-gathered gradient stack per chunk
    (replicate over 'rep' only, body sharding preserved); sharded engine:
    leave the contraction to XLA. Ring-model traffic is the same either way
    — (G-1)·P per device, HLO-audited by ``repro.analyze`` — the engines
    differ in whether the [G, ...] operand stack is materialised per device
    (temp memory) before the dot."""
    dt = jnp.dtype(cfg.exchange_dtype)

    def agg_chunk(chunk):  # [G, ...]
        c = chunk.astype(dt)
        if cfg.engine == "naive" and mesh is not None:
            c = jax.lax.with_sharding_constraint(
                c, NamedSharding(mesh, P(None, *body_spec(c.shape[1:], mesh))))
        out = jax.lax.dot_general(weights.astype(dt), c,
                                  (((1,), (0,)), ((), ())))  # [G_recv, ...]
        if mesh is not None:
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P("rep", *body_spec(out.shape[1:], mesh))))
        return out

    op = _leaf_stream(agg_chunk, cfg.chunk_bytes, mesh)
    return jax.tree.map(op, grads)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_init_fn(bundle, pcfg: ProtocolConfig):
    """Returns init(key) -> ByzState with replica-stacked params (and, for
    stateful optimizers, replica-stacked moment state)."""
    from .. import optim as _optim
    pdt = jnp.dtype(bundle.cfg.param_dtype)
    opt = _optim.get(pcfg.optimizer)

    def init(key):
        k_model, k_run = jax.random.split(key)
        p0 = bundle.init(k_model)
        p0 = jax.tree.map(lambda l: l.astype(pdt), p0)
        params = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (pcfg.n_groups,) + l.shape), p0)
        return ByzState(params=params, t=jnp.zeros((), jnp.int32), key=k_run,
                        opt=opt.init(params))

    return init


def make_scatter_step(bundle, pcfg: ProtocolConfig, lr_schedule,
                      with_attack: bool = False, mesh=None, delivery=None):
    """One ByzSGD scatter step. batch leaves: [G, per_group, ...].

    ``delivery`` is a :class:`~repro.core.quorum.DeliveryModel`; the default
    ``UniformDelivery`` over G-of-G nodes draws the same quorums (same key
    chain and split order) as the single-host simulator's scatter step, which
    is what makes the simulator the protocol's oracle. A netsim
    ``TraceDelivery`` replays realized quorums instead.
    """
    from .. import optim as _optim
    G = pcfg.n_groups
    delivery = delivery or UniformDelivery(G, G, pcfg.q_workers,
                                           pcfg.q_servers)
    optimizer = _optim.get(pcfg.optimizer)

    overrides = attn_overrides(bundle.cfg, mesh) if mesh is not None else {}

    def _constrain_like_params(tree):
        if mesh is None:
            return tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, l in flat:
            if l.ndim >= 1 and l.size > 2:
                nm = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
                l = jax.lax.with_sharding_constraint(
                    l, NamedSharding(mesh, leaf_spec(l.shape, mesh, name=nm,
                                                     overrides=overrides)))
            out.append(l)
        return jax.tree_util.tree_unflatten(treedef, out)

    def scatter_step(state: ByzState, batch):
        # split order matches ByzSGDSimulator.scatter_step exactly, so with
        # UniformDelivery and identical init the two paths draw the same
        # quorums step for step (the oracle equivalence)
        key, k_pull, k_matk, k_push, k_gatk = jax.random.split(state.key, 5)
        eta = lr_schedule(state.t).astype(jnp.float32)

        # 1. worker pull ------------------------------------------------------
        models = state.params
        if with_attack and pcfg.byz.server_attack:
            models = inject_models(models, pcfg.byz, k_matk)
        if pcfg.pull == "roundrobin":
            # synchronous variant (paper §5): each worker pulls ONE model via
            # a ring permutation over 'rep' (lowers to collective-permute,
            # O(P) vs the Median pull's O((q-1)P)), validated by a distance
            # filter against the worker's own replica (the Outliers filter of
            # Eq. 14 anchored locally; on rejection the worker falls back to
            # its own replica — a conservative, honest model by definition.
            # The Lipschitz filter needs the previous gradient: carried only
            # in the faithful simulator, where memory is free).
            idx = (jnp.arange(G) + state.t + 1) % G
            pulled = jax.tree.map(lambda l: jnp.take(l, idx, axis=0), models)
            own = state.params
            d2g = None
            n2g = None
            for pl, ow in zip(jax.tree.leaves(pulled), jax.tree.leaves(own)):
                ax = tuple(range(1, pl.ndim))
                d = jnp.sum((pl.astype(jnp.float32)
                             - ow.astype(jnp.float32)) ** 2, axis=ax)
                n = jnp.sum(ow.astype(jnp.float32) ** 2, axis=ax)
                d2g = d if d2g is None else d2g + d
                n2g = n if n2g is None else n2g + n
            growth = ((3.0 * pcfg.T + 2.0) * (G - pcfg.f_workers)
                      / (4.0 * max(pcfg.f_workers, 1)))
            bound2 = (eta * growth) ** 2 * n2g + 1e-6
            ok = d2g <= bound2                      # [G] per-worker verdict
            pulled = jax.tree.map(
                lambda p, o: jnp.where(
                    ok.reshape((G,) + (1,) * (p.ndim - 1)), p, o), pulled, own)
        else:
            # asynchronous variant: masked Median over the delivered quorum
            pull_idx = delivery.pull_indices(k_pull, state.t)
            pull_masks = jnp.zeros((G, G), bool).at[
                jnp.arange(G)[:, None], pull_idx].set(True)
            pulled = masked_pull(models, pull_masks, pcfg, mesh)
        pulled = jax.tree.map(
            lambda l: l.astype(jnp.dtype(bundle.cfg.act_dtype))
            if l.dtype == jnp.float32 else l, pulled)

        # 2. per-group worker gradients (vmap over 'rep'), accumulated over
        # grad_microbatches sequential micro-steps (bounds activation memory;
        # the batch arrives with a leading micro axis when n_micro > 1) ------
        gfn = jax.vmap(jax.grad(bundle.loss),
                       spmd_axis_name="rep" if mesh is not None else None)
        if pcfg.grad_microbatches > 1:
            from ..models import unroll_ctx as _uctx

            if _uctx.active():  # cost-probe: vmap micro-steps (flop-identical)
                gm = jax.vmap(gfn, in_axes=(None, 0))(pulled, batch)
                grads = jax.tree.map(
                    lambda x: jnp.mean(x.astype(jnp.float32), axis=0), gm)
            else:
                def micro_body(acc, mb):
                    g = gfn(pulled, mb)
                    return jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32)
                        / pcfg.grad_microbatches, acc, g), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
                zeros = _constrain_like_params(zeros)
                grads, _ = jax.lax.scan(micro_body, zeros, batch)
        else:
            grads = gfn(pulled, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.dtype(pcfg.exchange_dtype)),
                             grads)
        grads = _constrain_like_params(grads)
        if with_attack and pcfg.byz.worker_attack:
            grads = inject_gradients(grads, pcfg.byz, k_gatk)

        # 3. gradient rule (MDA by default) per server group over its quorum ---
        push_idx = delivery.push_indices(k_push, state.t)
        d2 = agg.rules.sqdists_from_gram(tree_gram(grads, mesh))
        weights = quorum_weights(d2, push_idx, pcfg.f_workers, pcfg)
        g_hat = aggregate_gradients(grads, weights, pcfg, mesh)

        # 4. local update (paper Eq. 2 for sgd; per-replica moments ride in
        # state.opt for stateful optimizers) -----------------------------------
        new_params, new_opt = optimizer.update(g_hat, state.opt, state.params,
                                               eta)
        return ByzState(params=new_params, t=state.t + 1, key=key,
                        opt=new_opt)

    return scatter_step


def make_gather_step(pcfg: ProtocolConfig, with_attack: bool = False,
                     mesh=None, delivery=None):
    """DMC: servers exchange replicas and apply the masked ``gather_gar``
    (Median by default) every T steps."""
    G = pcfg.n_groups
    delivery = delivery or UniformDelivery(G, G, pcfg.q_workers,
                                           pcfg.q_servers)

    def gather_step(state: ByzState):
        key, k_q, k_atk = jax.random.split(state.key, 3)
        idx = delivery.gather_indices(k_q, state.t)
        masks = jnp.zeros((G, G), bool).at[jnp.arange(G)[:, None], idx].set(True)
        models = state.params
        if with_attack and pcfg.byz.server_attack:
            models = inject_models(models, pcfg.byz, k_atk)
        new_params = masked_pull(models, masks, pcfg, mesh,
                                 rule=pcfg.gather_gar)
        new_params = jax.tree.map(lambda n, p: n.astype(p.dtype),
                                  new_params, state.params)
        return ByzState(params=new_params, t=state.t, key=key, opt=state.opt)

    return gather_step


def make_train_step(bundle, pcfg: ProtocolConfig, lr_schedule,
                    with_attack: bool = False, mesh=None, delivery=None):
    """Fused step: scatter, then DMC gather iff t % T == 0 (lax.cond)."""
    delivery = delivery or UniformDelivery(
        pcfg.n_groups, pcfg.n_groups, pcfg.q_workers, pcfg.q_servers)
    scatter = make_scatter_step(bundle, pcfg, lr_schedule, with_attack, mesh,
                                delivery)
    gather = make_gather_step(pcfg, with_attack, mesh, delivery)

    def train_step(state: ByzState, batch):
        state = scatter(state, batch)
        return jax.lax.cond(state.t % pcfg.T == 0, gather, lambda s: s, state)

    return train_step


# ---------------------------------------------------------------------------
# serving-side consolidation
# ---------------------------------------------------------------------------


def consolidate(params, pcfg: ProtocolConfig, chunk_bytes: int | None = None):
    """Median-of-replicas -> single serving model (DMC applied once, full
    delivery). The serving path is vanilla DP x TP decode (DESIGN.md §5)."""
    cb = chunk_bytes or pcfg.chunk_bytes

    def med(leaf):
        def chunk_fn(c):
            return jnp.median(c.astype(jnp.float32), axis=0).astype(c.dtype)
        if (leaf.ndim >= 3 and leaf.shape[1] <= _STREAM_MAX_DIM1
                and leaf.size * leaf.dtype.itemsize > cb):
            L = leaf.shape[1]
            def body(i, acc):
                sl = jnp.squeeze(jax.lax.dynamic_slice_in_dim(leaf, i, 1, 1), 1)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, chunk_fn(sl)[None], i, axis=0)
            out0 = jax.eval_shape(chunk_fn,
                                  jax.eval_shape(lambda l: jnp.squeeze(l[:, :1], 1), leaf))
            init = jnp.zeros((L,) + out0.shape, out0.dtype)
            return jax.lax.fori_loop(0, L, body, init)
        return chunk_fn(leaf)

    return jax.tree.map(med, params)


# ---------------------------------------------------------------------------
# fused epoch engine over the protocol (repro.core.epochs scaffolding)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _ProblemCfg:
    """Dtype carrier for paper-scale problems driven through the protocol
    step builders (the LM path passes full model-bundle configs instead)."""
    param_dtype: str = "float32"
    act_dtype: str = "float32"


@dataclass(frozen=True)
class ProblemBundle:
    """Minimal bundle adapter: wraps an ``(init_fn, loss_fn)`` problem (the
    repro.configs.paper_models factories) into the ``bundle`` interface the
    protocol step builders expect (``init``/``loss``/``cfg`` dtypes)."""
    init: Callable
    loss: Callable
    cfg: _ProblemCfg = field(default_factory=_ProblemCfg)


class ProtocolEngine(EpochRunner):
    """Fused multi-device epochs over the distributed ByzSGD protocol.

    The same scan/donation treatment ``repro.core.engine.EpochEngine`` gives
    the single-host simulator, applied to the replica-stacked (and, with a
    mesh, 'rep'-sharded) :class:`ByzState` for BOTH collective engines
    ('naive' | 'sharded'): one donated ``lax.scan`` per epoch whose body runs
    the scatter step and applies the DMC gather when the carried counter hits
    a multiple of T (``lax.cond`` — chunk lengths and run tails stay correct),
    with per-group metrics (accuracy on group 0's replica, the Lemma-4.2/4.3
    diameters) reduced on device into the scan's output buffers — ONE host
    transfer per ``run``.

    Epoch executables share the bounded semantic compile cache of
    ``repro.core.epochs`` (keyed on ProtocolConfig + loss/lr cache keys +
    delivery + mesh + metric flags), so spec sweeps over the protocol runner
    reuse compiled epochs. With the default ``UniformDelivery`` and
    ``pull="median"`` (the asynchronous schedule) the engine draws the same
    quorums as ``ByzSGDSimulator`` — the single-host engine is its oracle
    (params allclose, metrics identical on a 1-device mesh). The
    ``pull="roundrobin"`` mode is the protocol's own §5 collective
    formulation (ring permutation + distance filter); it is NOT oracle-matched
    against the simulator's sync filter variant.
    """

    def __init__(self, bundle, pcfg: ProtocolConfig, lr_schedule, *,
                 mesh=None, delivery=None, with_attack: bool = False,
                 acc_fn: Callable | None = None, eval_set: tuple | None = None,
                 track_delta: bool = False, metrics_every: int = 1,
                 rules: dict | None = None):
        if (acc_fn is None) != (eval_set is None):
            raise ValueError("acc_fn and eval_set must be given together")
        if metrics_every < 1:
            raise ValueError("metrics_every must be >= 1")
        self.bundle = bundle
        self.cfg = pcfg
        self.lr = lr_schedule
        self.mesh = mesh
        self.rules = dict(rules) if rules else None
        self.with_attack = with_attack
        self.delivery = delivery or UniformDelivery(
            pcfg.n_groups, pcfg.n_groups, pcfg.q_workers, pcfg.q_servers)
        self.acc_fn = acc_fn
        self.eval_set = eval_set
        self.track_delta = track_delta
        self.metrics_every = metrics_every
        self._epoch = self._get_or_build()

    # -- state -------------------------------------------------------------
    def init_state(self, key: jax.Array) -> ByzState:
        """Replica-stacked initial state (same PRNG chain as
        ``ByzSGDSimulator.init_state``: one split into model/run keys). With a
        mesh, the state is placed onto the per-leaf-name layouts."""
        init = make_init_fn(self.bundle, self.cfg)
        state = jax.jit(init)(key)
        if self.mesh is not None:
            shardings = state_shardings(
                jax.eval_shape(init, key), self.mesh,
                overrides=attn_overrides(self.bundle.cfg, self.mesh))
            state = jax.tree.map(jax.device_put, state, shardings)
        return state

    # -- epochs ------------------------------------------------------------
    def _flags(self):
        return (fn_cache_key(self.acc_fn), self.track_delta,
                self.metrics_every, self.with_attack,
                _agg_rules.sort_network_enabled(),
                _agg_dispatch.default_backend())

    def _cache_key(self):
        mesh_key = None if self.mesh is None else id(self.mesh)
        rules_key = (None if self.rules is None
                     else tuple(sorted(self.rules.items())))
        return ("protocol-epoch", self.cfg, fn_cache_key(self.bundle.loss),
                fn_cache_key(self.bundle.init), fn_cache_key(self.lr),
                delivery_cache_key(self.delivery), mesh_key, rules_key,
                *self._flags())

    def _instance_key(self):
        return ("protocol-epoch-inst", id(self), *self._flags())

    def _extra_args(self):
        if self.eval_set is not None:
            return self.eval_set
        return (jnp.zeros(()), jnp.zeros(()))

    def _build(self):
        pcfg = self.cfg
        T = pcfg.T
        h = pcfg.n_groups - pcfg.byz.n_byz_servers
        track_delta, acc_fn = self.track_delta, self.acc_fn
        metrics_every = self.metrics_every
        scatter = make_scatter_step(self.bundle, pcfg, self.lr,
                                    self.with_attack, self.mesh,
                                    self.delivery)
        gather = make_gather_step(pcfg, self.with_attack, self.mesh,
                                  self.delivery)

        def step_metrics(state: ByzState, delta_pre, eval_x, eval_y):
            m = {}
            if acc_fn is not None:
                def ev(_):
                    return acc_fn(jax.tree.map(lambda l: l[0], state.params),
                                  eval_x, eval_y)

                if metrics_every == 1:
                    m["acc"] = ev(None)
                else:
                    m["acc"] = lax.cond((state.t - 1) % metrics_every == 0,
                                        ev, lambda _: jnp.float32(0.0), None)
            if track_delta:
                from .simulator import (coordinatewise_diameter_sum,
                                        l2_diameter)
                m["delta_pre"] = delta_pre
                m["delta"] = coordinatewise_diameter_sum(state.params, h)
                m["l2_diam"] = l2_diameter(state.params, h)
            return m

        def epoch(state: ByzState, batches, eval_x, eval_y):
            def body(state, batch):
                state = scatter(state, batch)
                if track_delta:
                    from .simulator import coordinatewise_diameter_sum
                    delta_pre = coordinatewise_diameter_sum(state.params, h)
                else:
                    delta_pre = None
                # post-step boundary, like the async simulator: the gather
                # closes the scatter phase when t (already advanced) hits T
                state = lax.cond(state.t % T == 0, gather, lambda s: s, state)
                return state, step_metrics(state, delta_pre, eval_x, eval_y)

            return lax.scan(body, state, batches)

        if self.rules:
            # install the model's logical activation-sharding rules for the
            # whole epoch trace (loss fwd/bwd AND the in-scan eval), exactly
            # like the launch driver wraps its train step
            from ..models import sharding as shrules
            rules, inner_epoch = self.rules, epoch

            def epoch(state, batches, eval_x, eval_y):
                with shrules.sharding_rules(rules):
                    return inner_epoch(state, batches, eval_x, eval_y)

        return jax.jit(epoch, donate_argnums=(0,))


def collective_volume_bytes(pcfg: ProtocolConfig, n_params: int,
                            *, fsdp: int = 1) -> int:
    """Modeled per-device cross-'rep' collective exchange (bytes) of one
    scatter step's model/gradient payloads, HLO-verified by the compiled-
    artifact auditor (``repro.analyze``, REPRO-HLO-COLLECTIVES):

    * **pull** — the masked Median pull is an order statistic over the full
      replica stack, so it all-gathers ``[G, P]``: ``(G-1)·P·itemsize`` per
      device, for BOTH engines;
    * **push** — the ``[G_recv, G_send] x [G_send, P]`` weighted aggregation
      moves ``(G-1)·P·itemsize`` per device whichever way XLA lowers it
      (all-gather the operand stack, or partial-dot + reduce-scatter of the
      equally-sized ``[G, P]`` result — ring-model bytes are identical).

    Earlier revisions modeled the sharded engine at ``~2·P`` (a reduce-
    scatter of ONE replica's payload); auditing the compiled HLO showed
    XLA lowers both engines to the same ``(G-1)·P`` exchanges at these
    shapes — the engines differ in *temp memory* (the naive engine
    materializes the replicated stack per device; see
    ``aggregate_gradients``), not in ring-model traffic. The model covers
    the exchange primitives (``masked_pull`` + ``aggregate_gradients``);
    distance/Gram traffic for the selection weights rides on top.

    With the 'fsdp' axis lit (``fsdp`` = K > 1) each device holds 1/K of
    every replica's payload, so both exchanges ring-shift 1/K of the bytes:
    the all-gather result is the fsdp-sharded ``[G, P/K]`` stack, not the
    full ``[G, P]``. The default K=1 is the 1D model. Leaves whose dims K
    does not divide stay replicated and move full-size — the HLO audit's
    10% tolerance absorbs that remainder at repo shapes."""
    itemsize = jnp.dtype(pcfg.exchange_dtype).itemsize
    G = pcfg.n_groups
    return 2 * (G - 1) * n_params * itemsize // fsdp
