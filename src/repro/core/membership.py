"""Elastic fleet membership — join/leave-tolerant protocol training.

The distributed protocol (``core/protocol.py``) bakes G = n_groups co-located
worker+server groups into the mesh at launch; a crashed group is fatal. This
module makes membership a *declarative plan* over virtual steps, and the
elastic runner (``repro.exp`` ``runner="elastic"``) chunks the fused protocol
epochs at every membership boundary:

* :class:`MembershipPlan` — a sorted tuple of :class:`MembershipEvent`
  (``leave``/``join`` of a group id at a virtual step), authored directly or
  lowered from a realized ``netsim`` crash trace (:func:`plan_from_trace` —
  crash-recover is leave-then-join of the same group).
* :func:`MembershipPlan.epochs` — segments ``[0, steps)`` into
  :class:`MembershipEpoch` windows with a constant active-group set each.
* :func:`epoch_config` — re-derives the resilience parameters for the shrunk
  (or regrown) fleet, re-validating the paper's Table-1 bounds
  (``n_ps >= 3f_ps+2``, ``n_w >= 3f_w+1``) at every transition. Shrinking
  below the floor of the *actually present* Byzantine nodes is a hard,
  well-reported :class:`MembershipFloorError`, never a silent wedge.
* :func:`reform_params` — maps a replica-stacked params tree from one active
  set to the next. A re-admitted group is seeded from the coordinate-wise
  median of the survivors — the DMC contraction rule, whose Scatter/Gather
  drift bound (paper Lemma 4.3) is what makes late-joiner catch-up sound.

Quorum derivation under churn: the *declared* (f_w, f_ps) bound the adversary,
but a shrunk fleet may not be able to honour them. The effective per-epoch
resilience is ``f' = min(declared f, structural max for G')`` with
full-minus-f quorums, so a fleet that regrows returns to exactly the declared
configuration — an empty plan reproduces ``runner="protocol"`` bit for bit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .quorum import validate_counts


class MembershipFloorError(ValueError):
    """A membership transition would violate the Table-1 resilience floor
    (or leave no survivor to seed from). Raised at plan validation or at the
    epoch boundary — a hard failure, never a silent wedge."""


@dataclass(frozen=True)
class MembershipEvent:
    """One membership change at a virtual-step boundary: ``group`` leaves or
    (re-)joins *before* step ``step`` executes."""
    step: int
    kind: str          # "leave" | "join"
    group: int

    def __post_init__(self):
        if self.kind not in ("leave", "join"):
            raise ValueError(f"unknown membership event kind {self.kind!r}; "
                             "choose 'leave' or 'join'")
        if self.step < 1:
            raise ValueError(f"membership events happen at step boundaries "
                             f">= 1, got step={self.step}")
        if self.group < 0:
            raise ValueError(f"group must be >= 0, got {self.group}")


@dataclass(frozen=True)
class MembershipEpoch:
    """A maximal step window with a constant active-group set."""
    start: int
    stop: int
    active: tuple[int, ...]   # sorted group ids


@dataclass(frozen=True)
class MembershipPlan:
    """A declarative join/leave schedule in virtual steps (empty = static
    fleet). Events are normalized to (step, kind, group) order so two plans
    with the same events are equal and hash-stable."""
    events: tuple[MembershipEvent, ...] = ()

    def __post_init__(self):
        evs = []
        for ev in self.events:
            if isinstance(ev, dict):
                ev = MembershipEvent(step=int(ev["step"]),
                                     kind=str(ev["kind"]),
                                     group=int(ev["group"]))
            if not isinstance(ev, MembershipEvent):
                raise TypeError("MembershipPlan events must be "
                                f"MembershipEvent, got {type(ev).__name__}")
            evs.append(ev)
        evs.sort(key=lambda e: (e.step, e.kind, e.group))
        object.__setattr__(self, "events", tuple(evs))

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MembershipPlan":
        return cls(events=tuple(d.get("events", ())))

    # -- lowering to constant-membership windows ---------------------------
    def epochs(self, n_groups: int,
               steps: int) -> tuple[MembershipEpoch, ...]:
        """Segment ``[0, steps)`` into constant-membership windows, starting
        from ``active = {0..n_groups-1}``. Validates the plan against the run
        shape: events must land inside the run, a group must be active to
        leave and inactive to join (joins beyond the launch G are allowed —
        a genuinely new group id can enlist)."""
        by_step: dict[int, list[MembershipEvent]] = {}
        for ev in self.events:
            if ev.step >= steps:
                raise ValueError(
                    f"membership event at step {ev.step} is outside the run "
                    f"(steps={steps})")
            by_step.setdefault(ev.step, []).append(ev)
        active = set(range(n_groups))
        out = []
        start = 0
        for step in sorted(by_step):
            if step > start:
                out.append(MembershipEpoch(start, step,
                                           tuple(sorted(active))))
                start = step
            for ev in by_step[step]:
                if ev.kind == "leave":
                    if ev.group not in active:
                        raise ValueError(f"group {ev.group} leaves at step "
                                         f"{ev.step} but is not active")
                    active.remove(ev.group)
                else:
                    if ev.group in active:
                        raise ValueError(f"group {ev.group} joins at step "
                                         f"{ev.step} but is already active")
                    active.add(ev.group)
        out.append(MembershipEpoch(start, steps, tuple(sorted(active))))
        return tuple(out)


def epoch_config(pcfg0, active: tuple[int, ...], *,
                 synchronous: bool = False):
    """The :class:`~repro.core.protocol.ProtocolConfig` governing one
    membership epoch.

    Identity when the fleet is at the launch size (``len(active) ==
    pcfg0.n_groups``) — declared quorums pass through untouched, which is what
    makes an empty plan bit-identical to ``runner="protocol"``. Otherwise the
    effective resilience is churn-driven: ``f' = min(declared f, structural
    max for G')`` with full-minus-f quorums, re-validated against Table 1.
    Shrinking below the floor of the *declared-present* Byzantine counts
    raises :class:`MembershipFloorError`."""
    Gp = len(active)
    if Gp == pcfg0.n_groups:
        return pcfg0
    if Gp < 2:
        raise MembershipFloorError(
            f"membership shrank to {Gp} group(s) (active={active}); the "
            "protocol needs >= 2 groups to form any quorum")
    # the quorum window 2f_w+1 <= q_w <= G'-f_w caps f_w at (G'-1)//3 in
    # both variants (sync's cheaper n_w >= 2f_w+1 bound never binds first)
    f_w_max = (Gp - 1) // 3
    f_ps_max = max((Gp - 2) // 3, 0)
    f_w = min(pcfg0.f_workers, f_w_max)
    f_ps = min(pcfg0.f_servers, f_ps_max)
    byz = pcfg0.byz
    if byz.n_byz_workers > f_w or byz.n_byz_servers > f_ps:
        raise MembershipFloorError(
            f"shrinking to G'={Gp} caps the tolerable faults at "
            f"f_w'={f_w}, f_ps'={f_ps}, below the declared-present Byzantine "
            f"counts ({byz.n_byz_workers} workers, {byz.n_byz_servers} "
            "servers) — the surviving fleet cannot outvote the adversary "
            "(Table 1: n_w >= 3f_w+1, n_ps >= 3f_ps+2)")
    q_w = Gp - f_w
    q_ps = max(Gp - f_ps, min(2 * f_ps + 2, Gp))
    try:
        validate_counts(Gp, f_w, Gp, f_ps, q_w, q_ps,
                        synchronous=synchronous)
    except ValueError as err:
        raise MembershipFloorError(
            f"membership transition to active={active} (G'={Gp}) violates "
            f"the resilience preconditions: {err}") from err
    return dataclasses.replace(pcfg0, n_groups=Gp, f_workers=f_w,
                               f_servers=f_ps, q_workers=q_w, q_servers=q_ps)


def reform_params(params, old_active: tuple[int, ...],
                  new_active: tuple[int, ...]):
    """Re-stack replica params from one active set to the next.

    Survivor rows are carried over; a joining group's replica is seeded from
    the coordinate-wise median of the survivors (the DMC contraction rule —
    the joiner lands inside the honest-parameter diameter, so the paper's
    Scatter/Gather drift bound covers its catch-up). Leaves keep their dtypes;
    the median runs in float32 like every DMC site in the repo."""
    idx = {g: i for i, g in enumerate(old_active)}
    survivors = [g for g in new_active if g in idx]
    if not survivors:
        raise MembershipFloorError(
            f"no surviving group between active sets {old_active} -> "
            f"{new_active}; nothing to seed the new fleet from")
    src = jnp.asarray([idx.get(g, 0) for g in new_active], jnp.int32)
    join_mask = np.asarray([g not in idx for g in new_active], bool)
    take = jnp.asarray([idx[g] for g in survivors], jnp.int32)

    def leaf(l):
        out = jnp.take(l, src, axis=0)
        if join_mask.any():
            med = jnp.median(jnp.take(l, take, axis=0).astype(jnp.float32),
                             axis=0).astype(l.dtype)
            m = jnp.asarray(join_mask.reshape((-1,) + (1,) * (l.ndim - 1)))
            out = jnp.where(m, med[None], out)
        return out

    return jax.tree.map(leaf, params)


def plan_from_trace(scenario, trace) -> MembershipPlan:
    """Lower a realized netsim run into a :class:`MembershipPlan`.

    A protocol group is down while its server node (id g) — or, for the
    co-located G-group shape (n_workers == n_servers), its worker node
    (id n_servers + g) — sits inside a ``CrashPlan`` window. The *leave* step
    maps through the trace's realized step-completion times (the group leaves
    before the first step finishing after ``t_down``). The *join* step maps
    the outage duration through the honest pre-crash step rate: the trace's
    ``step_done_ms`` is the max over servers, so after ``t_up`` the recovered
    laggard replays its backlog almost instantly and the wall-clock mapping
    would compress any outage to one step — but the *survivors* keep stepping
    at the honest rate throughout, and their step clock is what membership is
    measured in. Windows that resolve before step 1 or open after the run are
    dropped; a crash whose recovery maps past the run is a leave without a
    join."""
    done = np.maximum.accumulate(np.asarray(trace.step_done_ms, np.float64))
    steps = len(done)
    # honest per-step duration from the pre-crash prefix (overall median when
    # a crash opens immediately)
    t_first = min((w.t_down for w in scenario.faults.crashes.windows),
                  default=np.inf)
    k_first = int(np.searchsorted(done, t_first, side="left"))
    diffs = np.diff(done[:k_first]) if k_first >= 2 else np.diff(done)
    rate = max(float(np.median(diffs)) if diffs.size else 1.0, 1e-9)
    colocated = scenario.n_workers == scenario.n_servers
    events = []
    for g in range(scenario.n_servers):
        nodes = {g} | ({scenario.n_servers + g} if colocated else set())
        iv = sorted((w.t_down, w.t_up)
                    for w in scenario.faults.crashes.windows
                    if w.node in nodes)
        merged: list[list[float]] = []
        for lo, hi in iv:
            if merged and lo <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], hi)
            else:
                merged.append([lo, hi])
        for lo, hi in merged:
            leave = max(int(np.searchsorted(done, lo, side="left")), 1)
            if leave >= steps:
                continue
            join = (leave + max(int(round((hi - lo) / rate)), 1)
                    if np.isfinite(hi) else steps)
            events.append(MembershipEvent(step=leave, kind="leave", group=g))
            if join < steps:
                events.append(MembershipEvent(step=join, kind="join",
                                              group=g))
    return MembershipPlan(events=tuple(events))
