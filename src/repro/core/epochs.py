"""Shared fused-epoch machinery — one scan/donation core, every engine.

Extracted from ``repro.core.engine`` (PR 3) so the single-host
:class:`~repro.core.engine.EpochEngine` and the distributed
:class:`~repro.core.protocol.ProtocolEngine` build on the same scaffolding
instead of duplicating it:

* **semantic compile cache** — epoch executables live in a bounded
  module-level cache keyed on the engine's *semantic* static configuration
  (config dataclass + callable ``cache_key``s + delivery model + metric
  flags), so parameter sweeps that rebuild engines per point reuse the
  compiled epoch instead of re-tracing (:func:`fn_cache_key`,
  :func:`delivery_cache_key`);
* **donated scan epochs** — subclasses provide ``_build()`` returning ONE
  jitted ``epoch(state, batches[L], *extras) -> (state, metrics_buf)``;
  :meth:`EpochRunner.run_epoch` invokes it with the carried state donated
  (and the donation-is-a-no-op-on-CPU warning suppressed per call);
* **chunked full runs with one host transfer** — :meth:`EpochRunner.run`
  drives any number of steps through compiled epochs from either a stacked
  batch pytree or a device stream, concatenating the on-device metric
  buffers with a single ``device_get`` at the end. Any ``epoch_steps`` chunk
  length is correct because the engines drive their gather boundary off the
  *carried* step counter, never the chunking.

The gather-boundary ``lax.cond`` logic itself stays with each engine (the
single-host engine distinguishes async/sync off-by-ones, the protocol always
gathers post-step), but both ride on this module's cache + run loop.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Callable

import jax
import numpy as np

from .quorum import UniformDelivery


def fn_cache_key(fn: Callable | None) -> tuple:
    """A hashable key identifying a callable's *semantics* for compile-cache
    reuse. ``functools.partial`` trees and callables exposing ``cache_key``
    (the repro.optim.schedules factories) key structurally — two sweep points
    built from the same factory with equal arguments share an executable.
    Anything else keys on object identity (always correct, never shared)."""
    if fn is None:
        return ("none",)
    ck = getattr(fn, "cache_key", None)
    if ck is not None:
        return ("ck", ck)
    if isinstance(fn, functools.partial):
        return ("partial", fn_cache_key(fn.func), fn.args,
                tuple(sorted(fn.keywords.items())))
    return ("fn", fn)


def delivery_cache_key(delivery) -> tuple:
    """UniformDelivery keys structurally; trace-backed models carry device
    arrays and key on identity."""
    if isinstance(delivery, UniformDelivery):
        return ("uniform", delivery.n_workers, delivery.n_servers,
                delivery.q_workers, delivery.q_servers)
    return (type(delivery).__name__, id(delivery))


# Semantic-key -> jitted epoch executable. Entries close over their engine's
# step functions (and, for TraceDelivery, staged trace arrays), so the cache
# is bounded: oldest entries are evicted past _EPOCH_CACHE_MAX to keep long
# sweeps over identity-keyed deliveries from pinning memory for the process
# lifetime. Single-host and protocol engines share the one cache (their keys
# are tagged differently).
_EPOCH_CACHE: dict[Any, Callable] = {}
_EPOCH_CACHE_MAX = 64

# Monotone count of cache MISSES (actual `_build` invocations = re-traces).
# The compiled-artifact auditor (repro.analyze, REPRO-HLO-RECOMPILE) sweeps
# semantically-identical and semantically-distinct engine configs against
# this sentinel to prove the cache key is complete end-to-end: identical
# configs must not increment it, distinct ones must.
_BUILD_COUNT = 0


def epoch_cache_size() -> int:
    return len(_EPOCH_CACHE)


def epoch_build_count() -> int:
    return _BUILD_COUNT


def clear_epoch_cache() -> None:
    _EPOCH_CACHE.clear()


class EpochRunner:
    """Scan/donation epoch scaffolding shared by the engines.

    Subclass contract:

    * ``_build() -> Callable`` — construct the jitted epoch function
      ``epoch(state, batches, *extras) -> (state, metrics_buf)`` with the
      state argument donated;
    * ``_cache_key() -> tuple`` — the semantic cache key (may contain
      unhashable parts; the base class falls back to a private
      instance-identity key);
    * ``_instance_key() -> tuple`` — the fallback identity key;
    * ``_extra_args() -> tuple`` — per-call epoch extras (e.g. eval sets);
    * ``default_epoch_steps -> int`` — the scan chunk when none is given.
    """

    def _build(self) -> Callable:
        raise NotImplementedError

    def _cache_key(self) -> tuple:
        raise NotImplementedError

    def _instance_key(self) -> tuple:
        return ("epoch-inst", id(self))

    def _extra_args(self) -> tuple:
        return ()

    @property
    def default_epoch_steps(self) -> int:
        return self.cfg.T

    def _get_or_build(self) -> Callable:
        try:
            key = self._cache_key()
            hash(key)
        except TypeError:  # unhashable closure args: private executable
            key = self._instance_key()
        fn = _EPOCH_CACHE.get(key)
        if fn is None:
            global _BUILD_COUNT
            _BUILD_COUNT += 1
            fn = self._build()
            while len(_EPOCH_CACHE) >= _EPOCH_CACHE_MAX:
                _EPOCH_CACHE.pop(next(iter(_EPOCH_CACHE)))
            _EPOCH_CACHE[key] = fn
        return fn

    # -- epoch-at-a-time API -------------------------------------------------
    def run_epoch(self, state, batches):
        """One compiled epoch over ``batches`` (leaves ``[L, n_w, ...]``).
        ``state`` is donated. Metrics stay on device (dict of ``[L]`` bufs)."""
        with warnings.catch_warnings():
            # donation is a no-op on CPU; keep that per-executable warning out
            # of benchmark output without touching the global filter state
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return self._epoch(state, batches, *self._extra_args())

    # -- full-run API --------------------------------------------------------
    def run(self, state, batches=None, *, stream=None,
            steps: int | None = None, epoch_steps: int | None = None
            ) -> tuple[Any, dict[str, np.ndarray]]:
        """Run ``steps`` protocol steps in compiled epochs.

        Feed either ``batches`` — a pytree with ``[steps, n_w, ...]`` leaves —
        or ``stream`` — an object with ``next(L)`` returning device batches
        (see ``DeviceBatchStream``). ``epoch_steps`` sets the scan length per
        dispatch (default: ``cfg.T``); any value is correct because the gather
        boundary is driven by the carried step counter, not the chunking.
        Returns the final state and the host metrics buffers (one transfer).
        """
        if (batches is None) == (stream is None):
            raise ValueError("provide exactly one of batches/stream")
        if steps is None:
            if batches is None:
                raise ValueError("steps is required with stream input")
            steps = jax.tree.leaves(batches)[0].shape[0]
        L = epoch_steps or self.default_epoch_steps
        bufs, done = [], 0
        while done < steps:
            n = min(L, steps - done)
            if batches is not None:
                chunk = jax.tree.map(lambda l: l[done:done + n], batches)
            else:
                chunk = stream.next(n)
            state, mbuf = self.run_epoch(state, chunk)
            bufs.append(mbuf)
            done += n
        if not bufs or not bufs[0]:
            return state, {}
        host = jax.device_get(bufs)  # ONE device->host transfer
        metrics = {k: np.concatenate([np.asarray(b[k]) for b in host])
                   for k in host[0]}
        return state, metrics


def stack_batches(batch_iter) -> Any:
    """Stack a host batch iterable into the ``[steps, ...]`` pytree the
    engines consume (for driving an engine from a legacy host stream in
    tests)."""
    import jax.numpy as jnp
    batches = list(batch_iter)
    return jax.tree.map(lambda *ls: jnp.stack(ls), *batches)
