"""Model-legitimacy filters for the synchronous ByzSGD variant (paper §5).

Workers pull ONE model per step (round-robin over servers) and validate it:

* **Lipschitz filter** — empirical Lipschitz coefficient
  k = ||g_{t+1} - g_t|| / ||theta_local - theta_prev|| must lie within the
  (n_ps - f_ps)/n_ps quantile of the worker's history of accepted coefficients.
* **Outliers filter** — the pulled model must be within the Eq. (14) ball of the
  locally-speculated model theta_local = theta_prev - eta * g_t.

Both are required: the Lipschitz filter bounds growth *direction*, the Outliers
filter bounds *distance* (each alone is attackable — paper §C.2.3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LipschitzHistory(NamedTuple):
    """Fixed-size ring buffer of past accepted Lipschitz coefficients."""
    buf: jax.Array   # [H] float32, NaN = empty
    idx: jax.Array   # scalar int32 write cursor

    @staticmethod
    def create(horizon: int = 128) -> "LipschitzHistory":
        return LipschitzHistory(jnp.full((horizon,), jnp.nan, jnp.float32),
                                jnp.zeros((), jnp.int32))

    def push(self, k: jax.Array) -> "LipschitzHistory":
        h = self.buf.shape[0]
        return LipschitzHistory(self.buf.at[self.idx % h].set(k), self.idx + 1)


def lipschitz_coefficient(new_grad, old_grad, local_model, old_model) -> jax.Array:
    """k = ||g_{t+1}-g_t|| / ||theta^{(j(l))}_{t+1} - theta^{(j)}_t|| (tree-aware)."""
    num = jnp.sqrt(sum(jnp.sum((a - b).astype(jnp.float32) ** 2)
                       for a, b in zip(jax.tree.leaves(new_grad), jax.tree.leaves(old_grad))))
    den = jnp.sqrt(sum(jnp.sum((a - b).astype(jnp.float32) ** 2)
                       for a, b in zip(jax.tree.leaves(local_model), jax.tree.leaves(old_model))))
    return num / jnp.maximum(den, 1e-20)


def lipschitz_cutoff(hist: LipschitzHistory, n_ps: int, f_ps: int) -> jax.Array:
    """The (n_ps-f_ps)/n_ps empirical quantile of the recorded history (NaN
    while the history is empty = accept everything). Split out from
    :func:`lipschitz_pass` so the sync-variant probe loop computes the cutoff
    ONCE per worker per step instead of re-sorting the history buffer for
    every probed candidate."""
    qlevel = 100.0 * (n_ps - f_ps) / n_ps
    return jnp.nanpercentile(hist.buf, qlevel)


def lipschitz_pass(k: jax.Array, hist: LipschitzHistory, n_ps: int, f_ps: int) -> jax.Array:
    """k <= quantile_{(n_ps-f_ps)/n_ps}{K}. Accepts while history is empty."""
    kp = lipschitz_cutoff(hist, n_ps, f_ps)
    return jnp.isnan(kp) | (k <= kp)


def outliers_bound(t: jax.Array, big_t: int, eta_anchor: jax.Array,
                   gnorm_anchor: jax.Array, n_w: int, f_w: int) -> jax.Array:
    """Eq. (14): eta_{T(t mod T)} ||g_{T(t mod T)}|| *
    ( (3T+2)(n_w-f_w) / 4f_w + 2((t-1) mod T) ).

    ``eta_anchor``/``gnorm_anchor`` are the learning rate / gradient norm at the
    last gather step (the anchor of the current scatter phase).
    """
    fw = max(f_w, 1)
    growth = (3.0 * big_t + 2.0) * (n_w - f_w) / (4.0 * fw) + 2.0 * ((t - 1) % big_t)
    return eta_anchor * gnorm_anchor * growth


def outliers_pass(pulled_model, local_model, bound: jax.Array) -> jax.Array:
    dist = jnp.sqrt(sum(jnp.sum((a - b).astype(jnp.float32) ** 2)
                        for a, b in zip(jax.tree.leaves(pulled_model),
                                        jax.tree.leaves(local_model))))
    return dist < bound


def safe_T(lipschitz_l: float, eta1: float) -> int:
    """Paper Eq. (13): T <= 1 / (3 * l * eta_1) — the max scatter length."""
    return max(int(1.0 / (3.0 * lipschitz_l * eta1)), 1)
