"""Print the registry-derived aggregator table (the README section).

    PYTHONPATH=src python -m repro.agg [n] [f]
"""
import sys

from .registry import markdown_table

if __name__ == "__main__":
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 18
    f = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    print(markdown_table(n, f))
