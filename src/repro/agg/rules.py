"""Pure-jnp reference implementations of the aggregation rules.

This module is the *reference backend* of :mod:`repro.agg`: every rule here is
plain jnp, jit/vmap/grad-compatible, and is what the Pallas kernels under
``repro.kernels`` are numerically checked against (tests/test_agg_backends.py).
Flat rules operate on a stack ``x`` of shape ``[n, d]`` with a declared number
of Byzantine inputs ``f``; each rule's natural arity is declared in the
registry (``repro.agg.registry``), so rules that ignore ``f`` simply do not
take it.

The paper's rules:
  * MDA   (Minimum-Diameter Averaging)  — tolerates f Byzantine among n >= 2f+1.
  * Median (coordinate-wise)            — tolerates f among n >= 2f+1.
  * MeaMed (mean-around-median)         — used by the synchronous worker gather.
Baselines the paper compares against / cites:
  * Krum, Multi-Krum (Blanchard et al. 2017), Bulyan, trimmed mean, plain mean.

Masked-delivery semantics
-------------------------
``masked_*`` variants and the ``*_weights_from_d2(..., mask=...)`` selection
helpers aggregate only the *delivered* subset indicated by a boolean ``[n]``
mask, with the delivered count ``q = sum(mask)`` allowed to be a traced value
(they are used inside jit where quorums are sampled on-device). For rules with
an order statistic this is done with sort tricks (non-delivered entries pushed
past the delivered ones) rather than dynamic gathers, so shapes stay static.
"""
from __future__ import annotations

import itertools
import math
import os
from contextlib import contextmanager
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

_BIG = jnp.float32(3.4e38)     # sorts after every real value, stays finite
_LATE = jnp.float32(1e30)      # "selectable, but after all delivered" score

# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------


def pairwise_sqdists(x: jax.Array) -> jax.Array:
    """Exact pairwise squared L2 distances via the Gram matrix. [n,d] -> [n,n].

    The Gram formulation is what makes the *sharded* distributed MDA possible:
    partial Grams over coordinate shards sum to the full Gram (see protocol.py).
    """
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    gram = x @ x.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def sqdists_from_gram(gram: jax.Array) -> jax.Array:
    """[n,n] Gram -> [n,n] squared distances (used by the sharded protocol)."""
    sq = jnp.diagonal(gram)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)


# ---------------------------------------------------------------------------
# MDA — Minimum-Diameter Averaging (the paper's worker-side GAR)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def subset_masks(n: int, f: int) -> np.ndarray:
    """All C(n, n-f) subsets of size n-f as a static bool mask array [S, n]."""
    if not 0 <= f < n:
        raise ValueError(f"need 0 <= f < n, got n={n} f={f}")
    masks = np.zeros((math.comb(n, n - f), n), dtype=bool)
    for i, c in enumerate(itertools.combinations(range(n), n - f)):
        masks[i, list(c)] = True
    return masks


def n_subsets(n: int, f: int) -> int:
    return math.comb(n, n - f)


def subset_diameters(d2: jax.Array, masks: jax.Array) -> jax.Array:
    """Max in-subset squared distance for each subset mask. [n,n],[S,n] -> [S]."""
    pair = masks[:, :, None] & masks[:, None, :]  # [S, n, n]
    return jnp.max(jnp.where(pair, d2[None], -jnp.inf), axis=(1, 2))


def mda_select_exact(d2: jax.Array, f: int, *,
                     diameters_fn=subset_diameters) -> jax.Array:
    """Exact minimum-diameter subset selection -> bool mask [n].

    ``diameters_fn`` lets the dispatch layer substitute the Pallas
    subset-diameter kernel while the enumeration stays here.
    """
    n = d2.shape[0]
    masks = jnp.asarray(subset_masks(n, f))
    diam = diameters_fn(d2, masks)
    return masks[jnp.argmin(diam)]


def mda_select_greedy(d2: jax.Array, f: int) -> jax.Array:
    """Greedy 2-approximation of the min-diameter subset -> bool mask [n].

    Seeds with the closest pair, then repeatedly adds the vector whose inclusion
    minimises the resulting diameter. O(n^2) selection given the distance matrix.
    Used when C(n, f) exceeds ``exact_limit`` (e.g. the 32-worker multi-pod
    mesh). DESIGN.md §2 discusses why Lemma 4.6 still holds up to a factor 2.
    """
    n = d2.shape[0]
    big = jnp.inf
    d2m = jnp.where(jnp.eye(n, dtype=bool), big, d2)
    ij = jnp.argmin(d2m)
    i, j = ij // n, ij % n
    sel = jnp.zeros((n,), bool).at[i].set(True).at[j].set(True)
    for _ in range(n - f - 2):
        # new diameter if k joined = max(current max dist to sel, in-sel diameter)
        dist_to_sel = jnp.max(jnp.where(sel[None, :], d2, -big), axis=1)  # [n]
        cand = jnp.where(sel, big, dist_to_sel)
        k = jnp.argmin(cand)
        sel = sel.at[k].set(True)
    return sel


def mda_select_greedy_masked(d2: jax.Array, f: int,
                             delivered: jax.Array) -> jax.Array:
    """Greedy min-diameter selection restricted to a delivered subset.

    Returns float32 weights [n] summing to 1 over the selected q-f delivered
    vectors (q = sum(delivered), allowed to be traced). The greedy order visits
    every delivered vector before any non-delivered one (their distances are
    pushed to a large finite sentinel), and the selection keeps the first
    q - f additions — with a full mask this reproduces ``mda_select_greedy``.
    """
    n = d2.shape[0]
    delivered = delivered.astype(bool)
    q = jnp.sum(delivered)
    pair_ok = delivered[:, None] & delivered[None, :]
    eye = jnp.eye(n, dtype=bool)
    d2d = jnp.where(pair_ok, d2, _LATE)          # undelivered pairs sort last
    ij = jnp.argmin(jnp.where(eye, jnp.inf, d2d))
    i, j = ij // n, ij % n
    sel0 = jnp.zeros((n,), bool).at[i].set(True).at[j].set(True)
    order0 = jnp.full((n,), n, jnp.int32).at[i].set(0).at[j].set(1)

    def body(s, carry):
        sel, order = carry
        dist_to_sel = jnp.max(jnp.where(sel[None, :], d2d, -jnp.inf), axis=1)
        cand = jnp.where(sel, jnp.inf, dist_to_sel)
        k = jnp.argmin(cand)
        return sel.at[k].set(True), order.at[k].set(s)

    _, order = jax.lax.fori_loop(2, n, body, (sel0, order0))
    keep = (q - f).astype(jnp.int32)
    sel = (order < jnp.maximum(keep, 1)) & delivered
    return sel.astype(jnp.float32) / jnp.maximum(jnp.sum(sel), 1)


def mda(x: jax.Array, f: int, *, exact_limit: int = 200_000,
        d2: jax.Array | None = None) -> jax.Array:
    """Minimum-Diameter Averaging. [n,d] -> [d].

    Average of the size-(n-f) subset with minimal L2 diameter (exact when the
    subset count is tractable, greedy otherwise).
    """
    n = x.shape[0]
    if n < 2 * f + 1:
        raise ValueError(f"MDA needs n >= 2f+1 (n={n}, f={f})")
    if f == 0:
        return jnp.mean(x, axis=0)
    if d2 is None:
        d2 = pairwise_sqdists(x)
    if n_subsets(n, f) <= exact_limit:
        sel = mda_select_exact(d2, f)
    else:
        sel = mda_select_greedy(d2, f)
    w = sel.astype(x.dtype) / (n - f)
    return w @ x


def mda_selection(d2: jax.Array, f: int, *, exact_limit: int = 200_000,
                  diameters_fn=subset_diameters) -> jax.Array:
    """Subset mask only (used by the sharded protocol where averaging is local)."""
    n = d2.shape[0]
    if f == 0:
        return jnp.ones((n,), bool)
    if n_subsets(n, f) <= exact_limit:
        return mda_select_exact(d2, f, diameters_fn=diameters_fn)
    return mda_select_greedy(d2, f)


def mda_weights_from_d2(d2: jax.Array, f: int, *, mask: jax.Array | None = None,
                        exact_limit: int = 200_000,
                        diameters_fn=subset_diameters) -> jax.Array:
    """[n,n] distances -> [n] float32 averaging weights (rows of the GAR).

    The d2-level entry point used by both the flat rule and the pytree /
    sharded-protocol paths (which build d2 from leaf-partial Grams). With a
    ``mask``, selection is restricted to delivered senders via the greedy
    scan (traced-q compatible).
    """
    n = d2.shape[0]
    if mask is not None:
        return mda_select_greedy_masked(d2, f, mask)
    sel = mda_selection(d2, f, exact_limit=exact_limit,
                        diameters_fn=diameters_fn)
    return sel.astype(jnp.float32) / (n - f if f else n)


# ---------------------------------------------------------------------------
# small-stack sorting network (hot-path optimization)
# ---------------------------------------------------------------------------

_NETWORK_MAX_N = 32

# Escape hatch: REPRO_SORT_NETWORK=0 (or use_sort_network(False)) routes the
# order-statistic rules back through XLA's jnp.sort — bitwise jnp.sort
# semantics for debugging, and the honest "seed hot path" lane of
# benchmarks/exp_throughput.py. Flipping it only affects traces compiled
# afterwards. The env var is resolved at CALL time (an import-time read
# would freeze the flag before tests/overrides can set it and poison the
# engines' compile-cache keys — REPRO-ENV-IMPORT); use_sort_network()
# takes precedence over the environment while active.
_SORT_NETWORK: bool | None = None    # None = defer to the environment


def sort_network_enabled() -> bool:
    """Current sort-network setting: the use_sort_network() override if one
    is active, else the REPRO_SORT_NETWORK environment default. Engines fold
    this into their compile-cache keys."""
    if _SORT_NETWORK is not None:
        return _SORT_NETWORK
    return os.environ.get("REPRO_SORT_NETWORK", "1") != "0"


@contextmanager
def use_sort_network(on: bool):
    global _SORT_NETWORK
    prev, _SORT_NETWORK = _SORT_NETWORK, bool(on)
    try:
        yield
    finally:
        _SORT_NETWORK = prev


@lru_cache(maxsize=None)
def _oddeven_pairs(n: int) -> tuple[tuple[int, int], ...]:
    """Batcher odd-even merge-sort compare-exchange schedule for arbitrary n."""
    pairs = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(min(k, n - j - k)):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return tuple(pairs)


def sort_stack(x: jax.Array) -> jax.Array:
    """``jnp.sort(x, axis=0)`` for a small static stack, as a compare-exchange
    network of vectorized min/max pairs.

    XLA lowers a generic sort to a per-coordinate comparator loop on CPU,
    which costs ~ms for the [n_quorum, d_model] stacks every protocol step
    sorts (the coordinate-wise Median pull is the single hottest op in the
    simulator). The Batcher network is pure elementwise min/max over full
    rows — order-of-magnitude faster on CPU and fusion-friendly inside the
    scanned epoch (repro.core.engine). Sorted *values* are identical to
    ``jnp.sort`` (value sorts are tie-insensitive); rules that need argsort
    keep the XLA sort for its stable tie-breaking. Falls back to ``jnp.sort``
    beyond n=32 (use the Pallas kernel there).
    """
    n = x.shape[0]
    if n <= 1:
        return x
    if n > _NETWORK_MAX_N or not sort_network_enabled():
        return jnp.sort(x, axis=0)
    # min/max would smear a single NaN across every rank; map NaN to the
    # finite _BIG sentinel first so Byzantine NaN payloads sort last exactly
    # like jnp.sort's NaN ordering (and get trimmed/outranked, not returned).
    if jnp.issubdtype(x.dtype, jnp.floating):
        x = jnp.where(jnp.isnan(x), jnp.asarray(_BIG, x.dtype), x)
    rows = list(x)
    for i, j in _oddeven_pairs(n):
        a, b = rows[i], rows[j]
        rows[i] = jnp.minimum(a, b)
        rows[j] = jnp.maximum(a, b)
    return jnp.stack(rows, axis=0)


def median_stack(x: jax.Array) -> jax.Array:
    """``jnp.median(x, axis=0)`` via :func:`sort_stack`."""
    n = x.shape[0]
    xs = sort_stack(x)
    if n % 2:
        return xs[n // 2]
    return 0.5 * (xs[n // 2 - 1] + xs[n // 2])


# ---------------------------------------------------------------------------
# coordinate-wise rules
# ---------------------------------------------------------------------------


def coordinate_median(x: jax.Array) -> jax.Array:
    """Coordinate-wise median ("Median" in the paper). [n,d] -> [d]."""
    return median_stack(x)


def masked_coordinate_median(x: jax.Array, delivered: jax.Array) -> jax.Array:
    """Median over the delivered subset only (asynchrony). [n,d],[n] -> [d].

    Non-delivered entries are pushed to +/-inf in equal numbers so the median of
    the remaining q values is recovered exactly for any q (sort-based).
    """
    q = jnp.sum(delivered)
    big = jnp.asarray(3.4e38, x.dtype)
    mask = delivered.reshape((-1,) + (1,) * (x.ndim - 1))
    xs = sort_stack(jnp.where(mask, x, big))  # delivered entries sort first
    lo = ((q - 1) // 2).astype(jnp.int32)
    hi = (q // 2).astype(jnp.int32)
    return 0.5 * (jnp.take(xs, lo, axis=0) + jnp.take(xs, hi, axis=0))


def vote(x: jax.Array) -> jax.Array:
    """Coordinate-wise plurality vote: per coordinate, the value held by the
    most inputs (ties break toward the lowest input index). [n, ...] -> [...].

    The read-quorum rule for *discrete* outputs (serving: argmax token ids):
    with n >= 2f+1 identical honest values, f arbitrary corruptions can never
    outvote the honest majority. Exact on any dtype — no averaging, the answer
    is always one of the inputs."""
    eq = (x[None, ...] == x[:, None, ...])          # [n, n, ...] pairwise
    counts = jnp.sum(eq, axis=1)                    # [n, ...] per coordinate
    win = jnp.argmax(counts, axis=0)                # [...] first max
    return jnp.take_along_axis(x, win[None, ...], axis=0)[0]


def masked_vote(x: jax.Array, delivered: jax.Array) -> jax.Array:
    """Plurality vote over the delivered subset only. [n, ...],[n] -> [...].

    Pairs are counted only between delivered inputs and undelivered rows get
    count -1, so the winner is exactly ``vote(x[delivered])`` (first-index tie
    break included: the subset gather preserves input order)."""
    m = delivered.astype(bool)
    shape = (-1,) + (1,) * (x.ndim - 1)
    pair = (m[:, None] & m[None, :]).reshape(m.shape * 2 + (1,) * (x.ndim - 1))
    eq = (x[None, ...] == x[:, None, ...]) & pair
    counts = jnp.where(m.reshape(shape), jnp.sum(eq, axis=1), -1)
    win = jnp.argmax(counts, axis=0)
    return jnp.take_along_axis(x, win[None, ...], axis=0)[0]


def mean(x: jax.Array) -> jax.Array:
    """Vanilla averaging (not Byzantine resilient — the paper's strawman)."""
    return jnp.mean(x, axis=0)


def masked_mean(x: jax.Array, delivered: jax.Array) -> jax.Array:
    """Mean of the delivered subset. [n,d],[n] -> [d]."""
    w = delivered.astype(jnp.float32)
    shape = (-1,) + (1,) * (x.ndim - 1)
    num = jnp.sum(x.astype(jnp.float32) * w.reshape(shape), axis=0)
    return (num / jnp.maximum(jnp.sum(w), 1.0)).astype(x.dtype)


def trimmed_mean(x: jax.Array, f: int) -> jax.Array:
    """Coordinate-wise trimmed mean: drop f lowest and f highest per coordinate."""
    n = x.shape[0]
    if n <= 2 * f:
        raise ValueError("trimmed_mean needs n > 2f")
    xs = sort_stack(x)
    return jnp.mean(xs[f:n - f], axis=0)


def masked_trimmed_mean(x: jax.Array, f: int, delivered: jax.Array) -> jax.Array:
    """Trimmed mean over the delivered subset: drop the f lowest and f highest
    of the q delivered values per coordinate (q may be traced)."""
    n = x.shape[0]
    q = jnp.sum(delivered)
    shape = (-1,) + (1,) * (x.ndim - 1)
    big = jnp.asarray(_BIG, x.dtype)
    xs = sort_stack(jnp.where(delivered.reshape(shape), x, big))
    rank = jnp.arange(n).reshape(shape)
    keep = (rank >= f) & (rank < q - f)
    num = jnp.sum(jnp.where(keep, xs.astype(jnp.float32), 0.0), axis=0)
    return (num / jnp.maximum(q - 2 * f, 1)).astype(x.dtype)


def meamed(x: jax.Array, f: int) -> jax.Array:
    """Mean-around-Median (Xie et al. 2018): per coordinate, mean of the n-f
    values closest to the coordinate median."""
    n = x.shape[0]
    med = median_stack(x)[None]
    dist = jnp.abs(x - med)
    idx = jnp.argsort(dist, axis=0)[: n - f]  # [n-f, d]
    vals = jnp.take_along_axis(x, idx, axis=0)
    return jnp.mean(vals, axis=0)


def masked_meamed(x: jax.Array, f: int, delivered: jax.Array) -> jax.Array:
    """Mean-around-Median over the delivered subset: per coordinate, mean of
    the q-f delivered values closest to the delivered median."""
    n = x.shape[0]
    q = jnp.sum(delivered)
    shape = (-1,) + (1,) * (x.ndim - 1)
    med = masked_coordinate_median(x, delivered)[None]
    dist = jnp.where(delivered.reshape(shape), jnp.abs(x - med), _BIG)
    order = jnp.argsort(dist, axis=0)                       # delivered first
    vals = jnp.take_along_axis(x, order, axis=0)
    rank = jnp.arange(n).reshape(shape)
    keep = rank < jnp.maximum(q - f, 1)
    num = jnp.sum(jnp.where(keep, vals.astype(jnp.float32), 0.0), axis=0)
    return (num / jnp.maximum(q - f, 1)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Krum family (baselines)
# ---------------------------------------------------------------------------


def _krum_scores(d2: jax.Array, f: int) -> jax.Array:
    """Krum score: sum of the n-f-2 smallest squared distances to neighbours."""
    n = d2.shape[0]
    m = n - f - 2
    if m < 1:
        raise ValueError(f"Krum needs n >= f+3 (n={n}, f={f})")
    d2nd = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
    srt = jnp.sort(d2nd, axis=1)
    return jnp.sum(srt[:, :m], axis=1)


def _krum_scores_masked(d2: jax.Array, f: int, delivered: jax.Array) -> jax.Array:
    """Krum scores over the delivered subset: each delivered vector scores the
    sum of its q-f-2 smallest distances to delivered neighbours (q traced);
    non-delivered vectors score +inf."""
    n = d2.shape[0]
    delivered = delivered.astype(bool)
    q = jnp.sum(delivered)
    ok = delivered[:, None] & delivered[None, :] & ~jnp.eye(n, dtype=bool)
    srt = jnp.sort(jnp.where(ok, d2, jnp.inf), axis=1)
    m = jnp.maximum(q - f - 2, 1)
    keep = jnp.arange(n)[None, :] < m
    scores = jnp.sum(jnp.where(keep & jnp.isfinite(srt), srt, 0.0), axis=1)
    return jnp.where(delivered, scores, jnp.inf)


def krum_weights_from_d2(d2: jax.Array, f: int,
                         *, mask: jax.Array | None = None) -> jax.Array:
    """One-hot [n] float32 weights on the best-scored vector."""
    scores = (_krum_scores(d2, f) if mask is None
              else _krum_scores_masked(d2, f, mask))
    return jax.nn.one_hot(jnp.argmin(scores), d2.shape[0], dtype=jnp.float32)


def multi_krum_weights_from_d2(d2: jax.Array, f: int, *,
                               mask: jax.Array | None = None,
                               m: int | None = None) -> jax.Array:
    """[n] float32 averaging weights over the m best-scored vectors
    (default m = n - f, or q - f under a delivery mask)."""
    n = d2.shape[0]
    if mask is None:
        scores = _krum_scores(d2, f)
        mm = n - f if m is None else m
        sel = jnp.zeros((n,), bool).at[jnp.argsort(scores)[:mm]].set(True)
    else:
        scores = _krum_scores_masked(d2, f, mask)
        q = jnp.sum(mask.astype(jnp.int32))
        mm = jnp.maximum(q - f, 1) if m is None else m
        rank = jnp.argsort(jnp.argsort(scores))
        sel = rank < mm
    return sel.astype(jnp.float32) / jnp.maximum(jnp.sum(sel), 1)


def krum(x: jax.Array, f: int) -> jax.Array:
    """Krum (Blanchard et al. 2017): the single vector with the best score."""
    scores = _krum_scores(pairwise_sqdists(x), f)
    return x[jnp.argmin(scores)]


def multi_krum(x: jax.Array, f: int, m: int | None = None) -> jax.Array:
    """Multi-Krum: average of the m best-scored vectors (default m = n - f)."""
    n = x.shape[0]
    m = n - f if m is None else m
    scores = _krum_scores(pairwise_sqdists(x), f)
    idx = jnp.argsort(scores)[:m]
    return jnp.mean(x[idx], axis=0)


def bulyan(x: jax.Array, f: int) -> jax.Array:
    """Bulyan (El Mhamdi et al. 2018): n-2f rounds of Krum selection, then
    coordinate-wise trimmed aggregation around the median. Needs n >= 4f+3."""
    n = x.shape[0]
    theta = n - 2 * f
    if theta < 1:
        raise ValueError(f"Bulyan needs n >= 4f+3 (n={n}, f={f})")
    d2 = pairwise_sqdists(x)
    alive = jnp.ones((n,), bool)
    picks = []
    for _ in range(theta):
        d2a = jnp.where(alive[None, :] & alive[:, None] & ~jnp.eye(n, dtype=bool),
                        d2, jnp.inf)
        srt = jnp.sort(d2a, axis=1)
        m = max(n - f - 2, 1)
        scores = jnp.sum(jnp.where(jnp.isinf(srt[:, :m]), 0.0, srt[:, :m]), axis=1)
        scores = jnp.where(alive, scores, jnp.inf)
        k = jnp.argmin(scores)
        picks.append(x[k])
        alive = alive.at[k].set(False)
    sel = jnp.stack(picks)  # [theta, d]
    beta = theta - 2 * f
    med = jnp.median(sel, axis=0, keepdims=True)
    idx = jnp.argsort(jnp.abs(sel - med), axis=0)[:max(beta, 1)]
    return jnp.mean(jnp.take_along_axis(sel, idx, axis=0), axis=0)


# ---------------------------------------------------------------------------
# variance-to-norm bounds (Appendix D / Fig. 7 reproduction)
# ---------------------------------------------------------------------------


def mda_variance_threshold(n: int, f: int) -> float:
    """Eq. (3)/(7): MDA is safe while stddev/||grad|| <= (n-f) / (2f)."""
    return float(n - f) / (2.0 * f) if f > 0 else float("inf")


def krum_variance_threshold(n: int, f: int) -> float:
    """Blanchard et al. 2017 condition: eta(n,f) * sigma < ||grad||, i.e. the
    usable stddev/norm ratio is 1/eta with
    eta(n,f) = sqrt(2 (n - f + f(n-f-2) + f^2 (n-f-1) / (n-2f-2)))."""
    if f == 0:
        return float("inf")
    if n - 2 * f - 2 <= 0:
        return 0.0
    eta2 = 2.0 * (n - f + (f * (n - f - 2) + f * f * (n - f - 1)) / (n - 2 * f - 2))
    return 1.0 / math.sqrt(eta2)
