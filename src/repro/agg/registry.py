"""The :class:`Aggregator` spec and the rule registry.

Every aggregation rule is described by one :class:`Aggregator`: its reference
callable with its *declared arity* (rules that ignore ``f`` simply don't take
it — no uniform-signature stubs), its breakdown point ``n >= k*f + c``, its
variance-to-norm safety threshold, and capability flags that replace every
call-site special case in the codebase:

  * ``needs_pairwise_d2`` / ``selection_based`` — the rule factors into a
    pairwise-distance computation plus a weights-on-inputs selection
    (``weights_from_d2``), which is what the sharded protocol and the pytree
    path exploit (leaf-partial Grams instead of flattening).
  * ``supports_masked_delivery`` — a traced-compatible masked implementation
    exists, so delivery masks built *inside jit* (quorum sampling, netsim
    traces) compose with the rule. Concrete (non-traced) masks work for every
    rule via subset gathering.
  * ``tree_mode`` — how the rule extends to pytrees: ``"leafwise"`` for
    coordinate-wise rules, ``"selection"`` for weights-based rules, ``None``
    for rules without a sound pytree decomposition (Bulyan).

Lookup is by name (:func:`get`); ``f`` bounds are validated uniformly at call
time from the spec's mechanical requirement with a uniform error message.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch, rules


def _is_traced(x) -> bool:
    return isinstance(x, jax.core.Tracer)


@dataclass(frozen=True)
class Aggregator:
    """Spec + entry point for one aggregation rule.

    Calling the spec aggregates a flat stack: ``spec(x, f, mask=..., ...)``.
    """
    name: str
    fn: Callable                     # reference callable, natural arity
    takes_f: bool                    # whether ``fn`` takes the declared f
    breakdown: str                   # human-readable resilience bound
    requires: tuple[int, int]        # enforced bound: n >= k*f + c (the
                                     # breakdown point for resilient rules)
    doc: str = ""
    variance_threshold: Callable[[int, int], float] | None = None
    needs_pairwise_d2: bool = False
    selection_based: bool = False
    tree_mode: str | None = "leafwise"      # 'leafwise' | 'selection' | None
    backends: tuple[str, ...] = ("jnp",)
    masked_fn: Callable | None = None       # traced-ok: (x, [f,] mask) -> [d]
    weights_from_d2: Callable | None = None  # (d2, f, *, mask=None, **kw)->[n]
    tunables: frozenset[str] = frozenset()  # extra kwargs the rule accepts

    @property
    def supports_masked_delivery(self) -> bool:
        return self.masked_fn is not None or (
            self.selection_based and self.weights_from_d2 is not None)

    @property
    def is_sanitizer(self) -> bool:
        """Whether the rule launders Byzantine influence: a nonzero
        breakdown point (``n >= k*f + c`` with ``k >= 2``). ``mean`` is
        not one. ``repro.analyze``'s REPRO-TAINT-BYZ derives its
        sanitizer set from exactly this predicate (over the AST)."""
        return self.requires[0] >= 2

    def validate(self, n: int, f: int) -> None:
        """Uniform f-bounds check from the spec's mechanical requirement."""
        k, c = self.requires
        if f < 0:
            raise ValueError(f"aggregator {self.name!r}: f must be >= 0, got {f}")
        if f >= n:
            raise ValueError(
                f"aggregator {self.name!r}: need f < n, got n={n}, f={f}")
        if n < k * f + c:
            need = (f"{k}f+{c}" if k else f"{c}").replace("1f", "f")
            raise ValueError(
                f"aggregator {self.name!r} requires n >= {need} "
                f"(breakdown point {self.breakdown}): got n={n}, f={f}")

    def filter_kwargs(self, **kw) -> dict[str, Any]:
        """Keep only the kwargs this rule accepts (lets generic call sites pass
        rule-specific knobs like ``exact_limit`` without special-casing)."""
        return {k: v for k, v in kw.items() if k in self.tunables}

    def _call_unmasked(self, x, f, backend, interpret, **kw):
        kw = self.filter_kwargs(**kw)
        if "pallas" in self.backends:   # fn is a dispatch-level callable
            kw.update(backend=backend, interpret=interpret)
        return self.fn(x, f, **kw) if self.takes_f else self.fn(x, **kw)

    def __call__(self, x: jax.Array, f: int = 0, *,
                 mask: jax.Array | None = None, backend: str | None = None,
                 interpret: bool | None = None, **kw) -> jax.Array:
        n = x.shape[0]
        self.validate(n, f)
        if mask is None:
            return self._call_unmasked(x, f, backend, interpret, **kw)
        if not (_is_traced(mask) or _is_traced(x)):
            # concrete mask: exact subset semantics for EVERY rule
            m = np.asarray(mask, bool)
            if m.shape != (n,):
                raise ValueError(f"mask must be [n={n}] bool, got {m.shape}")
            self.validate(int(m.sum()), f)
            return self._call_unmasked(x[m], f, backend, interpret, **kw)
        if not self.supports_masked_delivery:
            raise ValueError(
                f"aggregator {self.name!r} has no traced-mask implementation; "
                f"use a concrete mask or one of "
                f"{sorted(k for k, s in _REGISTRY.items() if s.supports_masked_delivery)}")
        if self.masked_fn is not None:
            return (self.masked_fn(x, f, mask) if self.takes_f
                    else self.masked_fn(x, mask))
        # selection-based: d2 -> masked weights -> convex combination
        d2 = dispatch.pairwise_sqdists(x, backend=backend, interpret=interpret)
        w = self.weights_from_d2(d2, f, mask=mask, **self.filter_kwargs(**kw))
        return (w @ x.astype(jnp.float32)).astype(x.dtype)


_REGISTRY: dict[str, Aggregator] = {}


def register(spec: Aggregator) -> Aggregator:
    if spec.name in _REGISTRY:
        raise ValueError(f"aggregator {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> Aggregator:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def specs() -> tuple[Aggregator, ...]:
    return tuple(_REGISTRY[n] for n in names())


# ---------------------------------------------------------------------------
# built-in rules
# ---------------------------------------------------------------------------

register(Aggregator(
    name="mda", fn=dispatch.mda, takes_f=True,
    breakdown="n >= 2f+1", requires=(2, 1),
    doc="Minimum-Diameter Averaging (the paper's worker-gradient GAR)",
    variance_threshold=rules.mda_variance_threshold,
    needs_pairwise_d2=True, selection_based=True, tree_mode="selection",
    backends=("jnp", "pallas"),
    weights_from_d2=rules.mda_weights_from_d2,
    tunables=frozenset({"exact_limit"})))

register(Aggregator(
    name="median", fn=dispatch.median, takes_f=False,
    breakdown="n >= 2f+1", requires=(2, 1),
    doc="coordinate-wise median (server-model DMC rule)",
    backends=("jnp", "pallas"),
    masked_fn=rules.masked_coordinate_median))

register(Aggregator(
    name="meamed", fn=dispatch.meamed, takes_f=True,
    breakdown="n >= 2f+1", requires=(2, 1),
    doc="mean-around-median (sync worker gather rule)",
    backends=("jnp", "pallas"),
    masked_fn=rules.masked_meamed))

register(Aggregator(
    name="trimmed_mean", fn=dispatch.trimmed_mean, takes_f=True,
    breakdown="n >= 2f+1", requires=(2, 1),
    doc="coordinate-wise trimmed mean (baseline)",
    backends=("jnp", "pallas"),
    masked_fn=rules.masked_trimmed_mean))

register(Aggregator(
    name="krum", fn=dispatch.krum, takes_f=True,
    breakdown="n >= 2f+3", requires=(2, 3),
    doc="Krum (Blanchard et al. 2017) — single best-scored vector",
    variance_threshold=rules.krum_variance_threshold,
    needs_pairwise_d2=True, selection_based=True, tree_mode="selection",
    backends=("jnp", "pallas"),
    weights_from_d2=rules.krum_weights_from_d2))

register(Aggregator(
    name="multi_krum", fn=dispatch.multi_krum, takes_f=True,
    breakdown="n >= 2f+3", requires=(2, 3),
    doc="Multi-Krum — average of the m best-scored vectors",
    variance_threshold=rules.krum_variance_threshold,
    needs_pairwise_d2=True, selection_based=True, tree_mode="selection",
    backends=("jnp", "pallas"),
    weights_from_d2=rules.multi_krum_weights_from_d2,
    tunables=frozenset({"m"})))

register(Aggregator(
    name="bulyan", fn=rules.bulyan, takes_f=True,
    breakdown="n >= 4f+3", requires=(4, 3),
    doc="Bulyan — recursive Krum + trimmed aggregation (baseline)",
    needs_pairwise_d2=True, tree_mode=None))

register(Aggregator(
    name="vote", fn=rules.vote, takes_f=False,
    breakdown="n >= 2f+1", requires=(2, 1),
    doc="coordinate-wise plurality vote (serve-quorum read rule for "
        "discrete outputs, e.g. argmax token ids)",
    masked_fn=rules.masked_vote))

register(Aggregator(
    name="mean", fn=rules.mean, takes_f=False,
    breakdown="none (f = 0 only)", requires=(0, 1),
    doc="plain averaging (the paper's non-resilient strawman)",
    masked_fn=rules.masked_mean))


# ---------------------------------------------------------------------------
# registry-derived documentation (README "Aggregators" table)
# ---------------------------------------------------------------------------


def markdown_table(n: int = 18, f: int = 2) -> str:
    """The README aggregator table, derived from the registry
    (``python -m repro.agg`` regenerates it)."""
    head = ("| rule | breakdown point | variance threshold (n=%d, f=%d) | "
            "backends | masked delivery | pytree |" % (n, f))
    sep = "|---|---|---|---|---|---|"
    out = [head, sep]
    for s in specs():
        if s.variance_threshold is None:
            vt = "—"
        else:
            v = s.variance_threshold(n, f)
            vt = "inf" if v == float("inf") else f"{v:.3f}"
        out.append(
            f"| `{s.name}` | {s.breakdown} | {vt} | {', '.join(s.backends)} | "
            f"{'yes' if s.supports_masked_delivery else 'concrete-only'} | "
            f"{s.tree_mode or '—'} |")
    return "\n".join(out)
