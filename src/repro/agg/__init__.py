"""repro.agg — the unified Aggregator API.

One entry point for every Byzantine-resilient gradient aggregation rule
(GAR) in the codebase, replacing the loose functions of the old
``repro.core.gars`` module (which remains as a deprecation shim):

    import repro.agg as agg

    agg.get("mda")(x, f)                    # flat [n,d] stack
    agg.get("median")(x, f, mask=delivered) # masked delivery (asynchrony)
    agg.tree_agg("mda", stacked_tree, f)    # pytree with [n, ...] leaves
    agg.selection_weights("mda", d2, f)     # sharded protocol (own distances)
    agg.aggregate("krum", x, f)             # functional spelling of get()(…)

Rules are described by :class:`~repro.agg.registry.Aggregator` specs (name,
breakdown point, variance threshold, capability flags) and dispatch to either
the pure-jnp reference or the Pallas kernels (``backend="auto"|"jnp"|"pallas"``,
see :mod:`repro.agg.dispatch`). ``python -m repro.agg`` prints the registry
table used in the README.
"""
from __future__ import annotations

from . import dispatch, registry, rules, tree
from .dispatch import (backend_override, cwise_median, default_backend,
                       pairwise_sqdists, resolve_backend, subset_diameters)
from .registry import Aggregator, get, markdown_table, names, register, specs
from .tree import selection_weights, tree_agg, tree_gram


def aggregate(rule, x, f: int = 0, **kw):
    """Functional spelling of ``get(rule)(x, f, **kw)``."""
    spec = rule if isinstance(rule, Aggregator) else get(rule)
    return spec(x, f, **kw)


__all__ = [
    "Aggregator", "aggregate", "backend_override", "cwise_median",
    "default_backend", "dispatch",
    "get", "markdown_table", "names", "pairwise_sqdists", "register",
    "registry", "resolve_backend", "rules", "selection_weights",
    "specs", "subset_diameters", "tree", "tree_agg", "tree_gram",
]
