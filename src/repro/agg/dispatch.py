"""Backend dispatch: route each aggregation primitive to its pure-jnp
reference or its Pallas kernel.

Three primitives have Pallas implementations under ``repro.kernels``:

  * ``pairwise_sqdist``  — Gram-matrix kernel, feeds every distance-based rule
  * ``mda_diameter``     — subset-diameter scan for exact MDA selection
  * ``cwise_median``     — per-coordinate median over a replica stack (n <= 64)

``backend`` is one of:

  * ``"auto"`` (default) — Pallas on TPU, jnp elsewhere (the kernels run in
    interpret mode off-TPU, which is correct but slow — useful for tests, not
    for the hot path);
  * ``"jnp"`` — always the reference implementation;
  * ``"pallas"`` — always the kernel (interpret mode is auto-enabled off-TPU,
    or forced with ``interpret=True``).

The ``REPRO_AGG_BACKEND`` environment variable overrides the default for a
whole process. Numerical equivalence of both backends is enforced by
``tests/test_agg_backends.py``.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from functools import partial

import jax
import jax.numpy as jnp

from . import rules

_VALID = ("auto", "jnp", "pallas")

# cwise_median kernel is sized for replica stacks (sorting network in regs)
_MEDIAN_KERNEL_MAX_N = 64


def default_backend() -> str:
    return os.environ.get("REPRO_AGG_BACKEND", "auto")


@contextmanager
def backend_override(backend: str | None):
    """Exception-safe process-default backend override.

    Sets ``REPRO_AGG_BACKEND`` for the dynamic extent of the block and
    restores the previous value (or absence) on ANY exit path. This is the
    sanctioned way to scope the default — bare ``os.environ[...] =``
    mutations leak state across runs when the block raises, and are linted
    against (REPRO-ENV-MUTATE). ``backend=None`` is a no-op, so callers can
    pass an optional spec field straight through.
    """
    if backend is None:
        yield
        return
    if backend not in _VALID:
        raise ValueError(f"unknown backend {backend!r}; choose from {_VALID}")
    prev = os.environ.get("REPRO_AGG_BACKEND")
    os.environ["REPRO_AGG_BACKEND"] = backend
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_AGG_BACKEND", None)
        else:
            os.environ["REPRO_AGG_BACKEND"] = prev


def resolve_backend(backend: str | None = None, *,
                    pallas_ok: bool = True) -> str:
    """Concrete backend for this call. ``pallas_ok=False`` marks shapes the
    kernel cannot take (auto falls back to jnp; explicit 'pallas' raises)."""
    b = backend or default_backend()
    if b not in _VALID:
        raise ValueError(f"unknown backend {b!r}; choose from {_VALID}")
    if b == "auto":
        return "pallas" if (pallas_ok and jax.default_backend() == "tpu") \
            else "jnp"
    if b == "pallas" and not pallas_ok:
        raise ValueError("shape not supported by the Pallas kernel "
                         f"(cwise_median needs a [n <= {_MEDIAN_KERNEL_MAX_N},"
                         " d] stack)")
    return b


def pairwise_sqdists(x: jax.Array, *, backend: str | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """[n, d] -> [n, n] exact squared L2 distances."""
    if resolve_backend(backend) == "pallas":
        from ..kernels.pairwise_sqdist import ops
        return ops.pairwise_sqdists(x, interpret=interpret)
    return rules.pairwise_sqdists(x)


def subset_diameters(d2: jax.Array, masks: jax.Array, *,
                     backend: str | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """[n,n] distances + [S,n] subset masks -> [S] subset diameters."""
    if resolve_backend(backend) == "pallas":
        from ..kernels.mda_diameter import ops
        return ops.subset_diameters(d2, masks.astype(bool),
                                    interpret=interpret)
    return rules.subset_diameters(d2, masks.astype(bool))


def cwise_median(x: jax.Array, *, backend: str | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """[n, ...] -> [...] coordinate-wise median (kernel path needs a 2D
    stack; multi-dim leaves — e.g. pytree weight matrices — fall back)."""
    ok = x.ndim == 2 and x.shape[0] <= _MEDIAN_KERNEL_MAX_N
    if resolve_backend(backend, pallas_ok=ok) == "pallas":
        from ..kernels.cwise_median import ops
        return ops.cwise_median(x, interpret=interpret)
    return rules.coordinate_median(x)


# ---------------------------------------------------------------------------
# dispatch-level rule entry points (referenced by the registry specs)
# ---------------------------------------------------------------------------


def median(x: jax.Array, *, backend: str | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Coordinate-wise median through the backend dispatch."""
    return cwise_median(x, backend=backend, interpret=interpret).astype(x.dtype)


def _cwise_rule(x: jax.Array, f: int, kernel_name: str, ref_fn,
                backend: str | None, interpret: bool | None) -> jax.Array:
    """Shared dispatch for the f-taking coordinate-wise order-statistic
    rules: the Pallas path shares cwise_median's sorting network; multi-dim
    leaves and stacks beyond the kernel's n limit fall back to the jnp
    reference."""
    ok = x.ndim == 2 and x.shape[0] <= _MEDIAN_KERNEL_MAX_N
    if resolve_backend(backend, pallas_ok=ok) == "pallas":
        from ..kernels.cwise_median import ops
        out = getattr(ops, kernel_name)(x, f, interpret=interpret)
        return out.astype(x.dtype)
    return ref_fn(x, f)


def trimmed_mean(x: jax.Array, f: int, *, backend: str | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """Coordinate-wise trimmed mean through the backend dispatch."""
    return _cwise_rule(x, f, "cwise_trimmed_mean", rules.trimmed_mean,
                       backend, interpret)


def meamed(x: jax.Array, f: int, *, backend: str | None = None,
           interpret: bool | None = None) -> jax.Array:
    """Mean-around-Median through the backend dispatch.

    Backend equivalence is exact except when two values are *exactly*
    equidistant from the median on opposite sides (probability zero on
    continuous data): both backends then select sets with identical distance
    profiles (same max, same sum — see the kernel's tie contract) but may
    average a different member of the tied pair."""
    return _cwise_rule(x, f, "cwise_meamed", rules.meamed, backend, interpret)


def mda(x: jax.Array, f: int, *, exact_limit: int = 200_000,
        backend: str | None = None,
        interpret: bool | None = None) -> jax.Array:
    """Minimum-Diameter Averaging through the backend dispatch: the Gram /
    distance step and (when exact) the subset-diameter scan both route to
    their kernels; selection logic stays in :mod:`repro.agg.rules`."""
    n = x.shape[0]
    if n < 2 * f + 1:
        raise ValueError(f"MDA needs n >= 2f+1 (n={n}, f={f})")
    if f == 0:
        return jnp.mean(x, axis=0)
    d2 = pairwise_sqdists(x, backend=backend, interpret=interpret)
    diam_fn = partial(subset_diameters, backend=backend, interpret=interpret)
    sel = rules.mda_selection(d2, f, exact_limit=exact_limit,
                              diameters_fn=diam_fn)
    w = sel.astype(jnp.float32) / (n - f)
    return (w @ x.astype(jnp.float32)).astype(x.dtype)


def krum(x: jax.Array, f: int, *, backend: str | None = None,
         interpret: bool | None = None) -> jax.Array:
    """Krum with the distance step routed through the backend dispatch."""
    d2 = pairwise_sqdists(x, backend=backend, interpret=interpret)
    w = rules.krum_weights_from_d2(d2, f)
    return (w @ x.astype(jnp.float32)).astype(x.dtype)


def multi_krum(x: jax.Array, f: int, *, m: int | None = None,
               backend: str | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Multi-Krum with the distance step routed through the backend dispatch."""
    d2 = pairwise_sqdists(x, backend=backend, interpret=interpret)
    w = rules.multi_krum_weights_from_d2(d2, f, m=m)
    return (w @ x.astype(jnp.float32)).astype(x.dtype)
