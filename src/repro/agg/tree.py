"""Pytree aggregation: apply any registered rule to a stacked pytree.

Leaves carry a leading stack axis ``[n, ...]`` (one entry per sender). The
rule's ``tree_mode`` capability decides the decomposition — no call site ever
branches on rule identity:

  * ``"leafwise"``  — coordinate-wise rules apply independently per leaf
    (exactly equal to the flat rule on the flattened stack);
  * ``"selection"`` — distance-based rules need *global* distances: the [n,n]
    distance matrix is assembled from per-leaf partial Grams (no full
    flatten/copy of the stack), the rule's ``weights_from_d2`` selects once,
    and leaves are combined with the selection weights.

An optional boolean delivery ``mask`` [n] restricts aggregation to delivered
senders; it composes with both modes (masked leafwise rules / masked
selection), so netsim ``TraceDelivery`` quorums work with any mask-capable
rule.

:func:`tree_gram` is the ONE streaming Gram path, shared with the distributed
protocol (``repro.core.protocol`` imports it): each leaf contributes a [n, n]
partial via a multi-dim ``dot_general`` — never a ``reshape(n, -1)`` flatten,
which would force the SPMD partitioner to replicate sharded leaves — and
large leaves stream chunk-by-chunk so no ``[n, P]`` stack (or all-gathered
full gradient) ever materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import registry, rules

# streaming thresholds (shared with the protocol's exchange streaming)
STREAM_MAX_DIM1 = 512   # layer-stack dims stream one layer at a time
STREAM_N_CHUNKS = 16    # wide dims (vocab tables) stream in 16 chunks
DEFAULT_CHUNK_BYTES = 256 * 2**20


def _gram_spec(shape, mesh) -> P:
    """Layout for the Gram contraction: the [n, n] output cannot be 'rep'-
    sharded on both dims, so we first all-to-all the leaf — replica axis
    replicated, 'model'/'rep'/'fsdp' spread over *body* dims — making the
    n x n dot fully local with a tiny psum over the sharded contraction dims.
    Without this, the SPMD partitioner all-gathers the entire replica stack
    per device (observed: 18 GiB temps on internlm2)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    order_axes = (("model", sizes["model"]), ("rep", sizes["rep"]),
                  ("fsdp", sizes["fsdp"]))
    body = list(shape[1:])
    spec: list = [None] * len(body)
    order = sorted(range(len(body)), key=lambda i: -body[i])
    taken: set = set()
    for ax, size in order_axes:
        if size <= 1:
            continue
        at = next((i for i in order
                   if i not in taken and body[i] % size == 0 and body[i] >= size),
                  None)
        if at is not None:
            spec[at] = ax
            taken.add(at)
    return P(None, *spec)


def _chunk_gram(chunk):
    lf = chunk.astype(jnp.float32)
    axes = tuple(range(1, lf.ndim))
    # dot_general with multi-dim contraction — NO flattening reshape
    # (tensordot reshapes to 2D, which forces XLA to replicate sharded
    # leaves; dot_general contracts sharded dims directly).
    return jax.lax.dot_general(lf, lf, ((axes, axes), ((), ())))   # [n, n]


def _reduce_stream(fn, leaf, chunk_bytes: int):
    """Accumulate fn(chunk) over slices of a large leaf: dim-1 for layer
    stacks, last dim for wide tables (mirrors the protocol's exchange
    streaming — bounds per-chunk transients without a full-leaf copy)."""
    from ..models import unroll_ctx
    big = leaf.size * leaf.dtype.itemsize > chunk_bytes
    n = leaf.shape[0]
    if leaf.ndim < 3 or not big:
        return fn(leaf)
    if leaf.shape[1] <= STREAM_MAX_DIM1:
        ax, n_steps, csize = 1, leaf.shape[1], 1
    elif leaf.shape[-1] % STREAM_N_CHUNKS == 0:
        ax = leaf.ndim - 1
        n_steps = STREAM_N_CHUNKS
        csize = leaf.shape[-1] // STREAM_N_CHUNKS
    else:
        return fn(leaf)

    def chunk_at(i):
        sl = jax.lax.dynamic_slice_in_dim(leaf, i * csize, csize, axis=ax)
        return jnp.squeeze(sl, 1) if (ax == 1 and csize == 1) else sl

    if unroll_ctx.active():
        return sum(fn(chunk_at(i)) for i in range(n_steps))

    def body(i, acc):
        return acc + fn(chunk_at(i))

    return jax.lax.fori_loop(0, n_steps, body, jnp.zeros((n, n), jnp.float32))


def tree_gram(stacked_tree, mesh=None,
              chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> jax.Array:
    """[n, n] Gram matrix over the full flattened stack, from per-leaf
    streaming partials.

    With a ('rep','fsdp','model') ``mesh``: whole-leaf all-to-all (gram_spec:
    'rep' moved onto a body dim) + local multi-dim dot + tiny psum.
    Empirically (EXPERIMENTS.md §Perf iteration log) this is the ONLY variant
    the SPMD partitioner handles without involuntary replication; per-chunk
    constraints and plain rep-sharded dots both blow up. Leaves whose bodies
    cannot host the 'rep' axis fall back to the streamed rep-gather."""
    total = None
    for l in jax.tree.leaves(stacked_tree):
        if mesh is not None and l.ndim >= 2:
            spec = _gram_spec(l.shape, mesh)
            if "rep" in jax.tree.leaves(tuple(spec)):
                lf = jax.lax.with_sharding_constraint(
                    l.astype(jnp.float32), NamedSharding(mesh, spec))
                g = _chunk_gram(lf)
            else:
                g = _reduce_stream(_chunk_gram, l, chunk_bytes)
        else:
            g = _reduce_stream(_chunk_gram, l, chunk_bytes)
        total = g if total is None else total + g
    return total


def tree_agg(rule, stacked_tree, f: int = 0, *, mask=None, mesh=None,
             chunk_bytes: int = DEFAULT_CHUNK_BYTES, **kw):
    """Aggregate a stacked pytree with a registered rule.

    ``rule`` is a registry name or an :class:`~repro.agg.registry.Aggregator`.
    Extra kwargs are filtered against the rule's declared tunables (e.g.
    ``exact_limit`` for MDA), so generic call sites can pass a superset.
    ``mesh``/``chunk_bytes`` tune the selection path's streaming Gram for
    sharded stacks (see :func:`tree_gram`).
    """
    spec = rule if isinstance(rule, registry.Aggregator) else registry.get(rule)
    leaves = jax.tree.leaves(stacked_tree)
    n = leaves[0].shape[0]
    spec.validate(n, f)
    if spec.tree_mode == "leafwise":
        # Per-leaf application beats flatten-then-apply here: coordinate-wise
        # rules commute with flattening, but the [n, D] concat/split copies
        # cost more than the repeated (elementwise, fusion-friendly) op graph,
        # especially under the simulator's receiver vmap.
        if mask is None:
            return jax.tree.map(
                lambda l: spec._call_unmasked(l, f, None, None, **kw),
                stacked_tree)
        return jax.tree.map(lambda l: spec(l, f, mask=mask, **kw),
                            stacked_tree)
    if spec.tree_mode != "selection":
        raise ValueError(
            f"aggregator {spec.name!r} does not support pytree aggregation "
            f"(tree_mode={spec.tree_mode!r})")
    d2 = rules.sqdists_from_gram(tree_gram(stacked_tree, mesh=mesh,
                                           chunk_bytes=chunk_bytes))
    w = spec.weights_from_d2(d2, f, mask=mask, **spec.filter_kwargs(**kw))
    return jax.tree.map(
        lambda l: jnp.tensordot(w, l.astype(jnp.float32), axes=1).astype(l.dtype),
        stacked_tree)


def selection_weights(rule, d2: jax.Array, f: int = 0, *, mask=None,
                      **kw) -> jax.Array:
    """[n,n] distances -> [n] aggregation weights for a selection-based rule.

    The entry point for call sites that already own the distance matrix (the
    sharded protocol builds it from leaf-partial Grams with a tiny [G,G] psum
    and averages locally with the returned weights).
    """
    spec = rule if isinstance(rule, registry.Aggregator) else registry.get(rule)
    if not spec.selection_based or spec.weights_from_d2 is None:
        raise ValueError(f"aggregator {spec.name!r} is not selection-based; "
                         "selection_weights needs one of "
                         f"{[s.name for s in registry.specs() if s.selection_based]}")
    spec.validate(d2.shape[0], f)
    return spec.weights_from_d2(d2, f, mask=mask, **spec.filter_kwargs(**kw))
