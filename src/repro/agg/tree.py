"""Pytree aggregation: apply any registered rule to a stacked pytree.

Leaves carry a leading stack axis ``[n, ...]`` (one entry per sender). The
rule's ``tree_mode`` capability decides the decomposition — no call site ever
branches on rule identity:

  * ``"leafwise"``  — coordinate-wise rules apply independently per leaf
    (exactly equal to the flat rule on the flattened stack);
  * ``"selection"`` — distance-based rules need *global* distances: the [n,n]
    distance matrix is assembled from per-leaf partial Grams (no full
    flatten/copy of the stack), the rule's ``weights_from_d2`` selects once,
    and leaves are combined with the selection weights.

An optional boolean delivery ``mask`` [n] restricts aggregation to delivered
senders; it composes with both modes (masked leafwise rules / masked
selection), so netsim ``TraceDelivery`` quorums work with any mask-capable
rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import registry, rules


def tree_gram(stacked_tree) -> jax.Array:
    """[n, n] Gram matrix of the flattened stack, from per-leaf partials."""
    leaves = jax.tree.leaves(stacked_tree)
    n = leaves[0].shape[0]
    return sum(jnp.einsum("na,ma->nm", l.reshape(n, -1).astype(jnp.float32),
                          l.reshape(n, -1).astype(jnp.float32)) for l in leaves)


def tree_agg(rule, stacked_tree, f: int = 0, *, mask=None, **kw):
    """Aggregate a stacked pytree with a registered rule.

    ``rule`` is a registry name or an :class:`~repro.agg.registry.Aggregator`.
    Extra kwargs are filtered against the rule's declared tunables (e.g.
    ``exact_limit`` for MDA), so generic call sites can pass a superset.
    """
    spec = rule if isinstance(rule, registry.Aggregator) else registry.get(rule)
    leaves = jax.tree.leaves(stacked_tree)
    n = leaves[0].shape[0]
    spec.validate(n, f)
    if spec.tree_mode == "leafwise":
        # Per-leaf application beats flatten-then-apply here: coordinate-wise
        # rules commute with flattening, but the [n, D] concat/split copies
        # cost more than the repeated (elementwise, fusion-friendly) op graph,
        # especially under the simulator's receiver vmap.
        if mask is None:
            return jax.tree.map(
                lambda l: spec._call_unmasked(l, f, None, None, **kw),
                stacked_tree)
        return jax.tree.map(lambda l: spec(l, f, mask=mask, **kw),
                            stacked_tree)
    if spec.tree_mode != "selection":
        raise ValueError(
            f"aggregator {spec.name!r} does not support pytree aggregation "
            f"(tree_mode={spec.tree_mode!r})")
    d2 = rules.sqdists_from_gram(tree_gram(stacked_tree))
    w = spec.weights_from_d2(d2, f, mask=mask, **spec.filter_kwargs(**kw))
    return jax.tree.map(
        lambda l: jnp.tensordot(w, l.astype(jnp.float32), axes=1).astype(l.dtype),
        stacked_tree)


def selection_weights(rule, d2: jax.Array, f: int = 0, *, mask=None,
                      **kw) -> jax.Array:
    """[n,n] distances -> [n] aggregation weights for a selection-based rule.

    The entry point for call sites that already own the distance matrix (the
    sharded protocol builds it from leaf-partial Grams with a tiny [G,G] psum
    and averages locally with the returned weights).
    """
    spec = rule if isinstance(rule, registry.Aggregator) else registry.get(rule)
    if not spec.selection_based or spec.weights_from_d2 is None:
        raise ValueError(f"aggregator {spec.name!r} is not selection-based; "
                         "selection_weights needs one of "
                         f"{[s.name for s in registry.specs() if s.selection_based]}")
    spec.validate(d2.shape[0], f)
    return spec.weights_from_d2(d2, f, mask=mask, **spec.filter_kwargs(**kw))
