"""qwen3-moe-235b-a22b [moe]: 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151936, n_experts=128, top_k=8,
    rope_theta=1e6, subquadratic=False,
    byz_group_divisor=8, byz_group_cap=2, param_dtype="bfloat16",
    notes="Layout B (n_ps=2, K=8) single-pod; fine-grained EP (8 experts/chip).",
)
