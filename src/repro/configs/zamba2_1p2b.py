"""zamba2-1.2b [hybrid]: Mamba2 + shared attn blocks. [arXiv:2411.15242; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, shared_attn_heads=32, shared_attn_d_ff=8192,
    subquadratic=True,
    notes="38 Mamba2 layers; ONE shared MHA+MLP block applied after every 6th "
          "layer (6 sites, per-site KV cache). long_500k runs (O(1) SSM state).",
)
