"""whisper-small [audio]: enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, encoder_layers=12, max_source_len=1500,
    norm="layernorm", tie_embeddings=True, subquadratic=False,
    notes="Frame embeddings [B,Se,D] are the stub frontend output. train_4k = "
          "2048 encoder frames + 2048 decoder tokens (seq split, documented).",
)
