"""Small reference models for the paper-claim reproduction experiments.

The paper's testbed models (MNIST_CNN ~80k params, CifarNet ~1.8M) are CPU-scale;
we mirror that scale with an MLP / tiny-CNN over the synthetic mixture task
(datasets are not vendored offline — see data/pipeline.py docstring).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def mlp_init(key: jax.Array, dim: int = 64, hidden: int = 128,
             n_classes: int = 10, depth: int = 2):
    params = {}
    sizes = [dim] + [hidden] * depth + [n_classes]
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp_apply(params, x):
    n = len(params) // 2
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, batch, l2: float = 1e-4):
    """Cross-entropy + L2 (the paper's Assumption 6 needs a regulariser)."""
    x, y = batch
    logits = mlp_apply(params, x)
    ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])
    reg = sum(jnp.sum(p ** 2) for p in jax.tree.leaves(params))
    return ce + l2 * reg


def mlp_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_apply(params, x), axis=-1) == y)


def make_mlp_problem(dim: int = 64, hidden: int = 128, n_classes: int = 10,
                     depth: int = 2, l2: float = 1e-4):
    init = partial(mlp_init, dim=dim, hidden=hidden, n_classes=n_classes,
                   depth=depth)
    loss = partial(mlp_loss, l2=l2)
    return init, loss, mlp_accuracy
