"""qwen2-vl-7b [vlm]: M-RoPE, dynamic resolution (frontend stubbed).
[arXiv:2409.12191; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, mrope=True, rope_theta=1e6, subquadratic=False,
    notes="Backbone only: input_specs provides merged patch/text embeddings "
          "[B,S,D] + 3-component M-RoPE position ids (vision frontend = stub).",
)
