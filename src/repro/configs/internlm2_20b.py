"""internlm2-20b [dense]: GQA. [arXiv:2403.17297; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, rope_theta=1e6, subquadratic=False,
    byz_group_divisor=2,
    notes="G=R/2 server groups: 16 full 20B fp32 replicas exceed v5e HBM; "
          "8 groups (f_w=f_ps=2) fit — the resilience-memory tradeoff "
          "(DESIGN.md §Worker granularity).",
)
