"""dbrx-132b [moe]: 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, n_experts=16, top_k=4,
    rope_theta=5e5, subquadratic=False,
    byz_group_divisor=4, param_dtype="bfloat16",
    notes="Layout B (n_ps=4, K=4) on the single-pod mesh; EP over 'model'.",
)
