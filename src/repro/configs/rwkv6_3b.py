"""rwkv6-3b [ssm]: Finch, data-dependent decay, attn-free. [arXiv:2404.05892; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab=65536, ssm_head_dim=64, subquadratic=True,
    notes="Attention-free; n_heads is derived (2560/64). long_500k runs "
          "(O(1) WKV state decode).",
)
