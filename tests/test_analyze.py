"""repro.analyze layer 1: every AST rule on tripping AND clean fixtures,
suppression/baseline mechanics, repo-scope invariants against the live
tree, and the CLI wiring. The forced-8-device layer-2 audit runs in
``test_analyze_distributed.py`` (subprocess lane)."""
import ast
import json
import os
import subprocess
import sys

import pytest

from repro.analyze import (Finding, lint_file, lint_paths, lint_repo,
                           load_baseline, markdown_table, rules,
                           split_baselined, write_baseline)
from repro.analyze.rules import preconditions, registry_parity

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def hits(source, rule_id=None, path="fixture.py"):
    found = lint_file(path, ROOT, source=source)
    if rule_id is None:
        return found
    return [f for f in found if f.rule_id == rule_id]


# ---------------------------------------------------------------------------
# REPRO-HOST-SYNC
# ---------------------------------------------------------------------------


HOST_SYNC_TRIPPING = [
    # jit-decorated step calling float() on a traced value
    ("@jax.jit\ndef step(s, b):\n    return s, float(s.loss)\n", 3),
    # .item() inside a lax.scan body (inner def passed by name)
    ("def outer(xs):\n"
     "    def body(c, x):\n"
     "        return c, x.item()\n"
     "    return jax.lax.scan(body, 0, xs)\n", 3),
    # np.asarray in a lambda handed to lax.cond
    ("def f(p, x):\n"
     "    return jax.lax.cond(p, lambda v: np.asarray(v), lambda v: v, x)\n",
     2),
    # transitive: helper called by name from a jitted fn
    ("def helper(x):\n"
     "    return x.block_until_ready()\n"
     "@jax.jit\n"
     "def step(x):\n"
     "    return helper(x)\n", 2),
    # @partial(jax.jit, ...) spelling
    ("@partial(jax.jit, static_argnums=0)\n"
     "def step(n, x):\n"
     "    return jax.device_get(x)\n", 3),
]

HOST_SYNC_CLEAN = [
    # device code stays on device
    ("@jax.jit\ndef step(s, b):\n    return s, jnp.mean(b)\n"),
    # host-side float() outside any traced fn
    ("def report(x):\n    return float(x)\n"),
    # float of a literal inside jit is definition-time constant folding
    ("@jax.jit\ndef step(x):\n    return x * float(1e-3)\n"),
    # scan body that behaves
    ("def outer(xs):\n"
     "    def body(c, x):\n"
     "        return c + jnp.sum(x), c\n"
     "    return jax.lax.scan(body, 0.0, xs)\n"),
    # .item() in a plain host helper never handed to a tracer
    ("def summarize(arr):\n    return arr.sum().item()\n"),
]


@pytest.mark.parametrize("src,line", HOST_SYNC_TRIPPING)
def test_host_sync_trips(src, line):
    found = hits(src, "REPRO-HOST-SYNC")
    assert found, src
    assert found[0].line == line


@pytest.mark.parametrize("src", HOST_SYNC_CLEAN)
def test_host_sync_clean(src):
    assert hits(src, "REPRO-HOST-SYNC") == []


# ---------------------------------------------------------------------------
# REPRO-ENV-IMPORT / REPRO-ENV-MUTATE
# ---------------------------------------------------------------------------


ENV_IMPORT_TRIPPING = [
    'FLAG = os.environ.get("REPRO_SORT_NETWORK", "1") != "0"\n',
    'BACKEND = os.getenv("REPRO_AGG_BACKEND", "auto")\n',
    'X = os.environ["REPRO_THING"]\n',
    # class body is still import time
    'class C:\n    FLAG = os.environ.get("REPRO_F", "")\n',
]

ENV_IMPORT_CLEAN = [
    # call-time read is the sanctioned pattern
    'def enabled():\n    return os.environ.get("REPRO_SORT_NETWORK") != "0"\n',
    # non-REPRO keys are out of scope
    'DEBUG = os.environ.get("JAX_DEBUG", "")\n',
]


@pytest.mark.parametrize("src", ENV_IMPORT_TRIPPING)
def test_env_import_trips(src):
    assert hits(src, "REPRO-ENV-IMPORT"), src


@pytest.mark.parametrize("src", ENV_IMPORT_CLEAN)
def test_env_import_clean(src):
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_env_mutate_trips_everywhere_but_dispatch():
    src = 'def f():\n    os.environ["REPRO_AGG_BACKEND"] = "jnp"\n'
    assert hits(src, "REPRO-ENV-MUTATE")
    # pop / setdefault count as mutations too
    assert hits('def f():\n    os.environ.pop("REPRO_X", None)\n',
                "REPRO-ENV-MUTATE")
    # the blessed owner of the env dance is exempt
    assert hits(src, "REPRO-ENV-MUTATE",
                path=os.path.join("src", "repro", "agg", "dispatch.py")) == []


def test_env_mutate_clean_on_reads():
    assert hits('def f():\n    return os.environ.get("REPRO_X")\n',
                "REPRO-ENV-MUTATE") == []


# ---------------------------------------------------------------------------
# REPRO-CACHE-KEY
# ---------------------------------------------------------------------------


CACHE_KEY_TRIPPING = """
class Eng(EpochRunner):
    def _build(self):
        flag = self.track_delta
        return lambda s, b: (s, flag)
    def _cache_key(self):
        return ("eng", self.cfg)
"""

CACHE_KEY_CLEAN = """
class Eng(EpochRunner):
    def _build(self):
        flag = self.track_delta
        return lambda s, b: (s, flag)
    def _cache_key(self):
        return ("eng", self.cfg, self.track_delta)
"""

CACHE_KEY_TRANSITIVE = """
class Eng(EpochRunner):
    def _make_step(self):
        return lambda s: s * self.lr_scale
    def _build(self):
        step = self._make_step()
        return lambda s, b: (step(s), None)
    def _cache_key(self):
        return ("eng", self.cfg)
"""


def test_cache_key_trips_on_uncovered_attr():
    found = hits(CACHE_KEY_TRIPPING, "REPRO-CACHE-KEY")
    assert found and "track_delta" in found[0].message


def test_cache_key_clean_when_covered():
    assert hits(CACHE_KEY_CLEAN, "REPRO-CACHE-KEY") == []


def test_cache_key_walks_helper_methods():
    found = hits(CACHE_KEY_TRANSITIVE, "REPRO-CACHE-KEY")
    assert found and "lr_scale" in found[0].message


def test_cache_key_requires_key_method():
    src = ("class Eng(EpochRunner):\n"
           "    def _build(self):\n"
           "        return lambda s, b: (s, None)\n")
    assert hits(src, "REPRO-CACHE-KEY")


# ---------------------------------------------------------------------------
# REPRO-MEMBERSHIP-FLOOR
# ---------------------------------------------------------------------------


MEMBERSHIP_TRIPPING = [
    # unguarded shrink of a liveness mask
    ("class Pool:\n"
     "    def eject(self, i):\n"
     "        self.active[i] = False\n"),
    # in-place intersection, module-level helper without any floor check
    ("def prune(pool, mask):\n"
     "    pool.active &= mask\n"),
    # symbolic: the plan shrinks the fleet below 2 groups
    ("register(Experiment(name='bad', n_workers=2, f_workers=0,\n"
     "    n_servers=2, f_servers=0,\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=1),))))\n"),
    # symbolic: shrink to G'=4 caps f_ps' at 0 under a present Byz server
    ("register(Experiment(name='bad2', n_workers=5, f_workers=1,\n"
     "    n_servers=5, f_servers=1,\n"
     "    byz=ByzantineSpec(server_attack='lie', n_byz_servers=1),\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=4),))))\n"),
]

MEMBERSHIP_CLEAN = [
    # shrink behind the quorum floor (ReplicaPool.deactivate shape)
    ("class Pool:\n"
     "    def eject(self, i):\n"
     "        if self.n_active - 1 < self.quorum_floor:\n"
     "            return False\n"
     "        self.active[i] = False\n"
     "        return True\n"),
    # explicit 2f+1 arithmetic counts as a guard
    ("def eject(active, i, f):\n"
     "    if active.sum() - 1 >= 2 * f + 1:\n"
     "        active[i] = False\n"),
    # growing the mask is never a shrink
    ("class Pool:\n"
     "    def readmit(self, i):\n"
     "        self.active[i] = True\n"),
    # a floor-respecting churn plan
    ("register(Experiment(name='ok', n_workers=5, f_workers=1,\n"
     "    n_servers=5, f_servers=1,\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=4),\n"
     "        MembershipEvent(step=8, kind='join', group=4)))))\n"),
    # unresolvable shape: skipped, owned by the runtime validator
    ("register(Experiment(name='dyn', n_workers=G,\n"
     "    membership_plan=MembershipPlan(events=EVENTS)))\n"),
]


@pytest.mark.parametrize("src", MEMBERSHIP_TRIPPING)
def test_membership_floor_trips(src):
    assert hits(src, "REPRO-MEMBERSHIP-FLOOR"), src


@pytest.mark.parametrize("src", MEMBERSHIP_CLEAN)
def test_membership_floor_clean(src):
    assert hits(src, "REPRO-MEMBERSHIP-FLOOR") == []


def test_membership_floor_resolves_common_dict_expansion():
    src = (
        "_COMMON = dict(n_workers=5, f_workers=1, n_servers=5, f_servers=1)\n"
        "register(Experiment(name='bad3',\n"
        "    byz=ByzantineSpec(worker_attack='alie', n_byz_workers=1),\n"
        "    membership_plan=MembershipPlan(events=(\n"
        "        MembershipEvent(step=4, kind='leave', group=4),\n"
        "        MembershipEvent(step=5, kind='leave', group=3),)),\n"
        "    **_COMMON))\n")
    found = hits(src, "REPRO-MEMBERSHIP-FLOOR")
    assert found and "bad3" in found[0].message


# ---------------------------------------------------------------------------
# suppression + baseline mechanics
# ---------------------------------------------------------------------------


def test_inline_suppression_with_justification():
    src = ('X = os.environ.get("REPRO_X")  '
           "# analyze: ignore[REPRO-ENV-IMPORT] fixture for the docs\n")
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_bare_suppression_is_itself_a_violation():
    # no justification: the marker is flagged AND buys no suppression
    src = ('X = os.environ.get("REPRO_X")  '
           "# analyze: ignore[REPRO-ENV-IMPORT]\n")
    found = sorted(f.rule_id for f in hits(src))
    assert found == ["REPRO-ENV-IMPORT", "REPRO-SUPPRESS"]


def test_suppression_on_previous_line_applies():
    src = ("# analyze: ignore[REPRO-ENV-IMPORT] fixture\n"
           'X = os.environ.get("REPRO_X")\n')
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_marker_inside_string_does_not_suppress():
    src = ('MSG = "analyze: ignore[REPRO-ENV-IMPORT] nope"\n'
           'X = os.environ.get("REPRO_X")\n')
    assert hits(src, "REPRO-ENV-IMPORT")


def test_baseline_roundtrip(tmp_path):
    f1 = Finding("REPRO-ENV-IMPORT", "a.py", 3, "import-time read")
    f2 = Finding("REPRO-HOST-SYNC", "b.py", 9, "float() in scan")
    path = str(tmp_path / "baseline.json")
    write_baseline([f1], path)
    base = load_baseline(path)
    new, known = split_baselined([f1, f2], base)
    assert [f.rule_id for f in new] == ["REPRO-HOST-SYNC"]
    assert [f.rule_id for f in known] == ["REPRO-ENV-IMPORT"]
    # baseline keys survive line-number churn
    assert Finding("REPRO-ENV-IMPORT", "a.py", 99,
                   "import-time read").key in base


def test_syntax_error_reported_not_raised():
    found = hits("def broken(:\n")
    assert [f.rule_id for f in found] == ["REPRO-PARSE"]


# ---------------------------------------------------------------------------
# repo-scope rules against the live tree
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    assert lint_repo(ROOT) == []


def test_byz_bounds_sees_all_presets():
    with open(os.path.join(ROOT, "src", "repro", "exp", "presets.py")) as f:
        tree = ast.parse(f.read())
    assert len(list(preconditions._preset_calls(tree))) >= 10
    assert preconditions.check(ROOT) == []


def test_byz_bounds_math_trips_on_bad_clusters():
    bad = dict(n_workers=3, f_workers=1, n_servers=5, f_servers=1,
               variant="async", q_workers=None, q_servers=None)
    assert any("3f_w+1" in p for p in preconditions._bounds_violations(bad))
    bad_srv = dict(bad, n_workers=9, n_servers=4)
    assert any("3f_ps+2" in p
               for p in preconditions._bounds_violations(bad_srv))
    ok = dict(bad, n_workers=9)
    assert preconditions._bounds_violations(ok) == []


def test_agg_parity_clean_on_live_registry():
    assert registry_parity.check(ROOT) == []


# ---------------------------------------------------------------------------
# registry + CLI
# ---------------------------------------------------------------------------


def test_rule_registry_covers_both_layers():
    ids = {r.rule_id for r in rules()}
    assert {"REPRO-HOST-SYNC", "REPRO-ENV-IMPORT", "REPRO-ENV-MUTATE",
            "REPRO-CACHE-KEY", "REPRO-BYZ-BOUNDS", "REPRO-AGG-PARITY",
            "REPRO-MEMBERSHIP-FLOOR",
            "REPRO-HLO-DONATION", "REPRO-HLO-HOST-TRANSFER",
            "REPRO-HLO-RECOMPILE", "REPRO-HLO-COLLECTIVES"} <= ids
    table = markdown_table()
    for rid in ids:
        assert rid in table


def test_lint_paths_skip_tests_and_results():
    paths = lint_paths(ROOT)
    assert paths, "lint roots found no files"
    assert not any(os.sep + "tests" + os.sep in p for p in paths)
    assert not any("__pycache__" in p for p in paths)
    assert any(p.endswith(os.path.join("analyze", "astlint.py"))
               for p in paths)


def test_cli_layer1_exits_zero(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    report = str(tmp_path / "report.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "--json", report],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stdout
    with open(report) as f:
        doc = json.load(f)
    assert doc["clean"] and doc["violations"] == []
    assert "REPRO-HOST-SYNC" in doc["stats"]["rules_run"]


def test_cli_table(capsys):
    from repro.analyze.__main__ import main
    assert main(["--table"]) == 0
    out = capsys.readouterr().out
    assert "REPRO-HLO-COLLECTIVES" in out and "| rule |" in out


def test_committed_baseline_is_empty():
    path = os.path.join(ROOT, "results", "analyze", "baseline.json")
    with open(path) as f:
        doc = json.load(f)
    assert doc["findings"] == []
