"""repro.analyze layer 1: every AST rule on tripping AND clean fixtures,
suppression/baseline mechanics, repo-scope invariants against the live
tree, and the CLI wiring. The forced-8-device layer-2 audit runs in
``test_analyze_distributed.py`` (subprocess lane)."""
import ast
import json
import os
import subprocess
import sys

import pytest

from repro.analyze import (Finding, lint_file, lint_paths, lint_repo,
                           load_baseline, markdown_table, rules,
                           split_baselined, write_baseline)
from repro.analyze.findings import refresh_baseline
from repro.analyze.rules import (dead_seed, pallas_audit, preconditions,
                                 registry_parity, taint_byz)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def hits(source, rule_id=None, path="fixture.py"):
    found = lint_file(path, ROOT, source=source)
    if rule_id is None:
        return found
    return [f for f in found if f.rule_id == rule_id]


# ---------------------------------------------------------------------------
# REPRO-HOST-SYNC
# ---------------------------------------------------------------------------


HOST_SYNC_TRIPPING = [
    # jit-decorated step calling float() on a traced value
    ("@jax.jit\ndef step(s, b):\n    return s, float(s.loss)\n", 3),
    # .item() inside a lax.scan body (inner def passed by name)
    ("def outer(xs):\n"
     "    def body(c, x):\n"
     "        return c, x.item()\n"
     "    return jax.lax.scan(body, 0, xs)\n", 3),
    # np.asarray in a lambda handed to lax.cond
    ("def f(p, x):\n"
     "    return jax.lax.cond(p, lambda v: np.asarray(v), lambda v: v, x)\n",
     2),
    # transitive: helper called by name from a jitted fn
    ("def helper(x):\n"
     "    return x.block_until_ready()\n"
     "@jax.jit\n"
     "def step(x):\n"
     "    return helper(x)\n", 2),
    # @partial(jax.jit, ...) spelling
    ("@partial(jax.jit, static_argnums=0)\n"
     "def step(n, x):\n"
     "    return jax.device_get(x)\n", 3),
]

HOST_SYNC_CLEAN = [
    # device code stays on device
    ("@jax.jit\ndef step(s, b):\n    return s, jnp.mean(b)\n"),
    # host-side float() outside any traced fn
    ("def report(x):\n    return float(x)\n"),
    # float of a literal inside jit is definition-time constant folding
    ("@jax.jit\ndef step(x):\n    return x * float(1e-3)\n"),
    # scan body that behaves
    ("def outer(xs):\n"
     "    def body(c, x):\n"
     "        return c + jnp.sum(x), c\n"
     "    return jax.lax.scan(body, 0.0, xs)\n"),
    # .item() in a plain host helper never handed to a tracer
    ("def summarize(arr):\n    return arr.sum().item()\n"),
]


@pytest.mark.parametrize("src,line", HOST_SYNC_TRIPPING)
def test_host_sync_trips(src, line):
    found = hits(src, "REPRO-HOST-SYNC")
    assert found, src
    assert found[0].line == line


@pytest.mark.parametrize("src", HOST_SYNC_CLEAN)
def test_host_sync_clean(src):
    assert hits(src, "REPRO-HOST-SYNC") == []


# ---------------------------------------------------------------------------
# REPRO-ENV-IMPORT / REPRO-ENV-MUTATE
# ---------------------------------------------------------------------------


ENV_IMPORT_TRIPPING = [
    'FLAG = os.environ.get("REPRO_SORT_NETWORK", "1") != "0"\n',
    'BACKEND = os.getenv("REPRO_AGG_BACKEND", "auto")\n',
    'X = os.environ["REPRO_THING"]\n',
    # class body is still import time
    'class C:\n    FLAG = os.environ.get("REPRO_F", "")\n',
]

ENV_IMPORT_CLEAN = [
    # call-time read is the sanctioned pattern
    'def enabled():\n    return os.environ.get("REPRO_SORT_NETWORK") != "0"\n',
    # non-REPRO keys are out of scope
    'DEBUG = os.environ.get("JAX_DEBUG", "")\n',
]


@pytest.mark.parametrize("src", ENV_IMPORT_TRIPPING)
def test_env_import_trips(src):
    assert hits(src, "REPRO-ENV-IMPORT"), src


@pytest.mark.parametrize("src", ENV_IMPORT_CLEAN)
def test_env_import_clean(src):
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_env_mutate_trips_everywhere_but_dispatch():
    src = 'def f():\n    os.environ["REPRO_AGG_BACKEND"] = "jnp"\n'
    assert hits(src, "REPRO-ENV-MUTATE")
    # pop / setdefault count as mutations too
    assert hits('def f():\n    os.environ.pop("REPRO_X", None)\n',
                "REPRO-ENV-MUTATE")
    # the blessed owner of the env dance is exempt
    assert hits(src, "REPRO-ENV-MUTATE",
                path=os.path.join("src", "repro", "agg", "dispatch.py")) == []


def test_env_mutate_clean_on_reads():
    assert hits('def f():\n    return os.environ.get("REPRO_X")\n',
                "REPRO-ENV-MUTATE") == []


# ---------------------------------------------------------------------------
# REPRO-CACHE-KEY
# ---------------------------------------------------------------------------


CACHE_KEY_TRIPPING = """
class Eng(EpochRunner):
    def _build(self):
        flag = self.track_delta
        return lambda s, b: (s, flag)
    def _cache_key(self):
        return ("eng", self.cfg)
"""

CACHE_KEY_CLEAN = """
class Eng(EpochRunner):
    def _build(self):
        flag = self.track_delta
        return lambda s, b: (s, flag)
    def _cache_key(self):
        return ("eng", self.cfg, self.track_delta)
"""

CACHE_KEY_TRANSITIVE = """
class Eng(EpochRunner):
    def _make_step(self):
        return lambda s: s * self.lr_scale
    def _build(self):
        step = self._make_step()
        return lambda s, b: (step(s), None)
    def _cache_key(self):
        return ("eng", self.cfg)
"""


def test_cache_key_trips_on_uncovered_attr():
    found = hits(CACHE_KEY_TRIPPING, "REPRO-CACHE-KEY")
    assert found and "track_delta" in found[0].message


def test_cache_key_clean_when_covered():
    assert hits(CACHE_KEY_CLEAN, "REPRO-CACHE-KEY") == []


def test_cache_key_walks_helper_methods():
    found = hits(CACHE_KEY_TRANSITIVE, "REPRO-CACHE-KEY")
    assert found and "lr_scale" in found[0].message


def test_cache_key_requires_key_method():
    src = ("class Eng(EpochRunner):\n"
           "    def _build(self):\n"
           "        return lambda s, b: (s, None)\n")
    assert hits(src, "REPRO-CACHE-KEY")


# ---------------------------------------------------------------------------
# REPRO-MEMBERSHIP-FLOOR
# ---------------------------------------------------------------------------


MEMBERSHIP_TRIPPING = [
    # unguarded shrink of a liveness mask
    ("class Pool:\n"
     "    def eject(self, i):\n"
     "        self.active[i] = False\n"),
    # in-place intersection, module-level helper without any floor check
    ("def prune(pool, mask):\n"
     "    pool.active &= mask\n"),
    # symbolic: the plan shrinks the fleet below 2 groups
    ("register(Experiment(name='bad', n_workers=2, f_workers=0,\n"
     "    n_servers=2, f_servers=0,\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=1),))))\n"),
    # symbolic: shrink to G'=4 caps f_ps' at 0 under a present Byz server
    ("register(Experiment(name='bad2', n_workers=5, f_workers=1,\n"
     "    n_servers=5, f_servers=1,\n"
     "    byz=ByzantineSpec(server_attack='lie', n_byz_servers=1),\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=4),))))\n"),
]

MEMBERSHIP_CLEAN = [
    # shrink behind the quorum floor (ReplicaPool.deactivate shape)
    ("class Pool:\n"
     "    def eject(self, i):\n"
     "        if self.n_active - 1 < self.quorum_floor:\n"
     "            return False\n"
     "        self.active[i] = False\n"
     "        return True\n"),
    # explicit 2f+1 arithmetic counts as a guard
    ("def eject(active, i, f):\n"
     "    if active.sum() - 1 >= 2 * f + 1:\n"
     "        active[i] = False\n"),
    # growing the mask is never a shrink
    ("class Pool:\n"
     "    def readmit(self, i):\n"
     "        self.active[i] = True\n"),
    # a floor-respecting churn plan
    ("register(Experiment(name='ok', n_workers=5, f_workers=1,\n"
     "    n_servers=5, f_servers=1,\n"
     "    membership_plan=MembershipPlan(events=(\n"
     "        MembershipEvent(step=4, kind='leave', group=4),\n"
     "        MembershipEvent(step=8, kind='join', group=4)))))\n"),
    # unresolvable shape: skipped, owned by the runtime validator
    ("register(Experiment(name='dyn', n_workers=G,\n"
     "    membership_plan=MembershipPlan(events=EVENTS)))\n"),
]


@pytest.mark.parametrize("src", MEMBERSHIP_TRIPPING)
def test_membership_floor_trips(src):
    assert hits(src, "REPRO-MEMBERSHIP-FLOOR"), src


@pytest.mark.parametrize("src", MEMBERSHIP_CLEAN)
def test_membership_floor_clean(src):
    assert hits(src, "REPRO-MEMBERSHIP-FLOOR") == []


def test_membership_floor_resolves_common_dict_expansion():
    src = (
        "_COMMON = dict(n_workers=5, f_workers=1, n_servers=5, f_servers=1)\n"
        "register(Experiment(name='bad3',\n"
        "    byz=ByzantineSpec(worker_attack='alie', n_byz_workers=1),\n"
        "    membership_plan=MembershipPlan(events=(\n"
        "        MembershipEvent(step=4, kind='leave', group=4),\n"
        "        MembershipEvent(step=5, kind='leave', group=3),)),\n"
        "    **_COMMON))\n")
    found = hits(src, "REPRO-MEMBERSHIP-FLOOR")
    assert found and "bad3" in found[0].message


# ---------------------------------------------------------------------------
# REPRO-TAINT-BYZ (interprocedural dataflow, repo scope — tmp-tree fixtures)
# ---------------------------------------------------------------------------


MINI_REGISTRY = """
register(Aggregator(name="mda", requires=(2, 1), selection_based=True,
                    weights_from_d2=rules.mda_weights_from_d2))
register(Aggregator(name="median", requires=(2, 1),
                    masked_fn=rules.masked_coordinate_median))
register(Aggregator(name="bulyan", requires=(4, 3)))
register(Aggregator(name="mean", requires=(0, 1),
                    masked_fn=rules.masked_mean))
"""


def taint_hits(tmp_path, source, fname="core/flow.py"):
    src = tmp_path / "src" / "repro"
    (src / "agg").mkdir(parents=True, exist_ok=True)
    (src / "agg" / "registry.py").write_text(MINI_REGISTRY)
    target = src / fname
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(source)
    return taint_byz.check(str(tmp_path))


MEAN_BYPASS = """\
def train(state, grads, byz, key):
    grads = inject_gradients(grads, byz, key)
    g_hat = mean(grads)
    new_params = state.params - 0.01 * g_hat
    return SimState(params=new_params)
"""


def test_taint_catches_mean_bypass_with_witness(tmp_path):
    found = taint_hits(tmp_path, MEAN_BYPASS)
    assert len(found) == 1
    f = found[0]
    assert f.rule_id == "REPRO-TAINT-BYZ" and f.line == 5
    # the witness path walks the flow file:line by file:line
    flow = os.path.join("src", "repro", "core", "flow.py")
    assert f"{flow}:2 source `inject_gradients(...)`" in f.message
    assert f"{flow}:3" in f.message and f"{flow}:4" in f.message
    assert "sink `SimState(params=...)`" in f.message


def test_taint_clean_when_laundered_by_robust_rule(tmp_path):
    src = MEAN_BYPASS.replace("mean(grads)", "median(grads)")
    assert taint_hits(tmp_path, src) == []


def test_taint_literal_get_of_nonrobust_rule_trips(tmp_path):
    src = MEAN_BYPASS.replace("mean(grads)", 'agg.get("mean")(grads)')
    found = taint_hits(tmp_path, src)
    assert found and "non-robust rule `mean`" in found[0].message


def test_taint_literal_get_of_robust_rule_launders(tmp_path):
    src = MEAN_BYPASS.replace("mean(grads)", 'agg.get("median")(grads, 1)')
    assert taint_hits(tmp_path, src) == []


def test_taint_masked_call_needs_masked_support(tmp_path):
    tripping = MEAN_BYPASS.replace(
        "mean(grads)", 'agg.get("bulyan")(grads, 1, mask=m)')
    found = taint_hits(tmp_path, tripping)
    assert found and "lacks masked-delivery support" in found[0].message
    clean = MEAN_BYPASS.replace(
        "mean(grads)", 'agg.get("median")(grads, 1, mask=m)')
    assert taint_hits(tmp_path, clean) == []


def test_taint_selection_weights_contraction_launders(tmp_path):
    src = """\
def train(state, grads, byz, key):
    grads = inject_gradients(grads, byz, key)
    w = selection_weights("mda", d2_of(grads), 1)
    g_hat = w @ grads
    return SimState(params=state.params - 0.01 * g_hat)
"""
    assert taint_hits(tmp_path, src) == []


def test_taint_flows_through_closures_and_tree_map(tmp_path):
    src = """\
def make_step(byz):
    def step(state, grads, key):
        bad = inject_gradients(grads, byz, key)
        avg = jax.tree.map(lambda g: g.mean(0), bad)
        return state._replace(params=avg)
    return step
"""
    found = taint_hits(tmp_path, src)
    assert found and found[0].line == 5
    assert "_replace(params=...)" in found[0].message


def test_taint_checkpoint_save_is_a_sink(tmp_path):
    src = """\
def snapshot(ckpt_dir, state, spec, key):
    corrupted = inject_models(state.params, spec, key)
    save(ckpt_dir, 0, corrupted)
"""
    found = taint_hits(tmp_path, src)
    assert found and "save(...)" in found[0].message


def test_taint_policy_derivation_matches_live_registry():
    pol = taint_byz.registry_policy(ROOT)
    import repro.agg as agg
    live = {s.name: s.supports_masked_delivery for s in agg.specs()
            if s.is_sanitizer}
    assert pol.robust_rules == live
    assert "mean" not in pol.sanitizers
    assert "mean" in pol.all_rules


def test_taint_scc_closure_pulls_in_callers():
    modules = taint_byz.taint_modules(ROOT)
    proto = os.path.join("src", "repro", "core", "protocol.py")
    scope = taint_byz.scc_closure(modules, {proto})
    assert proto in scope
    # the engine calls the protocol step builders -> re-checked too
    assert os.path.join("src", "repro", "core", "engine.py") in scope
    assert len(scope) < len(modules)


def test_lint_repo_only_files_restricts_file_scope_pass():
    # the --fast lane: file-scope rules see only the changed files,
    # repo-scope invariants still see the whole tree
    taint_byz.scope_to(set())
    try:
        found = lint_repo(ROOT, only_files=set())
    finally:
        taint_byz.scope_to(None)
    assert all(f.rule_id == "REPRO-DEAD-SEED" for f in found), found


def test_live_tree_taint_needs_no_unexplained_suppressions():
    # protocol.py lints clean on merit (selection-weights contraction +
    # dynamic spec handles); simulator.py carries exactly one justified
    # suppression (Algorithm 3 filter write)
    found = taint_byz.check(ROOT)
    assert [f.path for f in found] == [
        os.path.join("src", "repro", "core", "simulator.py")]


# ---------------------------------------------------------------------------
# REPRO-PALLAS-* (kernel auditor, repo scope — tmp-tree fixtures)
# ---------------------------------------------------------------------------


def pallas_hits(tmp_path, kernel_src, ops_src=None, rule_id=None):
    pkg = tmp_path / "src" / "repro" / "kernels" / "fake"
    pkg.mkdir(parents=True, exist_ok=True)
    (pkg / "kernel.py").write_text(kernel_src)
    if ops_src is not None:
        (pkg / "ops.py").write_text(ops_src)
    found = []
    for pkg_rel, files in pallas_audit._packages(str(tmp_path)):
        found += pallas_audit._check_grid(pkg_rel, files)
        found += pallas_audit._check_oob(pkg_rel, files)
        found += pallas_audit._check_acc(pkg_rel, files)
        found += pallas_audit._check_mask(pkg_rel, files)
    if rule_id is None:
        return found
    return [f for f in found if f.rule_id == rule_id]


GRID_KERNEL = """\
def call(xp, d_pad, block_d):
    return pl.pallas_call(
        kern,
        grid=(d_pad // block_d,),
        out_shape=jax.ShapeDtypeStruct((8, block_d), jnp.float32),
    )(xp)
"""


def test_pallas_grid_trips_without_divisibility_evidence(tmp_path):
    found = pallas_hits(tmp_path, GRID_KERNEL, rule_id="REPRO-PALLAS-GRID")
    assert found and found[0].line == 4
    assert "`d_pad // block_d`" in found[0].message


def test_pallas_grid_clean_with_ceil_div_pad_in_ops(tmp_path):
    ops = "def tile(x, d, block_d):\n    d_pad = -(-d // block_d) * block_d\n"
    assert pallas_hits(tmp_path, GRID_KERNEL, ops,
                       rule_id="REPRO-PALLAS-GRID") == []


def test_pallas_grid_clean_with_assert(tmp_path):
    ops = "def tile(d_pad, block_d):\n    assert d_pad % block_d == 0\n"
    assert pallas_hits(tmp_path, GRID_KERNEL, ops,
                       rule_id="REPRO-PALLAS-GRID") == []


def test_pallas_oob_trips_on_literal_overrun(tmp_path):
    src = """\
def kern(x_ref, o_ref):
    rows = [x_ref[i, :] for i in range(9)]
    o_ref[...] = rows[0] + x_ref[8, :]

def call(xp):
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i))],
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(xp)
"""
    found = pallas_hits(tmp_path, src, rule_id="REPRO-PALLAS-OOB")
    assert found
    assert {f.line for f in found} == {2, 3}


def test_pallas_oob_clean_within_bounds_and_symbolic(tmp_path):
    src = """\
def kern(x_ref, o_ref):
    rows = [x_ref[i, :] for i in range(8)]
    o_ref[...] = rows[0]

def call(xp, n_pow2, block_d):
    return pl.pallas_call(
        kern,
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i))],
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(xp)
"""
    assert pallas_hits(tmp_path, src, rule_id="REPRO-PALLAS-OOB") == []


def test_pallas_acc_trips_on_unpinned_dot(tmp_path):
    src = """\
def kern(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...])
"""
    found = pallas_hits(tmp_path, src, rule_id="REPRO-PALLAS-ACC")
    assert found and found[0].line == 2
    assert "preferred_element_type" in found[0].message


def test_pallas_acc_trips_on_bf16_accumulation(tmp_path):
    src = """\
def kern(a_ref, b_ref, o_ref):
    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)

def call(a, b, n):
    return pl.pallas_call(
        kern,
        grid=(n,),
        out_shape=jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
    )(a, b)
"""
    ops = "def tile(n, b):\n    assert n % b == 0\n"
    found = pallas_hits(tmp_path, src, ops, rule_id="REPRO-PALLAS-ACC")
    assert found and "bfloat16" in found[0].message


def test_pallas_acc_clean_with_f32_out(tmp_path):
    src = """\
def kern(a_ref, b_ref, o_ref):
    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)

def call(a, b):
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((128, 128), jnp.float32),
    )(a, b)
"""
    assert pallas_hits(tmp_path, src, rule_id="REPRO-PALLAS-ACC") == []


BITONIC_KERNEL = """\
def kern(x_ref, o_ref):
    # bitonic compare-exchange network
    a, b = x_ref[0, :], x_ref[1, :]
    o_ref[0, :] = jnp.minimum(a, b)
    o_ref[1, :] = jnp.maximum(a, b)
"""


def test_pallas_mask_trips_without_nan_sentinel(tmp_path):
    ops = ("def tile(x, n, d):\n"
           "    xp = jnp.full((n, d), jnp.inf, jnp.float32)\n"
           "    return xp.at[:n, :d].set(x)\n")
    found = pallas_hits(tmp_path, BITONIC_KERNEL, ops,
                        rule_id="REPRO-PALLAS-MASK")
    assert found and found[0].line == 2
    assert found[0].path.endswith("ops.py")


def test_pallas_mask_clean_with_big_sentinel(tmp_path):
    ops = ("_BIG = 3.4e38\n"
           "def tile(x, n, d):\n"
           "    x = jnp.where(jnp.isnan(x), _BIG, x)\n"
           "    xp = jnp.full((n, d), _BIG, jnp.float32)\n"
           "    return xp.at[:n, :d].set(x)\n")
    assert pallas_hits(tmp_path, BITONIC_KERNEL, ops,
                       rule_id="REPRO-PALLAS-MASK") == []


def test_pallas_live_kernels_audit_clean():
    found = []
    for pkg, files in pallas_audit._packages(ROOT):
        found += pallas_audit._check_grid(pkg, files)
        found += pallas_audit._check_oob(pkg, files)
        found += pallas_audit._check_acc(pkg, files)
        found += pallas_audit._check_mask(pkg, files)
    assert found == []
    # and the auditor actually saw the four shipped packages
    assert len(list(pallas_audit._packages(ROOT))) >= 4


# ---------------------------------------------------------------------------
# REPRO-DETERMINISM
# ---------------------------------------------------------------------------


DETERMINISM_TRIPPING = [
    # set iteration feeding an ordered artifact
    ("def manifest(names):\n"
     "    out = []\n"
     "    for n in {x for x in names}:\n"
     "        out.append(n)\n"
     "    return out\n", 3),
    # non-associative reduction over a set
    ("def total(xs):\n    return sum(set(xs))\n", 2),
    # unsorted json feeding a digest
    ("def cache_key(cfg):\n"
     "    return hashlib.sha256(json.dumps(cfg).encode()).hexdigest()\n", 2),
    # host entropy inside a jitted step
    ("@jax.jit\ndef step(x):\n    return x * random.random()\n", 3),
    ("@jax.jit\ndef step(x):\n    return x + time.time()\n", 3),
]

DETERMINISM_CLEAN = [
    # sorted() restores a deterministic order
    "def manifest(names):\n    return [n for n in sorted(set(names))]\n",
    # sort_keys pins the digest
    ("def cache_key(cfg):\n"
     "    blob = json.dumps(cfg, sort_keys=True)\n"
     "    return hashlib.sha256(blob.encode()).hexdigest()\n"),
    # key-threaded jax PRNG is deterministic
    "@jax.jit\ndef step(x, k):\n    return x + jax.random.normal(k, x.shape)\n",
    # wall-clock timing in plain host code (the epoch runners) is fine
    "def run(fn):\n    t0 = time.perf_counter()\n    fn()\n"
    "    return time.perf_counter() - t0\n",
    # plain json.dump of a manifest (not hash-feeding)
    "def write(doc, f):\n    json.dump(doc, f, indent=1)\n",
]


@pytest.mark.parametrize("src,line", DETERMINISM_TRIPPING)
def test_determinism_trips(src, line):
    found = hits(src, "REPRO-DETERMINISM")
    assert found, src
    assert found[0].line == line


@pytest.mark.parametrize("src", DETERMINISM_CLEAN)
def test_determinism_clean(src):
    assert hits(src, "REPRO-DETERMINISM") == []


# ---------------------------------------------------------------------------
# REPRO-DEAD-SEED
# ---------------------------------------------------------------------------


def test_dead_seed_flags_unimported_module(tmp_path):
    src = tmp_path / "src" / "repro"
    (src / "core").mkdir(parents=True)
    (src / "core" / "used.py").write_text("def f():\n    return 1\n")
    (src / "core" / "orphan.py").write_text("def g():\n    return 2\n")
    (src / "__init__.py").write_text("from .core import used\n")
    found = dead_seed.check(str(tmp_path))
    assert [f.path for f in found] == [
        os.path.join("src", "repro", "core", "orphan.py")]
    assert "repro.core.orphan" in found[0].message


def test_dead_seed_honors_dynamic_import_literals(tmp_path):
    src = tmp_path / "src" / "repro"
    (src / "configs").mkdir(parents=True)
    (src / "configs" / "arch.py").write_text("CONFIG = 1\n")
    (src / "loader.py").write_text(
        'MODULES = {"arch": "repro.configs.arch"}\n'
        "def load(k):\n"
        "    return importlib.import_module(MODULES[k]).CONFIG\n")
    found = dead_seed.check(str(tmp_path))
    assert [f.path for f in found] == [
        os.path.join("src", "repro", "loader.py")]  # arch is NOT flagged


def test_dead_seed_exempts_entry_points_and_oracles(tmp_path):
    src = tmp_path / "src" / "repro"
    (src / "kernels" / "k").mkdir(parents=True)
    (src / "kernels" / "k" / "ref.py").write_text("def ref():\n    pass\n")
    (src / "cli.py").write_text(
        "def main():\n    pass\n"
        'if __name__ == "__main__":\n    main()\n')
    assert dead_seed.check(str(tmp_path)) == []


def test_dead_seed_live_tree_matches_baseline():
    found = dead_seed.check(ROOT)
    flagged = {f.path for f in found}
    assert os.path.join("src", "repro", "core", "compression.py") in flagged
    base = load_baseline(os.path.join(ROOT, "results", "analyze",
                                      "baseline.json"))
    assert {f.key for f in found} <= base


# ---------------------------------------------------------------------------
# REPRO-CACHE-KEY @property resolution (satellite)
# ---------------------------------------------------------------------------


CACHE_KEY_PROPERTY_TRIPPING = """
class Eng(EpochRunner):
    @property
    def combo(self):
        return (self.alpha, self.beta)
    def _build(self):
        c = self.combo
        return lambda s, b: (s, c)
    def _cache_key(self):
        return ("eng", self.alpha)
"""

CACHE_KEY_PROPERTY_CLEAN = """
class Eng(EpochRunner):
    @property
    def combo(self):
        return (self.alpha, self.beta)
    def _build(self):
        c = self.combo
        return lambda s, b: (s, c)
    def _cache_key(self):
        return ("eng", self.alpha, self.beta)
"""


def test_cache_key_resolves_property_reads():
    found = hits(CACHE_KEY_PROPERTY_TRIPPING, "REPRO-CACHE-KEY")
    assert found and "beta" in found[0].message
    assert "combo" not in found[0].message  # the property itself is code


def test_cache_key_clean_when_property_fields_covered():
    assert hits(CACHE_KEY_PROPERTY_CLEAN, "REPRO-CACHE-KEY") == []


# ---------------------------------------------------------------------------
# suppression + baseline mechanics
# ---------------------------------------------------------------------------


def test_inline_suppression_with_justification():
    src = ('X = os.environ.get("REPRO_X")  '
           "# analyze: ignore[REPRO-ENV-IMPORT] fixture for the docs\n")
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_bare_suppression_is_itself_a_violation():
    # no justification: the marker is flagged AND buys no suppression
    src = ('X = os.environ.get("REPRO_X")  '
           "# analyze: ignore[REPRO-ENV-IMPORT]\n")
    found = sorted(f.rule_id for f in hits(src))
    assert found == ["REPRO-ENV-IMPORT", "REPRO-SUPPRESS"]


def test_suppression_on_previous_line_applies():
    src = ("# analyze: ignore[REPRO-ENV-IMPORT] fixture\n"
           'X = os.environ.get("REPRO_X")\n')
    assert hits(src, "REPRO-ENV-IMPORT") == []


def test_marker_inside_string_does_not_suppress():
    src = ('MSG = "analyze: ignore[REPRO-ENV-IMPORT] nope"\n'
           'X = os.environ.get("REPRO_X")\n')
    assert hits(src, "REPRO-ENV-IMPORT")


def test_baseline_roundtrip(tmp_path):
    f1 = Finding("REPRO-ENV-IMPORT", "a.py", 3, "import-time read")
    f2 = Finding("REPRO-HOST-SYNC", "b.py", 9, "float() in scan")
    path = str(tmp_path / "baseline.json")
    write_baseline([f1], path)
    base = load_baseline(path)
    new, known = split_baselined([f1, f2], base)
    assert [f.rule_id for f in new] == ["REPRO-HOST-SYNC"]
    assert [f.rule_id for f in known] == ["REPRO-ENV-IMPORT"]
    # baseline keys survive line-number churn
    assert Finding("REPRO-ENV-IMPORT", "a.py", 99,
                   "import-time read").key in base


def test_syntax_error_reported_not_raised():
    found = hits("def broken(:\n")
    assert [f.rule_id for f in found] == ["REPRO-PARSE"]


BAD_PRESET = (
    "register(Experiment(name='bad', n_workers=2, f_workers=0,\n"
    "    n_servers=2, f_servers=0,\n"
    "    membership_plan=MembershipPlan(events=(\n"
    "        MembershipEvent(step=4, kind='leave', group=1),))))\n")


def _mini_repo_tree(tmp_path, preset_src):
    src = tmp_path / "src" / "repro" / "exp"
    src.mkdir(parents=True, exist_ok=True)
    (src / "presets.py").write_text(preset_src)
    # the preconditions rule reads Experiment defaults from spec.py
    with open(os.path.join(ROOT, "src", "repro", "exp", "spec.py")) as f:
        (src / "spec.py").write_text(f.read())


def test_repo_scope_findings_honor_inline_suppression(tmp_path):
    # un-suppressed: the registration line is attributed and flagged
    _mini_repo_tree(tmp_path, BAD_PRESET)
    found = [f for f in lint_repo(str(tmp_path))
             if f.rule_id == "REPRO-MEMBERSHIP-FLOOR"]
    assert found and found[0].line == 1
    # a justified marker on the registration line suppresses it
    _mini_repo_tree(
        tmp_path,
        "# analyze: ignore[REPRO-MEMBERSHIP-FLOOR] floor fixture for docs\n"
        + BAD_PRESET)
    found = [f for f in lint_repo(str(tmp_path))
             if f.rule_id == "REPRO-MEMBERSHIP-FLOOR"]
    assert found == []


def test_repo_scope_suppression_still_requires_justification(tmp_path):
    _mini_repo_tree(
        tmp_path,
        "# analyze: ignore[REPRO-MEMBERSHIP-FLOOR]\n" + BAD_PRESET)
    by_rule = {f.rule_id for f in lint_repo(str(tmp_path))}
    assert "REPRO-MEMBERSHIP-FLOOR" in by_rule  # bare marker buys nothing
    assert "REPRO-SUPPRESS" in by_rule


def test_update_baseline_prunes_stale_entries(tmp_path):
    path = str(tmp_path / "baseline.json")
    stale_rule = Finding("REPRO-GONE", "src/repro/core/protocol.py", 0, "x")
    stale_path = Finding("REPRO-DEAD-SEED", "src/repro/deleted.py", 0, "y")
    kept_unrun = Finding("REPRO-HLO-DONATION",
                         "src/repro/core/protocol.py", 0, "donation gap")
    write_baseline([stale_rule, stale_path, kept_unrun], path)
    current = [Finding("REPRO-DEAD-SEED", "src/repro/core/compression.py",
                       0, "dead")]
    rule_scopes = {r.rule_id: r.scope for r in rules()}
    _, pruned = refresh_baseline(current, path, ROOT,
                                 scopes_run={"file", "repo"},
                                 rule_scopes=rule_scopes)
    # unregistered rule id and vanished file are both pruned
    assert sorted(pruned) == sorted([stale_rule.key, stale_path.key])
    base = load_baseline(path)
    # the hlo entry survives a layer-1-only rewrite; current findings land
    assert kept_unrun.key in base and current[0].key in base
    assert stale_rule.key not in base and stale_path.key not in base


def test_update_baseline_replaces_run_scope_entries(tmp_path):
    path = str(tmp_path / "baseline.json")
    fixed = Finding("REPRO-DEAD-SEED", "src/repro/core/protocol.py", 0,
                    "was dead, now wired in")
    write_baseline([fixed], path)
    rule_scopes = {r.rule_id: r.scope for r in rules()}
    refresh_baseline([], path, ROOT, scopes_run={"file", "repo"},
                     rule_scopes=rule_scopes)
    assert load_baseline(path) == set()  # fixed finding dropped, not kept


# ---------------------------------------------------------------------------
# repo-scope rules against the live tree
# ---------------------------------------------------------------------------


def test_repo_lints_clean_modulo_tracked_debt():
    # every live-tree finding is DEAD-SEED tracked debt in the baseline;
    # everything else (incl. the interprocedural taint layer) is clean
    found = lint_repo(ROOT)
    assert {f.rule_id for f in found} <= {"REPRO-DEAD-SEED"}
    base = load_baseline(os.path.join(ROOT, "results", "analyze",
                                      "baseline.json"))
    assert {f.key for f in found} == base


def test_byz_bounds_sees_all_presets():
    with open(os.path.join(ROOT, "src", "repro", "exp", "presets.py")) as f:
        tree = ast.parse(f.read())
    assert len(list(preconditions._preset_calls(tree))) >= 10
    assert preconditions.check(ROOT) == []


def test_byz_bounds_math_trips_on_bad_clusters():
    bad = dict(n_workers=3, f_workers=1, n_servers=5, f_servers=1,
               variant="async", q_workers=None, q_servers=None)
    assert any("3f_w+1" in p for p in preconditions._bounds_violations(bad))
    bad_srv = dict(bad, n_workers=9, n_servers=4)
    assert any("3f_ps+2" in p
               for p in preconditions._bounds_violations(bad_srv))
    ok = dict(bad, n_workers=9)
    assert preconditions._bounds_violations(ok) == []


def test_agg_parity_clean_on_live_registry():
    assert registry_parity.check(ROOT) == []


# ---------------------------------------------------------------------------
# registry + CLI
# ---------------------------------------------------------------------------


def test_rule_registry_covers_all_layers():
    ids = {r.rule_id for r in rules()}
    assert {"REPRO-HOST-SYNC", "REPRO-ENV-IMPORT", "REPRO-ENV-MUTATE",
            "REPRO-CACHE-KEY", "REPRO-BYZ-BOUNDS", "REPRO-AGG-PARITY",
            "REPRO-MEMBERSHIP-FLOOR",
            "REPRO-TAINT-BYZ", "REPRO-DETERMINISM", "REPRO-DEAD-SEED",
            "REPRO-PALLAS-GRID", "REPRO-PALLAS-OOB", "REPRO-PALLAS-ACC",
            "REPRO-PALLAS-MASK",
            "REPRO-HLO-DONATION", "REPRO-HLO-HOST-TRANSFER",
            "REPRO-HLO-RECOMPILE", "REPRO-HLO-COLLECTIVES"} <= ids
    table = markdown_table()
    for rid in ids:
        assert rid in table


def test_readme_rule_table_matches_registry():
    # doc-drift gate: adding/changing a rule must regenerate the README
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    assert markdown_table() in readme


def test_lint_paths_skip_tests_and_results():
    paths = lint_paths(ROOT)
    assert paths, "lint roots found no files"
    assert not any(os.sep + "tests" + os.sep in p for p in paths)
    assert not any("__pycache__" in p for p in paths)
    assert any(p.endswith(os.path.join("analyze", "astlint.py"))
               for p in paths)


def test_cli_layer1_exits_zero(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    report = str(tmp_path / "report.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "--json", report],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stdout
    with open(report) as f:
        doc = json.load(f)
    assert doc["clean"] and doc["violations"] == []
    assert "REPRO-HOST-SYNC" in doc["stats"]["rules_run"]


def test_cli_table(capsys):
    from repro.analyze.__main__ import main
    assert main(["--table"]) == 0
    out = capsys.readouterr().out
    assert "REPRO-HLO-COLLECTIVES" in out and "| rule |" in out


def test_committed_baseline_is_exactly_tracked_dead_seed_debt():
    path = os.path.join(ROOT, "results", "analyze", "baseline.json")
    with open(path) as f:
        doc = json.load(f)
    keys = [e["key"] for e in doc["findings"]]
    assert keys, "baseline should track the seeded-module debt"
    assert all(k.startswith("REPRO-DEAD-SEED::") for k in keys)
    # the roadmap's compression item is tracked, not silent
    assert any("compression" in k for k in keys)
