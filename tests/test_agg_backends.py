"""Backend-equivalence tests: Pallas kernels vs pure-jnp references through
the repro.agg dispatch layer, across odd/even n and non-multiple-of-block d,
with interpret-mode fallback on CPU (auto-enabled off-TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.agg as agg

# odd/even n; d off lane (128) and block (512/1024) multiples on purpose
SHAPES = [(5, 64), (8, 127), (9, 130), (12, 513), (16, 777), (31, 1025)]


def rand(n, d, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed or n * d + 1), (n, d),
                             dtype)


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_backends_agree(n, d, dtype):
    x = rand(n, d, dtype=dtype)
    ref = agg.pairwise_sqdists(x, backend="jnp")
    ker = agg.pairwise_sqdists(x, backend="pallas")
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(ker, ref, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,d", SHAPES)
def test_cwise_median_backends_agree(n, d):
    x = rand(n, d)
    ref = agg.cwise_median(x, backend="jnp")
    ker = agg.cwise_median(x, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,f", [(7, 2), (8, 2), (9, 2), (12, 3), (13, 4)])
def test_mda_diameter_backends_agree(n, f):
    d2 = agg.rules.pairwise_sqdists(rand(n, 50))
    masks = jnp.asarray(agg.rules.subset_masks(n, f))
    ref = agg.subset_diameters(d2, masks, backend="jnp")
    ker = agg.subset_diameters(d2, masks, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["mda", "median", "krum", "multi_krum"])
@pytest.mark.parametrize("n,d", [(9, 100), (8, 127), (13, 257)])
def test_rule_backends_agree(name, n, d):
    """End-to-end: the registry rule produces the same aggregate on both
    backends for every rule that declares a pallas path."""
    spec = agg.get(name)
    assert "pallas" in spec.backends
    x = rand(n, d, seed=n + d)
    f = 2
    ref = spec(x, f, backend="jnp")
    ker = spec(x, f, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-4, atol=1e-4)


def test_median_kernel_size_limit_falls_back():
    """auto backend silently falls back past the kernel's n<=64 limit;
    explicit pallas raises the documented error."""
    x = rand(65, 32)
    np.testing.assert_allclose(agg.cwise_median(x), jnp.median(x, axis=0),
                               rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match="n <= 64"):
        agg.cwise_median(x, backend="pallas")


def test_interpret_flag_forced():
    """interpret=True is honored (the CPU fallback the benchmarks use)."""
    x = rand(9, 130)
    got = agg.pairwise_sqdists(x, backend="pallas", interpret=True)
    np.testing.assert_allclose(got, agg.rules.pairwise_sqdists(x),
                               rtol=1e-4, atol=1e-3)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        agg.pairwise_sqdists(rand(5, 8), backend="cuda")


def test_auto_resolution_matches_platform():
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert agg.resolve_backend("auto") == expect
    assert agg.resolve_backend(None) in ("jnp", "pallas")
