"""Backend-equivalence tests: Pallas kernels vs pure-jnp references through
the repro.agg dispatch layer, across odd/even n and non-multiple-of-block d,
with interpret-mode fallback on CPU (auto-enabled off-TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.agg as agg

# odd/even n; d off lane (128) and block (512/1024) multiples on purpose
SHAPES = [(5, 64), (8, 127), (9, 130), (12, 513), (16, 777), (31, 1025)]


def rand(n, d, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed or n * d + 1), (n, d),
                             dtype)


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_backends_agree(n, d, dtype):
    x = rand(n, d, dtype=dtype)
    ref = agg.pairwise_sqdists(x, backend="jnp")
    ker = agg.pairwise_sqdists(x, backend="pallas")
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(ker, ref, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,d", SHAPES)
def test_cwise_median_backends_agree(n, d):
    x = rand(n, d)
    ref = agg.cwise_median(x, backend="jnp")
    ker = agg.cwise_median(x, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,f", [(7, 2), (8, 2), (9, 2), (12, 3), (13, 4)])
def test_mda_diameter_backends_agree(n, f):
    d2 = agg.rules.pairwise_sqdists(rand(n, 50))
    masks = jnp.asarray(agg.rules.subset_masks(n, f))
    ref = agg.subset_diameters(d2, masks, backend="jnp")
    ker = agg.subset_diameters(d2, masks, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["mda", "median", "krum", "multi_krum",
                                  "meamed", "trimmed_mean"])
@pytest.mark.parametrize("n,d", [(9, 100), (8, 127), (13, 257)])
def test_rule_backends_agree(name, n, d):
    """End-to-end: the registry rule produces the same aggregate on both
    backends for every rule that declares a pallas path."""
    spec = agg.get(name)
    assert "pallas" in spec.backends
    x = rand(n, d, seed=n + d)
    f = 2
    ref = spec(x, f, backend="jnp")
    ker = spec(x, f, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("f", [0, 1, 2])
def test_cwise_order_statistic_kernels_agree(n, d, f):
    """meamed + trimmed_mean share cwise_median's sorting network; their
    kernels must match the jnp references across odd/even n, off-block d,
    and every admissible f."""
    if n <= 2 * f:
        pytest.skip("trimmed_mean needs n > 2f")
    x = rand(n, d, seed=7 * n + d + f)
    for name in ("meamed", "trimmed_mean"):
        spec = agg.get(name)
        ref = spec(x, f, backend="jnp")
        ker = spec(x, f, backend="pallas")
        np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{name} n={n} d={d} f={f}")


def test_meamed_kernel_asymmetric_ties_match_reference():
    """Colluding duplicate payloads tie several candidate windows on max
    endpoint distance; the kernel must still pick the reference's window
    (the one with the n-f smallest distances), not a window stuffed with
    tied outliers."""
    col = jnp.asarray([0., -3., 0., 0., 1., -3., -3., -1., -3., 1.])[:, None]
    ref = agg.rules.meamed(col, 3)
    ker = agg.get("meamed")(col, 3, backend="pallas")
    np.testing.assert_allclose(ker, ref, rtol=1e-6, atol=1e-6)


def test_meamed_kernel_tie_quality_on_integer_stacks():
    """On tie-heavy integer data the kernel's selection must match the
    reference's *quality* exactly — same max distance and same distance sum
    (the quantities the robustness analysis uses). The averaged values may
    differ only when a pair sits exactly equidistant on opposite sides of
    the median (the reference breaks that tie by input position, which a
    sorted tile cannot see)."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        n = int(rng.integers(3, 14))
        f = int(rng.integers(0, (n - 1) // 2 + 1))
        x = np.asarray(rng.integers(-3, 4, size=(n, 8)), np.float32)
        ker = np.asarray(agg.get("meamed")(jnp.asarray(x), f,
                                           backend="pallas"))
        m = n - f
        med = np.median(x, axis=0)
        for c in range(x.shape[1]):
            d_ref = np.sort(np.abs(x[:, c] - med[c]))[:m]
            s = np.sort(x[:, c])
            # the kernel's lexicographic (max, sum) window criterion
            cand = [(max(abs(s[i] - med[c]), abs(s[i + m - 1] - med[c])),
                     np.abs(s[i:i + m] - med[c]).sum(), s[i:i + m].mean())
                    for i in range(f + 1)]
            kmax, ksum, kmean = min(cand, key=lambda t: (t[0], t[1]))
            assert kmax == pytest.approx(d_ref.max(), abs=1e-5)
            assert ksum == pytest.approx(d_ref.sum(), abs=1e-4)
            assert ker[c] == pytest.approx(kmean, abs=1e-5)


def test_order_statistic_kernels_size_limit_falls_back():
    """auto backend falls back past n<=64 / multi-dim leaves; explicit pallas
    raises (same contract as the median kernel)."""
    x = rand(65, 32)
    np.testing.assert_allclose(agg.get("meamed")(x, 2),
                               agg.rules.meamed(x, 2), rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match="n <= 64"):
        agg.get("trimmed_mean")(x, 2, backend="pallas")


def test_median_kernel_size_limit_falls_back():
    """auto backend silently falls back past the kernel's n<=64 limit;
    explicit pallas raises the documented error."""
    x = rand(65, 32)
    np.testing.assert_allclose(agg.cwise_median(x), jnp.median(x, axis=0),
                               rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match="n <= 64"):
        agg.cwise_median(x, backend="pallas")


def test_interpret_flag_forced():
    """interpret=True is honored (the CPU fallback the benchmarks use)."""
    x = rand(9, 130)
    got = agg.pairwise_sqdists(x, backend="pallas", interpret=True)
    np.testing.assert_allclose(got, agg.rules.pairwise_sqdists(x),
                               rtol=1e-4, atol=1e-3)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        agg.pairwise_sqdists(rand(5, 8), backend="cuda")


def test_auto_resolution_matches_platform():
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert agg.resolve_backend("auto") == expect
    assert agg.resolve_backend(None) in ("jnp", "pallas")
