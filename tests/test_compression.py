"""Gradient compression: correctness + MDA composability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gars
from repro.core.compression import (randk_compress, sign_compress,
                                    topk_compress)

KEY = jax.random.PRNGKey(0)


def tree(seed, scale=1.0):
    k = jax.random.fold_in(KEY, seed)
    return {"w": scale * jax.random.normal(k, (32, 16)),
            "b": scale * jax.random.normal(jax.random.fold_in(k, 1), (64,))}


def test_topk_sparsity_and_support():
    g = tree(0)
    c = topk_compress(g, frac=0.1)
    for l, lc in zip(jax.tree.leaves(g), jax.tree.leaves(c)):
        nz = int(jnp.sum(lc != 0))
        assert nz <= int(l.size * 0.1) + 1
        # kept values unchanged
        mask = lc != 0
        np.testing.assert_array_equal(lc[mask], l[mask])


def test_randk_unbiased():
    g = {"w": jnp.ones((2048,))}
    outs = [randk_compress(g, jax.random.fold_in(KEY, i), frac=0.25)["w"]
            for i in range(64)]
    mean = jnp.mean(jnp.stack(outs), axis=0)
    assert abs(float(jnp.mean(mean)) - 1.0) < 0.1  # E[compressed] = g


def test_sign_preserves_direction():
    g = tree(1)
    c = sign_compress(g)
    dot = sum(jnp.sum(a * b) for a, b in zip(jax.tree.leaves(g),
                                             jax.tree.leaves(c)))
    assert float(dot) > 0


def test_mda_on_compressed_still_excludes_byzantine():
    """MDA selection on compressed gradients keeps rejecting the outlier."""
    honest = [tree(i, scale=1.0) for i in range(7)]
    byz = [tree(99, scale=500.0) for _ in range(2)]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *(honest + byz))
    comp = topk_compress(stacked, frac=0.2)
    agg = gars.tree_gar(gars.mda, comp, 2)
    norm = jnp.sqrt(sum(jnp.sum(l ** 2) for l in jax.tree.leaves(agg)))
    assert float(norm) < 50.0  # Byzantine scale (500) excluded
