"""Layer-level oracles: blocked attention, flash decode, chunked scans,
chunked cross-entropy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.mamba2 import ssd_chunked
from repro.models.rwkv6 import wkv_chunked

KEY = jax.random.PRNGKey(0)


def k(i):
    return jax.random.fold_in(KEY, i)


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("S,qb,kb", [(37, 8, 16), (64, 64, 64), (50, 16, 8)])
def test_blocked_attention_vs_naive(window, S, qb, kb):
    B, H, kvH, hd = 2, 4, 2, 16
    q = jax.random.normal(k(1), (B, S, H, hd))
    kk = jax.random.normal(k(2), (B, S, kvH, hd))
    vv = jax.random.normal(k(3), (B, S, kvH, hd))
    got = L.blocked_attention(q, kk, vv, window=window, q_block=qb, kv_block=kb)
    want = L._naive_attention(q, kk, vv, causal=True, window=window,
                              cross=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cross_attention():
    B, Sq, Skv, H, hd = 2, 9, 21, 3, 8
    q = jax.random.normal(k(4), (B, Sq, H, hd))
    kk = jax.random.normal(k(5), (B, Skv, H, hd))
    vv = jax.random.normal(k(6), (B, Skv, H, hd))
    got = L.blocked_attention(q, kk, vv, causal=False, cross=True, q_block=4,
                              kv_block=8)
    want = L._naive_attention(q, kk, vv, causal=False, window=0, cross=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_decode_matches_full_attention():
    B, S, H, kvH, hd = 2, 40, 4, 2, 16
    kk = jax.random.normal(k(7), (B, S, kvH, hd))
    vv = jax.random.normal(k(8), (B, S, kvH, hd))
    cache = L.KVCache.create(B, kvH, 48, hd, n_chunks=4, dtype=jnp.float32)
    cache = L.cache_prefill(cache, kk, vv)
    q = jax.random.normal(k(9), (B, 1, H, hd))
    got = L.flash_decode(q, cache)
    want = L._naive_attention(q, kk, vv, causal=True, window=0, cross=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cache_insert_then_decode():
    B, kvH, hd = 1, 2, 8
    cache = L.KVCache.create(B, kvH, 16, hd, n_chunks=4, dtype=jnp.float32)
    ks_, vs_ = [], []
    for i in range(5):
        kn = jax.random.normal(k(10 + i), (B, 1, kvH, hd))
        vn = jax.random.normal(k(20 + i), (B, 1, kvH, hd))
        cache = L.cache_insert(cache, kn, vn)
        ks_.append(kn)
        vs_.append(vn)
    assert int(cache.length) == 5
    q = jax.random.normal(k(30), (B, 1, 4, hd))
    got = L.flash_decode(q, cache)
    want = L._naive_attention(q, jnp.concatenate(ks_, 1),
                              jnp.concatenate(vs_, 1), causal=True, window=0,
                              cross=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_wkv_chunked_vs_naive():
    B, S, H, K = 2, 37, 3, 8
    r, kk, v = (jax.random.normal(k(40 + i), (B, S, H, K)) for i in range(3))
    lw = -jax.nn.softplus(jax.random.normal(k(43), (B, S, H, K)))
    u = 0.3 * jax.random.normal(k(44), (H, K))
    s0 = jax.random.normal(k(45), (B, H, K, K))
    yc, sc = wkv_chunked(r, kk, v, lw, u, s0, chunk=16)
    s = s0
    ys = []
    for t in range(S):
        rt, kt, vt = r[:, t], kk[:, t], v[:, t]
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       s + u[None, :, :, None] * jnp.einsum("bhk,bhv->bhkv",
                                                            kt, vt))
        ys.append(y)
        s = jnp.exp(lw[:, t])[..., None] * s + jnp.einsum("bhk,bhv->bhkv",
                                                          kt, vt)
    np.testing.assert_allclose(yc, jnp.stack(ys, 1), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(sc, s, rtol=3e-4, atol=3e-4)


def test_ssd_chunked_vs_naive():
    B, S, H, P, N = 2, 29, 3, 4, 5
    xh = jax.random.normal(k(50), (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(k(51), (B, S, H)))
    Bm = jax.random.normal(k(52), (B, S, N))
    Cm = jax.random.normal(k(53), (B, S, N))
    h0 = jax.random.normal(k(54), (B, H, P, N))
    yc, hc = ssd_chunked(xh, la, Bm, Cm, h0, chunk=8)
    h = h0
    ys = []
    for t in range(S):
        h = jnp.exp(la[:, t])[..., None, None] * h + jnp.einsum(
            "bhp,bn->bhpn", xh[:, t], Bm[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    np.testing.assert_allclose(yc, jnp.stack(ys, 1), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hc, h, rtol=3e-4, atol=3e-4)


def test_chunked_cross_entropy_matches_full():
    B, S, D, V = 2, 23, 16, 97
    hidden = jax.random.normal(k(60), (B, S, D), jnp.bfloat16)
    table = {"table": jax.random.normal(k(61), (V, D))}
    labels = jax.random.randint(k(62), (B, S), 0, V)
    got = L.cross_entropy_chunked(hidden, table, labels, chunk=8)
    logits = L.unembed(table, hidden)
    want = L.cross_entropy(logits, labels)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_mrope_matches_rope_for_equal_ids():
    """Text-only M-RoPE (all three components equal) == standard RoPE."""
    B, S, H, hd = 2, 11, 3, 16
    x = jax.random.normal(k(70), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[None], (3, B, S))
    a = L.apply_rope(x, pos, 1e4)
    b = L.apply_rope(x, pos3, 1e4)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
