"""Subprocess body for multi-device serve tests (8 forced host devices, set
before jax initialises — hence not in-process). Gates two things the 1-device
suite cannot: rep>1 protocol meshes emitting replica-stacked checkpoints, and
multi-replica quorum serving under serve-mesh sharding rules."""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.exp as exp  # noqa: E402
from repro.checkpoint import checkpointer as ck  # noqa: E402
from repro.core.attacks import ByzantineSpec  # noqa: E402
from repro.launch.mesh import (compat_make_mesh, make_serve_mesh,  # noqa: E402
                               use_mesh)
from repro.launch.steps import serve_rules  # noqa: E402
from repro.models.registry import get_bundle  # noqa: E402
from repro.serve import QuorumService, ReplicaPool  # noqa: E402


def main():
    assert jax.device_count() == 8

    # 1. protocol training on a rep=5 multi-device mesh emits replica-stacked
    #    checkpoints that restore straight into a pool
    with tempfile.TemporaryDirectory() as td:
        d = os.path.join(td, "ck")
        res = exp.run("serve/ckpt_smoke", ckpt_dir=d)
        assert res.provenance["mesh"]["rep"] == 5, res.provenance["mesh"]
        assert ck.latest_step(d) == exp.get("serve/ckpt_smoke").steps
        e = exp.get("serve/ckpt_smoke")
        init_fn, _, _ = e.build_problem()
        pool = ReplicaPool.from_checkpoint(d, init_fn, f=1)
        assert pool.n_replicas == 5
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree.leaves(pool.params))
        for a, b in zip(jax.tree.leaves(pool.params),
                        jax.tree.leaves(res.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("protocol ckpt on rep=5 mesh OK")

    # 2. multi-replica transformer serving under the serve mesh's sharding
    #    rules: 1-of-4 Byzantine, continuations token-identical to honest
    base = compat_make_mesh((4, 2), ("data", "model"))
    smesh = make_serve_mesh(base)
    bundle = get_bundle("phi4-mini-3.8b", reduced=True)
    rules = serve_rules(smesh, bundle.cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    prompts = [[3, 5, 7, 9], [11, 2, 4, 6]]
    with use_mesh(smesh):
        svc1 = QuorumService(ReplicaPool.from_params(params, 1, f=0), bundle,
                             n_slots=2, max_len=32, rules=rules)
        honest = svc1.generate(prompts, max_new=5)
        pool4 = ReplicaPool.from_params(params, 4, f=1).corrupt(
            ByzantineSpec(server_attack="reversed", n_byz_servers=1),
            jax.random.PRNGKey(7))
        svc4 = QuorumService(pool4, bundle, n_slots=2, max_len=32,
                             rules=rules)
        outs = svc4.generate(prompts, max_new=5)
    assert outs == honest, (outs, honest)
    rep = svc4.report()
    assert [i for _, i in rep["ejections"]] == [3]
    print(f"quorum serve on {jax.device_count()} devices OK "
          f"(tok/s {rep['tok_s']:.1f}, ejected {rep['ejections']})")
    print("SERVE_TESTS_PASS")


if __name__ == "__main__":
    main()
