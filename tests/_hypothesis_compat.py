"""Graceful degradation when `hypothesis` is not installed (offline image).

Property tests import `given`/`settings`/`st` from here instead of from
hypothesis directly. When hypothesis is available we re-export it unchanged.
When it is missing (it cannot be pip-installed in the offline container) we
fall back to a deterministic seeded-parametrization shim: each @given test is
executed `max_examples` times with samples drawn from a PRNG seeded by the
test's qualified name, so the property checks still execute — reproducibly —
rather than being skipped wholesale via pytest.importorskip.

The shim implements only the strategy surface these tests use
(st.integers, st.floats).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback

    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return self.lo + (self.hi - self.lo) * rng.random()

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

    st = _St()

    def settings(max_examples: int = 20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: copying fn's signature would make pytest
            # treat the property arguments as fixtures.
            def wrapper(*args):  # *args: (self,) for methods, () for functions
                n = getattr(wrapper, "_max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    kw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kw)
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper
        return deco
