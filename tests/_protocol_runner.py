"""Subprocess body for distributed-protocol tests (needs 8 forced devices,
which must be set before jax initialises — hence not in-process)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import protocol  # noqa: E402
from repro.core.attacks import ByzantineSpec  # noqa: E402
from repro.launch.mesh import (compat_make_mesh, make_byz_mesh,  # noqa: E402
                               use_mesh)
from repro.models.registry import get_bundle  # noqa: E402
from repro.optim.schedules import inverse_linear  # noqa: E402


def main():
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    bmesh = make_byz_mesh(mesh, n_groups=4)
    bundle = get_bundle("phi4-mini-3.8b", reduced=True)

    for engine in ("naive", "sharded"):
        pcfg = protocol.ProtocolConfig.derive(4, T=3, engine=engine)
        init = protocol.make_init_fn(bundle, pcfg)
        step = protocol.make_train_step(bundle, pcfg,
                                        inverse_linear(0.05, 0.01), mesh=bmesh)
        with use_mesh(bmesh):
            state = jax.jit(init)(jax.random.PRNGKey(0))
            shardings = protocol.state_shardings(
                jax.eval_shape(init, jax.random.PRNGKey(0)), bmesh,
                overrides=protocol.attn_overrides(bundle.cfg, bmesh))
            state = jax.tree.map(jax.device_put, state, shardings)
            G, B, S = 4, 2, 16
            batch = bundle.make_batch("train", G * B, S, jax.random.PRNGKey(1))
            batch = jax.tree.map(
                lambda l: jax.device_put(
                    l.reshape((G, B) + l.shape[1:]),
                    NamedSharding(bmesh, P("rep"))), batch)
            jstep = jax.jit(step, donate_argnums=0)
            losses = []
            for _ in range(7):
                p0 = jax.tree.map(lambda l: l[0], state.params)
                losses.append(float(bundle.loss(
                    p0, jax.tree.map(lambda x: x[0], batch))))
                state = jstep(state, batch)
            assert losses[-1] < losses[0] - 0.2, (engine, losses)
            assert all(bool(jnp.all(jnp.isfinite(l)))
                       for l in jax.tree.leaves(state.params)), engine
            # consolidate for serving: median over replicas
            served = protocol.consolidate(state.params, pcfg)
            assert jax.tree.leaves(served)[0].shape == \
                jax.tree.leaves(state.params)[0].shape[1:]
            print(f"{engine}: loss {losses[0]:.3f} -> {losses[-1]:.3f} OK")

    # Byzantine run: reversed gradients from 1 group, with attack injection
    pcfg = protocol.ProtocolConfig.derive(
        4, T=3, byz=ByzantineSpec(worker_attack="reversed", n_byz_workers=1))
    init = protocol.make_init_fn(bundle, pcfg)
    step = protocol.make_train_step(bundle, pcfg, inverse_linear(0.05, 0.01),
                                    with_attack=True, mesh=bmesh)
    with use_mesh(bmesh):
        state = jax.jit(init)(jax.random.PRNGKey(0))
        G, B, S = 4, 2, 16
        batch = bundle.make_batch("train", G * B, S, jax.random.PRNGKey(1))
        batch = jax.tree.map(
            lambda l: l.reshape((G, B) + l.shape[1:]), batch)
        jstep = jax.jit(step, donate_argnums=0)
        l0 = float(bundle.loss(jax.tree.map(lambda l: l[0], state.params),
                               jax.tree.map(lambda x: x[0], batch)))
        for _ in range(7):
            state = jstep(state, batch)
        l1 = float(bundle.loss(jax.tree.map(lambda l: l[0], state.params),
                               jax.tree.map(lambda x: x[0], batch)))
        assert l1 < l0 - 0.2, ("byzantine", l0, l1)
        print(f"byzantine(MDA): loss {l0:.3f} -> {l1:.3f} OK")
    print("PROTOCOL_TESTS_PASS")


if __name__ == "__main__":
    main()
