"""Tests for the unified Aggregator API: registry semantics, uniform f
validation, masked-delivery aggregation, pytree paths, and netsim-trace
composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.agg as agg

MASKABLE = [n for n in agg.names() if agg.get(n).supports_masked_delivery]
TREE_CAPABLE = [n for n in agg.names() if agg.get(n).tree_mode is not None]
# rules whose traced-mask path is *exactly* the subset rule (mda's traced path
# is the greedy 2-approximation, documented in repro.agg.rules)
EXACT_MASKED = [n for n in MASKABLE if n != "mda"]


def rand(n, d, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def valid_f(name: str, n: int, f: int) -> bool:
    k, c = agg.get(name).requires
    return 0 <= f < n and n >= k * f + c


# --------------------------- registry semantics -----------------------------


class TestRegistry:
    def test_lookup_and_names(self):
        assert "mda" in agg.names()
        assert agg.get("mda").name == "mda"
        with pytest.raises(KeyError, match="unknown aggregator"):
            agg.get("nope")

    def test_uniform_f_validation(self):
        x = rand(5, 8)
        with pytest.raises(ValueError, match="mda.*n >= 2f\\+1"):
            agg.get("mda")(x, 3)
        with pytest.raises(ValueError, match="f must be >= 0"):
            agg.get("median")(x, -1)
        with pytest.raises(ValueError, match="krum.*n >= 2f\\+3"):
            agg.get("krum")(x, 2)
        with pytest.raises(ValueError, match="bulyan.*n >= 4f\\+3"):
            agg.get("bulyan")(x, 1)

    def test_declared_arity_no_f_stub(self):
        """mean/median take no f — the old `mean(x, f=0)` stub is gone."""
        x = rand(6, 4)
        assert not agg.get("mean").takes_f
        assert not agg.get("median").takes_f
        with pytest.raises(TypeError):
            agg.rules.mean(x, 2)
        np.testing.assert_allclose(agg.get("mean")(x, 2), jnp.mean(x, 0),
                                   rtol=1e-6)

    def test_aggregate_functional_form(self):
        x = rand(9, 12)
        np.testing.assert_allclose(agg.aggregate("mda", x, 2),
                                   agg.get("mda")(x, 2), rtol=1e-6)

    def test_tunable_filtering(self):
        x = rand(9, 12)
        spec = agg.get("mda")
        # foreign kwargs are dropped, declared ones honored
        out = agg.tree_agg("median", {"a": x}, 1, exact_limit=10)
        np.testing.assert_allclose(out["a"], jnp.median(x, 0), rtol=1e-6)
        got = spec(x, 2, exact_limit=1)   # force greedy
        sel = agg.rules.mda_select_greedy(agg.rules.pairwise_sqdists(x), 2)
        np.testing.assert_allclose(got, sel.astype(jnp.float32) @ x / 7,
                                   rtol=1e-5, atol=1e-5)

    def test_markdown_table_covers_registry(self):
        table = agg.markdown_table()
        for name in agg.names():
            assert f"`{name}`" in table

    def test_variance_thresholds_from_spec(self):
        assert agg.get("mda").variance_threshold(18, 1) == pytest.approx(8.5)
        assert (agg.get("krum").variance_threshold(18, 1)
                < agg.get("mda").variance_threshold(18, 1))

    def test_legacy_shim_warns_and_works(self):
        import importlib
        import repro.core.gars as gars
        with pytest.warns(DeprecationWarning):
            importlib.reload(gars)
        x = rand(9, 7)
        np.testing.assert_allclose(gars.mda(x, 2), agg.get("mda")(x, 2),
                                   rtol=1e-6)
        # old tree_gar(callable, ...) still routes through the new API
        got = gars.tree_gar(gars.coordinate_median, {"a": x}, 1)
        np.testing.assert_allclose(got["a"], jnp.median(x, 0), rtol=1e-6)


# --------------------------- masked delivery --------------------------------


class TestMaskedDelivery:
    @pytest.mark.parametrize("name", sorted(agg.names()))
    def test_concrete_mask_is_subset_rule(self, name):
        """A concrete mask gives exact delivered-subset semantics: for EVERY
        registered rule, masked == rule on the gathered subset."""
        x = rand(11, 13, seed=3)
        mask = np.array([1, 0, 1, 1, 0, 1, 1, 1, 1, 0, 1], bool)
        f = 1
        spec = agg.get(name)
        got = spec(x, f, mask=mask)
        want = spec(x[mask], f)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("name", sorted(MASKABLE))
    def test_traced_full_mask_reproduces_unmasked(self, name):
        """All-ones traced mask reproduces the unmasked rule (mda: its greedy
        selection, the documented traced-mask semantics)."""
        x = rand(9, 17, seed=5)
        f = 1
        spec = agg.get(name)
        got = jax.jit(lambda x, m: spec(x, f, mask=m))(x, jnp.ones(9, bool))
        if name == "mda":
            sel = agg.rules.mda_select_greedy(agg.rules.pairwise_sqdists(x), f)
            want = sel.astype(jnp.float32) @ x / 8
        else:
            want = spec(x, f)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("name", sorted(EXACT_MASKED))
    def test_traced_mask_agrees_with_subset(self, name):
        """Traced partial masks agree with the rule on the delivered subset
        (the masked_coordinate_median contract, for every exact masked rule)."""
        x = rand(10, 9, seed=7)
        mask_np = np.array([1, 1, 0, 1, 1, 0, 1, 1, 1, 0], bool)
        f = 1
        spec = agg.get(name)
        got = jax.jit(lambda x, m: spec(x, f, mask=m))(x, jnp.asarray(mask_np))
        want = spec(x[mask_np], f)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_masked_median_is_masked_coordinate_median(self):
        x = rand(9, 21, seed=11)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0, 1], bool)
        np.testing.assert_allclose(
            agg.get("median")(x, mask=mask),
            agg.rules.masked_coordinate_median(x, mask), rtol=1e-6)

    def test_traced_mask_requires_capability(self):
        x = rand(11, 6)
        with pytest.raises(ValueError, match="no traced-mask"):
            jax.jit(lambda x, m: agg.get("bulyan")(x, 1, mask=m))(
                x, jnp.ones(11, bool))

    def test_masked_mda_stays_in_delivered_hull(self):
        x = rand(9, 8, seed=13)
        x = x.at[0].set(500.0)       # undelivered outlier must not leak in
        mask_np = np.array([0, 1, 1, 1, 0, 1, 1, 1, 1], bool)
        got = jax.jit(lambda x, m: agg.get("mda")(x, 2, mask=m))(
            x, jnp.asarray(mask_np))
        sub = x[mask_np]
        assert bool(jnp.all(got >= jnp.min(sub, 0) - 1e-4))
        assert bool(jnp.all(got <= jnp.max(sub, 0) + 1e-4))

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(5, 12), f=st.integers(0, 2), seed=st.integers(0, 999),
           q=st.integers(3, 12))
    def test_prop_full_mask_identity(self, n, f, seed, q):
        """Property: a full concrete mask is the identity wrapper for every
        registered rule at any valid (n, f)."""
        del q
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, 7))
        full = np.ones(n, bool)
        for name in agg.names():
            if not valid_f(name, n, f):
                continue
            spec = agg.get(name)
            np.testing.assert_allclose(spec(x, f, mask=full), spec(x, f),
                                       rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(6, 12), q=st.integers(3, 12), d=st.integers(1, 16),
           seed=st.integers(0, 999))
    def test_prop_masked_median_subset(self, n, q, d, seed):
        """Property: masked median == median of the delivered subset."""
        q = min(q, n)
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (n, d))
        idx = np.asarray(jax.random.permutation(jax.random.fold_in(key, 1), n))[:q]
        mask = np.zeros(n, bool)
        mask[idx] = True
        got = jax.jit(lambda x, m: agg.get("median")(x, mask=m))(
            x, jnp.asarray(mask))
        np.testing.assert_allclose(got, jnp.median(x[mask], axis=0),
                                   rtol=1e-5, atol=1e-6)


# --------------------------- pytree paths -----------------------------------


def make_stacked(n, seed=0):
    trees = []
    for i in range(n):
        k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        trees.append({"a": jax.random.normal(k, (3, 4)),
                      "b": jax.random.normal(jax.random.fold_in(k, 1), (5,))})
    return (jax.tree.map(lambda *ls: jnp.stack(ls), *trees),
            jnp.stack([jnp.concatenate([t["a"].ravel(), t["b"]])
                       for t in trees]))


class TestTreeAgg:
    @pytest.mark.parametrize("name", sorted(set(TREE_CAPABLE) - {"krum"}))
    def test_tree_equals_flat(self, name):
        stacked, flat = make_stacked(7)
        got = agg.tree_agg(name, stacked, 2)
        want = agg.get(name)(flat, 2)
        np.testing.assert_allclose(
            jnp.concatenate([got["a"].ravel(), got["b"]]), want,
            rtol=1e-4, atol=1e-5)

    def test_tree_krum_picks_same_vector(self):
        stacked, flat = make_stacked(7)
        got = agg.tree_agg("krum", stacked, 2)
        want = agg.rules.krum(flat, 2)
        np.testing.assert_allclose(
            jnp.concatenate([got["a"].ravel(), got["b"]]), want,
            rtol=1e-4, atol=1e-5)

    def test_tree_masked_median(self):
        stacked, _ = make_stacked(7)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1], bool)
        got = jax.jit(lambda s, m: agg.tree_agg("median", s, 1, mask=m))(
            stacked, mask)
        np.testing.assert_allclose(
            got["b"], jnp.median(stacked["b"][np.asarray(mask)], 0),
            rtol=1e-5, atol=1e-6)

    def test_tree_masked_mda_excludes_undelivered_outlier(self):
        stacked, _ = make_stacked(9)
        stacked = jax.tree.map(lambda l: l.at[0].set(300.0), stacked)
        mask = jnp.asarray([0, 1, 1, 1, 1, 1, 1, 1, 1], bool)
        got = jax.jit(lambda s, m: agg.tree_agg("mda", s, 2, mask=m))(
            stacked, mask)
        assert float(jnp.max(jnp.abs(got["a"]))) < 50.0

    def test_tree_rejects_bulyan(self):
        stacked, _ = make_stacked(7)
        with pytest.raises(ValueError, match="pytree"):
            agg.tree_agg("bulyan", stacked, 1)

    def test_selection_weights_guard(self):
        d2 = agg.rules.pairwise_sqdists(rand(7, 5))
        w = agg.selection_weights("mda", d2, 2)
        assert w.shape == (7,) and float(jnp.sum(w)) == pytest.approx(1.0)
        with pytest.raises(ValueError, match="not selection-based"):
            agg.selection_weights("median", d2, 2)


# --------------------------- streaming Gram ---------------------------------


def _gram_reference(stacked_tree):
    """Materialized-flatten oracle: [n, P] stack, one einsum."""
    leaves = [np.asarray(l, np.float32) for l in jax.tree.leaves(stacked_tree)]
    n = leaves[0].shape[0]
    flat = np.concatenate([l.reshape(n, -1) for l in leaves], axis=1)
    return np.einsum("na,ma->nm", flat, flat)


def _mixed_tree(n, seed=0):
    """Mixed-dtype, mixed-rank leaves: a small 'layer stack', a bf16 matrix,
    a wide f32 table, and a vector."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "blocks": {"w": jax.random.normal(ks[0], (n, 3, 4, 8))},
        "proj": jax.random.normal(ks[1], (n, 6, 5)).astype(jnp.bfloat16),
        "table": jax.random.normal(ks[2], (n, 8, 32)),
        "bias": jax.random.normal(ks[3], (n, 7)),
    }


class TestStreamingGram:
    """The streaming leaf-partial Gram (the ONLY selection path) against the
    materialized [n, P] flatten it replaced."""

    @pytest.mark.parametrize("n", [4, 5, 7, 8])   # odd and even stack widths
    def test_streaming_equals_materialized(self, n):
        tree = _mixed_tree(n)
        got = np.asarray(agg.tree_gram(tree))
        np.testing.assert_allclose(got, _gram_reference(tree),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("n", [5, 6])
    def test_streaming_equals_materialized_when_chunked(self, n):
        # tiny chunk_bytes forces the _reduce_stream path on every big leaf
        tree = _mixed_tree(n, seed=3)
        got = np.asarray(agg.tree_gram(tree, chunk_bytes=64))
        np.testing.assert_allclose(got, _gram_reference(tree),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("n", [3, 6])
    def test_streaming_under_protocol_mesh(self, n):
        # the sharded path (gram_spec constraint + local dot + psum); on the
        # tier-1 host this is a (1,1,1) mesh — the forced-8-device subprocess
        # lanes (tests/test_protocol_distributed.py) re-check it sharded
        from repro.launch.mesh import make_protocol_mesh, use_mesh
        mesh = make_protocol_mesh(n)
        tree = _mixed_tree(n, seed=1)
        with use_mesh(mesh):
            got = np.asarray(jax.jit(
                lambda t: agg.tree_gram(t, mesh=mesh))(tree))
        np.testing.assert_allclose(got, _gram_reference(tree),
                                   rtol=2e-4, atol=2e-4)

    def test_tree_agg_selection_rides_streaming_gram(self, monkeypatch):
        # tree_agg's selection path must route through tree_gram (no other
        # distance assembly exists)
        calls = []
        orig = agg.tree.tree_gram
        monkeypatch.setattr(agg.tree, "tree_gram",
                            lambda *a, **kw: calls.append(1) or orig(*a, **kw))
        stacked, flat = make_stacked(7)
        got = agg.tree_agg("mda", stacked, 2)
        want = agg.get("mda")(flat, 2)
        assert calls, "selection tree_agg did not use the streaming Gram"
        np.testing.assert_allclose(
            jnp.concatenate([got["a"].ravel(), got["b"]]), want,
            rtol=1e-4, atol=1e-5)


# --------------------------- netsim composition -----------------------------


class TestNetsimMaskComposition:
    def test_trace_masks_drive_any_masked_rule(self):
        """Realized netsim quorums, as masks, compose with every mask-capable
        rule and agree with index-subset aggregation of the same trace."""
        from repro.netsim import scenarios
        from repro.netsim.cluster import ClusterSim
        sc = scenarios.build("heavy_tail_stragglers", steps=4, seed=2)
        tr = ClusterSim(sc).run()
        masks = tr.push_masks()          # [steps, n_ps, n_w]
        x = rand(sc.n_workers, 15, seed=17)
        for name in ("median", "meamed", "multi_krum"):
            spec = agg.get(name)
            for s in range(sc.n_servers):
                m = masks[0, s]
                got = spec(x, 1, mask=m)
                want = spec(x[m], 1)
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_scenario_gar_is_registry_validated(self):
        from repro.netsim import scenarios
        with pytest.raises(KeyError, match="unknown aggregator"):
            scenarios.build("baseline_uniform", gar="nope")
        assert scenarios.build("baseline_uniform", gar="krum").gar == "krum"


class TestSortNetwork:
    """The Batcher compare-exchange sort behind the order-statistic rules."""

    def test_matches_jnp_sort_all_small_n(self):
        from repro.agg.rules import sort_stack
        rng = np.random.default_rng(0)
        for n in range(1, 33):
            x = rng.normal(size=(n, 11)).astype(np.float32)
            np.testing.assert_array_equal(np.asarray(sort_stack(jnp.asarray(x))),
                                          np.sort(x, axis=0), err_msg=f"n={n}")
            ties = rng.integers(0, 3, size=(n, 7)).astype(np.float32)
            np.testing.assert_array_equal(
                np.asarray(sort_stack(jnp.asarray(ties))),
                np.sort(ties, axis=0))

    def test_nan_payloads_sort_last_and_get_trimmed(self):
        """A Byzantine NaN input must not smear through min/max: like
        jnp.sort, NaNs rank last, so trimmed_mean/median stay finite."""
        from repro.agg import rules
        x = jnp.array([[2.0, 1.0], [jnp.nan, 5.0], [1.0, jnp.nan],
                       [3.0, 2.0], [4.0, 3.0]])
        assert np.isfinite(np.asarray(rules.trimmed_mean(x, 1))).all()
        assert np.isfinite(np.asarray(rules.coordinate_median(x))).all()
        assert np.isfinite(np.asarray(rules.meamed(x, 1))).all()

    def test_toggle_restores_jnp_sort(self):
        from repro.agg.rules import sort_stack, use_sort_network
        x = jnp.asarray(np.random.default_rng(1).normal(size=(6, 9)),
                        jnp.float32)
        with use_sort_network(False):
            off = np.asarray(sort_stack(x))
        np.testing.assert_array_equal(off, np.asarray(jnp.sort(x, axis=0)))
        np.testing.assert_array_equal(off, np.asarray(sort_stack(x)))
