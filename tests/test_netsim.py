"""repro.netsim: determinism, quorum validity, analytic cross-validation,
and trace-driven protocol behaviour (DMC contraction under stragglers)."""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import make_mlp_problem
from repro.core.quorum import TraceDelivery, UniformDelivery
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum)
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.netsim import ClusterSim, scenarios
from repro.netsim.accounting import compare_with_model

SMALL = dict(n_workers=7, f_workers=2, n_servers=5, f_servers=1,
             T=5, steps=10, model_d=1000)


def _run(name, **kw):
    sc = scenarios.build(name, **{**SMALL, **kw})
    return sc, ClusterSim(sc).run()


class TestDeterminism:
    def test_same_seed_bit_identical(self):
        _, a = _run("crash_storm", seed=11)
        _, b = _run("crash_storm", seed=11)
        for f in ("pull_idx", "push_idx", "gather_idx", "pull_stale",
                  "push_stale", "gather_stale", "step_done_ms"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
        assert a.ledger == b.ledger
        assert a.events == b.events and a.shortfalls == b.shortfalls

    def test_seed_changes_trace(self):
        _, a = _run("heavy_tail_stragglers", seed=0)
        _, b = _run("heavy_tail_stragglers", seed=1)
        assert not np.array_equal(a.pull_stale, b.pull_stale)


class TestQuorumValidity:
    def test_uniform_quorums_exact(self):
        sc, t = _run("baseline_uniform")
        assert t.pull_idx.shape == (sc.steps, sc.n_workers, sc.q_servers)
        assert t.push_idx.shape == (sc.steps, sc.n_servers, sc.q_workers)
        # exactly q distinct senders, all in range
        for arr, n in ((t.pull_idx, sc.n_servers), (t.push_idx, sc.n_workers)):
            assert arr.min() >= 0 and arr.max() < n
            for row in arr.reshape(-1, arr.shape[-1]):
                assert len(set(row.tolist())) == arr.shape[-1]
        assert t.shortfalls == 0

    @pytest.mark.parametrize("name", ["baseline_uniform",
                                      "heavy_tail_stragglers", "crash_storm"])
    def test_gather_includes_self(self, name):
        """A server always aggregates its own model — even when remote models
        arrive before the (straggling) server enters the gather round."""
        sc, t = _run(name, steps=20)
        assert t.gather_idx.shape[0] == sc.steps // sc.T
        for r in range(t.gather_idx.shape[0]):
            for s in range(sc.n_servers):
                assert t.gather_idx[r, s][0] == s  # own model always first

    def test_staleness_nonnegative_and_populated(self):
        _, t = _run("heavy_tail_stragglers")
        assert (t.pull_stale >= 0).all() and (t.push_stale >= 0).all()
        assert t.pull_stale.max() > 0


class TestAccounting:
    def test_uniform_matches_analytic_model(self):
        """Acceptance: per-step message/byte totals within 1% of
        exp_messages.model_bytes on the no-fault uniform scenario."""
        sc, t = _run("baseline_uniform", steps=20)
        cmp = compare_with_model(t.ledger, sc, sc.steps, t.n_gathers)
        assert set(cmp) == {"worker_rx", "worker_tx", "server_rx",
                            "server_tx", "dmc_server_exchange"}
        for k, (sim, analytic, rel) in cmp.items():
            assert rel < 0.01, (k, sim, analytic)

    def test_faults_visible_in_ledger(self):
        _, t = _run("crash_storm", steps=20)
        tot = t.ledger.totals()
        dropped = sum(d["dropped_msgs"] for d in tot.values())
        assert dropped > 0
        _, t2 = _run("partitioned_dmc", steps=20)
        tot2 = t2.ledger.totals()
        assert sum(d["dropped_msgs"] for d in tot2.values()) > 0
        assert t2.shortfalls > 0  # partition starved some quorums

    def test_trace_always_complete_under_faults(self):
        sc, t = _run("crash_storm", steps=20)
        # every quorum slot filled with a valid sender id even under crashes
        assert t.pull_idx.min() >= 0 and t.pull_idx.max() < sc.n_servers
        assert t.push_idx.min() >= 0 and t.push_idx.max() < sc.n_workers
        assert t.gather_idx.min() >= 0 and t.gather_idx.max() < sc.n_servers


MIX = MixtureSpec(n_classes=5, dim=16, sep=2.5)


def _sim(delivery):
    cfg = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5, f_servers=1, T=5)
    init, loss, _ = make_mlp_problem(dim=MIX.dim, hidden=32,
                                     n_classes=MIX.n_classes)
    from repro.optim.schedules import inverse_linear
    return cfg, ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01),
                                delivery=delivery)


class TestTraceDelivery:
    def test_heavy_tail_dmc_still_contracts(self):
        """Acceptance: under the seeded heavy-tail straggler scenario, the
        DMC gather still shrinks correct-server diameter (Lemma 4.3 holds for
        ANY delivery schedule, not just uniform ones)."""
        sc, trace = _run("heavy_tail_stragglers")
        cfg, sim = _sim(trace.to_delivery())
        state = sim.init_state(jax.random.PRNGKey(0))
        stream, _ = classification_stream(0, MIX, cfg.n_workers, 16, cfg.T)
        for b in stream:
            state = sim.scatter_step(state, b)
        d_pre = float(coordinatewise_diameter_sum(state.params, cfg.h_servers))
        state = sim.gather_step(state)
        d_post = float(coordinatewise_diameter_sum(state.params, cfg.h_servers))
        assert d_post <= d_pre + 1e-6
        assert d_post < 0.9 * d_pre

    def test_trace_driven_run_deterministic(self):
        _, trace = _run("heavy_tail_stragglers")

        def go():
            cfg, sim = _sim(trace.to_delivery())
            state = sim.init_state(jax.random.PRNGKey(0))
            stream, _ = classification_stream(0, MIX, cfg.n_workers, 16, 8)
            state, logs = sim.run(state, stream, metrics_fn=lambda s: {
                "delta": float(coordinatewise_diameter_sum(s.params, 4))},
                metrics_every=7)
            return logs
        a, b = go(), go()
        assert a == b
        assert "staleness_pull_ms" in a[-1]  # staleness threaded into metrics

    def test_uniform_delivery_unchanged(self):
        """The refactor keeps the default path identical: UniformDelivery is
        what ByzSGDSimulator uses when no delivery model is given."""
        cfg, sim = _sim(None)
        assert isinstance(sim.delivery, UniformDelivery)
        k = jax.random.PRNGKey(0)
        from repro.core.quorum import receiver_quorum_indices
        np.testing.assert_array_equal(
            sim.delivery.pull_indices(k, 0),
            receiver_quorum_indices(k, cfg.n_workers, cfg.n_servers,
                                    cfg.q_servers))

    def test_trace_wraps_past_end(self):
        _, trace = _run("baseline_uniform")
        d = trace.to_delivery()
        k = jax.random.PRNGKey(0)
        np.testing.assert_array_equal(d.pull_indices(k, 3),
                                      d.pull_indices(k, 3 + trace.scenario.steps))

    def test_gather_trace_required(self):
        with pytest.raises(ValueError):
            TraceDelivery(np.zeros((5, 7, 4), np.int32),
                          np.zeros((5, 5, 5), np.int32),
                          np.zeros((0, 5, 4), np.int32), T=10)


# ---------------------------------------------------------------------------
# request floods (serving-side netsim: repro.netsim.flood)
# ---------------------------------------------------------------------------


class TestRequestFlood:
    def test_deterministic_per_seed(self):
        from repro.netsim import run_flood
        sc = scenarios.request_flood(n_clients=200, rate=2.0, seed=3)
        a, b = run_flood(sc), run_flood(sc)
        assert a.n_requests == b.n_requests
        np.testing.assert_array_equal(a.quorum_ms, b.quorum_ms)
        assert a.ledger == b.ledger
        c = run_flood(scenarios.request_flood(n_clients=200, rate=2.0,
                                              seed=4))
        assert not np.array_equal(a.quorum_ms, c.quorum_ms)

    def test_accounting_invariants(self):
        from repro.netsim import run_flood
        sc = scenarios.request_flood(n_clients=300, rate=2.0, seed=0)
        tr = run_flood(sc)
        led, Rn = tr.ledger, sc.n_replicas
        # every request reaches every replica; every reply is consumed or late
        push, pull = led.c["push"], led.c["pull"]
        assert push["tx_msgs"].sum() == tr.n_requests * Rn
        assert push["rx_msgs"][:Rn].sum() == tr.n_requests * Rn
        assert pull["tx_msgs"][:Rn].sum() == tr.n_requests * Rn
        assert (pull["rx_msgs"].sum() + pull["late_msgs"].sum()
                == tr.n_requests * Rn)
        # exactly f late replies per request can't exceed the tail count
        assert pull["late_msgs"].sum() == tr.replica_late.sum()
        # clients only ever appear past the replica ids
        assert push["tx_msgs"][:Rn].sum() == 0
        assert pull["rx_msgs"][:Rn].sum() == 0

    def test_slow_replica_absorbed_by_quorum(self):
        from repro.netsim import run_flood
        base = run_flood(scenarios.request_flood(n_clients=400, seed=1))
        slow = run_flood(scenarios.request_flood(
            n_clients=400, seed=1, slow_replicas=(0,), slow_factor=50.0))
        # the slow replica goes fully late; read latency barely moves
        assert slow.replica_late[0] == slow.n_requests
        assert slow.percentiles()["p50"] < 4 * base.percentiles()["p50"] + 1.0
        assert slow.replica_busy_ms[0] > 10 * base.replica_busy_ms[0]

    def test_scenario_validation_and_registry_isolation(self):
        from repro.netsim.flood import RequestFloodScenario
        with pytest.raises(ValueError):
            RequestFloodScenario(n_replicas=2, f=1)       # n < 2f+1
        with pytest.raises(ValueError):
            RequestFloodScenario(slow_replicas=(9,))
        # serving floods are not trainable scenarios: outside SCENARIOS
        assert "request_flood" not in scenarios.SCENARIOS
        sc = scenarios.request_flood(n_clients=10)
        assert sc.n_clients == 10

    def test_deadline_and_percentiles(self):
        from repro.netsim import run_flood
        sc = scenarios.request_flood(n_clients=300, seed=2, deadline_ms=0.1)
        tr = run_flood(sc)
        assert tr.deadline_missed == tr.n_requests     # nothing beats 0.1ms
        pc = tr.percentiles((50, 95, 99))
        assert pc["p50"] <= pc["p95"] <= pc["p99"]
        assert "deadline" in tr.summary()


# ---------------------------------------------------------------------------
# fault-plan composition (crash x partition x slow churn)
# ---------------------------------------------------------------------------


class TestFaultComposition:
    def test_next_up_chains_back_to_back_windows(self):
        from repro.netsim.faults import INF, CrashPlan, CrashWindow
        plan = CrashPlan((CrashWindow(node=2, t_down=10.0, t_up=20.0),
                          CrashWindow(node=2, t_down=20.0, t_up=30.0),
                          CrashWindow(node=3, t_down=5.0, t_up=INF)))
        # inside the first window, recovery chains through the second
        assert plan.next_up(2, 12.0) == 30.0
        assert plan.next_up(2, 30.0) == 30.0      # boundary is up
        assert plan.next_up(2, 5.0) == 5.0        # before any window
        assert plan.next_up(3, 6.0) == INF        # crash without recovery
        assert not plan.is_up(2, 20.0) and plan.is_up(2, 30.0)

    def test_crash_inside_partition_window(self):
        """A node that crashes while already partitioned: liveness and
        reachability compose independently, and the realized trace still
        fills every quorum slot from the connected survivors."""
        from repro.netsim.faults import (CrashPlan, CrashWindow, FaultPlan,
                                         PartitionPlan, PartitionWindow)
        faults = FaultPlan(
            crashes=CrashPlan((CrashWindow(node=1, t_down=20.0, t_up=60.0),)),
            partitions=PartitionPlan((PartitionWindow(
                t0=10.0, t1=80.0, groups=((1,), tuple(range(2, 12)))),)))
        assert not faults.is_up(1, 30.0)          # crashed inside the cut
        assert faults.blocked(1, 5, 30.0) and faults.blocked(5, 1, 15.0)
        assert not faults.blocked(0, 5, 30.0)     # unlisted node is free
        assert faults.is_up(1, 60.0)              # recovers inside the cut
        assert faults.blocked(1, 5, 70.0)         # ... but stays partitioned
        sc, t = _run("baseline_uniform", steps=20, faults=faults)
        assert t.pull_idx.min() >= 0 and t.pull_idx.max() < sc.n_servers
        assert t.push_idx.min() >= 0 and t.push_idx.max() < sc.n_workers
        tot = t.ledger.totals()
        assert sum(d["dropped_msgs"] for d in tot.values()) > 0

    def test_slow_churn_only_overlapping_crashed_node(self):
        """SlowChurn.only pinning a node that also crashes: latency scaling
        applies whenever the node is addressed, liveness is orthogonal."""
        from repro.netsim.faults import (CrashPlan, CrashWindow, FaultPlan,
                                         SlowChurn)
        faults = FaultPlan(
            crashes=CrashPlan((CrashWindow(node=6, t_down=0.0, t_up=40.0),)),
            churn=SlowChurn(n_nodes=12, n_slow=1, factor=8.0, only=(6,)))
        assert faults.latency_scale(6, 0, 10.0) == 8.0   # slow even if down
        assert not faults.is_up(6, 10.0)
        assert faults.is_up(6, 40.0)
        assert faults.latency_scale(0, 6, 50.0) == 8.0   # slow after recovery
        assert faults.latency_scale(0, 7, 50.0) == 1.0   # only= is exhaustive
        sc, t = _run("baseline_uniform", steps=15, faults=faults)
        assert t.push_idx.min() >= 0 and t.push_idx.max() < sc.n_workers
        assert (t.pull_stale >= 0).all() and (t.push_stale >= 0).all()
