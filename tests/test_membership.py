"""core/membership + the elastic runner: plan mechanics, churn-driven
quorums, replica re-forming, netsim lowering, and the elastic acceptance
gates (empty-plan bit-identity vs runner="protocol", churn convergence vs
the static oracle, kill-and-resume mid-churn)."""
import os
import shutil

import jax
import numpy as np
import pytest

import repro.exp as exp
from repro.core.membership import (MembershipEpoch, MembershipEvent,
                                   MembershipFloorError, MembershipPlan,
                                   epoch_config, plan_from_trace,
                                   reform_params)
from repro.netsim import ClusterSim, scenarios

# ---------------------------------------------------------------------------
# plan mechanics
# ---------------------------------------------------------------------------


def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        MembershipEvent(step=3, kind="vanish", group=0)
    with pytest.raises(ValueError, match="boundaries"):
        MembershipEvent(step=0, kind="leave", group=0)
    with pytest.raises(ValueError, match="group"):
        MembershipEvent(step=3, kind="leave", group=-1)


def test_plan_normalizes_and_roundtrips():
    plan = MembershipPlan(events=(
        {"step": 16, "kind": "join", "group": 4},       # dict form accepted
        MembershipEvent(step=8, kind="leave", group=4)))
    assert [e.step for e in plan.events] == [8, 16]      # sorted
    assert MembershipPlan.from_dict(plan.to_dict()) == plan
    assert MembershipPlan.from_dict({"events": []}) == MembershipPlan()


def test_epochs_segmentation():
    plan = MembershipPlan(events=(
        MembershipEvent(step=8, kind="leave", group=4),
        MembershipEvent(step=16, kind="join", group=4)))
    segs = plan.epochs(5, 24)
    assert [(s.start, s.stop, s.active) for s in segs] == [
        (0, 8, (0, 1, 2, 3, 4)),
        (8, 16, (0, 1, 2, 3)),
        (16, 24, (0, 1, 2, 3, 4))]
    # empty plan: one full-run epoch at the launch fleet
    assert MembershipPlan().epochs(5, 24) == (
        MembershipEpoch(0, 24, (0, 1, 2, 3, 4)),)


def test_epochs_validation():
    with pytest.raises(ValueError, match="outside the run"):
        MembershipPlan(events=(
            MembershipEvent(step=30, kind="leave", group=0),)).epochs(5, 24)
    with pytest.raises(ValueError, match="not active"):
        MembershipPlan(events=(
            MembershipEvent(step=4, kind="leave", group=7),)).epochs(5, 24)
    with pytest.raises(ValueError, match="already active"):
        MembershipPlan(events=(
            MembershipEvent(step=4, kind="join", group=2),)).epochs(5, 24)


def test_epochs_allow_joins_beyond_launch_fleet():
    plan = MembershipPlan(events=(
        MembershipEvent(step=6, kind="join", group=5),))
    segs = plan.epochs(5, 12)
    assert segs[-1].active == (0, 1, 2, 3, 4, 5)


# ---------------------------------------------------------------------------
# churn-driven quorum derivation
# ---------------------------------------------------------------------------


def _pcfg(**kw):
    from repro.core.protocol import ProtocolConfig
    return ProtocolConfig.derive(5, f_workers=1, f_servers=1, T=5, **kw)


def test_epoch_config_identity_at_launch_size():
    pcfg = _pcfg()
    assert epoch_config(pcfg, (0, 1, 2, 3, 4)) is pcfg


def test_epoch_config_shrinks_quorums():
    out = epoch_config(_pcfg(), (0, 1, 2, 3))
    assert (out.n_groups, out.f_workers, out.f_servers) == (4, 1, 0)
    assert (out.q_workers, out.q_servers) == (3, 4)
    # the quorum window caps f_w at (G'-1)//3 even for sync: at G'=3 no
    # fault is tolerable, the full fleet is the quorum
    sync = epoch_config(_pcfg(), (0, 1, 2), synchronous=True)
    assert (sync.f_workers, sync.q_workers) == (0, 3)


def test_epoch_config_floor_errors():
    with pytest.raises(MembershipFloorError, match=">= 2 groups"):
        epoch_config(_pcfg(), (0,))
    from repro.core.attacks import ByzantineSpec
    byz = _pcfg(byz=ByzantineSpec(server_attack="lie", n_byz_servers=1))
    with pytest.raises(MembershipFloorError, match="outvote"):
        epoch_config(byz, (0, 1, 2, 3))    # f_ps' caps at 0 < 1 present


# ---------------------------------------------------------------------------
# replica re-forming
# ---------------------------------------------------------------------------


def test_reform_params_carries_survivors_and_seeds_joiners():
    params = {"w": jax.numpy.arange(20.0).reshape(5, 4)}
    shrunk = reform_params(params, (0, 1, 2, 3, 4), (0, 1, 2, 3))
    np.testing.assert_array_equal(np.asarray(shrunk["w"]),
                                  np.asarray(params["w"][:4]))
    grown = reform_params(shrunk, (0, 1, 2, 3), (0, 1, 2, 3, 4))
    np.testing.assert_array_equal(np.asarray(grown["w"][:4]),
                                  np.asarray(shrunk["w"]))
    med = np.median(np.asarray(shrunk["w"]), axis=0)
    np.testing.assert_array_equal(np.asarray(grown["w"][4]), med)
    assert grown["w"].dtype == params["w"].dtype


def test_reform_params_needs_a_survivor():
    params = {"w": jax.numpy.ones((2, 3))}
    with pytest.raises(MembershipFloorError, match="surviving"):
        reform_params(params, (0, 1), (2, 3))


# ---------------------------------------------------------------------------
# netsim lowering
# ---------------------------------------------------------------------------


def test_plan_from_trace_realizes_multi_step_outage():
    sc = scenarios.build("membership_churn", steps=24)
    trace = ClusterSim(sc).run()
    plan = plan_from_trace(sc, trace)
    kinds = [(e.kind, e.group) for e in plan.events]
    assert kinds == [("leave", 4), ("join", 4)]
    leave, join = plan.events[0].step, plan.events[1].step
    assert 1 <= leave < join < 24
    # the outage spans the crash duration at the honest step rate, not the
    # post-recovery completion burst (which would compress it to one step)
    assert join - leave >= 4


def test_plan_from_trace_crash_without_recovery_is_leave_only():
    sc = scenarios.build("membership_churn", steps=24,
                         t_down=66.0, t_up=float("inf"))
    plan = plan_from_trace(sc, ClusterSim(sc).run())
    assert [e.kind for e in plan.events] == ["leave"]


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_membership_plan_requires_elastic_runner():
    plan = MembershipPlan(events=(
        MembershipEvent(step=4, kind="leave", group=4),))
    with pytest.raises(ValueError, match="elastic"):
        exp.get("smoke", membership_plan=plan)
    with pytest.raises(ValueError, match="uniform"):
        exp.get("elastic/static", delivery="trace")
    # a plan that violates the floor is rejected at construction
    from repro.core.attacks import ByzantineSpec
    with pytest.raises(MembershipFloorError, match="outvote"):
        exp.get("elastic/planned_churn",
                byz=ByzantineSpec(server_attack="lie", n_byz_servers=1))


def test_membership_plan_json_roundtrip():
    e = exp.get("elastic/planned_churn")
    back = exp.Experiment.from_dict(e.to_dict())
    assert back == e and back.membership_plan == e.membership_plan


# ---------------------------------------------------------------------------
# elastic runner acceptance gates
# ---------------------------------------------------------------------------


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_empty_plan_elastic_bit_identical_to_protocol():
    rp = exp.run("elastic/static", runner="protocol")
    re_ = exp.run("elastic/static")
    _assert_trees_equal(rp.state.params, re_.state.params)
    for k in rp.buffers:
        np.testing.assert_array_equal(np.asarray(rp.buffers[k]),
                                      np.asarray(re_.buffers[k]), err_msg=k)
    assert rp.logs == re_.logs and rp.final == re_.final


def test_churn_converges_within_tolerance_of_static():
    static = exp.run("elastic/static")
    churned = exp.run("elastic/planned_churn")
    assert churned.final["acc"] >= static.final["acc"] - 0.1
    mem = churned.provenance["membership"]
    assert [len(ep["active"]) for ep in mem["epochs"]] == [5, 4, 5]
    assert mem["plan_source"] == "spec"


def test_netsim_churn_lowers_and_converges():
    res = exp.run("elastic/netsim_churn")
    mem = res.provenance["membership"]
    assert mem["plan_source"] == "scenario:membership_churn"
    assert [len(ep["active"]) for ep in mem["epochs"]] == [5, 4, 5]
    assert res.final["acc"] >= 0.8
    assert res.netsim is not None and "virtual_ms" in res.netsim


def test_kill_and_resume_mid_churn_bit_identical(tmp_path):
    oracle = exp.run("elastic/planned_churn")
    d = os.path.join(str(tmp_path), "ck")
    full = exp.run("elastic/planned_churn", ckpt_dir=d, ckpt_every=4)
    _assert_trees_equal(oracle.state.params, full.state.params)

    # kill after step 12 — mid-shrunk-epoch, so the resume restores at G'=4
    for name in sorted(os.listdir(d)):
        if int(name.split("_")[-1]) > 12:
            shutil.rmtree(os.path.join(d, name))
    resumed = exp.run("elastic/planned_churn", ckpt_dir=d, ckpt_every=4)
    assert resumed.provenance["membership"]["resumed_at"] == 12
    _assert_trees_equal(oracle.state.params, resumed.state.params)
    assert resumed.final == oracle.final
    # resumed logs splice bit-exactly onto the uninterrupted run's tail
    by_step = {m["step"]: m for m in oracle.logs}
    assert resumed.logs and all(m == by_step[m["step"]]
                                for m in resumed.logs)


def test_elastic_final_checkpoint_without_ckpt_every(tmp_path):
    d = os.path.join(str(tmp_path), "ck")
    res = exp.run("elastic/planned_churn", ckpt_dir=d)
    from repro.checkpoint import checkpointer as ck
    assert ck.latest_step(d) == res.experiment.steps
    meta = ck.read_manifest(d, res.experiment.steps).get("meta")
    assert meta["elastic"] and list(meta["active"]) == [0, 1, 2, 3, 4]
