"""Subprocess body for the layer-2 compiled-artifact audit (needs 8 forced
devices, which must be set before jax initialises — hence not in-process)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402

from repro.analyze import hlo  # noqa: E402
from repro.core import protocol  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402


def main():
    assert len(jax.devices()) == 8

    # collective bytes: HLO ring-model vs collective_volume_bytes, both
    # engines, within the audit's 10% tolerance (measured: exact for the
    # smoke preset's G=5 / P=1765 exchange)
    for engine in ("naive", "sharded"):
        for two_d in (False, True):
            measured, modeled, n_params = hlo.measure_exchange_bytes(
                engine, two_d=two_d)
            assert n_params > 0 and modeled > 0
            err = abs(measured - modeled) / modeled
            label = f"{engine}[rep,fsdp]" if two_d else engine
            print(f"{label}: model={modeled}B hlo={measured:.0f}B "
                  f"err={err:.1%} P={n_params}")
            assert err <= 0.10, (label, measured, modeled)
    # the 2D model halves with K: same exchange, half of it local
    pcfg4 = protocol.ProtocolConfig.derive(4, T=5, engine="sharded")
    assert protocol.collective_volume_bytes(pcfg4, 1000, fsdp=2) == \
        protocol.collective_volume_bytes(pcfg4, 1000) // 2
    assert hlo.check_collectives(".") == []

    # donation: every donated state leaf must appear in input_output_alias
    # of the compiled protocol epochs (spot-check the parser on the way)
    for engine in ("naive", "sharded"):
        _, _, mesh, eng, state, stream = hlo._protocol_engine(engine)
        from repro.launch.mesh import use_mesh
        with use_mesh(mesh):
            txt = hlo._epoch_compiled_text(eng, state, stream)
        n_state = len(jax.tree.leaves(state))
        aliased = hlo_analysis.aliased_param_numbers(txt)
        print(f"{engine}: {n_state} state leaves, aliased={sorted(aliased)}")
        assert set(range(n_state)) <= aliased, (engine, n_state, aliased)
    assert hlo.check_donation(".") == []

    # host transfers + recompiles: the full audit rules run clean
    assert hlo.check_host_transfers(".") == []
    assert hlo.check_recompiles(".") == []

    # the alias parser itself, against a fabricated table
    entries = hlo_analysis.donation_aliases(
        "HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
        "{1}: (2, {0}, must-alias) }")
    assert [(e.output_index, e.param_number, e.kind) for e in entries] == \
        [((0,), 0, "may-alias"), ((1,), 2, "must-alias")]
    assert hlo_analysis.aliased_param_numbers("no alias table here") == set()

    # the model itself: engine-independent, HLO-verified form
    pcfg = protocol.ProtocolConfig.derive(5, T=5, engine="naive")
    assert protocol.collective_volume_bytes(pcfg, 1000) == 2 * 4 * 1000 * 4

    print("ANALYZE_HLO_TESTS_PASS")


if __name__ == "__main__":
    main()
