"""Protocol-semantics tests on the faithful single-host simulator."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum)
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=5, dim=16, sep=2.5)


def make_sim(cfg):
    init, loss, acc = make_mlp_problem(dim=MIX.dim, hidden=32,
                                       n_classes=MIX.n_classes)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01))
    return sim, acc


def run(cfg, steps=40, batch=16, seed=0, track=False):
    sim, acc = make_sim(cfg)
    state = sim.init_state(jax.random.PRNGKey(seed))
    stream, eval_set = classification_stream(seed, MIX, cfg.n_workers, batch,
                                             steps)
    ex, ey = eval_set(512)
    state, logs = sim.run(state, stream, metrics_fn=lambda s: {
        "acc": float(acc(jax.tree.map(lambda l: l[0], s.params), ex, ey)),
        **({"delta": float(coordinatewise_diameter_sum(s.params,
                                                       cfg.h_servers))}
           if track else {})}, metrics_every=steps - 1)
    return logs, state


class TestAsync:
    def test_clean_convergence(self):
        logs, _ = run(ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5,
                                   f_servers=1, T=5))
        assert logs[-1]["acc"] > 0.75, logs

    @pytest.mark.parametrize("attack", ["reversed", "alie", "sign_flip"])
    def test_byzantine_workers_tolerated(self, attack):
        cfg = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5, f_servers=1,
                           T=5, byz=ByzantineSpec(worker_attack=attack,
                                                  n_byz_workers=2,
                                                  equivocate=True))
        logs, _ = run(cfg)
        assert logs[-1]["acc"] > 0.70, (attack, logs)

    @pytest.mark.parametrize("attack", ["reversed", "lie", "random",
                                        "partial_drop"])
    def test_byzantine_servers_tolerated(self, attack):
        cfg = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5, f_servers=1,
                           T=5, byz=ByzantineSpec(server_attack=attack,
                                                  n_byz_servers=1,
                                                  equivocate=True))
        logs, _ = run(cfg)
        assert logs[-1]["acc"] > 0.70, (attack, logs)

    def test_mean_gar_not_resilient(self):
        """Sanity: plain averaging diverges/stalls under the reversed attack
        (the paper's 'averaging tolerates not a single corrupted input')."""
        byz = ByzantineSpec(worker_attack="reversed", n_byz_workers=2,
                            attack_kwargs=(("scale", 10.0),), equivocate=True)
        good = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5,
                            f_servers=1, T=5, gar="mda", byz=byz)
        bad = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5,
                           f_servers=1, T=5, gar="mean", byz=byz)
        g_logs, _ = run(good)
        b_logs, _ = run(bad)
        assert g_logs[-1]["acc"] > b_logs[-1]["acc"] + 0.15

    def test_gather_contracts(self):
        cfg = ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5, f_servers=1,
                           T=5)
        sim, _ = make_sim(cfg)
        state = sim.init_state(jax.random.PRNGKey(0))
        stream, _ = classification_stream(0, MIX, 7, 16, 5)
        for b in stream:
            state = sim.scatter_step(state, b)
        d_pre = float(coordinatewise_diameter_sum(state.params, 4))
        state = sim.gather_step(state)
        d_post = float(coordinatewise_diameter_sum(state.params, 4))
        assert d_post <= d_pre + 1e-6
        assert d_post < 0.9 * d_pre  # expected strict contraction (Lemma 4.3)


class TestSync:
    def test_clean_convergence(self):
        cfg = ByzSGDConfig(n_workers=5, f_workers=1, n_servers=5, f_servers=1,
                           T=5, variant="sync")
        logs, _ = run(cfg)
        assert logs[-1]["acc"] > 0.75

    def test_byzantine_server_filtered(self):
        cfg = ByzSGDConfig(n_workers=5, f_workers=1, n_servers=5, f_servers=1,
                           T=5, variant="sync",
                           byz=ByzantineSpec(server_attack="reversed",
                                             n_byz_servers=1, equivocate=True))
        logs, _ = run(cfg)
        assert logs[-1]["acc"] > 0.70


class TestConfigValidation:
    def test_counts_enforced(self):
        with pytest.raises(ValueError):
            ByzSGDConfig(n_workers=6, f_workers=2, n_servers=5, f_servers=1)
        with pytest.raises(ValueError):
            ByzSGDConfig(n_workers=7, f_workers=2, n_servers=4, f_servers=1)

    def test_quorum_bounds(self):
        cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5, f_servers=1)
        assert cfg.q_workers >= 2 * cfg.f_workers + 1
        assert cfg.q_servers >= 2 * cfg.f_servers + 2
