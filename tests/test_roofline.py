"""launch/roofline.py smoke: param counting, model-FLOP accounting, and a
row/table render from a synthetic dry-run artifact (the module was dead
code — never imported by tests — until this lane)."""
import json
import os

import pytest

from repro.configs.shapes import SHAPES
from repro.launch import roofline


@pytest.fixture(scope="module")
def whisper_counts():
    return roofline.param_counts("whisper-small")


def test_param_counts_dense_arch(whisper_counts):
    total, active = whisper_counts
    # whisper-small is dense: every parameter is active
    assert total == active
    # ~88M headline params; the reproduction's count must be in range
    assert 5e7 < total < 3e8


def test_model_flops_accounting(whisper_counts):
    _, n_active = whisper_counts
    cell = SHAPES["train_4k"]
    train = roofline.model_flops("whisper-small", "train_4k")
    assert train == 6.0 * n_active * cell.global_batch * cell.seq_len
    # decode counts one token per sequence
    dcell = SHAPES["decode_32k"]
    decode = roofline.model_flops("whisper-small", "decode_32k")
    assert decode == 2.0 * n_active * dcell.global_batch
    prefill = roofline.model_flops("whisper-small", "prefill_32k")
    assert prefill > decode


def test_roofline_row_and_table_from_artifact(tmp_path, monkeypatch):
    mesh_dir = tmp_path / "16x16"
    mesh_dir.mkdir()
    artifact = {
        "kind": "train",
        "n_devices": 256,
        "n_groups": 4,
        "extrapolated": {
            "flops": 2.0e12,
            "bytes_accessed": 1.0e12,          # memory term dominates
            "collective_bytes_per_device": 5.0e9,
        },
        "gather": {
            "flops": 1.0e10,
            "bytes_accessed": 1.0e10,
            "collective_bytes_per_device": 1.0e9,
        },
        "full": {"memory": {"argument_bytes": 8 * 2**30,
                            "temp_bytes": 2 * 2**30,
                            "output_bytes": 1 * 2**30,
                            "alias_bytes": 1 * 2**30}},
    }
    with open(mesh_dir / "whisper-small__train_4k__naive.json", "w") as f:
        json.dump(artifact, f)
    monkeypatch.setattr(roofline, "RESULTS_DIR", str(tmp_path))

    row = roofline.roofline_row("whisper-small", "train_4k")
    assert row["dominant"] == "memory"
    assert row["t_memory_s"] == pytest.approx(
        1.0e12 / roofline.HBM_BW + 1.0e10 / roofline.HBM_BW / 50)
    assert row["est_step_s"] == pytest.approx(row["t_memory_s"])
    assert 0 < row["roofline_fraction"] < 1
    assert row["mem_per_dev_gib"] == pytest.approx(10.0)
    assert row["lever"]                      # every cell names its lever

    # missing cells render as SKIP rows, present cells render with terms
    skip = roofline.roofline_row("whisper-small", "decode_32k")
    assert skip["skipped"] == "missing"
    table = roofline.format_table([row, skip])
    assert "whisper-small" in table and "SKIP" in table
    assert "memory" in table


def test_load_cell_missing_is_none(tmp_path, monkeypatch):
    monkeypatch.setattr(roofline, "RESULTS_DIR", str(tmp_path))
    assert roofline.load_cell("whisper-small", "train_4k") is None
