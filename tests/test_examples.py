"""The committed example drivers stay runnable (subprocess smokes)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_train_lm_distributed_tiny():
    """`--scale tiny` rides the lm/tfm_tiny preset: protocol runner on the
    forced-8-device (rep=4, fsdp=2) mesh, negative-eval-loss metric."""
    script = os.path.join(os.path.dirname(__file__), "..", "examples",
                          "train_lm_distributed.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, script, "--scale", "tiny", "--steps", "4"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "lm/tfm_tiny mesh={'rep': 4, 'fsdp': 2, 'model': 1}" \
        in out.stdout, out.stdout
    assert "final neg-eval-loss" in out.stdout, out.stdout
