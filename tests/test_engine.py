"""Fused epoch engine: equivalence with the stepwise reference loop.

The engine (repro.core.engine) must reproduce ``ByzSGDSimulator.run`` exactly:
same parameters (allclose — XLA may fuse differently inside the scan), same
metrics at every step, for the async and sync variants, across the gather
boundary off-by-ones (async gathers when ``(i+1) % T == 0``, sync when
``i % T == 0`` with ``i > 0``), and with a netsim ``TraceDelivery`` plugged in.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import make_mlp_problem
from repro.core.engine import (EpochEngine, epoch_cache_size, fn_cache_key,
                               stack_batches)
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum)
from repro.data.pipeline import (DeviceBatchStream, MixtureSpec,
                                 classification_stream)
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=5, dim=16, sep=2.5)
BATCH = 8


def make_cfg(variant="async", T=5):
    if variant == "sync":
        return ByzSGDConfig(n_workers=5, f_workers=1, n_servers=5,
                            f_servers=1, T=T, variant="sync")
    return ByzSGDConfig(n_workers=7, f_workers=2, n_servers=5, f_servers=1,
                        T=T)


def make_sim(cfg, delivery=None):
    init, loss, acc = make_mlp_problem(dim=MIX.dim, hidden=32,
                                       n_classes=MIX.n_classes)
    return ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01),
                           delivery=delivery), acc


def stepwise_reference(cfg, steps, eval_set, delivery=None, seed=0):
    """Per-step run() with per-step metrics — the correctness oracle."""
    sim, acc = make_sim(cfg, delivery)
    ex, ey = eval_set
    state = sim.init_state(jax.random.PRNGKey(seed))
    stream, _ = classification_stream(seed, MIX, cfg.n_workers, BATCH, steps)
    state, logs = sim.run(state, stream, metrics_fn=lambda s: {
        "acc": float(acc(jax.tree.map(lambda l: l[0], s.params), ex, ey)),
        "delta": float(coordinatewise_diameter_sum(s.params, cfg.h_servers))},
        metrics_every=1)
    return state, logs


def fused(cfg, steps, eval_set, delivery=None, seed=0, epoch_steps=None):
    sim, acc = make_sim(cfg, delivery)
    eng = EpochEngine(sim, acc_fn=acc, eval_set=eval_set, track_delta=True)
    state = sim.init_state(jax.random.PRNGKey(seed))
    stream = DeviceBatchStream(seed, MIX, cfg.n_workers, BATCH)
    return eng.run(state, stream=stream, steps=steps, epoch_steps=epoch_steps)


def assert_equivalent(cfg, steps, delivery_fn=None, epoch_steps=None):
    _, eval_set = classification_stream(0, MIX, cfg.n_workers, BATCH, 1)
    ex, ey = eval_set(256)
    s_ref, logs = stepwise_reference(
        cfg, steps, (ex, ey), delivery_fn() if delivery_fn else None)
    s_fus, mbuf = fused(cfg, steps, (ex, ey),
                        delivery_fn() if delivery_fn else None,
                        epoch_steps=epoch_steps)
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_fus.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    assert int(s_fus.t) == steps
    np.testing.assert_allclose([m["acc"] for m in logs], mbuf["acc"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose([m["delta"] for m in logs], mbuf["delta"],
                               rtol=1e-4, atol=1e-5)
    return logs, mbuf


class TestAsyncEquivalence:
    def test_partial_tail_epoch(self):
        # 12 = 2 full T=5 epochs (gathers after steps 4 and 9) + 2 tail steps
        assert_equivalent(make_cfg("async"), steps=12)

    def test_exact_epoch_boundary(self):
        # gather fires after the LAST step: (i+1) % T == 0 at i = T-1
        assert_equivalent(make_cfg("async"), steps=5)

    def test_one_step_past_boundary(self):
        assert_equivalent(make_cfg("async"), steps=6)

    def test_chunking_does_not_change_results(self):
        # the scan chunk length is free: boundary logic rides on state.t
        assert_equivalent(make_cfg("async"), steps=12, epoch_steps=7)


class TestSyncEquivalence:
    def test_sync_with_boundary(self):
        # sync gathers BEFORE steps 5 and 10 (i % T == 0, i > 0), not step 0
        logs, mbuf = assert_equivalent(make_cfg("sync"), steps=12)
        assert mbuf["rejects"].shape == (12, 5)

    def test_sync_exact_epoch_no_trailing_gather(self):
        # steps == T: the i=T gather never runs; engine must match
        assert_equivalent(make_cfg("sync"), steps=5)

    def test_sync_rejects_match_stepwise(self):
        cfg = make_cfg("sync")
        _, eval_set = classification_stream(0, MIX, cfg.n_workers, BATCH, 1)
        ex, ey = eval_set(256)
        sim, _ = make_sim(cfg)
        state = sim.init_state(jax.random.PRNGKey(0))
        stream, _ = classification_stream(0, MIX, cfg.n_workers, BATCH, 8)
        rej = []
        for i, b in enumerate(stream):
            if i > 0 and i % cfg.T == 0:
                state = sim.jitted("sync_gather_step")(state)
            state, diag = sim.jitted("sync_step")(state, b)
            rej.append(np.asarray(diag["rejects"]))
        _, mbuf = fused(cfg, 8, (ex, ey))
        np.testing.assert_array_equal(np.stack(rej), mbuf["rejects"])


def heavy_tail_delivery():
    from repro.netsim import ClusterSim, scenarios
    sc = scenarios.build("heavy_tail_stragglers", n_workers=7, f_workers=2,
                       n_servers=5, f_servers=1, T=5, steps=10, model_d=1000)
    return ClusterSim(sc).run().to_delivery()


class TestTraceDelivery:
    def test_fused_equals_stepwise_on_trace(self):
        assert_equivalent(make_cfg("async"), steps=10,
                          delivery_fn=heavy_tail_delivery)

    def test_run_past_trace_length_wraps(self):
        # trace has 10 steps; 14-step run must wrap, not crash, in both paths
        assert_equivalent(make_cfg("async"), steps=14,
                          delivery_fn=heavy_tail_delivery)

    def test_staleness_is_host_only_and_stable(self):
        d = heavy_tail_delivery()
        s3 = d.staleness(3)
        assert s3 is not None and s3["staleness_pull_ms"] >= 0.0
        assert isinstance(s3["staleness_pull_ms"], float)
        assert d.staleness(3 + d.steps) == s3          # wraps
        assert "staleness_gather_ms" in d.staleness(4)  # (4+1) % T == 0


class TestMetricsStride:
    def test_strided_acc_matches_dense_on_stride(self):
        cfg = make_cfg("async")
        _, eval_set = classification_stream(0, MIX, cfg.n_workers, BATCH, 1)
        ex, ey = eval_set(256)
        sim_a, acc = make_sim(cfg)
        dense_eng = EpochEngine(sim_a, acc_fn=acc, eval_set=(ex, ey))
        _, dense = dense_eng.run(sim_a.init_state(jax.random.PRNGKey(0)),
                                 stream=DeviceBatchStream(0, MIX,
                                                          cfg.n_workers,
                                                          BATCH), steps=10)
        sim_b, _ = make_sim(cfg)
        strided_eng = EpochEngine(sim_b, acc_fn=acc, eval_set=(ex, ey),
                                  metrics_every=5)
        _, strided = strided_eng.run(sim_b.init_state(jax.random.PRNGKey(0)),
                                     stream=DeviceBatchStream(0, MIX,
                                                              cfg.n_workers,
                                                              BATCH), steps=10)
        np.testing.assert_allclose(strided["acc"][::5], dense["acc"][::5],
                                   rtol=1e-5, atol=1e-6)
        off = np.delete(strided["acc"], np.s_[::5])
        np.testing.assert_array_equal(off, np.zeros_like(off))


class TestSortNetworkFlag:
    def test_flag_keys_the_executable(self):
        from repro.agg.rules import use_sort_network
        cfg = make_cfg("async")
        eng_on = EpochEngine(make_sim(cfg)[0])
        with use_sort_network(False):
            eng_off = EpochEngine(make_sim(cfg)[0])
        assert eng_on._epoch is not eng_off._epoch


class TestCompileCache:
    def test_equal_configs_share_executable(self):
        cfg = make_cfg("async")
        sim_a, acc = make_sim(cfg)
        sim_b, _ = make_sim(cfg)   # fresh problem closures, same semantics
        assert EpochEngine(sim_a)._epoch is EpochEngine(sim_b)._epoch

    def test_different_metrics_flags_do_not_collide(self):
        cfg = make_cfg("async")
        sim, acc = make_sim(cfg)
        n0 = epoch_cache_size()
        e1 = EpochEngine(sim)
        e2 = EpochEngine(sim, track_delta=True)
        assert e1._epoch is not e2._epoch
        assert epoch_cache_size() >= n0

    def test_schedule_cache_key_structural(self):
        assert fn_cache_key(inverse_linear(0.05, 0.01)) == \
            fn_cache_key(inverse_linear(0.05, 0.01))
        assert fn_cache_key(inverse_linear(0.05, 0.01)) != \
            fn_cache_key(inverse_linear(0.05, 0.02))

    def test_simulator_run_reuses_jitted_steps(self):
        cfg = make_cfg("async")
        sim, _ = make_sim(cfg)
        state = sim.init_state(jax.random.PRNGKey(0))
        stream, _ = classification_stream(0, MIX, cfg.n_workers, BATCH, 2)
        state, _ = sim.run(state, stream)
        first = sim._jit_cache["scatter_step"]
        stream, _ = classification_stream(0, MIX, cfg.n_workers, BATCH, 2)
        state, _ = sim.run(state, stream)
        assert sim._jit_cache["scatter_step"] is first


class TestDeviceStream:
    def test_matches_host_stream_across_chunks(self):
        ds = DeviceBatchStream(0, MIX, 7, BATCH)
        chunks = [ds.next(3), ds.next(5)]
        dev = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), *chunks)
        host_iter, _ = classification_stream(0, MIX, 7, BATCH, 8)
        host = stack_batches(host_iter)
        for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(dev)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_eval_set_matches_host(self):
        ds = DeviceBatchStream(0, MIX, 7, BATCH)
        _, eval_set = classification_stream(0, MIX, 7, BATCH, 1)
        hx, hy = eval_set(64)
        dx, dy = ds.eval_set(64)
        np.testing.assert_array_equal(np.asarray(hx), np.asarray(dx))
        np.testing.assert_array_equal(np.asarray(hy), np.asarray(dy))


class TestEngineAPI:
    def test_stacked_batches_input(self):
        cfg = make_cfg("async")
        sim, _ = make_sim(cfg)
        stream, _ = classification_stream(0, MIX, cfg.n_workers, BATCH, 7)
        batches = stack_batches(stream)
        eng = EpochEngine(sim)
        state, mbuf = eng.run(sim.init_state(jax.random.PRNGKey(0)), batches)
        assert int(state.t) == 7 and mbuf == {}

    def test_requires_exactly_one_input(self):
        cfg = make_cfg("async")
        sim, _ = make_sim(cfg)
        eng = EpochEngine(sim)
        state = sim.init_state(jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            eng.run(state)
        with pytest.raises(ValueError):
            eng.run(state, batches=(), stream=object())

    def test_acc_fn_requires_eval_set(self):
        cfg = make_cfg("async")
        sim, acc = make_sim(cfg)
        with pytest.raises(ValueError):
            EpochEngine(sim, acc_fn=acc)


class TestThroughputCompare:
    def test_regression_detected(self):
        from benchmarks.exp_throughput import compare
        base = {"lanes": {"async/mlp_h64": {"fused": {"steps_per_s": 100.0}}}}
        ok = {"lanes": {"async/mlp_h64": {"fused": {"steps_per_s": 80.0}}}}
        bad = {"lanes": {"async/mlp_h64": {"fused": {"steps_per_s": 60.0}}}}
        assert compare(ok, base, tol=0.25) == []
        assert len(compare(bad, base, tol=0.25)) == 1
        assert len(compare({"lanes": {}}, base, tol=0.25)) == 1
